//! Interleaving stress for the process-global trace sink
//! ([`gapsafe::obs`]): emitters hammer `enabled()` / `emit()` while other
//! threads race `install()` / `uninstall()` swaps of the `AtomicPtr`.
//!
//! The sink is process-global state, so these scenarios live in their own
//! integration binary (`obs_trace.rs` owns the sink in *its* process) and
//! run as ONE `#[test]` — Rust runs tests in a binary concurrently, and
//! two tests toggling the global sink would race each other, not the
//! code under test.
//!
//! What a failure looks like:
//! * a torn install (Relaxed publish) lets an emitter call `record` on a
//!   half-constructed sink — the per-sink canary below would read a bad
//!   value, and the nightly TSan leg flags the unsynchronized write;
//! * a freed sink (if replaced sinks were dropped instead of leaked)
//!   turns the emit-side dereference into a use-after-free — Miri / TSan
//!   territory, exercised here by constant re-installation under load.

use gapsafe::obs::{self, Event, Sink};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A sink whose construction is made visible: `canary` is written last in
/// the constructor, so an emitter that observes a half-published sink
/// reads 0 instead of `CANARY`.
struct CountingSink {
    hits: Arc<AtomicU64>,
    torn: Arc<AtomicU64>,
    canary: u64,
}

const CANARY: u64 = 0x5afe_5afe_5afe_5afe;

impl CountingSink {
    fn new(hits: Arc<AtomicU64>, torn: Arc<AtomicU64>) -> Self {
        CountingSink { hits, torn, canary: CANARY }
    }
}

impl Sink for CountingSink {
    fn record(&self, _ev: &Event) {
        if self.canary != CANARY {
            self.torn.fetch_add(1, Ordering::Relaxed);
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
    }
}

#[test]
fn sink_install_emit_uninstall_races_are_safe() {
    let hits = Arc::new(AtomicU64::new(0));
    let torn = Arc::new(AtomicU64::new(0));

    // Phase 1: emitters vs. togglers, all racing the one AtomicPtr.
    let emitters = 4;
    let per_emitter = 20_000;
    let toggles = 2_000;
    std::thread::scope(|s| {
        for _ in 0..emitters {
            s.spawn(|| {
                for i in 0..per_emitter {
                    // Exercise both the guarded fast path real call sites
                    // use and the bare emit (must also be sound: enabled()
                    // can go stale between the check and the emit).
                    if i % 2 == 0 {
                        if obs::enabled() {
                            obs::emit(&Event::Request {
                                endpoint: "stress",
                                status: 200,
                                secs: 0.0,
                            });
                        }
                    } else {
                        obs::emit(&Event::Request {
                            endpoint: "stress",
                            status: 200,
                            secs: 0.0,
                        });
                    }
                }
            });
        }
        for t in 0..2usize {
            let hits = Arc::clone(&hits);
            let torn = Arc::clone(&torn);
            s.spawn(move || {
                for i in 0..toggles {
                    if (i + t) % 3 == 0 {
                        obs::uninstall();
                    } else {
                        obs::install(Box::new(CountingSink::new(
                            Arc::clone(&hits),
                            Arc::clone(&torn),
                        )));
                    }
                }
            });
        }
    });
    assert_eq!(torn.load(Ordering::Relaxed), 0, "emitter saw a half-published sink");
    let racy_hits = hits.load(Ordering::Relaxed);
    assert!(
        racy_hits <= (emitters * per_emitter) as u64,
        "more records than emits: {racy_hits}"
    );

    // Phase 2: quiesced sanity — a freshly installed sink sees exactly
    // the events emitted after it, and none after uninstall.
    obs::uninstall();
    let before = hits.load(Ordering::Relaxed);
    obs::install(Box::new(CountingSink::new(Arc::clone(&hits), Arc::clone(&torn))));
    assert!(obs::enabled());
    for _ in 0..10 {
        obs::emit(&Event::Request { endpoint: "stress", status: 200, secs: 0.0 });
    }
    assert_eq!(hits.load(Ordering::Relaxed), before + 10);
    obs::uninstall();
    assert!(!obs::enabled());
    obs::emit(&Event::Request { endpoint: "stress", status: 200, secs: 0.0 });
    assert_eq!(hits.load(Ordering::Relaxed), before + 10, "emit after uninstall recorded");
    assert_eq!(torn.load(Ordering::Relaxed), 0);
}
