//! Cross-module integration tests: PJRT-vs-native gap equivalence for every
//! artifact family, whole-path safety across the rule zoo for all four
//! estimators, and end-to-end coordinator protocols.

use gapsafe::data::synth;
use gapsafe::linalg::Mat;
use gapsafe::penalty::ActiveSet;
use gapsafe::runtime::PjrtEngine;
use gapsafe::screening::{NoScreening, Rule};
use gapsafe::solver::path::{solve_path, PathConfig, WarmStart};
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::util::prng::Prng;
use gapsafe::{build_problem, Task};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / (1.0 + a.abs())
}

#[test]
fn pjrt_matches_native_lasso() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = PjrtEngine::new(std::path::Path::new("artifacts")).unwrap();
    let ds = synth::leukemia_like_scaled(16, 40, 5, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let exe = engine.bind(&prob, "lasso").unwrap();
    let mut rng = Prng::new(9);
    for trial in 0..5 {
        let mut beta = Mat::zeros(40, 1);
        for j in 0..40 {
            if rng.bernoulli(0.2) {
                beta[(j, 0)] = rng.gaussian();
            }
        }
        let lam = rng.uniform_in(0.05, 1.0) * prob.lambda_max();
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let nat = prob.gap_pass(&beta, &z, lam, &active);
        let pj = exe.gap_pass(&prob, &beta, lam).unwrap();
        assert!(rel(nat.primal, pj.primal) < 1e-9, "trial {trial} primal");
        assert!(rel(nat.dual, pj.dual) < 1e-9, "trial {trial} dual");
        assert!(rel(nat.gap, pj.gap) < 1e-9, "trial {trial} gap");
        assert!(rel(nat.radius, pj.radius) < 1e-9, "trial {trial} radius");
        for j in 0..40 {
            assert!(
                (nat.stats.group_dual[j] - pj.stats.group_dual[j]).abs() < 1e-9,
                "trial {trial} score {j}"
            );
        }
        for i in 0..16 {
            assert!((nat.theta[(i, 0)] - pj.theta[(i, 0)]).abs() < 1e-9);
        }
    }
}

#[test]
fn pjrt_matches_native_logreg() {
    if !artifacts_available() {
        return;
    }
    let engine = PjrtEngine::new(std::path::Path::new("artifacts")).unwrap();
    let ds = synth::leukemia_like_scaled(16, 40, 6, true);
    let prob = build_problem(ds, Task::Logreg).unwrap();
    let exe = engine.bind(&prob, "logreg").unwrap();
    let mut rng = Prng::new(10);
    let mut beta = Mat::zeros(40, 1);
    for j in 0..40 {
        if rng.bernoulli(0.3) {
            beta[(j, 0)] = 0.3 * rng.gaussian();
        }
    }
    let lam = 0.4 * prob.lambda_max();
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let nat = prob.gap_pass(&beta, &z, lam, &active);
    let pj = exe.gap_pass(&prob, &beta, lam).unwrap();
    assert!(rel(nat.primal, pj.primal) < 1e-9);
    assert!(rel(nat.dual, pj.dual) < 1e-9);
    assert!(rel(nat.radius, pj.radius) < 1e-9);
}

#[test]
fn pjrt_matches_native_multitask() {
    if !artifacts_available() {
        return;
    }
    let engine = PjrtEngine::new(std::path::Path::new("artifacts")).unwrap();
    let ds = synth::meg_like(16, 40, 4, 3);
    let prob = build_problem(ds, Task::MultiTask).unwrap();
    let exe = engine.bind(&prob, "multitask").unwrap();
    let mut rng = Prng::new(11);
    let mut b = Mat::zeros(40, 4);
    for j in 0..40 {
        if rng.bernoulli(0.2) {
            for k in 0..4 {
                b[(j, k)] = rng.gaussian();
            }
        }
    }
    let lam = 0.5 * prob.lambda_max();
    let z = prob.predict(&b);
    let active = ActiveSet::full(prob.pen.groups());
    let nat = prob.gap_pass(&b, &z, lam, &active);
    let pj = exe.gap_pass(&prob, &b, lam).unwrap();
    assert!(rel(nat.primal, pj.primal) < 1e-9);
    assert!(rel(nat.dual, pj.dual) < 1e-9);
    assert!(rel(nat.gap, pj.gap) < 1e-9);
    for j in 0..40 {
        assert!((nat.stats.group_dual[j] - pj.stats.group_dual[j]).abs() < 1e-9);
    }
}

#[test]
fn pjrt_matches_native_sgl() {
    if !artifacts_available() {
        return;
    }
    let engine = PjrtEngine::new(std::path::Path::new("artifacts")).unwrap();
    let mut ds = synth::leukemia_like_scaled(16, 40, 8, false);
    ds.group_size = Some(4);
    let prob = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
    let exe = engine.bind(&prob, "sgl").unwrap();
    let mut rng = Prng::new(12);
    let mut beta = Mat::zeros(40, 1);
    for j in 0..40 {
        if rng.bernoulli(0.25) {
            beta[(j, 0)] = rng.gaussian();
        }
    }
    let lam = 0.5 * prob.lambda_max();
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let nat = prob.gap_pass(&beta, &z, lam, &active);
    let pj = exe.gap_pass(&prob, &beta, lam).unwrap();
    assert!(rel(nat.primal, pj.primal) < 1e-9);
    assert!(rel(nat.dual, pj.dual) < 1e-9);
    assert!(rel(nat.gap, pj.gap) < 1e-9);
    let nsgl = nat.stats.sgl.as_ref().unwrap();
    let psgl = pj.stats.sgl.as_ref().unwrap();
    for g in 0..10 {
        assert!((nsgl.st_norm[g] - psgl.st_norm[g]).abs() < 1e-9);
        assert!((nsgl.max_abs[g] - psgl.max_abs[g]).abs() < 1e-9);
    }
    for j in 0..40 {
        assert!((nsgl.feat_abs[j] - psgl.feat_abs[j]).abs() < 1e-9);
    }
}

/// The central safety property (Prop. 4): on every estimator, for every safe
/// rule, every feature screened at any point is zero in a high-precision
/// reference solution.
#[test]
fn safety_invariant_across_estimators_and_rules() {
    let cases: Vec<(Task, gapsafe::data::Dataset)> = vec![
        (Task::Lasso, synth::leukemia_like_scaled(22, 50, 31, false)),
        (Task::Logreg, synth::leukemia_like_scaled(22, 40, 32, true)),
        (Task::MultiTask, synth::meg_like(18, 30, 3, 33)),
        (Task::SparseGroupLasso { tau: 0.4 }, {
            let mut d = synth::leukemia_like_scaled(20, 40, 34, false);
            d.group_size = Some(4);
            d
        }),
        (Task::GroupLasso, {
            let mut d = synth::leukemia_like_scaled(20, 40, 35, false);
            d.group_size = Some(4);
            d
        }),
    ];
    for (task, ds) in cases {
        let prob = build_problem(ds, task).unwrap();
        let lam = 0.25 * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-12, max_epochs: 50_000, ..Default::default() };
        let mut none = NoScreening;
        let oracle = solve_fixed_lambda(&prob, lam, &mut none, &opts);
        assert!(oracle.converged, "{task:?} oracle did not converge");
        for rule in [Rule::StaticGap, Rule::GapSafeDyn, Rule::GapSafeFull] {
            let mut r = rule.build();
            let res = solve_fixed_lambda(&prob, lam, r.as_mut(), &opts);
            assert!(res.converged, "{task:?}/{} did not converge", rule.label());
            for j in 0..prob.p() {
                if !res.active.feat[j] {
                    for k in 0..prob.q() {
                        assert!(
                            oracle.beta[(j, k)].abs() < 1e-7,
                            "{task:?}/{}: screened feature {j} is nonzero ({}) in oracle",
                            rule.label(),
                            oracle.beta[(j, k)]
                        );
                    }
                }
            }
        }
    }
}

/// Property: across random problems, dynamic Gap Safe never screens a
/// feature of the true support (run on many random seeds).
#[test]
fn property_no_support_feature_screened() {
    gapsafe::util::check_property("support_never_screened", 15, |rng| {
        let n = 12 + rng.below(12);
        let p = 20 + rng.below(40);
        let ds = synth::leukemia_like_scaled(n, p, rng.next_u64(), false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = rng.uniform_in(0.1, 0.8) * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-11, max_epochs: 30_000, ..Default::default() };
        let mut none = NoScreening;
        let oracle = solve_fixed_lambda(&prob, lam, &mut none, &opts);
        if !oracle.converged {
            return Ok(()); // skip unconverged corner cases
        }
        let mut r = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lam, r.as_mut(), &opts);
        for j in 0..prob.p() {
            if oracle.beta[(j, 0)].abs() > 1e-6 && !res.active.feat[j] {
                return Err(format!("support feature {j} screened"));
            }
        }
        Ok(())
    });
}

/// Fig. 1 inclusions: supp(beta_hat) subset of equicorrelation subset of any
/// safe active set.
#[test]
fn inclusions_support_equicorrelation_active() {
    let ds = synth::leukemia_like_scaled(24, 60, 41, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam = 0.2 * prob.lambda_max();
    let opts = SolveOptions { eps: 1e-13, max_epochs: 100_000, ..Default::default() };
    let mut r = Rule::GapSafeDyn.build();
    let res = solve_fixed_lambda(&prob, lam, r.as_mut(), &opts);
    assert!(res.converged);
    // equicorrelation set from the final dual point
    let full = ActiveSet::full(prob.pen.groups());
    let stats = prob.stats_for_center(&res.theta, &full);
    for j in 0..prob.p() {
        let in_support = res.beta[(j, 0)] != 0.0;
        let in_equicorr = stats.group_dual[j] >= 1.0 - 1e-6;
        let in_active = res.active.feat[j];
        if in_support {
            assert!(in_equicorr, "support outside equicorrelation at {j}");
        }
        if in_equicorr {
            assert!(in_active, "equicorrelation outside active set at {j}");
        }
    }
}

/// Whole-path runs for every estimator with the full Gap Safe rule converge
/// and produce monotone-ish screening behaviour.
#[test]
fn paths_all_estimators() {
    let cfg = PathConfig {
        n_lambdas: 10,
        delta: 2.0,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Active,
        eps: 1e-6,
        eps_is_absolute: false,
        max_epochs: 5000,
        screen_every: 10,
        threads: 1,
        compact: true,
        ..Default::default()
    };
    let cases: Vec<(Task, gapsafe::data::Dataset)> = vec![
        (Task::Lasso, synth::leukemia_like_scaled(20, 50, 51, false)),
        (Task::Logreg, synth::leukemia_like_scaled(20, 30, 52, true)),
        (Task::MultiTask, synth::meg_like(16, 24, 3, 53)),
        (Task::SparseGroupLasso { tau: 0.4 }, synth::climate_like(36, 8, 54)),
        (Task::Multinomial, synth::multinomial_like(20, 16, 3, 55).0),
    ];
    for (task, ds) in cases {
        let prob = build_problem(ds, task).unwrap();
        // n < p logistic data is linearly separable: solutions blow up at
        // tiny lambda and plain CD needs far more epochs there — shorten the
        // grid as the paper's own logistic experiments do for hard tails.
        let cfg = if matches!(task, Task::Logreg) {
            PathConfig { delta: 1.5, max_epochs: 20_000, ..cfg.clone() }
        } else {
            cfg.clone()
        };
        let res = solve_path(&prob, &cfg);
        assert!(
            res.points.iter().all(|p| p.converged),
            "{task:?}: some path points did not converge: {:?}",
            res.points.iter().map(|p| p.gap).collect::<Vec<_>>()
        );
        assert_eq!(res.points[0].nnz_rows, 0, "{task:?}: nonzero support at lambda_max");
    }
}

/// Sparse designs run through the whole stack.
#[test]
fn sparse_design_end_to_end() {
    let ds = synth::sparse_regression(30, 80, 0.15, 61);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig {
        n_lambdas: 8,
        delta: 2.0,
        eps: 1e-6,
        ..Default::default()
    };
    let res = solve_path(&prob, &cfg);
    assert!(res.points.iter().all(|p| p.converged));
}
