//! Cross-backend parity gate for the SIMD kernel engine.
//!
//! The `linalg::kernels` contract says every backend is **bitwise
//! identical**. This file enforces it at two levels:
//!
//! 1. raw kernels (`dot` / `xtv` / `gemv` / `xtm` / CSC gather+scatter /
//!    `axpy` / `soft_threshold` / `sub`) on randomized shapes, including
//!    remainder lanes and odd row counts;
//! 2. whole `solve_path` runs (Lasso + logistic, dense + sparse designs)
//!    executed once per backend, compared `PathResult`-deep to the bit.
//!
//! On hosts without AVX2 the tests log a `kernel-parity: SKIPPED` notice
//! and pass vacuously (the scalar backend is its own reference); CI greps
//! the notice to make sure the gate ran non-trivially where AVX2 exists.

use gapsafe::data::{synth, Dataset};
use gapsafe::linalg::kernels::{self, BackendKind, Kernels};
use gapsafe::linalg::Mat;
use gapsafe::solver::path::{solve_path, PathConfig, PathResult};
use gapsafe::util::prng::Prng;
use gapsafe::{build_problem, Task};

/// The AVX2 table, or a logged skip.
fn avx2_or_skip(gate: &str) -> Option<&'static Kernels> {
    let t = kernels::table(BackendKind::Avx2);
    if t.is_none() {
        println!("kernel-parity: SKIPPED {gate} — AVX2 not available on this host (scalar only)");
    }
    t
}

fn rand_vec(rng: &mut Prng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.gaussian()).collect()
}

fn rand_mat(rng: &mut Prng, n: usize, p: usize) -> Mat {
    let mut m = Mat::zeros(n, p);
    for v in m.as_mut_slice() {
        *v = rng.gaussian();
    }
    m
}

#[test]
fn raw_kernels_bitwise_parity_on_randomized_shapes() {
    let Some(avx2) = avx2_or_skip("raw-kernel gate") else {
        return;
    };
    let scalar = kernels::scalar_table();
    let mut rng = Prng::new(7_700);
    let mut compared = 0usize;
    for trial in 0..40 {
        // shapes deliberately indivisible by the 4-lane width most of the
        // time, with a few exact multiples mixed in
        let n = 1 + rng.below(97);
        let p = 1 + rng.below(33);
        let x = rand_mat(&mut rng, n, p);
        let v = rand_vec(&mut rng, n);
        let mut b = rand_vec(&mut rng, p);
        if trial % 3 == 0 {
            b[trial % p] = 0.0; // exercise the gemv zero-skip path
        }

        // dot / axpy / sub / soft_threshold
        let a1 = rand_vec(&mut rng, n);
        let a2 = rand_vec(&mut rng, n);
        assert_eq!((scalar.dot)(&a1, &a2).to_bits(), (avx2.dot)(&a1, &a2).to_bits(), "dot n={n}");
        let (mut y1, mut y2) = (a1.clone(), a1.clone());
        (scalar.axpy)(-2.5, &a2, &mut y1);
        (avx2.axpy)(-2.5, &a2, &mut y2);
        let (mut d1, mut d2) = (vec![0.0; n], vec![0.0; n]);
        (scalar.sub)(&a1, &a2, &mut d1);
        (avx2.sub)(&a1, &a2, &mut d2);
        let (mut s1, mut s2) = (a1.clone(), a1.clone());
        (scalar.soft_threshold)(&mut s1, 0.6);
        (avx2.soft_threshold)(&mut s2, 0.6);
        for i in 0..n {
            assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "axpy {i}");
            assert_eq!(d1[i].to_bits(), d2[i].to_bits(), "sub {i}");
            assert_eq!(s1[i].to_bits(), s2[i].to_bits(), "soft_threshold {i}");
        }

        // xtv / gemv / xtm
        let (mut c1, mut c2) = (vec![0.0; p], vec![0.0; p]);
        (scalar.xtv)(&x, &v, &mut c1);
        (avx2.xtv)(&x, &v, &mut c2);
        let (mut z1, mut z2) = (vec![0.0; n], vec![0.0; n]);
        (scalar.gemv)(&x, &b, &mut z1);
        (avx2.gemv)(&x, &b, &mut z2);
        for j in 0..p {
            assert_eq!(c1[j].to_bits(), c2[j].to_bits(), "xtv n={n} p={p} j={j}");
        }
        for i in 0..n {
            assert_eq!(z1[i].to_bits(), z2[i].to_bits(), "gemv n={n} p={p} i={i}");
        }
        let q = 1 + trial % 4;
        let vm = rand_mat(&mut rng, n, q);
        let (mut m1, mut m2) = (Mat::zeros(p, q), Mat::zeros(p, q));
        (scalar.xtm)(&x, &vm, &mut m1);
        (avx2.xtm)(&x, &vm, &mut m2);
        for (w1, w2) in m1.as_slice().iter().zip(m2.as_slice()) {
            assert_eq!(w1.to_bits(), w2.to_bits(), "xtm n={n} p={p} q={q}");
        }

        // CSC gather (sptv) / scatter (spmv) on a random sparsity pattern
        let nnz = 1 + rng.below(60);
        let idx: Vec<usize> = (0..nnz).map(|_| rng.below(n)).collect();
        let val = rand_vec(&mut rng, nnz);
        assert_eq!(
            (scalar.gather_dot)(&idx, &val, &v).to_bits(),
            (avx2.gather_dot)(&idx, &val, &v).to_bits(),
            "gather_dot nnz={nnz}"
        );
        let (mut o1, mut o2) = (v.clone(), v.clone());
        (scalar.scatter_axpy)(&idx, 1.25, &val, &mut o1);
        (avx2.scatter_axpy)(&idx, 1.25, &val, &mut o2);
        for i in 0..n {
            assert_eq!(o1[i].to_bits(), o2[i].to_bits(), "scatter_axpy {i}");
        }
        compared += 1;
    }
    println!("kernel-parity: OK raw-kernel gate — {compared} randomized shapes, scalar vs avx2");
}

/// Binarize a regression dataset's targets so the sparse design can also
/// drive the logistic fit.
fn binarize(mut ds: Dataset) -> Dataset {
    let mean = ds.y.as_slice().iter().sum::<f64>() / ds.y.as_slice().len() as f64;
    for v in ds.y.as_mut_slice() {
        *v = if *v > mean { 1.0 } else { 0.0 };
    }
    ds
}

fn solve_under(kind: BackendKind, ds: &Dataset, task: Task, cfg: &PathConfig) -> PathResult {
    kernels::select(kind).expect("backend availability checked by caller");
    let prob = build_problem(ds.clone(), task).unwrap();
    solve_path(&prob, cfg)
}

fn assert_paths_bit_identical(a: &PathResult, b: &PathResult, label: &str) {
    assert_eq!(a.lambdas.len(), b.lambdas.len(), "{label}: grid length");
    for (la, lb) in a.lambdas.iter().zip(&b.lambdas) {
        assert_eq!(la.to_bits(), lb.to_bits(), "{label}: lambda");
    }
    assert_eq!(a.lam_max.to_bits(), b.lam_max.to_bits(), "{label}: lam_max");
    for (t, (pa, pb)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(pa.gap.to_bits(), pb.gap.to_bits(), "{label}: gap at t={t}");
        assert_eq!(pa.epochs, pb.epochs, "{label}: epochs at t={t}");
        assert_eq!(pa.n_active_feats, pb.n_active_feats, "{label}: active at t={t}");
        assert_eq!(pa.nnz_coefs, pb.nnz_coefs, "{label}: nnz at t={t}");
        assert_eq!(pa.converged, pb.converged, "{label}: converged at t={t}");
        assert_eq!(pa.kkt_violations, pb.kkt_violations, "{label}: kkt at t={t}");
    }
    for (t, (ba, bb)) in a.betas.iter().zip(&b.betas).enumerate() {
        for (va, vb) in ba.as_slice().iter().zip(bb.as_slice()) {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: beta bits at t={t}");
        }
    }
}

#[test]
fn solve_path_bit_identical_across_backends() {
    if avx2_or_skip("solve_path gate").is_none() {
        return;
    }
    let entry_backend = kernels::active_kind();
    let cfg = PathConfig {
        n_lambdas: 12,
        delta: 2.0,
        eps: 1e-5,
        ..PathConfig::default()
    };
    let scenarios: Vec<(&str, Dataset, Task)> = vec![
        ("lasso-dense", synth::leukemia_like_scaled(30, 120, 3, false), Task::Lasso),
        ("logreg-dense", synth::leukemia_like_scaled(30, 120, 3, true), Task::Logreg),
        ("lasso-sparse", synth::sparse_regression(40, 150, 0.15, 5), Task::Lasso),
        ("logreg-sparse", binarize(synth::sparse_regression(40, 150, 0.15, 6)), Task::Logreg),
    ];
    for (label, ds, task) in &scenarios {
        let on_scalar = solve_under(BackendKind::Scalar, ds, *task, &cfg);
        let on_avx2 = solve_under(BackendKind::Avx2, ds, *task, &cfg);
        assert_paths_bit_identical(&on_scalar, &on_avx2, label);
        // sanity: the run did real work (several lambdas, nonzero coefs)
        assert!(on_scalar.points.len() >= 12, "{label}: path too short");
        assert!(
            on_scalar.betas.last().unwrap().nnz() > 0,
            "{label}: degenerate all-zero path"
        );
    }
    // restore the entry backend (keeps a GAPSAFE_KERNEL-forced run forced)
    kernels::select(entry_backend).unwrap();
    println!(
        "kernel-parity: OK solve_path gate — {} scenarios bit-identical scalar vs avx2",
        scenarios.len()
    );
}
