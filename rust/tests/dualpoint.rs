//! End-to-end properties of the dual-point engine (`screening::dual`):
//!
//! * with `dual = best` / `refine` the gap reported at successive gap
//!   passes is non-increasing for every estimator family (the reported
//!   dual objective is non-decreasing by construction and the CD primal
//!   only decreases — see the "Dual points" section of the `screening`
//!   module docs);
//! * no dual strategy ever screens a feature of the `rescale` reference
//!   support, across the whole rule zoo (Thm. 2 holds for any feasible
//!   pair, so the kept point's sphere is exactly as safe);
//! * an adversarial strong-rule case where the heuristic discard is
//!   provably wrong and only the KKT re-check saves the solution.

use gapsafe::data::synth;
use gapsafe::linalg::Mat;
use gapsafe::penalty::ActiveSet;
use gapsafe::screening::{DualStrategy, NoScreening, PrevSolution, Rule, StrongRule};
use gapsafe::solver::path::scaled_eps;
use gapsafe::solver::{solve_fixed_lambda, solve_fixed_lambda_with, SolveOptions};
use gapsafe::{build_problem, Task};

/// One workload per estimator family (Lasso / logistic / SGL /
/// multi-task / Poisson), with a lambda ratio each family converges
/// comfortably at.
fn family_cases() -> Vec<(Task, gapsafe::data::Dataset, f64)> {
    vec![
        (Task::Lasso, synth::leukemia_like_scaled(28, 80, 5, false), 0.1),
        (Task::Logreg, synth::leukemia_like_scaled(28, 50, 6, true), 0.2),
        (Task::SparseGroupLasso { tau: 0.4 }, synth::climate_like(36, 8, 7), 0.2),
        (Task::MultiTask, synth::meg_like(18, 30, 4, 8), 0.2),
        (Task::Poisson, synth::poisson_like(24, 50, 9), 0.2),
    ]
}

/// Property: with the best-kept (or refined) dual point the reported gap
/// never increases between gap passes — the exact monotonicity the Gap
/// Safe radius inherits. A tiny relative slack absorbs floating-point
/// rounding of the primal/dual evaluations; the sequence itself must not
/// bounce.
#[test]
fn best_kept_gap_trace_is_monotone_non_increasing() {
    for (task, ds, ratio) in family_cases() {
        let prob = build_problem(ds, task).unwrap();
        let lam = ratio * prob.lambda_max();
        for dual in [DualStrategy::BestKept, DualStrategy::Refine] {
            let opts = SolveOptions {
                eps: scaled_eps(&prob, 1e-8),
                screen_every: 5,
                max_epochs: 30_000,
                dual,
                ..Default::default()
            };
            let mut rule = Rule::GapSafeFull.build();
            let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
            assert!(res.converged, "{task:?} dual={} did not converge", dual.label());
            assert!(
                res.gap_trace.len() >= 2,
                "{task:?}: too few gap passes ({}) for a monotonicity check",
                res.gap_trace.len()
            );
            for (i, w) in res.gap_trace.windows(2).enumerate() {
                assert!(
                    w[1] <= w[0] * (1.0 + 1e-9) + 1e-12,
                    "{task:?} dual={}: gap increased at pass {}: {} -> {} (trace {:?})",
                    dual.label(),
                    i + 1,
                    w[0],
                    w[1],
                    res.gap_trace
                );
            }
        }
    }
}

/// Regression pin for the `gap_safe_radius` curvature-hook refactor: for
/// every global-gamma datafit (quadratic / logistic / multinomial) the
/// radius of a gap pass must be `sqrt(2 gap / gamma) / lambda` **bit for
/// bit** — the verbatim pre-hook formula — both at beta = 0 and at a
/// partially solved iterate. Only the Poisson fit (no global gamma) may
/// deviate from it.
#[test]
fn global_gamma_radii_are_bitwise_the_historical_formula() {
    let cases: Vec<(Task, gapsafe::data::Dataset, f64)> = vec![
        (Task::Lasso, synth::leukemia_like_scaled(22, 40, 31, false), 0.3),
        (Task::Logreg, synth::leukemia_like_scaled(22, 40, 32, true), 0.3),
        (Task::Multinomial, synth::multinomial_like(22, 30, 3, 33).0, 0.3),
    ];
    for (task, ds, ratio) in cases {
        let prob = build_problem(ds, task).unwrap();
        let lam = ratio * prob.lambda_max();
        let active = ActiveSet::full(prob.pen.groups());
        let beta0 = Mat::zeros(prob.p(), prob.q());
        let z0 = prob.predict(&beta0);
        let at0 = prob.gap_pass(&beta0, &z0, lam, &active);
        let want0 = (2.0 * at0.gap / prob.fit.gamma().unwrap()).sqrt() / lam;
        assert_eq!(
            at0.radius.to_bits(),
            want0.to_bits(),
            "{task:?}: radius at beta=0 deviates from the global-gamma formula"
        );
        // a handful of epochs away from zero, where gap and theta are
        // nontrivial
        let mut none = NoScreening;
        let opts = SolveOptions { eps: 0.0, max_epochs: 5, ..Default::default() };
        let part = solve_fixed_lambda(&prob, lam, &mut none, &opts);
        let z = prob.predict(&part.beta);
        let mid = prob.gap_pass(&part.beta, &z, lam, &active);
        let want = (2.0 * mid.gap / prob.fit.gamma().unwrap()).sqrt() / lam;
        assert_eq!(
            mid.radius.to_bits(),
            want.to_bits(),
            "{task:?}: radius at a partial iterate deviates from the global-gamma formula"
        );
    }
}

/// Safety across the rule zoo: the support of the `rescale` reference
/// solution (no screening — the historical solver output) must survive
/// every (rule, dual strategy) combination. Safe rules must also keep
/// every reference-support feature in their final active set; the strong
/// rule is un-safe by design, so for it only the repaired solution is
/// pinned.
#[test]
fn no_dual_strategy_screens_the_rescale_reference_support() {
    let ds = synth::leukemia_like_scaled(30, 90, 12, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam = 0.15 * prob.lambda_max();
    let opts_with = |dual| SolveOptions { eps: 1e-9, dual, ..Default::default() };
    let mut none = NoScreening;
    let reference =
        solve_fixed_lambda(&prob, lam, &mut none, &opts_with(DualStrategy::Rescale));
    assert!(reference.converged);
    let support: Vec<usize> = (0..prob.p())
        .filter(|&j| reference.beta[(j, 0)].abs() > 1e-6)
        .collect();
    assert!(!support.is_empty(), "degenerate reference: empty support");

    let safe_rules = [
        Rule::StaticGap,
        Rule::StaticElGhaoui,
        Rule::Dst3,
        Rule::DynamicBonnefoy,
        Rule::GapSafeSeq,
        Rule::GapSafeDyn,
        Rule::GapSafeFull,
    ];
    for rule in safe_rules {
        for dual in [DualStrategy::Rescale, DualStrategy::BestKept, DualStrategy::Refine] {
            let mut r = rule.build();
            let res = solve_fixed_lambda(&prob, lam, r.as_mut(), &opts_with(dual));
            assert!(res.converged, "rule {} dual {}", rule.label(), dual.label());
            for &j in &support {
                assert!(
                    res.active.feat[j],
                    "rule {} with dual {} screened support feature {j}",
                    rule.label(),
                    dual.label()
                );
                assert!(
                    (res.beta[(j, 0)] - reference.beta[(j, 0)]).abs() < 1e-4,
                    "rule {} dual {} diverged from the rescale reference at {j}",
                    rule.label(),
                    dual.label()
                );
            }
        }
    }
    // Strong rule: un-safe heuristic + KKT repair — the solution (not the
    // intermediate active set) is what must match.
    for dual in [DualStrategy::Rescale, DualStrategy::BestKept, DualStrategy::Refine] {
        let mut r = Rule::Strong.build();
        let res = solve_fixed_lambda(&prob, lam, r.as_mut(), &opts_with(dual));
        assert!(res.converged, "strong dual {}", dual.label());
        for &j in &support {
            assert!(
                (res.beta[(j, 0)] - reference.beta[(j, 0)]).abs() < 1e-4,
                "strong rule with dual {} lost support feature {j}",
                dual.label()
            );
        }
    }
}

/// Adversarial strong-rule case: a *stale* previous dual point (theta = 0
/// — feasible, but carrying no correlation information) makes the strong
/// extrapolation (Eq. 23-24) under-estimate every group, so the heuristic
/// discards the entire problem including the true support at
/// lambda = 0.9 lambda_max. The discard is provably wrong — the KKT
/// re-check at convergence must flag violators (`kkt_violations > 0`),
/// reactivate them, and land on the no-screening solution.
#[test]
fn strong_rule_stale_theta_discard_is_repaired_by_kkt() {
    let ds = synth::leukemia_like_scaled(20, 50, 21, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lmax = prob.lambda_max();
    let lam = 0.9 * lmax;
    let beta0 = Mat::zeros(prob.p(), 1);
    let z0 = prob.predict(&beta0);
    let prev = PrevSolution {
        lam: lmax,
        beta: beta0.clone(),
        z: z0.clone(),
        theta: Mat::zeros(prob.n(), prob.q()),
        loss: prob.fit.loss(&z0),
        pen_value: 0.0,
        active: ActiveSet::full(prob.pen.groups()),
    };
    // The heuristic really is wrong here: the strong threshold at
    // lam = 0.9 lam_prev is 0.8, every stat of theta = 0 is 0, so the
    // strong set is empty — yet the true support at 0.9 lambda_max is not.
    let strong_set = StrongRule::strong_active_set(&prob, &prev, lam);
    assert_eq!(
        strong_set.n_active_feats(),
        0,
        "stale theta should have discarded every group"
    );
    let opts = SolveOptions { eps: 1e-9, ..Default::default() };
    let mut rule = Rule::Strong.build();
    let res = solve_fixed_lambda_with(
        &prob,
        lam,
        lmax,
        None,
        None,
        rule.as_mut(),
        Some(&prev),
        &opts,
    );
    assert!(
        res.kkt_violations > 0,
        "the wrong discard must surface as KKT violations"
    );
    assert!(res.converged, "gap={}", res.gap);
    let mut none = NoScreening;
    let want = solve_fixed_lambda(&prob, lam, &mut none, &opts);
    for j in 0..prob.p() {
        assert!(
            (res.beta[(j, 0)] - want.beta[(j, 0)]).abs() < 1e-4,
            "j={j}: repaired={} oracle={} (kkt_violations={})",
            res.beta[(j, 0)],
            want.beta[(j, 0)],
            res.kkt_violations
        );
        if want.beta[(j, 0)].abs() > 1e-6 {
            assert!(
                res.active.feat[j],
                "support feature {j} was never reactivated by the KKT re-check"
            );
        }
    }
}
