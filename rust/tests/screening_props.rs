//! Deeper property tests on the screening machinery: convergence of the
//! Gap Safe regions (Prop. 5 / Remark 8), finite identification of the
//! equicorrelation set (Prop. 6), sequential-vs-dynamic consistency,
//! lambda_max criticality (Prop. 3), and failure injection (degenerate
//! designs, zero columns, constant targets).

use gapsafe::data::synth;
use gapsafe::datafit::{Logistic, Multinomial, Poisson, Quadratic};
use gapsafe::linalg::sparse::{Csc, Design};
use gapsafe::linalg::Mat;
use gapsafe::penalty::{ActiveSet, GroupL2, Groups, L1};
use gapsafe::problem::Problem;
use gapsafe::screening::{NoScreening, PrevSolution, Rule};
use gapsafe::solver::path::{lambda_grid, solve_path, PathConfig, WarmStart};
use gapsafe::solver::{solve_fixed_lambda, solve_fixed_lambda_with, SolveOptions};
use gapsafe::util::{check_property, prng::Prng};
use gapsafe::{build_problem, Task};

/// Prop. 3: at lambda >= lambda_max the solution is exactly 0 and everything
/// is screened instantly; just below, the top feature survives.
#[test]
fn lambda_max_criticality() {
    check_property("lambda_max_critical", 10, |rng| {
        let ds = synth::leukemia_like_scaled(15 + rng.below(10), 30, rng.next_u64(), false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lmax = prob.lambda_max();
        let opts = SolveOptions { eps: 1e-12, ..Default::default() };
        let mut rule = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lmax * 1.0001, rule.as_mut(), &opts);
        if res.beta.nnz() != 0 {
            return Err("nonzero solution above lambda_max".into());
        }
        let mut rule = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lmax * 0.999, rule.as_mut(), &opts);
        if !res.converged {
            return Err("did not converge just below lambda_max".into());
        }
        Ok(())
    });
}

/// Remark 8: the Gap Safe radius goes to zero along the iterations, so the
/// active set converges; the trace must be non-increasing in feature count.
#[test]
fn dynamic_active_set_monotone_within_lambda() {
    let ds = synth::leukemia_like_scaled(30, 120, 77, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam = 0.1 * prob.lambda_max();
    let opts = SolveOptions { eps: 1e-12, screen_every: 5, ..Default::default() };
    let mut rule = Rule::GapSafeDyn.build();
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    let counts: Vec<usize> = res.screen_trace.iter().map(|t| t.active_after).collect();
    for w in counts.windows(2) {
        assert!(w[1] <= w[0], "active set grew within a lambda: {counts:?}");
    }
    // radius converges to 0 => final active set equals the equicorrelation
    // set (Prop. 6): every active feature has |X_j^T theta| ~ 1.
    let full = ActiveSet::full(prob.pen.groups());
    let stats = prob.stats_for_center(&res.theta, &full);
    for j in 0..prob.p() {
        if res.active.feat[j] {
            assert!(
                stats.group_dual[j] > 1.0 - 1e-4,
                "active feature {j} has score {} << 1 at convergence (eps=1e-12)",
                stats.group_dual[j]
            );
        }
    }
}

/// Sequential screening with an *exact* previous solution can never be less
/// safe than dynamic screening started cold (both must keep the support).
#[test]
fn sequential_and_dynamic_consistent_along_path() {
    let ds = synth::leukemia_like_scaled(24, 80, 78, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg_seq = PathConfig {
        n_lambdas: 15,
        delta: 2.0,
        rule: Rule::GapSafeSeq,
        eps: 1e-8,
        ..Default::default()
    };
    let cfg_dyn = PathConfig { rule: Rule::GapSafeDyn, ..cfg_seq.clone() };
    let seq = solve_path(&prob, &cfg_seq);
    let dyn_ = solve_path(&prob, &cfg_dyn);
    for (a, b) in seq.betas.iter().zip(&dyn_.betas) {
        for j in 0..prob.p() {
            assert!((a[(j, 0)] - b[(j, 0)]).abs() < 1e-5);
        }
    }
}

/// Degenerate designs must not break anything: zero columns are screened
/// immediately (their correlation is 0 forever).
#[test]
fn zero_columns_are_harmless() {
    let mut rng = Prng::new(5);
    let n = 15;
    let p = 20;
    let mut x = Mat::zeros(n, p);
    for j in 0..p {
        if j % 4 != 0 {
            for i in 0..n {
                x[(i, j)] = rng.gaussian();
            }
        } // every 4th column stays identically zero
    }
    let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(p)),
    );
    let lam = 0.3 * prob.lambda_max();
    let mut rule = Rule::GapSafeDyn.build();
    let opts = SolveOptions { eps: 1e-10, ..Default::default() };
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    for j in (0..p).step_by(4) {
        assert_eq!(res.beta[(j, 0)], 0.0);
        assert!(!res.active.feat[j], "zero column {j} not screened");
    }
}

/// Constant (zero) target: lambda_max = 0 edge; solving at any lambda > 0
/// returns beta = 0 instantly.
#[test]
fn zero_target_trivial_solution() {
    let mut rng = Prng::new(6);
    let mut x = Mat::zeros(10, 8);
    for v in x.as_mut_slice() {
        *v = rng.gaussian();
    }
    let y = vec![0.0; 10];
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(8)),
    );
    assert_eq!(prob.lambda_max(), 0.0);
    let mut rule = NoScreening;
    let opts = SolveOptions { eps: 1e-12, ..Default::default() };
    let res = solve_fixed_lambda(&prob, 0.5, &mut rule, &opts);
    assert!(res.converged);
    assert_eq!(res.beta.nnz(), 0);
}

/// Duplicated columns (non-unique solutions, Tibshirani 2013): safe rules
/// must still converge and the active set must contain every equicorrelated
/// copy.
#[test]
fn duplicated_columns_non_unique_solutions() {
    let mut rng = Prng::new(7);
    let n = 12;
    let mut x = Mat::zeros(n, 10);
    for j in 0..5 {
        for i in 0..n {
            x[(i, j)] = rng.gaussian();
        }
    }
    for j in 5..10 {
        for i in 0..n {
            x[(i, j)] = x[(i, j - 5)]; // exact duplicates
        }
    }
    let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(10)),
    );
    let lam = 0.4 * prob.lambda_max();
    let mut rule = Rule::GapSafeDyn.build();
    let opts = SolveOptions { eps: 1e-10, ..Default::default() };
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    for j in 0..5 {
        // a feature and its duplicate have identical screening scores: both
        // in or both out.
        assert_eq!(res.active.feat[j], res.active.feat[j + 5], "asymmetric screen at {j}");
    }
}

/// Sparse CSC path equals the dense path on identical data.
#[test]
fn sparse_dense_paths_identical() {
    let ds = synth::sparse_regression(25, 60, 0.2, 13);
    let dense = gapsafe::data::Dataset {
        x: Design::Dense(ds.x.to_dense()),
        y: ds.y.clone(),
        group_size: None,
        name: "densified".into(),
    };
    let cfg = PathConfig { n_lambdas: 8, delta: 2.0, eps: 1e-8, ..Default::default() };
    let ps = solve_path(&build_problem(ds, Task::Lasso).unwrap(), &cfg);
    let pd = solve_path(&build_problem(dense, Task::Lasso).unwrap(), &cfg);
    for (a, b) in ps.betas.iter().zip(&pd.betas) {
        for j in 0..60 {
            assert!((a[(j, 0)] - b[(j, 0)]).abs() < 1e-7);
        }
    }
}

/// CSC construction from triplets in scrambled order must canonicalise.
#[test]
fn csc_triplet_order_invariance() {
    let mut rng = Prng::new(8);
    let mut trip = Vec::new();
    for j in 0..6 {
        for i in 0..5 {
            if rng.bernoulli(0.5) {
                trip.push((j, i, rng.gaussian()));
            }
        }
    }
    let a = Csc::from_triplets(5, 6, trip.clone());
    rng.shuffle(&mut trip);
    let b = Csc::from_triplets(5, 6, trip);
    assert_eq!(a.to_dense(), b.to_dense());
}

/// Group Lasso with sqrt-size weights (Yuan & Lin) runs the whole path.
#[test]
fn group_lasso_weighted_path() {
    use gapsafe::penalty::GroupL2;
    let ds = synth::climate_like(36, 8, 17);
    let p = ds.p();
    let prob = Problem::new(
        ds.x,
        Box::new(Quadratic::new(ds.y)),
        Box::new(GroupL2::sqrt_size_weights(Groups::contiguous(p, 7))),
    );
    let cfg = PathConfig { n_lambdas: 8, delta: 1.5, eps: 1e-6, ..Default::default() };
    let res = solve_path(&prob, &cfg);
    assert!(res.points.iter().all(|pt| pt.converged));
}

/// The lambda grid endpoints and spacing follow Sec. 3.2 exactly.
#[test]
fn grid_matches_paper_formula() {
    let lmax = 3.7;
    let g = lambda_grid(lmax, 100, 3.0);
    assert_eq!(g.len(), 100);
    for (t, &l) in g.iter().enumerate() {
        let want = lmax * 10f64.powf(-3.0 * t as f64 / 99.0);
        assert!((l - want).abs() < 1e-12 * want);
    }
}

/// Datafit families covered by the randomized safety harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FitFam {
    Quadratic,
    Logistic,
    Multinomial,
    Poisson,
}

impl FitFam {
    const ALL: [FitFam; 4] =
        [FitFam::Quadratic, FitFam::Logistic, FitFam::Multinomial, FitFam::Poisson];

    fn label(&self) -> &'static str {
        match self {
            FitFam::Quadratic => "quadratic",
            FitFam::Logistic => "logistic",
            FitFam::Multinomial => "multinomial",
            FitFam::Poisson => "poisson",
        }
    }

    /// Per-combination salt so every (fit, design) cell draws distinct
    /// problems even though `check_property` reseeds per case only.
    fn salt(&self) -> u64 {
        match self {
            FitFam::Quadratic => 0x51AD,
            FitFam::Logistic => 0x106,
            FitFam::Multinomial => 0x3017,
            FitFam::Poisson => 0x9015,
        }
    }
}

/// A small random problem of the given family on a dense or CSC design.
fn random_problem(fit: FitFam, sparse: bool, rng: &mut Prng) -> Problem {
    let n = 10 + rng.below(5);
    let p = 12 + rng.below(5);
    let x: Design = if sparse {
        let mut trip = Vec::new();
        for j in 0..p {
            for i in 0..n {
                if rng.bernoulli(0.5) {
                    trip.push((j, i, rng.gaussian()));
                }
            }
        }
        Design::Sparse(Csc::from_triplets(n, p, trip))
    } else {
        let mut m = Mat::zeros(n, p);
        for v in m.as_mut_slice() {
            *v = rng.gaussian();
        }
        Design::Dense(m)
    };
    match fit {
        FitFam::Quadratic => {
            let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            Problem::new(x, Box::new(Quadratic::from_vec(&y)), Box::new(L1::new(p)))
        }
        FitFam::Logistic => {
            let y: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            Problem::new(x, Box::new(Logistic::new(&y)), Box::new(L1::new(p)))
        }
        FitFam::Multinomial => {
            let q = 3;
            let mut y = Mat::zeros(n, q);
            for i in 0..n {
                y[(i, rng.below(q))] = 1.0;
            }
            Problem::new(
                x,
                Box::new(Multinomial::new(y)),
                Box::new(GroupL2::new(Groups::singletons(p))),
            )
        }
        FitFam::Poisson => {
            let mut counts: Vec<f64> = (0..n).map(|_| rng.below(5) as f64).collect();
            counts[0] = counts[0].max(1.0);
            Problem::new(x, Box::new(Poisson::new(&counts)), Box::new(L1::new(p)))
        }
    }
}

/// Randomized rule-zoo safety harness: for every (rule x datafit x
/// dense/CSC) combination, 200 seeded trials assert that no safe rule
/// ever screens a coordinate of the high-precision no-screening reference
/// support, and that every rule's solution (including the un-safe strong
/// rule after its KKT repair) matches the reference. Each trial hands the
/// rules a converged `PrevSolution` at a larger lambda so the sequential
/// spheres are exercised, not just the dynamic ones. The
/// `SAFETY-HARNESS ... trials=N` marker lines below are grepped by CI.
#[test]
fn safety_harness_rule_zoo_never_screens_reference_support() {
    const TRIALS: u64 = 200;
    for fit in FitFam::ALL {
        for sparse in [false, true] {
            let design = if sparse { "csc" } else { "dense" };
            let combo = format!("safety_{}_{}", fit.label(), design);
            let salt = fit.salt() ^ if sparse { 0xC5C0_0000 } else { 0 };
            check_property(&combo, TRIALS, |seed_rng| {
                let mut rng = Prng::new(seed_rng.next_u64() ^ salt);
                let prob = random_problem(fit, sparse, &mut rng);
                let lmax = prob.lambda_max();
                if !(lmax.is_finite() && lmax > 0.0) {
                    return Err(format!("degenerate lambda_max {lmax}"));
                }
                let lam = (0.1 + 0.5 * rng.uniform()) * lmax;
                let opts =
                    SolveOptions { eps: 1e-9, max_epochs: 50_000, ..Default::default() };
                let mut none = NoScreening;
                let reference = solve_fixed_lambda(&prob, lam, &mut none, &opts);
                if !reference.converged {
                    return Err(format!("reference did not converge (gap {})", reference.gap));
                }
                let support: Vec<usize> = (0..prob.p())
                    .filter(|&j| (0..prob.q()).any(|c| reference.beta[(j, c)].abs() > 1e-5))
                    .collect();
                // A converged previous path point at a larger lambda feeds
                // the sequential spheres and the strong extrapolation.
                let lam_prev = (1.3 * lam).min(lmax);
                let mut none2 = NoScreening;
                let prev_res = solve_fixed_lambda(&prob, lam_prev, &mut none2, &opts);
                if !prev_res.converged {
                    return Err(format!("prev point did not converge (gap {})", prev_res.gap));
                }
                let prev = PrevSolution {
                    lam: lam_prev,
                    loss: prob.fit.loss(&prev_res.z),
                    pen_value: prob.pen.value(&prev_res.beta),
                    z: prev_res.z.clone(),
                    theta: prev_res.theta.clone(),
                    active: prev_res.active.clone(),
                    beta: prev_res.beta.clone(),
                };
                for rule in Rule::ALL {
                    if rule.regression_only() && fit != FitFam::Quadratic {
                        continue;
                    }
                    let mut r = rule.build();
                    let res = solve_fixed_lambda_with(
                        &prob,
                        lam,
                        lmax,
                        None,
                        None,
                        r.as_mut(),
                        Some(&prev),
                        &opts,
                    );
                    if !res.converged {
                        return Err(format!(
                            "rule {} did not converge (gap {})",
                            rule.label(),
                            res.gap
                        ));
                    }
                    let safe = rule != Rule::Strong;
                    for &j in &support {
                        if safe && !res.active.feat[j] {
                            return Err(format!(
                                "rule {} screened reference-support feature {j}",
                                rule.label()
                            ));
                        }
                        for c in 0..prob.q() {
                            let (a, b) = (res.beta[(j, c)], reference.beta[(j, c)]);
                            if (a - b).abs() > 1e-4 {
                                return Err(format!(
                                    "rule {} diverged from the reference at ({j},{c}): {a} vs {b}",
                                    rule.label()
                                ));
                            }
                        }
                    }
                }
                Ok(())
            });
            for rule in Rule::ALL {
                if rule.regression_only() && fit != FitFam::Quadratic {
                    continue;
                }
                println!(
                    "SAFETY-HARNESS rule={} fit={} design={} trials={}",
                    rule.label(),
                    fit.label(),
                    design,
                    TRIALS
                );
            }
        }
    }
}

/// Poisson lambda_max = 0 edge: all-zero counts under a column-centered
/// design make the null residual a constant that centered columns cannot
/// correlate with — `lambda_grid` must refuse to build a path there
/// (`lambda_grid_checked` errors instead of producing NaNs).
#[test]
fn poisson_all_zero_counts_has_zero_lambda_max() {
    use gapsafe::solver::path::lambda_grid_checked;
    let mut rng = Prng::new(23);
    let (n, p) = (12, 9);
    let mut x = Mat::zeros(n, p);
    // exactly balanced +-c columns: every column sums to 0.0 *exactly*
    // (partial sums are small integer multiples of c), so the constant
    // null residual of all-zero counts correlates to exactly 0
    for j in 0..p {
        let c = 0.5 + rng.uniform();
        let mut vals: Vec<f64> = (0..n).map(|i| if i < n / 2 { c } else { -c }).collect();
        rng.shuffle(&mut vals);
        for (i, v) in vals.into_iter().enumerate() {
            x[(i, j)] = v;
        }
    }
    let counts = vec![0.0; n];
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Poisson::new(&counts)),
        Box::new(L1::new(p)),
    );
    let lmax = prob.lambda_max();
    assert_eq!(lmax, 0.0, "expected lambda_max = 0, got {lmax}");
    let err = lambda_grid_checked(lmax, 10, 2.0).unwrap_err();
    assert!(err.contains("lambda_max"), "unhelpful error: {err}");
}

/// Multinomial path with the full rule set that applies to it.
#[test]
fn multinomial_path_with_screening() {
    let (ds, _) = synth::multinomial_like(24, 18, 3, 19);
    let prob = build_problem(ds, Task::Multinomial).unwrap();
    let cfg = PathConfig {
        n_lambdas: 6,
        delta: 1.5,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Active,
        eps: 1e-5,
        max_epochs: 20_000,
        ..Default::default()
    };
    let res = solve_path(&prob, &cfg);
    assert!(res.points.iter().all(|p| p.converged), "{:?}",
        res.points.iter().map(|p| p.gap).collect::<Vec<_>>());
}

/// Provenance ledger at the penalty layer, sink-free: handing
/// `sphere_screen` a kill-record buffer must (a) not change a single
/// screening decision, (b) produce exactly one record per killed feature
/// (matching the active-set diff), and (c) record only sound inequalities
/// `stat + r * norm < thresh`. Runs the full datafit x dense/CSC matrix
/// without touching the process-global trace sink.
#[test]
fn kill_records_match_active_set_diff_and_hold_inequalities() {
    const TRIALS: u64 = 40;
    for fit in FitFam::ALL {
        for sparse in [false, true] {
            let design = if sparse { "csc" } else { "dense" };
            let combo = format!("killrec_{}_{}", fit.label(), design);
            let salt = fit.salt() ^ if sparse { 0x0B5E_0000 } else { 0 };
            check_property(&combo, TRIALS, |seed_rng| {
                let mut rng = Prng::new(seed_rng.next_u64() ^ salt);
                let prob = random_problem(fit, sparse, &mut rng);
                let lmax = prob.lambda_max();
                if !(lmax.is_finite() && lmax > 0.0) {
                    return Err(format!("degenerate lambda_max {lmax}"));
                }
                let lam = (0.2 + 0.6 * rng.uniform()) * lmax;
                // A partial solve gives a genuine dual point and a radius
                // small enough that the sphere usually kills something.
                let opts = SolveOptions { eps: 1e-6, max_epochs: 300, ..Default::default() };
                let mut none = NoScreening;
                let res = solve_fixed_lambda(&prob, lam, &mut none, &opts);
                let full = ActiveSet::full(prob.pen.groups());
                let gp = prob.gap_pass(&res.beta, &res.z, lam, &full);
                if !(gp.radius.is_finite() && gp.radius >= 0.0) {
                    return Err(format!("bad radius {}", gp.radius));
                }
                let mut with_recs = full.clone();
                let mut without = full.clone();
                let mut recs = Vec::new();
                let killed_with = prob.pen.sphere_screen(
                    &gp.stats,
                    gp.radius,
                    &prob.norms,
                    &mut with_recs,
                    Some(&mut recs),
                );
                let killed_without =
                    prob.pen.sphere_screen(&gp.stats, gp.radius, &prob.norms, &mut without, None);
                if killed_with != killed_without {
                    return Err(format!(
                        "ledger changed screening: {killed_with:?} vs {killed_without:?}"
                    ));
                }
                if with_recs.feat != without.feat || with_recs.group != without.group {
                    return Err("ledger changed the resulting active set".to_string());
                }
                let killed: Vec<usize> =
                    (0..prob.p()).filter(|&j| !with_recs.feat[j]).collect();
                if recs.len() != killed.len() || recs.len() != killed_with.1 {
                    return Err(format!(
                        "record count {} != killed features {} (reported {})",
                        recs.len(),
                        killed.len(),
                        killed_with.1
                    ));
                }
                let mut rec_js: Vec<usize> = recs.iter().map(|r| r.j).collect();
                rec_js.sort_unstable();
                if rec_js != killed {
                    return Err(format!("recorded columns {rec_js:?} != killed {killed:?}"));
                }
                for r in &recs {
                    if prob.pen.groups().group_of(r.j) != r.group {
                        return Err(format!("record for column {} names group {}", r.j, r.group));
                    }
                    if !(r.stat.is_finite() && r.norm.is_finite() && r.thresh.is_finite()) {
                        return Err(format!("non-finite record for column {}: {r:?}", r.j));
                    }
                    // Both SGL branches record the unclamped statistic, so
                    // the linear form is sound for every test kind.
                    if r.stat + gp.radius * r.norm >= r.thresh {
                        return Err(format!(
                            "unsound record for column {}: {} + {} * {} >= {}",
                            r.j, r.stat, gp.radius, r.norm, r.thresh
                        ));
                    }
                }
                Ok(())
            });
        }
    }
}
