//! Deeper property tests on the screening machinery: convergence of the
//! Gap Safe regions (Prop. 5 / Remark 8), finite identification of the
//! equicorrelation set (Prop. 6), sequential-vs-dynamic consistency,
//! lambda_max criticality (Prop. 3), and failure injection (degenerate
//! designs, zero columns, constant targets).

use gapsafe::data::synth;
use gapsafe::linalg::sparse::{Csc, Design};
use gapsafe::linalg::Mat;
use gapsafe::penalty::{ActiveSet, Groups, L1};
use gapsafe::datafit::Quadratic;
use gapsafe::problem::Problem;
use gapsafe::screening::{NoScreening, Rule};
use gapsafe::solver::path::{lambda_grid, solve_path, PathConfig, WarmStart};
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::util::{check_property, prng::Prng};
use gapsafe::{build_problem, Task};

/// Prop. 3: at lambda >= lambda_max the solution is exactly 0 and everything
/// is screened instantly; just below, the top feature survives.
#[test]
fn lambda_max_criticality() {
    check_property("lambda_max_critical", 10, |rng| {
        let ds = synth::leukemia_like_scaled(15 + rng.below(10), 30, rng.next_u64(), false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lmax = prob.lambda_max();
        let opts = SolveOptions { eps: 1e-12, ..Default::default() };
        let mut rule = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lmax * 1.0001, rule.as_mut(), &opts);
        if res.beta.nnz() != 0 {
            return Err("nonzero solution above lambda_max".into());
        }
        let mut rule = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lmax * 0.999, rule.as_mut(), &opts);
        if !res.converged {
            return Err("did not converge just below lambda_max".into());
        }
        Ok(())
    });
}

/// Remark 8: the Gap Safe radius goes to zero along the iterations, so the
/// active set converges; the trace must be non-increasing in feature count.
#[test]
fn dynamic_active_set_monotone_within_lambda() {
    let ds = synth::leukemia_like_scaled(30, 120, 77, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam = 0.1 * prob.lambda_max();
    let opts = SolveOptions { eps: 1e-12, screen_every: 5, ..Default::default() };
    let mut rule = Rule::GapSafeDyn.build();
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    let counts: Vec<usize> = res.screen_trace.iter().map(|t| t.2).collect();
    for w in counts.windows(2) {
        assert!(w[1] <= w[0], "active set grew within a lambda: {counts:?}");
    }
    // radius converges to 0 => final active set equals the equicorrelation
    // set (Prop. 6): every active feature has |X_j^T theta| ~ 1.
    let full = ActiveSet::full(prob.pen.groups());
    let stats = prob.stats_for_center(&res.theta, &full);
    for j in 0..prob.p() {
        if res.active.feat[j] {
            assert!(
                stats.group_dual[j] > 1.0 - 1e-4,
                "active feature {j} has score {} << 1 at convergence (eps=1e-12)",
                stats.group_dual[j]
            );
        }
    }
}

/// Sequential screening with an *exact* previous solution can never be less
/// safe than dynamic screening started cold (both must keep the support).
#[test]
fn sequential_and_dynamic_consistent_along_path() {
    let ds = synth::leukemia_like_scaled(24, 80, 78, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg_seq = PathConfig {
        n_lambdas: 15,
        delta: 2.0,
        rule: Rule::GapSafeSeq,
        eps: 1e-8,
        ..Default::default()
    };
    let cfg_dyn = PathConfig { rule: Rule::GapSafeDyn, ..cfg_seq.clone() };
    let seq = solve_path(&prob, &cfg_seq);
    let dyn_ = solve_path(&prob, &cfg_dyn);
    for (a, b) in seq.betas.iter().zip(&dyn_.betas) {
        for j in 0..prob.p() {
            assert!((a[(j, 0)] - b[(j, 0)]).abs() < 1e-5);
        }
    }
}

/// Degenerate designs must not break anything: zero columns are screened
/// immediately (their correlation is 0 forever).
#[test]
fn zero_columns_are_harmless() {
    let mut rng = Prng::new(5);
    let n = 15;
    let p = 20;
    let mut x = Mat::zeros(n, p);
    for j in 0..p {
        if j % 4 != 0 {
            for i in 0..n {
                x[(i, j)] = rng.gaussian();
            }
        } // every 4th column stays identically zero
    }
    let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(p)),
    );
    let lam = 0.3 * prob.lambda_max();
    let mut rule = Rule::GapSafeDyn.build();
    let opts = SolveOptions { eps: 1e-10, ..Default::default() };
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    for j in (0..p).step_by(4) {
        assert_eq!(res.beta[(j, 0)], 0.0);
        assert!(!res.active.feat[j], "zero column {j} not screened");
    }
}

/// Constant (zero) target: lambda_max = 0 edge; solving at any lambda > 0
/// returns beta = 0 instantly.
#[test]
fn zero_target_trivial_solution() {
    let mut rng = Prng::new(6);
    let mut x = Mat::zeros(10, 8);
    for v in x.as_mut_slice() {
        *v = rng.gaussian();
    }
    let y = vec![0.0; 10];
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(8)),
    );
    assert_eq!(prob.lambda_max(), 0.0);
    let mut rule = NoScreening;
    let opts = SolveOptions { eps: 1e-12, ..Default::default() };
    let res = solve_fixed_lambda(&prob, 0.5, &mut rule, &opts);
    assert!(res.converged);
    assert_eq!(res.beta.nnz(), 0);
}

/// Duplicated columns (non-unique solutions, Tibshirani 2013): safe rules
/// must still converge and the active set must contain every equicorrelated
/// copy.
#[test]
fn duplicated_columns_non_unique_solutions() {
    let mut rng = Prng::new(7);
    let n = 12;
    let mut x = Mat::zeros(n, 10);
    for j in 0..5 {
        for i in 0..n {
            x[(i, j)] = rng.gaussian();
        }
    }
    for j in 5..10 {
        for i in 0..n {
            x[(i, j)] = x[(i, j - 5)]; // exact duplicates
        }
    }
    let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let prob = Problem::new(
        Design::Dense(x),
        Box::new(Quadratic::from_vec(&y)),
        Box::new(L1::new(10)),
    );
    let lam = 0.4 * prob.lambda_max();
    let mut rule = Rule::GapSafeDyn.build();
    let opts = SolveOptions { eps: 1e-10, ..Default::default() };
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    assert!(res.converged);
    for j in 0..5 {
        // a feature and its duplicate have identical screening scores: both
        // in or both out.
        assert_eq!(res.active.feat[j], res.active.feat[j + 5], "asymmetric screen at {j}");
    }
}

/// Sparse CSC path equals the dense path on identical data.
#[test]
fn sparse_dense_paths_identical() {
    let ds = synth::sparse_regression(25, 60, 0.2, 13);
    let dense = gapsafe::data::Dataset {
        x: Design::Dense(ds.x.to_dense()),
        y: ds.y.clone(),
        group_size: None,
        name: "densified".into(),
    };
    let cfg = PathConfig { n_lambdas: 8, delta: 2.0, eps: 1e-8, ..Default::default() };
    let ps = solve_path(&build_problem(ds, Task::Lasso).unwrap(), &cfg);
    let pd = solve_path(&build_problem(dense, Task::Lasso).unwrap(), &cfg);
    for (a, b) in ps.betas.iter().zip(&pd.betas) {
        for j in 0..60 {
            assert!((a[(j, 0)] - b[(j, 0)]).abs() < 1e-7);
        }
    }
}

/// CSC construction from triplets in scrambled order must canonicalise.
#[test]
fn csc_triplet_order_invariance() {
    let mut rng = Prng::new(8);
    let mut trip = Vec::new();
    for j in 0..6 {
        for i in 0..5 {
            if rng.bernoulli(0.5) {
                trip.push((j, i, rng.gaussian()));
            }
        }
    }
    let a = Csc::from_triplets(5, 6, trip.clone());
    rng.shuffle(&mut trip);
    let b = Csc::from_triplets(5, 6, trip);
    assert_eq!(a.to_dense(), b.to_dense());
}

/// Group Lasso with sqrt-size weights (Yuan & Lin) runs the whole path.
#[test]
fn group_lasso_weighted_path() {
    use gapsafe::penalty::GroupL2;
    let ds = synth::climate_like(36, 8, 17);
    let p = ds.p();
    let prob = Problem::new(
        ds.x,
        Box::new(Quadratic::new(ds.y)),
        Box::new(GroupL2::sqrt_size_weights(Groups::contiguous(p, 7))),
    );
    let cfg = PathConfig { n_lambdas: 8, delta: 1.5, eps: 1e-6, ..Default::default() };
    let res = solve_path(&prob, &cfg);
    assert!(res.points.iter().all(|pt| pt.converged));
}

/// The lambda grid endpoints and spacing follow Sec. 3.2 exactly.
#[test]
fn grid_matches_paper_formula() {
    let lmax = 3.7;
    let g = lambda_grid(lmax, 100, 3.0);
    assert_eq!(g.len(), 100);
    for (t, &l) in g.iter().enumerate() {
        let want = lmax * 10f64.powf(-3.0 * t as f64 / 99.0);
        assert!((l - want).abs() < 1e-12 * want);
    }
}

/// Multinomial path with the full rule set that applies to it.
#[test]
fn multinomial_path_with_screening() {
    let (ds, _) = synth::multinomial_like(24, 18, 3, 19);
    let prob = build_problem(ds, Task::Multinomial).unwrap();
    let cfg = PathConfig {
        n_lambdas: 6,
        delta: 1.5,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Active,
        eps: 1e-5,
        max_epochs: 20_000,
        ..Default::default()
    };
    let res = solve_path(&prob, &cfg);
    assert!(res.points.iter().all(|p| p.converged), "{:?}",
        res.points.iter().map(|p| p.gap).collect::<Vec<_>>());
}
