//! End-to-end tests for the model-serving subsystem, in the determinism
//! style of `tests/parallel.rs`:
//!
//! * a real server on a real TCP socket: submit a fit job over HTTP, poll
//!   it to completion, predict, and check the returned coefficients are
//!   **bitwise** equal to a direct `solve_path` call;
//! * a second fit of a *perturbed* lambda grid is warm-started from the
//!   cache: `/metrics` records the warm hit and the job spends fewer
//!   epochs than the cold fit;
//! * N client threads hammering fit/predict on the same key are bitwise
//!   identical to a serial run (single-flight registry).

use gapsafe::screening::Rule;
use gapsafe::serve::registry::{FitKind, ModelKey, Registry};
use gapsafe::serve::{Metrics, ServeConfig, Server};
use gapsafe::solver::path::{solve_path, PathConfig, WarmStart};
use gapsafe::util::json::Json;
use gapsafe::{build_problem, Task};

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One HTTP request over a fresh connection; returns (status, body JSON).
fn call(port: u16, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(("127.0.0.1", port)).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split_whitespace().next())
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw}"));
    let body_start = raw.find("\r\n\r\n").map(|i| i + 4).unwrap_or(raw.len());
    let v = Json::parse(raw[body_start..].trim())
        .unwrap_or_else(|e| panic!("bad JSON body ({e}): {raw}"));
    (status, v)
}

/// One raw GET over a fresh connection, without assuming a JSON body:
/// returns (status, content type, body text). `accept` sets an `Accept`
/// header when given (the content-negotiation path of `/metrics`).
fn call_raw(port: u16, target: &str, accept: Option<&str>) -> (u16, String, String) {
    let mut s = TcpStream::connect(("127.0.0.1", port)).expect("connect");
    let accept_line = accept.map(|a| format!("Accept: {a}\r\n")).unwrap_or_default();
    let req = format!("GET {target} HTTP/1.1\r\nHost: t\r\n{accept_line}Content-Length: 0\r\n\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split_whitespace().next())
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw}"));
    let head_end = raw.find("\r\n\r\n").expect("headers terminated");
    let content_type = raw[..head_end]
        .lines()
        .find_map(|l| l.strip_prefix("Content-Type: "))
        .unwrap_or("")
        .to_string();
    (status, content_type, raw[head_end + 4..].to_string())
}

/// The exact solver configuration the server pins for these parameters
/// (mirrors `ModelKey::path_config`).
fn direct_cfg(grid: usize, delta: f64, eps: f64) -> PathConfig {
    PathConfig {
        n_lambdas: grid,
        delta,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Standard,
        eps,
        eps_is_absolute: false,
        max_epochs: 10_000,
        screen_every: 10,
        threads: 1,
        compact: true,
        // `dual` (and any future knob) must track ModelKey::path_config —
        // the Default impl is the shared source of both.
        ..Default::default()
    }
}

fn start_server() -> (Server, u16) {
    let server = Server::bind(&ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        http_threads: 2,
        fit_workers: 2,
        cache_mb: 64,
        ..Default::default()
    })
    .expect("bind");
    let port = server.port();
    (server, port)
}

#[test]
fn end_to_end_fit_poll_predict_bitwise_and_warm_metrics() {
    let (server, port) = start_server();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run().unwrap());

    // --- healthz ---
    let (st, v) = call(port, "GET", "/healthz", "");
    assert_eq!(st, 200);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));

    // --- submit a cold fit and poll it to completion ---
    let fit_body = r#"{"data":"synth:reg:30x80","task":"lasso","seed":11,
                       "grid":10,"delta":2.0,"eps":1e-6}"#;
    let (st, v) = call(port, "POST", "/v1/fit", fit_body);
    assert_eq!(st, 202, "{v:?}");
    let id = v.get("job_id").and_then(Json::as_usize).expect("job id");
    let deadline = Instant::now() + Duration::from_secs(120);
    let cold_job = loop {
        let (st, j) = call(port, "GET", &format!("/v1/jobs/{id}"), "");
        assert_eq!(st, 200, "{j:?}");
        match j.get("state").and_then(Json::as_str) {
            Some("done") => break j,
            Some("failed") => panic!("cold fit failed: {j:?}"),
            _ => {
                assert!(Instant::now() < deadline, "fit did not finish in time");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    assert_eq!(cold_job.get("fit").and_then(Json::as_str), Some("cold"));
    assert_eq!(cold_job.get("converged").and_then(Json::as_bool), Some(true));
    let cold_epochs = cold_job.get("epochs").and_then(Json::as_usize).unwrap();

    // --- predict must match a direct solve_path bitwise ---
    let t = 9usize;
    let (st, pred) = call(
        port,
        "POST",
        "/v1/predict",
        r#"{"data":"synth:reg:30x80","task":"lasso","seed":11,
            "grid":10,"delta":2.0,"eps":1e-6,"t":9,"beta":true}"#,
    );
    assert_eq!(st, 200, "{pred:?}");
    let ds = gapsafe::data::load_spec("synth:reg:30x80", 11, false).unwrap();
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let direct = solve_path(&prob, &direct_cfg(10, 2.0, 1e-6));
    let beta = &direct.betas[t];
    let z = prob.predict(beta);
    let served_lam = pred.get("lam").and_then(Json::as_f64).unwrap();
    assert_eq!(served_lam.to_bits(), direct.lambdas[t].to_bits(), "lambda drifted");
    let served_beta = pred.get("beta").unwrap().as_arr().unwrap();
    assert_eq!(served_beta.len(), prob.p());
    for (j, sb) in served_beta.iter().enumerate() {
        let want = beta[(j, 0)];
        let got = sb.as_f64().unwrap();
        assert_eq!(
            want.to_bits(),
            got.to_bits(),
            "beta[{j}] not bitwise identical: {want:?} vs {got:?}"
        );
    }
    let served_z = pred.get("z").unwrap().as_arr().unwrap();
    assert_eq!(served_z.len(), prob.n());
    for (i, sz) in served_z.iter().enumerate() {
        assert_eq!(z[(i, 0)].to_bits(), sz.as_f64().unwrap().to_bits(), "z[{i}] drifted");
    }

    // --- perturbed grid: warm-start cache hit, fewer epochs ---
    let (st, warm_job) = call(
        port,
        "POST",
        "/v1/fit",
        r#"{"data":"synth:reg:30x80","task":"lasso","seed":11,
            "grid":10,"delta":2.04,"eps":1e-6,"wait":true}"#,
    );
    assert_eq!(st, 200, "{warm_job:?}");
    assert_eq!(warm_job.get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(warm_job.get("fit").and_then(Json::as_str), Some("warm"));
    assert_eq!(warm_job.get("warm").and_then(Json::as_bool), Some(true));
    assert_eq!(warm_job.get("converged").and_then(Json::as_bool), Some(true));
    let warm_epochs = warm_job.get("epochs").and_then(Json::as_usize).unwrap();
    assert!(
        warm_epochs < cold_epochs,
        "warm start did not save epochs: warm {warm_epochs} vs cold {cold_epochs}"
    );

    // --- exact repeat is a cache hit ---
    let fit_again = r#"{"data":"synth:reg:30x80","task":"lasso","seed":11,
                        "grid":10,"delta":2.0,"eps":1e-6,"wait":true}"#;
    let (st, hit_job) = call(port, "POST", "/v1/fit", fit_again);
    assert_eq!(st, 200);
    assert_eq!(hit_job.get("fit").and_then(Json::as_str), Some("hit"));

    // --- metrics reflect all of it ---
    let (st, m) = call(port, "GET", "/metrics", "");
    assert_eq!(st, 200);
    let count = |k: &str| m.get(k).and_then(Json::as_usize).unwrap_or(0);
    assert!(count("warm_hits") >= 1, "{m:?}");
    assert!(count("cache_hits") >= 1, "{m:?}");
    assert!(count("cold_fits") >= 1, "{m:?}");
    assert!(count("epochs_saved") >= 1, "no epochs saved recorded: {m:?}");
    assert_eq!(count("queue_depth"), 0);
    assert_eq!(count("jobs_failed"), 0);
    assert!(count("registry_models") >= 2);
    let rate = m.get("cache_hit_rate").and_then(Json::as_f64).unwrap();
    assert!(rate > 0.0 && rate < 1.0, "hit rate {rate}");

    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

/// Satellite: `/metrics` end to end over real TCP — Prometheus text
/// exposition (query-param and Accept-header negotiation, counter and
/// histogram line shapes, cumulative `le` ladders) and the JSON side's
/// structurally monotone latency quantiles.
#[test]
fn metrics_prometheus_exposition_and_latency_quantiles() {
    let (server, port) = start_server();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run().unwrap());

    // Traffic so the request histograms hold real samples.
    for _ in 0..20 {
        let (st, _) = call(port, "GET", "/healthz", "");
        assert_eq!(st, 200);
    }

    // --- ?format=prometheus selects the text exposition ---
    let (st, ct, body) = call_raw(port, "/metrics?format=prometheus", None);
    assert_eq!(st, 200);
    assert!(ct.starts_with("text/plain"), "content type: {ct}");
    assert!(
        body.contains("# TYPE gapsafe_http_requests_total counter"),
        "missing counter TYPE line:\n{body}"
    );
    assert!(
        body.contains("# TYPE gapsafe_request_duration_seconds histogram"),
        "missing histogram TYPE line:\n{body}"
    );
    // the shared-name histogram emits its TYPE line exactly once
    assert_eq!(body.matches("# TYPE gapsafe_request_duration_seconds histogram").count(), 1);
    assert!(
        body.contains("gapsafe_request_duration_seconds_bucket{endpoint=\"healthz\",le=\"+Inf\"}"),
        "missing healthz +Inf bucket:\n{body}"
    );
    assert!(body.contains("gapsafe_request_duration_seconds_count{endpoint=\"healthz\"} "));
    assert!(body.contains("gapsafe_uptime_seconds "));
    assert!(body.contains("gapsafe_jobs_running "));
    assert!(body.contains("gapsafe_kernel_backend{backend="));
    // screening provenance ledger: the per-rule counter family and the
    // process-wide screened fraction are part of the exposition
    assert!(
        body.contains("# TYPE gapsafe_screened_columns_total counter"),
        "missing screened counter TYPE line:\n{body}"
    );
    assert!(
        body.contains("gapsafe_screened_columns_total{rule=\"gap-dyn\"} "),
        "missing per-rule screened sample:\n{body}"
    );
    assert!(body.contains("gapsafe_screened_fraction "), "missing screened fraction:\n{body}");
    // every sample line is `name{labels} value` with a parseable value
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let val = line.rsplit(' ').next().unwrap();
        assert!(val.parse::<f64>().is_ok(), "unparseable sample value in: {line}");
    }
    // cumulative le ladder of the healthz histogram never decreases
    let mut last = 0u64;
    for line in body
        .lines()
        .filter(|l| l.starts_with("gapsafe_request_duration_seconds_bucket{endpoint=\"healthz\""))
    {
        let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(v >= last, "bucket ladder not cumulative: {line}");
        last = v;
    }
    assert!(last >= 20, "healthz histogram missed samples: +Inf cum = {last}");

    // --- Accept-header negotiation picks the same exposition ---
    let (st, ct, body2) = call_raw(port, "/metrics", Some("text/plain"));
    assert_eq!(st, 200);
    assert!(ct.starts_with("text/plain"), "content type: {ct}");
    assert!(body2.starts_with("# TYPE "), "not Prometheus text:\n{body2}");

    // --- default stays JSON, with monotone latency quantiles ---
    let (st, m) = call(port, "GET", "/metrics", "");
    assert_eq!(st, 200);
    let g = |k: &str| {
        m.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing {k}: {m:?}"))
    };
    assert!(g("request_seconds_count") >= 20.0);
    let (p50, p99, p999) =
        (g("request_seconds_p50"), g("request_seconds_p99"), g("request_seconds_p999"));
    assert!(p50 > 0.0, "p50 must be positive with samples recorded");
    assert!(p50 <= p99 && p99 <= p999, "quantiles not monotone: {p50} {p99} {p999}");
    assert_eq!(g("jobs_running"), 0.0);
    // the JSON view carries the same ledger rollup
    let frac = g("screened_fraction");
    assert!((0.0..=1.0).contains(&frac), "screened_fraction out of range: {frac}");
    let by_rule = m.get("screened_columns").expect("screened_columns object");
    assert!(
        by_rule.get("gap-dyn").and_then(Json::as_f64).is_some(),
        "screened_columns missing per-rule entry: {by_rule:?}"
    );

    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap();
}

#[test]
fn concurrent_same_key_fits_are_bitwise_identical_to_serial() {
    let metrics = Arc::new(Metrics::default());
    let reg = Arc::new(Registry::new(64, metrics));
    let key = ModelKey::new("synth:reg:16x24", "lasso", 7, false, 5, 1.5, 1e-6, 10_000);

    // serial reference
    let ds = gapsafe::data::load_spec("synth:reg:16x24", 7, false).unwrap();
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let direct = solve_path(&prob, &direct_cfg(5, 1.5, 1e-6));

    // N threads hammer the same key; single-flight must hand everyone the
    // same artifact, bitwise equal to the serial run.
    let n_threads = 8;
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                let reg = reg.clone();
                let key = key.clone();
                s.spawn(move || reg.fit(&key).expect("fit"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(results.len(), n_threads);
    assert!(
        results.iter().filter(|(_, kind)| *kind != FitKind::Hit).count() >= 1,
        "someone must have computed it"
    );
    let first = &results[0].0;
    for (model, _) in &results {
        assert!(Arc::ptr_eq(first, model), "single-flight returned distinct artifacts");
    }
    assert_eq!(first.path.betas.len(), direct.betas.len());
    for (t, (a, b)) in direct.betas.iter().zip(&first.path.betas).enumerate() {
        assert_eq!(a, b, "betas diverged from the serial run at lambda index {t}");
    }

    // concurrent predicts on the shared artifact are identical too
    let zs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                let m = first.clone();
                s.spawn(move || m.prob.predict(&m.path.betas[4]))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let z0 = prob.predict(&direct.betas[4]);
    for z in &zs {
        assert_eq!(&z0, z, "concurrent predict diverged");
    }
}
