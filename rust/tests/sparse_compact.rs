//! Sparse-design end-to-end safety and active-set compaction equivalence.
//!
//! Two invariants are pinned here:
//!
//! * **Screening safety on sparse designs** — every screening rule must
//!   produce the same path as no screening on a `Design::Sparse` problem,
//!   including designs built from *duplicate* triplets (the
//!   `Csc::from_triplets` merge regression) and designs with empty
//!   columns, for Lasso and logistic regression.
//! * **Compaction transparency** — `solve_path` with the packed working
//!   view ([`gapsafe::linalg::compact::CompactDesign`]) is bitwise equal
//!   to the full-scan path: identical betas, gaps and epoch counts.

use gapsafe::data::Dataset;
use gapsafe::linalg::sparse::{Csc, Design};
use gapsafe::linalg::Mat;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{solve_path, PathConfig, WarmStart};
use gapsafe::util::prng::Prng;
use gapsafe::{build_problem, Task};

/// A sparse design built from triplets *with duplicates* (merged on
/// construction) and with a few structurally empty columns, plus targets.
/// `binary` turns the targets into {0,1} labels for logistic problems.
fn tricky_sparse_dataset(n: usize, p: usize, seed: u64, binary: bool) -> Dataset {
    let mut rng = Prng::new(seed);
    let mut trip = Vec::new();
    for j in 0..p {
        if j % 11 == 7 {
            continue; // empty column
        }
        for i in 0..n {
            if rng.bernoulli(0.25) {
                let v = rng.gaussian();
                trip.push((j, i, v));
                if rng.bernoulli(0.3) {
                    // duplicate entry: must merge by summing, not corrupt
                    // the column norms
                    trip.push((j, i, 0.5 * v));
                }
            }
        }
    }
    let x = Csc::from_triplets(n, p, trip);
    // planted signal over a few nonempty columns
    let mut y = vec![0.0; n];
    for j in (0..p).step_by(9) {
        if j % 11 != 7 {
            x.col_axpy(j, if j % 2 == 0 { 1.0 } else { -1.0 }, &mut y);
        }
    }
    for v in y.iter_mut() {
        *v += 0.3 * rng.gaussian();
    }
    if binary {
        for v in y.iter_mut() {
            *v = if *v > 0.0 { 1.0 } else { 0.0 };
        }
    }
    Dataset {
        x: Design::Sparse(x),
        y: Mat::col_vec(&y),
        group_size: None,
        name: format!("tricky-sparse(n={n},p={p},seed={seed})"),
    }
}

fn cfg(rule: Rule, n_lambdas: usize, delta: f64, max_epochs: usize, eps: f64) -> PathConfig {
    PathConfig {
        n_lambdas,
        delta,
        rule,
        warm: WarmStart::Standard,
        eps,
        eps_is_absolute: false,
        max_epochs,
        screen_every: 10,
        threads: 1,
        compact: true,
        ..Default::default()
    }
}

#[test]
fn duplicate_triplet_design_matches_dense_rebuild() {
    // The satellite regression: with unmerged duplicates, col_norms_sq
    // (and nnz) disagree with the dense equivalent and every sphere test
    // built on ||x_j|| is unsafe.
    let ds = tricky_sparse_dataset(20, 45, 3, false);
    let Design::Sparse(s) = &ds.x else { panic!("expected sparse") };
    let dense_rebuild = Csc::from_dense(&s.to_dense());
    assert_eq!(s.nnz(), dense_rebuild.nnz(), "duplicates were not merged");
    let n1 = ds.x.col_norms_sq();
    let n2 = Design::Sparse(dense_rebuild).col_norms_sq();
    for j in 0..45 {
        assert_eq!(
            n1[j].to_bits(),
            n2[j].to_bits(),
            "column {j} norm corrupted by duplicate triplets"
        );
    }
}

#[test]
fn rules_produce_identical_paths_sparse_lasso() {
    let ds = tricky_sparse_dataset(24, 50, 5, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let base = solve_path(&prob, &cfg(Rule::None, 10, 2.0, 5000, 1e-8));
    assert!(base.points.iter().all(|p| p.converged));
    for rule in [
        Rule::StaticGap,
        Rule::StaticElGhaoui,
        Rule::Dst3,
        Rule::DynamicBonnefoy,
        Rule::GapSafeSeq,
        Rule::GapSafeDyn,
        Rule::GapSafeFull,
        Rule::Strong,
    ] {
        let other = solve_path(&prob, &cfg(rule, 10, 2.0, 5000, 1e-8));
        for (t, (a, b)) in base.betas.iter().zip(&other.betas).enumerate() {
            for j in 0..prob.p() {
                assert!(
                    (a[(j, 0)] - b[(j, 0)]).abs() < 1e-4,
                    "rule {} diverged at lambda {t}, feature {j}: {} vs {}",
                    rule.label(),
                    a[(j, 0)],
                    b[(j, 0)]
                );
            }
        }
    }
}

#[test]
fn rules_produce_identical_paths_sparse_logistic() {
    let ds = tricky_sparse_dataset(30, 40, 7, true);
    let prob = build_problem(ds, Task::Logreg).unwrap();
    // shorter grid: separable tails need many epochs under plain CD
    let base = solve_path(&prob, &cfg(Rule::None, 8, 1.5, 20_000, 1e-6));
    assert!(base.points.iter().all(|p| p.converged));
    for rule in [Rule::GapSafeSeq, Rule::GapSafeDyn, Rule::GapSafeFull, Rule::Strong] {
        let other = solve_path(&prob, &cfg(rule, 8, 1.5, 20_000, 1e-6));
        for (t, (a, b)) in base.betas.iter().zip(&other.betas).enumerate() {
            for j in 0..prob.p() {
                assert!(
                    (a[(j, 0)] - b[(j, 0)]).abs() < 1e-4,
                    "rule {} diverged at lambda {t}, feature {j}",
                    rule.label()
                );
            }
        }
    }
}

/// Compaction equivalence on sparse problems with duplicate-built and
/// empty columns: packed and full-scan paths must agree to the bit.
#[test]
fn compaction_bitwise_equal_on_tricky_sparse_designs() {
    for (task, binary, grid, delta, epochs) in [
        (Task::Lasso, false, 10, 2.0, 5000),
        (Task::Logreg, true, 6, 1.5, 20_000),
    ] {
        let ds = tricky_sparse_dataset(26, 44, 17, binary);
        let prob = build_problem(ds, task).unwrap();
        let on = cfg(Rule::GapSafeFull, grid, delta, epochs, 1e-6);
        let off = PathConfig { compact: false, ..on.clone() };
        let a = solve_path(&prob, &on);
        let b = solve_path(&prob, &off);
        for (t, (ba, bb)) in a.betas.iter().zip(&b.betas).enumerate() {
            for j in 0..prob.p() {
                assert_eq!(
                    ba[(j, 0)].to_bits(),
                    bb[(j, 0)].to_bits(),
                    "{task:?}: compaction changed beta at lambda {t}, feature {j}"
                );
            }
        }
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.gap.to_bits(), pb.gap.to_bits(), "{task:?}: gap diverged");
            assert_eq!(pa.epochs, pb.epochs, "{task:?}: epoch count diverged");
        }
    }
}

/// Compaction packs whole live groups, so SGL's feature-level screening
/// (which can kill features inside an active group) must stay bitwise
/// transparent too.
#[test]
fn compaction_bitwise_equal_sgl_and_multitask() {
    use gapsafe::data::synth;
    // SGL on a grouped climate-like dense design
    let ds = synth::climate_like(36, 8, 21);
    let prob = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
    let on = cfg(Rule::GapSafeFull, 8, 2.0, 8000, 1e-7);
    let off = PathConfig { compact: false, ..on.clone() };
    let a = solve_path(&prob, &on);
    let b = solve_path(&prob, &off);
    for (ba, bb) in a.betas.iter().zip(&b.betas) {
        for j in 0..prob.p() {
            assert_eq!(ba[(j, 0)].to_bits(), bb[(j, 0)].to_bits(), "sgl diverged at {j}");
        }
    }
    // multi-task (q > 1): link-free quadratic path with row groups
    let dsm = synth::meg_like(18, 30, 4, 23);
    let probm = build_problem(dsm, Task::MultiTask).unwrap();
    let am = solve_path(&probm, &cfg(Rule::GapSafeFull, 8, 2.0, 8000, 1e-7));
    let offm = PathConfig { compact: false, ..cfg(Rule::GapSafeFull, 8, 2.0, 8000, 1e-7) };
    let bm = solve_path(&probm, &offm);
    for (ba, bb) in am.betas.iter().zip(&bm.betas) {
        for j in 0..probm.p() {
            for k in 0..probm.q() {
                assert_eq!(
                    ba[(j, k)].to_bits(),
                    bb[(j, k)].to_bits(),
                    "multitask diverged at ({j},{k})"
                );
            }
        }
    }
}

/// The serving warm-start path (`solve_path_seeded`) runs with compaction
/// on; seed a registry fit and check the warm-started artifact still
/// converges and matches a direct solve.
#[test]
fn registry_warm_start_with_compaction_converges() {
    use gapsafe::serve::registry::{ModelKey, Registry};
    use gapsafe::serve::Metrics;
    use std::sync::Arc;
    let reg = Registry::new(128, Arc::new(Metrics::default()));
    let cold = ModelKey::new("synth:reg:30x80", "lasso", 9, false, 8, 2.0, 1e-6, 10_000);
    let (c, _) = reg.fit(&cold).unwrap();
    assert!(c.path.points.iter().all(|p| p.converged));
    let warm = ModelKey::new("synth:reg:30x80", "lasso", 9, false, 8, 2.05, 1e-6, 10_000);
    let (w, _) = reg.fit(&warm).unwrap();
    assert!(w.warm_started);
    assert!(w.path.points.iter().all(|p| p.converged));
    // The warm-seeded path takes different iterates than a direct fit, but
    // both certify the same duality-gap tolerance, so their objectives
    // agree to ~2x the scaled eps at every lambda.
    let direct = solve_path(&*w.prob, &warm.path_config());
    for ((&lam, a), b) in w.path.lambdas.iter().zip(&w.path.betas).zip(&direct.betas) {
        let pa = w.prob.primal(a, &w.prob.predict(a), lam);
        let pb = w.prob.primal(b, &w.prob.predict(b), lam);
        assert!(
            (pa - pb).abs() < 1e-3,
            "objectives diverged at lambda {lam}: {pa} vs {pb}"
        );
    }
}
