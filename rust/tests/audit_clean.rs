//! The audit gate, as a test: the shipped source tree must produce zero
//! unsuppressed findings from `gapsafe::analysis` — the same invariant CI
//! enforces through the `gapsafe audit` exit code, pinned here so a plain
//! `cargo test` catches a violation without the CLI in the loop.

use gapsafe::analysis;
use std::path::Path;

#[test]
fn source_tree_audits_clean() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let report = analysis::audit_tree(root).expect("audit walk failed");
    assert!(report.files > 0, "audit walked no files — wrong root?");
    let dirty: Vec<String> = report
        .findings
        .iter()
        .filter(|f| !f.suppressed)
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.lint, f.message))
        .collect();
    assert!(
        dirty.is_empty(),
        "unsuppressed audit findings in the tree:\n{}",
        dirty.join("\n")
    );
}

#[test]
fn audit_json_reports_zero_unsuppressed() {
    // CI greps `"unsuppressed":0` out of `gapsafe audit --format json`;
    // keep the exact serialized shape honest.
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let report = analysis::audit_tree(root).expect("audit walk failed");
    let json = report.to_json().to_string();
    assert!(
        json.contains("\"unsuppressed\":0"),
        "JSON gate key missing or non-zero: {json}"
    );
}
