//! The observability transparency contract, end to end: installing a
//! trace sink must never change an output bit, and the JSONL it writes
//! must round-trip through the crate's own JSON layer and the
//! `gapsafe trace` analyzers.
//!
//! Everything lives in ONE test function: the sink registry is a
//! process-wide global (`obs::install` / `obs::uninstall`), and the test
//! harness runs `#[test]` fns of one binary concurrently — two tests
//! toggling the global sink would race each other's solves.

use gapsafe::data::synth;
use gapsafe::obs;
use gapsafe::obs::trace::FileSink;
use gapsafe::solver::path::{solve_path, PathConfig};
use gapsafe::{build_problem, Task};

#[test]
fn tracing_is_bitwise_transparent_and_jsonl_round_trips() {
    let ds = synth::leukemia_like_scaled(24, 200, 7, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig { n_lambdas: 8, delta: 2.0, eps: 1e-6, ..Default::default() };

    // Baseline: no sink installed (the default process state, but be
    // explicit so the test owns the global).
    obs::uninstall();
    let base = solve_path(&prob, &cfg);

    let path = std::env::temp_dir().join(format!("gapsafe_obs_trace_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    obs::install(Box::new(FileSink::create(&path_s).unwrap()));
    let traced = solve_path(&prob, &cfg);
    obs::uninstall();

    // 1. Bitwise transparency: every coefficient, lambda and reported gap
    //    is identical bit for bit with the sink on.
    assert_eq!(base.lambdas.len(), traced.lambdas.len());
    for (a, b) in base.lambdas.iter().zip(&traced.lambdas) {
        assert_eq!(a.to_bits(), b.to_bits(), "tracing changed a lambda");
    }
    for (t, (a, b)) in base.points.iter().zip(&traced.points).enumerate() {
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "tracing changed the gap at lambda {t}");
        assert_eq!(a.epochs, b.epochs, "tracing changed the epoch count at lambda {t}");
    }
    for (t, (a, b)) in base.betas.iter().zip(&traced.betas).enumerate() {
        for j in 0..a.rows() {
            for c in 0..a.cols() {
                assert_eq!(
                    a[(j, c)].to_bits(),
                    b[(j, c)].to_bits(),
                    "tracing changed beta at lambda {t}, ({j},{c})"
                );
            }
        }
    }

    // 2. The trace file is well-formed JSONL (load() hard-errors on any
    //    malformed or untagged line) and carries the solver span events.
    let events = gapsafe::obs::analyze::load(&path_s).expect("trace must parse");
    assert!(!events.is_empty(), "trace file is empty");
    let count = |kind: &str| {
        events
            .iter()
            .filter(|e| e.get("type").and_then(|t| t.as_str()) == Some(kind))
            .count()
    };
    assert_eq!(count("path_start"), 1, "exactly one path_start span");
    assert_eq!(count("path_end"), 1, "exactly one path_end span");
    assert_eq!(count("path_point"), cfg.n_lambdas, "one path_point per lambda");
    assert_eq!(count("solve"), cfg.n_lambdas, "one solve span per lambda");
    assert!(count("gap_pass") >= cfg.n_lambdas, "every solve runs at least one gap pass");

    // 3. The analyzers render from a real trace: the per-lambda table has
    //    header + one row per lambda, and the summary embeds the rollup.
    let table = gapsafe::obs::analyze::lambda_table(&events);
    assert_eq!(table.lines().count(), 1 + cfg.n_lambdas, "table:\n{table}");
    let summary = gapsafe::obs::analyze::summarize(&events);
    assert!(summary.contains(&format!("events: {}", events.len())), "{summary}");
    assert!(summary.contains("lambda"), "summary must embed the per-lambda table:\n{summary}");
    let flame = gapsafe::obs::analyze::flame(&events);
    assert!(flame.contains("total"), "{flame}");

    let _ = std::fs::remove_file(&path);
}
