//! The observability transparency contract, end to end: installing a
//! trace sink must never change an output bit, and the JSONL it writes
//! must round-trip through the crate's own JSON layer and the
//! `gapsafe trace` analyzers.
//!
//! Everything lives in ONE test function: the sink registry is a
//! process-wide global (`obs::install` / `obs::uninstall`), and the test
//! harness runs `#[test]` fns of one binary concurrently — two tests
//! toggling the global sink would race each other's solves.

use gapsafe::data::synth;
use gapsafe::linalg::sparse::{Csc, Design};
use gapsafe::obs;
use gapsafe::obs::trace::{CollectSink, FileSink};
use gapsafe::problem::Problem;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{solve_path, PathConfig};
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::util::json::Json;
use gapsafe::{build_problem, Task};
use std::collections::BTreeMap;

#[test]
fn tracing_is_bitwise_transparent_and_jsonl_round_trips() {
    let ds = synth::leukemia_like_scaled(24, 200, 7, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig { n_lambdas: 8, delta: 2.0, eps: 1e-6, ..Default::default() };

    // Baseline: no sink installed (the default process state, but be
    // explicit so the test owns the global).
    obs::uninstall();
    let base = solve_path(&prob, &cfg);

    let path = std::env::temp_dir().join(format!("gapsafe_obs_trace_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    obs::install(Box::new(FileSink::create(&path_s).unwrap()));
    let traced = solve_path(&prob, &cfg);
    obs::uninstall();

    // 1. Bitwise transparency: every coefficient, lambda and reported gap
    //    is identical bit for bit with the sink on.
    assert_eq!(base.lambdas.len(), traced.lambdas.len());
    for (a, b) in base.lambdas.iter().zip(&traced.lambdas) {
        assert_eq!(a.to_bits(), b.to_bits(), "tracing changed a lambda");
    }
    for (t, (a, b)) in base.points.iter().zip(&traced.points).enumerate() {
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "tracing changed the gap at lambda {t}");
        assert_eq!(a.epochs, b.epochs, "tracing changed the epoch count at lambda {t}");
    }
    for (t, (a, b)) in base.betas.iter().zip(&traced.betas).enumerate() {
        for j in 0..a.rows() {
            for c in 0..a.cols() {
                assert_eq!(
                    a[(j, c)].to_bits(),
                    b[(j, c)].to_bits(),
                    "tracing changed beta at lambda {t}, ({j},{c})"
                );
            }
        }
    }

    // 2. The trace file is well-formed JSONL (load() hard-errors on any
    //    malformed or untagged line) and carries the solver span events.
    let events = gapsafe::obs::analyze::load(&path_s).expect("trace must parse");
    assert!(!events.is_empty(), "trace file is empty");
    let count = |kind: &str| {
        events
            .iter()
            .filter(|e| e.get("type").and_then(|t| t.as_str()) == Some(kind))
            .count()
    };
    assert_eq!(count("path_start"), 1, "exactly one path_start span");
    assert_eq!(count("path_end"), 1, "exactly one path_end span");
    assert_eq!(count("path_point"), cfg.n_lambdas, "one path_point per lambda");
    assert_eq!(count("solve"), cfg.n_lambdas, "one solve span per lambda");
    assert!(count("gap_pass") >= cfg.n_lambdas, "every solve runs at least one gap pass");

    // 3. The analyzers render from a real trace: the per-lambda table has
    //    header + one row per lambda, and the summary embeds the rollup.
    let table = gapsafe::obs::analyze::lambda_table(&events);
    assert_eq!(table.lines().count(), 1 + cfg.n_lambdas, "table:\n{table}");
    let summary = gapsafe::obs::analyze::summarize(&events);
    assert!(summary.contains(&format!("events: {}", events.len())), "{summary}");
    assert!(summary.contains("lambda"), "summary must embed the per-lambda table:\n{summary}");
    let flame = gapsafe::obs::analyze::flame(&events);
    assert!(flame.contains("total"), "{flame}");

    // 4. The provenance ledger rode along: every solve left a certificate,
    //    and the screening that visibly shrank the path's active sets left
    //    per-column kill records tied to recorded sphere centers.
    assert_eq!(count("certificate"), count("solve"), "one certificate per solve");
    assert!(count("sphere_center") >= 1, "no sphere centers recorded");
    assert!(count("screen_col") >= 1, "no per-column kill records");
    assert!(summary.contains("ledger:"), "summary must roll the ledger up:\n{summary}");

    // 5. The offline verifier accepts the genuine trace end to end: every
    //    recorded kill re-passes its sphere test against the raw design,
    //    every certificate's dual point is feasible, and support replay
    //    matches.
    let rep = gapsafe::obs::analyze::verify(&events, &prob);
    assert!(rep.ok(), "verifier rejected a genuine trace:\n{}", rep.render());
    assert_eq!(rep.certificates, count("certificate"));
    assert_eq!(rep.screen_cols, count("screen_col"));
    assert_eq!(rep.sphere_centers, count("sphere_center"));

    // 6. ...and rejects a hand-corrupted copy of the same trace: lie about
    //    one kill's recorded statistic and the re-check must fail (this is
    //    exactly the CI hard gate's failure mode).
    let mut bad = events.clone();
    let idx = bad
        .iter()
        .position(|e| e.get("type").and_then(|t| t.as_str()) == Some("screen_col"))
        .expect("trace has a screen_col to corrupt");
    if let Json::Obj(m) = &mut bad[idx] {
        m.insert("stat".to_string(), Json::Num(-3.0));
    }
    let rep_bad = gapsafe::obs::analyze::verify(&bad, &prob);
    assert!(!rep_bad.ok(), "verifier accepted a corrupted trace");
    assert!(
        rep_bad.render().contains("VIOLATION"),
        "corrupted-trace report must list violations:\n{}",
        rep_bad.render()
    );

    let _ = std::fs::remove_file(&path);

    // 7. Ledger/solver reconciliation, across every datafit and both
    //    design storages: within each solve, what a gap pass reports as
    //    screened (active_before - active_after) must equal the number of
    //    ScreenCol records stamped with that pass's epoch, and the
    //    certificate's support must equal the solver's final active set.
    let mut quadratic_dense_kills = 0usize;
    for sparse in [false, true] {
        let tag = if sparse { "csc" } else { "dense" };
        let sparsify = |mut ds: gapsafe::data::Dataset| {
            if sparse {
                ds.x = Design::Sparse(Csc::from_dense(&ds.x.to_dense()));
            }
            ds
        };
        let cases: Vec<(String, Problem)> = vec![
            (
                format!("quadratic/{tag}"),
                build_problem(sparsify(synth::leukemia_like_scaled(24, 80, 11, false)), Task::Lasso)
                    .unwrap(),
            ),
            (
                format!("logistic/{tag}"),
                build_problem(sparsify(synth::leukemia_like_scaled(24, 60, 12, true)), Task::Logreg)
                    .unwrap(),
            ),
            (
                format!("multinomial/{tag}"),
                build_problem(sparsify(synth::multinomial_like(24, 30, 3, 13).0), Task::Multinomial)
                    .unwrap(),
            ),
            (
                format!("poisson/{tag}"),
                build_problem(sparsify(synth::poisson_like(20, 40, 14)), Task::Poisson).unwrap(),
            ),
        ];
        for (label, prob) in &cases {
            let kills = reconcile_one_solve(prob, label);
            if label.starts_with("quadratic/dense") {
                quadratic_dense_kills = kills;
            }
        }
    }
    assert!(quadratic_dense_kills > 0, "reconciliation exercised zero kills — test has no teeth");
}

/// Solve one lambda with a `CollectSink` installed and reconcile the typed
/// ledger events against the solver's own `screen_trace` and final active
/// set. Returns the number of kill records seen (so the caller can assert
/// the harness actually exercised screening somewhere).
fn reconcile_one_solve(prob: &Problem, label: &str) -> usize {
    let sink = CollectSink::new();
    let handle = sink.events.clone();
    obs::install(Box::new(sink));
    let lam = 0.3 * prob.lambda_max();
    let mut rule = Rule::GapSafeDyn.build();
    let opts = SolveOptions { eps: 1e-8, ..Default::default() };
    let res = solve_fixed_lambda(prob, lam, rule.as_mut(), &opts);
    obs::uninstall();
    let evs: Vec<obs::Event> = std::mem::take(&mut *handle.lock().unwrap());

    let mut site_of: BTreeMap<u64, &'static str> = BTreeMap::new();
    // epoch -> ScreenCol records from the dynamic (gap-pass) sphere site
    let mut dyn_kills: BTreeMap<usize, usize> = BTreeMap::new();
    let mut total_kills = 0usize;
    let mut cert_support: Option<Vec<usize>> = None;
    for ev in &evs {
        match ev {
            obs::Event::SphereCenter { cid, site, .. } => {
                site_of.insert(*cid, *site);
            }
            obs::Event::ScreenCol { cid, epoch, .. } => {
                total_kills += 1;
                match site_of.get(cid).copied() {
                    Some("dyn") => *dyn_kills.entry(*epoch).or_insert(0) += 1,
                    Some(_) => {} // pre-solve (seq/strong) kills precede pass 0
                    None => panic!("({label}) screen_col references unknown center {cid}"),
                }
            }
            obs::Event::Certificate { support, .. } => {
                assert!(cert_support.is_none(), "({label}) more than one certificate");
                cert_support = Some(support.clone());
            }
            _ => {}
        }
    }

    for se in &res.screen_trace {
        let want = se.active_before - se.active_after;
        let got = dyn_kills.remove(&se.epoch).unwrap_or(0);
        assert_eq!(
            got, want,
            "({label}) gap pass at epoch {}: solver screened {want}, ledger recorded {got}",
            se.epoch
        );
    }
    assert!(
        dyn_kills.is_empty(),
        "({label}) dyn kill records at epochs with no gap pass: {dyn_kills:?}"
    );

    let support = cert_support.unwrap_or_else(|| panic!("({label}) solve left no certificate"));
    let want: Vec<usize> = (0..prob.p()).filter(|&j| res.active.feat[j]).collect();
    assert_eq!(support, want, "({label}) certificate support != final active set");
    total_kills
}
