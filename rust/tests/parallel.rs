//! Determinism / equivalence tests for the parallel execution subsystem:
//!
//! * `threads = 1` is the serial path bitwise;
//! * the chunked path engine (`threads in {2, 4}`) reaches the same
//!   duality-gap certificate at every lambda, so per-lambda objectives
//!   match the serial run within 1e-10 and coefficients agree tightly, on
//!   Lasso, multi-task and Sparse-Group Lasso problems;
//! * fold-parallel CV, the tau sweep and the batch runner are bitwise
//!   identical at any thread count (work items are independent and results
//!   are re-assembled in input order).

use gapsafe::coordinator::cv::{kfold_cv, select_tau_sgl, select_tau_sgl_threaded, CvConfig};
use gapsafe::coordinator::BatchRunner;
use gapsafe::data::{synth, Dataset};
use gapsafe::problem::Problem;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{solve_path, solve_path_serial, PathConfig, PathResult, WarmStart};
use gapsafe::{build_problem, Task};

fn tight_cfg(threads: usize) -> PathConfig {
    PathConfig {
        n_lambdas: 14,
        delta: 2.0,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Standard,
        // Absolute gap certificate: both runs end with gap <= 2e-11 at
        // every lambda, so their objectives bracket the optimum to
        // 4e-11 < 1e-10 (and the tolerance stays well above the f64
        // noise floor of the gap evaluation on these loss magnitudes).
        eps: 2e-11,
        eps_is_absolute: true,
        max_epochs: 50_000,
        screen_every: 10,
        threads,
        compact: true,
        ..Default::default()
    }
}

fn cases() -> Vec<(Task, Dataset)> {
    vec![
        (Task::Lasso, synth::leukemia_like_scaled(24, 60, 101, false)),
        (Task::MultiTask, synth::meg_like(20, 30, 4, 102)),
        (Task::SparseGroupLasso { tau: 0.4 }, synth::climate_like(36, 8, 103)),
    ]
}

fn max_beta_diff(prob: &Problem, a: &PathResult, b: &PathResult) -> f64 {
    let mut worst: f64 = 0.0;
    for (ba, bb) in a.betas.iter().zip(&b.betas) {
        for j in 0..prob.p() {
            for k in 0..prob.q() {
                worst = worst.max((ba[(j, k)] - bb[(j, k)]).abs());
            }
        }
    }
    worst
}

#[test]
fn threads_one_is_exactly_the_serial_path() {
    for (task, ds) in cases() {
        let prob = build_problem(ds, task).unwrap();
        let via_dispatch = solve_path(&prob, &tight_cfg(1));
        let serial = solve_path_serial(&prob, &tight_cfg(1));
        assert_eq!(via_dispatch.betas.len(), serial.betas.len());
        for (a, b) in via_dispatch.betas.iter().zip(&serial.betas) {
            assert_eq!(a, b, "{task:?}: threads=1 is not the serial path");
        }
        for (a, b) in via_dispatch.points.iter().zip(&serial.points) {
            assert_eq!(a.epochs, b.epochs, "{task:?}: epoch counts differ");
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "{task:?}: gaps differ");
        }
    }
}

#[test]
fn chunked_path_matches_serial_objectives_within_1e10() {
    for (task, ds) in cases() {
        let prob = build_problem(ds, task).unwrap();
        let serial = solve_path(&prob, &tight_cfg(1));
        assert!(serial.points.iter().all(|p| p.converged), "{task:?}: serial unconverged");
        for threads in [2, 4] {
            let par = solve_path(&prob, &tight_cfg(threads));
            assert_eq!(par.points.len(), serial.points.len());
            assert_eq!(par.lambdas, serial.lambdas, "{task:?}: grids differ");
            assert!(
                par.points.iter().all(|p| p.converged),
                "{task:?}/threads={threads}: some chunked path point unconverged"
            );
            // Both runs certify gap <= 2e-11 at every lambda, so their
            // primal objectives bracket the optimum to 4e-11 < 1e-10.
            for (t, (&lam, (ba, bb))) in serial
                .lambdas
                .iter()
                .zip(serial.betas.iter().zip(&par.betas))
                .enumerate()
            {
                let pa = prob.primal(ba, &prob.predict(ba), lam);
                let pb = prob.primal(bb, &prob.predict(bb), lam);
                assert!(
                    (pa - pb).abs() <= 1e-10,
                    "{task:?}/threads={threads}: objective diverged at lambda index {t}: \
                     serial {pa:.15e} vs parallel {pb:.15e}"
                );
            }
            let diff = max_beta_diff(&prob, &serial, &par);
            assert!(
                diff < 1e-5,
                "{task:?}/threads={threads}: coefficients diverged (max diff {diff:.3e})"
            );
            // "Identical screened sets": a screened feature is exactly zero
            // (prox/screening write literal zeros), so the zero pattern of
            // the certified solutions is the observable screening outcome —
            // it must agree feature-for-feature at every lambda.
            for (t, (ba, bb)) in serial.betas.iter().zip(&par.betas).enumerate() {
                for j in 0..prob.p() {
                    let sa = (0..prob.q()).any(|k| ba[(j, k)] != 0.0);
                    let sb = (0..prob.q()).any(|k| bb[(j, k)] != 0.0);
                    assert_eq!(
                        sa, sb,
                        "{task:?}/threads={threads}: screened/support sets differ at \
                         lambda index {t}, feature {j}"
                    );
                }
            }
        }
    }
}

#[test]
fn fold_parallel_cv_is_bitwise_deterministic() {
    let ds = synth::leukemia_like_scaled(30, 40, 7, false);
    let cfg = PathConfig {
        n_lambdas: 10,
        delta: 2.0,
        eps: 1e-8,
        max_epochs: 5000,
        ..Default::default()
    };
    let serial = kfold_cv(&ds, Task::Lasso, &cfg, &CvConfig { folds: 4, seed: 3, threads: 1 })
        .unwrap();
    for threads in [2, 4] {
        let par =
            kfold_cv(&ds, Task::Lasso, &cfg, &CvConfig { folds: 4, seed: 3, threads }).unwrap();
        assert_eq!(par.best_index, serial.best_index);
        assert_eq!(par.best_lambda.to_bits(), serial.best_lambda.to_bits());
        for (f, (a, b)) in serial.fold_mse.iter().zip(&par.fold_mse).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "fold {f} diverged at threads={threads}");
            }
        }
    }
}

#[test]
fn threaded_tau_sweep_is_bitwise_deterministic() {
    let ds = synth::climate_like(36, 6, 9);
    let cfg = PathConfig {
        n_lambdas: 5,
        delta: 1.5,
        eps: 1e-4,
        max_epochs: 500,
        ..Default::default()
    };
    let serial = select_tau_sgl(&ds, &cfg, 7);
    let par = select_tau_sgl_threaded(&ds, &cfg, 7, 4);
    assert_eq!(serial.best_tau, par.best_tau);
    for (a, b) in serial.test_mse.iter().zip(&par.test_mse) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn batch_runner_results_independent_of_pool_size() {
    let mk_jobs = || -> Vec<(Problem, PathConfig)> {
        (0..5u64)
            .map(|s| {
                let ds = synth::leukemia_like_scaled(20, 30, s, false);
                let cfg = PathConfig {
                    n_lambdas: 6,
                    delta: 1.5,
                    eps: 1e-6,
                    max_epochs: 2000,
                    ..Default::default()
                };
                (build_problem(ds, Task::Lasso).unwrap(), cfg)
            })
            .collect()
    };
    let one = BatchRunner::new(1).run(mk_jobs());
    let many = BatchRunner::new(4).run(mk_jobs());
    assert_eq!(one.len(), many.len());
    for (job, (a, b)) in one.iter().zip(&many).enumerate() {
        for (ba, bb) in a.betas.iter().zip(&b.betas) {
            assert_eq!(ba, bb, "job {job} diverged with a bigger pool");
        }
    }
}
