//! Poisson (KL) screening benchmark: the locally-bounded Gap Safe radius
//! (Dantas, Soubies & Fevotte 2021) vs the quadratic family's global
//! gamma = 1 radius, at the small lambda ratios where screening power
//! decides the epoch count.
//!
//! The Poisson radius `r = (gap + sqrt(gap^2 + 2 gap v_max)) / lambda` is
//! O(sqrt(gap)) like the global formula, so the dynamic rule keeps its
//! converging-screening property — the table below shows the screened
//! fraction and solver work side by side with a Lasso of the same shape.
//!
//! Records results/BENCH_poisson.json (see docs/BENCHMARKS.md):
//! `epochs_<fit>_<ratio>`, `gap_passes_<fit>_<ratio>`,
//! `screened_frac_<fit>_<ratio>`, `seconds_<fit>_<ratio>`.

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::scaled_eps;
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::{build_problem, Task};

fn main() {
    let smoke = common::smoke();
    let full = common::full_size();
    let (n, p) = if smoke {
        (30, 300)
    } else if full {
        (200, 5000)
    } else {
        (72, 2000)
    };
    common::banner(
        "poisson",
        "Gap Safe screening under the locally-bounded Poisson dual vs the\n\
         quadratic family at the same shape: screened fraction and epochs at\n\
         small lambda ratios",
    );
    let cases: Vec<(&str, Task, gapsafe::data::Dataset)> = vec![
        ("poisson", Task::Poisson, synth::poisson_like(n, p, 42)),
        ("quadratic", Task::Lasso, synth::leukemia_like_scaled(n, p, 42, false)),
    ];
    let ratios = [0.1, 0.05, 0.02];
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (label, task, ds) in cases {
        let prob = build_problem(ds, task).unwrap();
        let lmax = prob.lambda_max();
        let eps = scaled_eps(&prob, 1e-8);
        println!("\nfit {label}: n={} p={}", prob.n(), prob.p());
        println!(
            "{:>10} {:>8} {:>10} {:>13} {:>9}",
            "lam/lmax", "epochs", "gap passes", "screened frac", "seconds"
        );
        for r in ratios {
            let lam = r * lmax;
            let rtag = format!("r{:03}", (r * 100.0).round() as usize);
            let opts = SolveOptions { eps, max_epochs: 100_000, ..Default::default() };
            // One measured solve for the solver-work counters ...
            let mut rule = Rule::GapSafeFull.build();
            let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
            assert!(res.converged, "{label} r={r} did not converge (gap {})", res.gap);
            let screened_frac = 1.0 - res.active.n_active_feats() as f64 / prob.p() as f64;
            // ... and timed repetitions for the wall clock.
            let reps = common::reps(3);
            let (_, secs) = common::time_it(reps, || {
                let mut rule = Rule::GapSafeFull.build();
                std::hint::black_box(solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts));
            });
            println!(
                "{:>10.2} {:>8} {:>10} {:>13.3} {:>9.4}",
                r, res.epochs, res.gap_passes, screened_frac, secs
            );
            if screened_frac <= 0.0 {
                eprintln!(
                    "warning: {label} r={r}: Gap Safe screened nothing — the sphere \
                     never got tight enough on this workload"
                );
            }
            metrics.push((format!("epochs_{label}_{rtag}"), res.epochs as f64));
            metrics.push((format!("gap_passes_{label}_{rtag}"), res.gap_passes as f64));
            metrics.push((format!("screened_frac_{label}_{rtag}"), screened_frac));
            metrics.push((format!("seconds_{label}_{rtag}"), secs));
        }
    }
    let borrowed: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    common::record_bench_json("poisson", &borrowed);
}
