//! Reproduces Fig. 6: Sparse-Group Lasso on the NCEP/NCAR-like climate
//! workload (groups of 7 variables per grid point, tau = 0.4, grid
//! lmax -> lmax/10^2.5 as in Sec. 5.4).
//!
//! Panels: (a) coordinate-level active fraction, (b) group-level active
//! fraction (both in the CSV), (c) time to convergence per strategy.

#[path = "common.rs"]
mod common;

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let full = common::full_size();
    let (ds, n_lambdas, eps_list): (_, usize, Vec<f64>) = if common::smoke() {
        (synth::climate_like(36, 30, 42), 8, vec![1e-2, 1e-4])
    } else if full {
        // paper: n=814, p=73577 (10511 groups of 7); largest offline size
        (synth::climate_like(814, 10_511, 42), 100, vec![1e-2, 1e-4, 1e-6, 1e-8])
    } else {
        (synth::climate_like(120, 300, 42), 30, vec![1e-2, 1e-4, 1e-6])
    };
    common::banner(
        "fig6_sgl",
        &format!("SGL (tau=0.4) path on {} ({} lambdas, delta=2.5)", ds.name, n_lambdas),
    );
    let prob = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
    let delta = 2.5;

    let budgets: Vec<usize> = (1..=8).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("Fig6(a) feature level", &lambdas, &rows);
    println!("\n(Fig6(b) group-level fractions: frac_groups column of the CSV)");
    report::write_active_fraction_csv(
        &common::results_dir().join("fig6_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::StaticGap, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 20_000);
    report::print_timing("Fig6(c)", &cells);
    report::write_timing_csv(&common::results_dir().join("fig6_timing.csv"), &cells).unwrap();
}
