//! Ablations called out in DESIGN.md:
//!
//! 1. lambda_critic (Sec. 3.1): measured dead zone of the static El Ghaoui
//!    rule vs the closed-form prediction.
//! 2. Screening cadence f_ce: path time as a function of how often the
//!    duality gap is evaluated (paper fixes f_ce = 10).
//! 3. Warm-start strategies: standard vs active vs strong (Sec. 3.4/3.6).
//! 4. Solver-agnosticism (Sec. 3.3): Gap Safe accelerating FISTA, and the
//!    Blitz-like working-set comparator (Sec. 5.1).

#[path = "common.rs"]
mod common;

use gapsafe::coordinator::time_to_convergence;
use gapsafe::data::synth;
use gapsafe::penalty::ActiveSet;
use gapsafe::screening::{Rule, StaticElGhaouiRule, ScreeningRule};
use gapsafe::solver::ista::solve_fista;
use gapsafe::solver::path::{lambda_grid, scaled_eps, WarmStart};
use gapsafe::solver::working_set::{solve_working_set, WorkingSetOptions};
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::util::{write_csv, Stopwatch};
use gapsafe::{build_problem, Task};

fn main() {
    common::banner("ablation", "lambda_critic, f_ce cadence, warm starts, solver-agnosticism");
    let ds = if common::smoke() {
        synth::leukemia_like_scaled(30, 200, 42, false)
    } else {
        synth::leukemia_like_scaled(72, 1500, 42, false)
    };
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam_max = prob.lambda_max();

    // ---- 1. lambda_critic ------------------------------------------------
    println!("\n-- ablation 1: static-rule dead zone (Sec. 3.1) --");
    let crit = StaticElGhaouiRule::lambda_critic(&prob, lam_max);
    println!("closed-form lambda_critic / lambda_max = {:.4}", crit / lam_max);
    let lambdas = lambda_grid(lam_max, 40, 2.0);
    let mut rows = Vec::new();
    let mut measured_crit = 0.0f64;
    for &lam in &lambdas {
        let mut rule = StaticElGhaouiRule::new();
        let mut active = ActiveSet::full(prob.pen.groups());
        rule.begin_lambda(&prob, lam, lam_max, None, &mut active);
        let frac = active.n_active_feats() as f64 / prob.p() as f64;
        if frac < 1.0 {
            measured_crit = lam;
        }
        rows.push(vec![format!("{lam}"), format!("{}", lam / lam_max), format!("{frac}")]);
    }
    println!("smallest lambda with any static screening / lambda_max = {:.4}", measured_crit / lam_max);
    write_csv(&common::results_dir().join("ablation_lambda_critic.csv"),
        &["lambda", "lambda_ratio", "active_fraction"], &rows).unwrap();

    // ---- 2. screening cadence f_ce ---------------------------------------
    println!("\n-- ablation 2: screening cadence f_ce (paper default 10) --");
    let mut rows = Vec::new();
    for fce in [1usize, 2, 5, 10, 20, 50] {
        let lam = 0.05 * lam_max;
        let opts = SolveOptions {
            eps: scaled_eps(&prob, 1e-8),
            screen_every: fce,
            ..Default::default()
        };
        let (mean, _min) = common::time_it(common::reps(3), || {
            let mut rule = Rule::GapSafeDyn.build();
            let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
            assert!(res.converged);
        });
        println!("f_ce = {fce:>3}: {mean:>8.4}s per solve");
        rows.push(vec![fce.to_string(), format!("{mean}")]);
    }
    write_csv(&common::results_dir().join("ablation_fce.csv"), &["f_ce", "seconds"], &rows)
        .unwrap();

    // ---- 3. warm starts ---------------------------------------------------
    println!("\n-- ablation 3: warm-start strategies on the path --");
    let cells = time_to_convergence(
        &prob,
        &[
            (Rule::GapSafeFull, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Active),
            (Rule::Strong, WarmStart::Strong),
        ],
        &[1e-6],
        40,
        3.0,
        50_000,
    );
    for c in &cells {
        println!(
            "{:<28} {:>8.3}s (converged: {})",
            format!("{}+{}", c.rule.label(), c.warm.label()),
            c.seconds,
            c.all_converged
        );
    }
    gapsafe::coordinator::report::write_timing_csv(
        &common::results_dir().join("ablation_warm_start.csv"),
        &cells,
    )
    .unwrap();

    // ---- 4. solver-agnosticism -------------------------------------------
    println!("\n-- ablation 4: Gap Safe with FISTA / working sets --");
    let lam = 0.1 * lam_max;
    let opts = SolveOptions { eps: scaled_eps(&prob, 1e-6), max_epochs: 100_000, ..Default::default() };
    let mut rows = Vec::new();
    for (name, f) in [
        (
            "fista+none",
            Box::new(|| {
                let mut r = Rule::None.build();
                solve_fista(&prob, lam, r.as_mut(), &opts).converged
            }) as Box<dyn Fn() -> bool>,
        ),
        (
            "fista+gap-dyn",
            Box::new(|| {
                let mut r = Rule::GapSafeDyn.build();
                solve_fista(&prob, lam, r.as_mut(), &opts).converged
            }),
        ),
        (
            "cd+gap-dyn",
            Box::new(|| {
                let mut r = Rule::GapSafeDyn.build();
                solve_fixed_lambda(&prob, lam, r.as_mut(), &opts).converged
            }),
        ),
        (
            "working-set(blitz-like)",
            Box::new(|| {
                let ws = WorkingSetOptions { inner: opts.clone(), ..Default::default() };
                solve_working_set(&prob, lam, &ws).converged
            }),
        ),
    ] {
        let sw = Stopwatch::start();
        let ok = f();
        let secs = sw.secs();
        println!("{name:<26} {secs:>8.3}s (converged: {ok})");
        rows.push(vec![name.to_string(), format!("{secs}"), ok.to_string()]);
    }
    write_csv(
        &common::results_dir().join("ablation_solvers.csv"),
        &["solver", "seconds", "converged"],
        &rows,
    )
    .unwrap();
}
