//! Audit-engine benchmark: wall time of a full `audit_tree` pass over
//! `rust/src/` — lexing, item parsing, the crate-wide call graph, all
//! nine lints, and suppression.
//!
//! The audit runs on every CI push and as a pre-commit habit, so its
//! cost is a developer-facing latency budget: a whole-crate pass should
//! stay well under a second. The findings count is recorded alongside
//! the timing so a regression in either direction (lint suddenly silent,
//! or suddenly noisy) shows up in the same artifact.
//!
//! Records results/BENCH_audit.json (see docs/BENCHMARKS.md).

#[path = "common.rs"]
mod common;

use gapsafe::analysis::audit_tree;
use std::path::PathBuf;

fn main() {
    let smoke = common::smoke();
    common::banner(
        "audit",
        "full static-analysis pass over rust/src (lexer + parser + call graph + 9 lints)",
    );
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");

    // Correctness gate before timing: the shipped tree must audit clean,
    // and two passes must agree (the engine is deterministic).
    let first = audit_tree(&root).expect("audit_tree");
    let second = audit_tree(&root).expect("audit_tree");
    assert_eq!(first.unsuppressed(), 0, "shipped tree must audit clean:\n{}", first.render_text());
    assert_eq!(first.render_text(), second.render_text(), "audit must be deterministic");
    println!(
        "clean: {} files, {} finding(s) (all suppressed)",
        first.files,
        first.findings.len()
    );

    let reps = common::reps(if smoke { 3 } else { 10 });
    let (mean, min) = common::time_it(reps, || {
        let report = audit_tree(&root).expect("audit_tree");
        std::hint::black_box(report.unsuppressed());
    });
    println!("audit_tree: mean {:.1} ms, min {:.1} ms over {reps} reps", mean * 1e3, min * 1e3);

    common::record_bench_json(
        "audit",
        &[
            ("seconds_mean", mean),
            ("seconds_min", min),
            ("files", first.files as f64),
            ("findings", first.findings.len() as f64),
            ("suppressed", first.suppressed() as f64),
            ("unsuppressed", first.unsuppressed() as f64),
        ],
    );
}
