//! Shared bench harness (the offline registry has no criterion): warmup +
//! repeated timing with mean/min reporting, plus helpers to emit the
//! paper-style tables and results/*.csv.

#![allow(dead_code)]

use std::time::Instant;

/// Time a closure `reps` times after one warmup; returns (mean, min) seconds.
pub fn time_it<F: FnMut()>(reps: usize, mut f: F) -> (f64, f64) {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    (mean, min)
}

/// True when the full-size paper workloads were requested.
pub fn full_size() -> bool {
    std::env::var("GAPSAFE_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// True in smoke mode (`cargo bench --bench <b> -- --smoke`, or
/// `GAPSAFE_BENCH_SMOKE=1`): benches shrink to seconds-scale workloads and
/// a single repetition so CI can exercise every table printer and
/// `BENCH_*.json` writer on each commit without owning a perf budget.
/// Numbers recorded in smoke mode are plumbing checks, not measurements.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("GAPSAFE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Repetition count honoring smoke mode (1) vs the requested default.
pub fn reps(default: usize) -> usize {
    if smoke() {
        1
    } else {
        default
    }
}

/// Results directory (created).
pub fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

pub fn banner(name: &str, detail: &str) {
    println!("\n================================================================");
    println!("bench: {name}");
    println!("{detail}");
    println!("(set GAPSAFE_BENCH_FULL=1 for the paper's full-size workloads)");
    println!("================================================================");
}

/// The shared environment-metadata block every `BENCH_*.json` carries
/// under `"meta"`: which kernel backend produced the numbers, how many
/// cores the host offers, whether the run was a smoke-mode plumbing check,
/// and the source revision (`git describe`, "unknown" outside a checkout).
/// Perf-trajectory diffs need this to tell a regression from a machine or
/// backend change.
fn meta_block() -> gapsafe::util::json::Json {
    use gapsafe::util::json::Json;
    let git = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty", "--tags"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    Json::obj([
        (
            "kernel",
            Json::Str(gapsafe::linalg::kernels::active_kind().label().to_string()),
        ),
        ("threads", Json::Num(threads as f64)),
        ("smoke", Json::Bool(smoke())),
        ("git", Json::Str(git)),
    ])
}

/// Record headline numbers as `results/BENCH_<name>.json` — the perf-
/// trajectory convention (docs/BENCHMARKS.md): one flat object of numeric
/// metrics per bench plus a shared `"meta"` environment block, overwritten
/// on each run so successive commits can be diffed. Serialized through the
/// crate's own `util::json` (JSON has no NaN/inf literals, so non-finite
/// metrics are recorded as null).
pub fn record_bench_json(name: &str, metrics: &[(&str, f64)]) {
    use gapsafe::util::json::Json;
    use std::collections::BTreeMap;
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str(name.to_string()));
    obj.insert("full_size".to_string(), Json::Bool(full_size()));
    obj.insert("meta".to_string(), meta_block());
    for (k, v) in metrics {
        let val = if v.is_finite() { Json::Num(*v) } else { Json::Null };
        obj.insert((*k).to_string(), val);
    }
    let path = results_dir().join(format!("BENCH_{name}.json"));
    if let Err(e) = std::fs::write(&path, format!("{}\n", Json::Obj(obj))) {
        eprintln!("warning: could not record {}: {e}", path.display());
    } else {
        println!("recorded {}", path.display());
    }
}
