//! Serving-layer benchmark: cold fit vs. warm-start cache hit.
//!
//! Measures the three registry outcomes a resident `gapsafe serve`
//! process distinguishes (see `rust/src/serve/registry.rs`):
//!
//! * **cold** — no cached family member; the full path solve;
//! * **warm** — a perturbed lambda grid seeded per-lambda from the
//!   closest cached solution (the Gap Safe + warm-start payoff);
//! * **hit** — the exact key again; artifact fetch, no solver work.
//!
//! Records results/BENCH_serve.json (docs/BENCHMARKS.md convention).

#[path = "common.rs"]
mod common;

use gapsafe::serve::registry::{ModelKey, Registry};
use gapsafe::serve::Metrics;
use std::cell::Cell;
use std::sync::Arc;

fn key(data: &str, grid: usize, delta: f64) -> ModelKey {
    ModelKey::new(data, "lasso", 42, false, grid, delta, 1e-6, 20_000)
}

fn main() {
    let full = common::full_size();
    let (data, grid) = if common::smoke() {
        ("synth:reg:30x200", 8)
    } else if full {
        ("synth:reg:200x5000", 60)
    } else {
        ("synth:reg:60x800", 30)
    };
    common::banner(
        "serve_warm",
        &format!("registry cold fit vs warm-start vs exact hit on {data} ({grid} lambdas)"),
    );
    let reps = if full { 2 } else { common::reps(5) };
    let base_delta = 2.0;

    // Cold: a fresh registry every repetition (nothing to seed from).
    let (cold_mean, cold_min) = common::time_it(reps, || {
        let reg = Registry::new(4096, Arc::new(Metrics::default()));
        let (m, _) = reg.fit(&key(data, grid, base_delta)).unwrap();
        std::hint::black_box(m);
    });

    // Warm: one resident registry holding the base fit; each repetition
    // fits a slightly different grid so every call really solves (the
    // delta perturbation grows per rep to dodge exact-key hits).
    let reg = Registry::new(4096, Arc::new(Metrics::default()));
    let (base, _) = reg.fit(&key(data, grid, base_delta)).unwrap();
    let rep = Cell::new(0u32);
    let (warm_mean, warm_min) = common::time_it(reps, || {
        rep.set(rep.get() + 1);
        let delta = base_delta + 0.01 * rep.get() as f64;
        let (m, _) = reg.fit(&key(data, grid, delta)).unwrap();
        assert!(m.warm_started, "expected a warm-started fit");
        std::hint::black_box(m);
    });

    // Hit: the exact base key, already resident.
    let (hit_mean, hit_min) = common::time_it(reps, || {
        let (m, _) = reg.fit(&key(data, grid, base_delta)).unwrap();
        std::hint::black_box(m);
    });

    // Epoch accounting for the headline "epochs saved" story.
    let (warm_model, _) = reg.fit(&key(data, grid, base_delta + 0.005)).unwrap();
    let cold_epochs = base.total_epochs as f64;
    let warm_epochs = warm_model.total_epochs as f64;

    println!(
        "cold fit:        mean {:.4}s  min {:.4}s  ({} epochs)",
        cold_mean, cold_min, base.total_epochs
    );
    println!(
        "warm-start fit:  mean {:.4}s  min {:.4}s  ({} epochs)",
        warm_mean, warm_min, warm_model.total_epochs
    );
    println!("exact cache hit: mean {:.6}s  min {:.6}s", hit_mean, hit_min);
    println!(
        "warm speedup {:.2}x  hit speedup {:.0}x  epochs saved {:.0}",
        cold_min / warm_min.max(1e-12),
        cold_min / hit_min.max(1e-12),
        (cold_epochs - warm_epochs).max(0.0)
    );

    common::record_bench_json(
        "serve",
        &[
            ("seconds_cold_fit", cold_min),
            ("seconds_warm_fit", warm_min),
            ("seconds_cache_hit", hit_min),
            ("speedup_warm_vs_cold", cold_min / warm_min.max(1e-12)),
            ("speedup_hit_vs_cold", cold_min / hit_min.max(1e-12)),
            ("epochs_cold", cold_epochs),
            ("epochs_warm", warm_epochs),
        ],
    );
}
