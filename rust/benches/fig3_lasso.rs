//! Reproduces Fig. 3: Lasso on the Leukemia-shaped workload.
//!
//! Left panel  -> fraction of active variables per (lambda, K) for the
//!                Gap Safe rule, K = 2..2^9.
//! Right panel -> time to solve the 100-lambda path (lmax -> lmax/10^3) to
//!                each duality-gap tolerance, per screening strategy.

#[path = "common.rs"]
mod common;

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let full = common::full_size();
    let (ds, n_lambdas, eps_list): (_, usize, Vec<f64>) = if common::smoke() {
        (synth::leukemia_like_scaled(30, 200, 42, false), 10, vec![1e-2, 1e-4])
    } else if full {
        (synth::leukemia_like(42, false), 100, vec![1e-2, 1e-4, 1e-6, 1e-8])
    } else {
        (synth::leukemia_like_scaled(72, 2000, 42, false), 50, vec![1e-2, 1e-4, 1e-6])
    };
    common::banner(
        "fig3_lasso",
        &format!("Lasso path on {} ({} lambdas, delta=3)", ds.name, n_lambdas),
    );
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let delta = 3.0;

    // ---- left panel ----
    let budgets: Vec<usize> = (1..=9).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("Fig3-left (Gap Safe dynamic)", &lambdas, &rows);
    report::write_active_fraction_csv(
        &common::results_dir().join("fig3_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    // ---- right panel ----
    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::StaticElGhaoui, WarmStart::Standard),
        (Rule::Dst3, WarmStart::Standard),
        (Rule::DynamicBonnefoy, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
        (Rule::Strong, WarmStart::Strong),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 20_000);
    report::print_timing("Fig3-right", &cells);
    report::write_timing_csv(&common::results_dir().join("fig3_timing.csv"), &cells).unwrap();

    // Perf-trajectory record: the headline cells at the tightest tolerance.
    let tight = eps_list.iter().cloned().fold(f64::INFINITY, f64::min);
    let secs = |r: Rule, w: WarmStart| {
        cells
            .iter()
            .find(|c| c.rule == r && c.warm == w && c.eps == tight)
            .map(|c| c.seconds)
            .unwrap_or(f64::NAN)
    };
    let t_none = secs(Rule::None, WarmStart::Standard);
    let t_gap = secs(Rule::GapSafeFull, WarmStart::Standard);
    let t_gap_active = secs(Rule::GapSafeFull, WarmStart::Active);
    common::record_bench_json(
        "fig3_lasso",
        &[
            ("eps", tight),
            ("seconds_no_screening", t_none),
            ("seconds_gap_full", t_gap),
            ("seconds_gap_full_active", t_gap_active),
            ("speedup_gap_full_active", t_none / t_gap_active),
        ],
    );
}
