//! Dual-point strategy benchmark: `rescale` vs `best` vs `refine` at the
//! small lambda ratios where screening power decides the epoch count.
//!
//! The Gap Safe radius is `sqrt(2 gap)/(lambda sqrt(gamma))` — at small
//! lambda a dual point with a slightly better objective shrinks the
//! sphere noticeably, so the best-kept / refined strategies
//! ([`gapsafe::screening::dual`]) should converge in fewer or equal
//! epochs and gap passes than the plain per-pass rescaling (provably so
//! while both runs share a trajectory; a loud warning flags the cells
//! where diverging screening decisions broke that ordering), with at
//! least as much of the design screened at exit.
//!
//! Records results/BENCH_dualpoint.json (see docs/BENCHMARKS.md):
//! `epochs_<shape>_<ratio>_<strategy>`, `gap_passes_...`,
//! `screened_frac_...`, `seconds_...`.

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::screening::{DualStrategy, Rule};
use gapsafe::solver::path::scaled_eps;
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::{build_problem, Task};

fn main() {
    let smoke = common::smoke();
    let full = common::full_size();
    let shapes: Vec<(&str, gapsafe::data::Dataset)> = if smoke {
        vec![
            ("dense", synth::leukemia_like_scaled(24, 300, 42, false)),
            ("sparse10", synth::sparse_regression(50, 400, 0.10, 42)),
        ]
    } else if full {
        vec![
            ("dense", synth::leukemia_like(42, false)),
            ("sparse10", synth::sparse_regression(500, 20_000, 0.10, 42)),
        ]
    } else {
        vec![
            ("dense", synth::leukemia_like_scaled(72, 3000, 42, false)),
            ("sparse10", synth::sparse_regression(200, 5000, 0.10, 42)),
        ]
    };
    common::banner(
        "dualpoint",
        "dual-point strategies (rescale | best | refine) at small lambda ratios:\n\
         epochs, gap passes and screened fraction per strategy — best-kept radii\n\
         are monotone, so screening can only tighten between passes",
    );
    let ratios = [0.1, 0.05, 0.02];
    let strategies =
        [DualStrategy::Rescale, DualStrategy::BestKept, DualStrategy::Refine];
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (label, ds) in shapes {
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lmax = prob.lambda_max();
        let eps = scaled_eps(&prob, 1e-8);
        println!("\nshape {label}: n={} p={}", prob.n(), prob.p());
        println!(
            "{:>10} {:>9} {:>8} {:>10} {:>13} {:>9}",
            "lam/lmax", "strategy", "epochs", "gap passes", "screened frac", "seconds"
        );
        for r in ratios {
            let lam = r * lmax;
            let rtag = format!("r{:03}", (r * 100.0).round() as usize);
            let mut rescale_cost: Option<usize> = None;
            for strat in strategies {
                let opts = SolveOptions {
                    eps,
                    max_epochs: 100_000,
                    dual: strat,
                    ..Default::default()
                };
                // One measured solve for the solver-work counters ...
                let mut rule = Rule::GapSafeFull.build();
                let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
                assert!(res.converged, "{label} r={r} {} did not converge", strat.label());
                let screened_frac =
                    1.0 - res.active.n_active_feats() as f64 / prob.p() as f64;
                // ... and timed repetitions for the wall clock.
                let reps = common::reps(3);
                let (_, secs) = common::time_it(reps, || {
                    let mut rule = Rule::GapSafeFull.build();
                    std::hint::black_box(solve_fixed_lambda(
                        &prob,
                        lam,
                        rule.as_mut(),
                        &opts,
                    ));
                });
                println!(
                    "{:>10.2} {:>9} {:>8} {:>10} {:>13.3} {:>9.4}",
                    r,
                    strat.label(),
                    res.epochs,
                    res.gap_passes,
                    screened_frac,
                    secs
                );
                let cost = res.epochs + res.gap_passes;
                match strat {
                    DualStrategy::Rescale => rescale_cost = Some(cost),
                    _ => {
                        // The monotone-radius strategies should not pay
                        // more solver work than the oscillating baseline.
                        // This is a theorem only while both runs walk the
                        // same beta trajectory — once screening decisions
                        // diverge, epoch counts are unordered — so a
                        // violation is flagged loudly for the recorded
                        // JSON to expose, not asserted (a benchmark must
                        // not turn a legitimate trajectory split into a
                        // red CI).
                        if let Some(base) = rescale_cost {
                            if cost > base {
                                eprintln!(
                                    "warning: {label} r={r}: dual={} cost {cost} \
                                     (epochs+gap passes) exceeds rescale {base} — \
                                     screening trajectories diverged",
                                    strat.label()
                                );
                            }
                        }
                    }
                }
                let s = strat.label();
                metrics.push((format!("epochs_{label}_{rtag}_{s}"), res.epochs as f64));
                metrics
                    .push((format!("gap_passes_{label}_{rtag}_{s}"), res.gap_passes as f64));
                metrics.push((format!("screened_frac_{label}_{rtag}_{s}"), screened_frac));
                metrics.push((format!("seconds_{label}_{rtag}_{s}"), secs));
            }
        }
    }
    let borrowed: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    common::record_bench_json("dualpoint", &borrowed);
}
