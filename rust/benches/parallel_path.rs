//! Parallel path engine benchmark: the Fig. 3 Lasso workload solved with
//! 1, 2, 4 and 8 chunk workers.
//!
//! This is the acceptance benchmark for the chunked engine: `--threads 4`
//! must be at least ~2x faster than the serial path on the leukemia-like
//! shape while reproducing the same objectives (checked here to 1e-10 via
//! the shared tight-tolerance certificate, like tests/parallel.rs).
//!
//! Records results/BENCH_parallel_path.json (see docs/BENCHMARKS.md).

#[path = "common.rs"]
mod common;

use gapsafe::screening::Rule;
use gapsafe::solver::path::{solve_path, PathConfig, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let full = common::full_size();
    let (ds, n_lambdas) = if common::smoke() {
        (gapsafe::data::synth::leukemia_like_scaled(30, 300, 42, false), 12)
    } else if full {
        (gapsafe::data::synth::leukemia_like(42, false), 100)
    } else {
        (gapsafe::data::synth::leukemia_like_scaled(72, 2000, 42, false), 60)
    };
    common::banner(
        "parallel_path",
        &format!("chunked Lasso path on {} ({} lambdas, delta=3)", ds.name, n_lambdas),
    );
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = |threads| PathConfig {
        n_lambdas,
        delta: 3.0,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Standard,
        eps: 1e-6,
        eps_is_absolute: false,
        max_epochs: 20_000,
        screen_every: 10,
        threads,
        compact: true,
        ..Default::default()
    };

    let serial = solve_path(&prob, &cfg(1));
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut t1 = f64::NAN;
    for threads in [1usize, 2, 4, 8] {
        let (mean, min) = common::time_it(if full { 1 } else { common::reps(3) }, || {
            std::hint::black_box(solve_path(&prob, &cfg(threads)));
        });
        if threads == 1 {
            t1 = min;
        }
        let res = solve_path(&prob, &cfg(threads));
        let all_converged = res.points.iter().all(|p| p.converged);
        let mut max_obj_diff: f64 = 0.0;
        for ((&lam, a), b) in res.lambdas.iter().zip(&res.betas).zip(&serial.betas) {
            let pa = prob.primal(a, &prob.predict(a), lam);
            let pb = prob.primal(b, &prob.predict(b), lam);
            max_obj_diff = max_obj_diff.max((pa - pb).abs());
        }
        println!(
            "threads={threads}: mean {:.3}s  min {:.3}s  speedup {:.2}x  converged={}  \
             max |obj - serial obj| = {:.2e}",
            mean,
            min,
            t1 / min,
            all_converged,
            max_obj_diff
        );
        metrics.push((format!("seconds_threads_{threads}"), min));
        metrics.push((format!("speedup_threads_{threads}"), t1 / min));
    }
    let borrowed: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    common::record_bench_json("parallel_path", &borrowed);
}
