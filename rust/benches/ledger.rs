//! Provenance-ledger overhead benchmark: the same `solve_path` run three
//! ways — no trace sink at all, a JSONL [`FileSink`] with ledger event
//! emission turned off (`obs::ledger::set_emit(false)`: span tracing only),
//! and the full ledger (sphere centers, per-column kill records, per-solve
//! certificates).
//!
//! The contract (see `gapsafe::obs::ledger`): ledger ids and counters are
//! unconditional, but event construction — including the O(n q) dual-point
//! copies in `SphereCenter` / `Certificate` — only happens when a sink is
//! installed *and* emission is on. All three configurations must produce
//! bitwise-identical paths (asserted before timing anything); the bench
//! then prices the two observability tiers against the silent baseline.
//!
//! Records results/BENCH_ledger.json (see docs/BENCHMARKS.md).

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::obs;
use gapsafe::obs::ledger;
use gapsafe::obs::trace::FileSink;
use gapsafe::solver::path::{solve_path, PathConfig};
use gapsafe::{build_problem, Task};

fn assert_bitwise_equal(
    a: &gapsafe::solver::path::PathResult,
    b: &gapsafe::solver::path::PathResult,
    what: &str,
) {
    assert_eq!(a.betas.len(), b.betas.len(), "{what}: path length changed");
    for (t, (ba, bb)) in a.betas.iter().zip(&b.betas).enumerate() {
        for j in 0..ba.rows() {
            for c in 0..ba.cols() {
                assert_eq!(
                    ba[(j, c)].to_bits(),
                    bb[(j, c)].to_bits(),
                    "{what}: beta diverged at lambda {t}, ({j},{c})"
                );
            }
        }
    }
    for (t, (pa, pb)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(pa.gap.to_bits(), pb.gap.to_bits(), "{what}: gap diverged at lambda {t}");
        assert_eq!(pa.epochs, pb.epochs, "{what}: epochs diverged at lambda {t}");
    }
}

fn main() {
    let smoke = common::smoke();
    let full = common::full_size();
    let (n, p) = if smoke {
        (24, 200)
    } else if full {
        (72, 7000)
    } else {
        (48, 2000)
    };
    common::banner(
        "ledger",
        "solve_path silent vs span tracing (ledger off) vs the full provenance \
         ledger (all three must be bitwise identical before timing starts)",
    );
    let ds = synth::leukemia_like_scaled(n, p, 42, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig {
        n_lambdas: if smoke { 10 } else { 40 },
        delta: 2.5,
        eps: 1e-6,
        max_epochs: 10_000,
        ..Default::default()
    };
    let trace_path =
        std::env::temp_dir().join(format!("gapsafe_bench_ledger_{}.jsonl", std::process::id()));
    let trace_str = trace_path.to_string_lossy().to_string();

    // --- bit-equality gate across all three configurations ---
    obs::uninstall();
    ledger::set_emit(true);
    let base = solve_path(&prob, &cfg);
    obs::install(Box::new(FileSink::create(&trace_str).unwrap()));
    ledger::set_emit(false);
    let spans_only = solve_path(&prob, &cfg);
    ledger::set_emit(true);
    let with_ledger = solve_path(&prob, &cfg);
    obs::uninstall();
    assert_bitwise_equal(&base, &spans_only, "spans-only tracing");
    assert_bitwise_equal(&base, &with_ledger, "full ledger");

    // Ledger volume of one traced path, from the trace the gate just wrote
    // (both runs share the file; ledger kinds only come from the second).
    let count_kind = |text: &str, kind: &str| {
        let needle = format!("\"type\":\"{kind}\"");
        text.lines().filter(|l| l.contains(&needle)).count()
    };
    let text = std::fs::read_to_string(&trace_path).unwrap_or_default();
    let n_centers = count_kind(&text, "sphere_center");
    let n_cols = count_kind(&text, "screen_col");
    let n_certs = count_kind(&text, "certificate");
    let trace_bytes = text.len();
    println!(
        "bitwise gate passed (ledger volume: {n_centers} centers, {n_cols} kill \
         records, {n_certs} certificates, {trace_bytes} trace bytes)"
    );
    assert!(n_certs >= cfg.n_lambdas, "every solve must leave a certificate");

    // --- timing ---
    let reps = common::reps(3);
    let (_, t_off) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    obs::install(Box::new(FileSink::create(&trace_str).unwrap()));
    ledger::set_emit(false);
    let (_, t_spans) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    ledger::set_emit(true);
    let (_, t_ledger) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    obs::uninstall();
    let _ = std::fs::remove_file(&trace_path);

    let pct = |t: f64| 100.0 * (t - t_off) / t_off.max(1e-12);
    println!(
        "no sink {t_off:.4}s  spans-only {t_spans:.4}s ({:+.2}%)  \
         full ledger {t_ledger:.4}s ({:+.2}%)",
        pct(t_spans),
        pct(t_ledger)
    );
    common::record_bench_json(
        "ledger",
        &[
            ("seconds_no_sink", t_off),
            ("seconds_spans_only", t_spans),
            ("seconds_full_ledger", t_ledger),
            ("spans_only_overhead_pct", pct(t_spans)),
            ("full_ledger_overhead_pct", pct(t_ledger)),
            ("sphere_centers_per_path", n_centers as f64),
            ("screen_cols_per_path", n_cols as f64),
            ("certificates_per_path", n_certs as f64),
            ("trace_bytes_per_path", trace_bytes as f64),
        ],
    );
}
