//! Reproduces Fig. 5: l1/l2 multi-task regression on the MEG/EEG-like
//! workload (q = 20 time instants). Compares Gap Safe against the dynamic
//! safe rule of Bonnefoy et al. and no screening, over gap tolerances
//! 1e-2 .. 1e-8 (right panel).

#[path = "common.rs"]
mod common;

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let full = common::full_size();
    let (ds, n_lambdas, eps_list): (_, usize, Vec<f64>) = if common::smoke() {
        (synth::meg_like(30, 200, 4, 42), 8, vec![1e-2, 1e-4])
    } else if full {
        (synth::meg_like(360, 22_494, 20, 42), 100, vec![1e-2, 1e-4, 1e-6, 1e-8])
    } else {
        (synth::meg_like(120, 1500, 10, 42), 30, vec![1e-2, 1e-4, 1e-6])
    };
    common::banner(
        "fig5_multitask",
        &format!("multi-task path on {} ({} lambdas, delta=2)", ds.name, n_lambdas),
    );
    let prob = build_problem(ds, Task::MultiTask).unwrap();
    let delta = 2.0;

    let budgets: Vec<usize> = (1..=8).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("Fig5-left (Gap Safe dynamic)", &lambdas, &rows);
    report::write_active_fraction_csv(
        &common::results_dir().join("fig5_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::DynamicBonnefoy, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 20_000);
    report::print_timing("Fig5-right", &cells);
    report::write_timing_csv(&common::results_dir().join("fig5_timing.csv"), &cells).unwrap();
}
