//! Active-set compaction benchmark: full-scan vs compacted solves across
//! lambda ratios and design densities.
//!
//! Small lambda ratios are the regime where Gap Safe screening kills most
//! columns, so this is where physically repacking the survivors
//! ([`gapsafe::linalg::compact::CompactDesign`]) should buy the most —
//! CD epochs and gap passes stop scanning the full feature bitmap and
//! iterate a contiguous working matrix instead. The solves are verified
//! bitwise-identical before timing (compaction must never change an
//! output bit).
//!
//! Records results/BENCH_compaction.json (see docs/BENCHMARKS.md).

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::scaled_eps;
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::{build_problem, Task};

fn main() {
    let smoke = common::smoke();
    let full = common::full_size();
    let shapes: Vec<(&str, gapsafe::data::Dataset)> = if smoke {
        vec![
            ("dense", synth::leukemia_like_scaled(24, 300, 42, false)),
            ("sparse10", synth::sparse_regression(50, 400, 0.10, 42)),
        ]
    } else if full {
        vec![
            ("dense", synth::leukemia_like(42, false)),
            ("sparse05", synth::sparse_regression(500, 20_000, 0.05, 42)),
            ("sparse20", synth::sparse_regression(500, 20_000, 0.20, 42)),
        ]
    } else {
        vec![
            ("dense", synth::leukemia_like_scaled(72, 3000, 42, false)),
            ("sparse05", synth::sparse_regression(200, 5000, 0.05, 42)),
            ("sparse20", synth::sparse_regression(200, 5000, 0.20, 42)),
        ]
    };
    common::banner(
        "compaction",
        "full-scan vs compacted epochs across lambda ratios and densities \
         (smaller lambda => more screening => more to gain from repacking)",
    );
    let ratios = [0.3, 0.1, 0.05];
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (label, ds) in shapes {
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lmax = prob.lambda_max();
        let eps = scaled_eps(&prob, 1e-6);
        println!("\nshape {label}: n={} p={}", prob.n(), prob.p());
        for r in ratios {
            let lam = r * lmax;
            let mk = |compact| SolveOptions {
                eps,
                max_epochs: 100_000,
                compact,
                ..Default::default()
            };
            // Transparency gate before timing: identical gap and betas.
            let mut ra = Rule::GapSafeFull.build();
            let mut rb = Rule::GapSafeFull.build();
            let a = solve_fixed_lambda(&prob, lam, ra.as_mut(), &mk(true));
            let b = solve_fixed_lambda(&prob, lam, rb.as_mut(), &mk(false));
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "compaction changed the gap");
            assert_eq!(a.epochs, b.epochs, "compaction changed the epoch count");
            for j in 0..prob.p() {
                assert_eq!(
                    a.beta[(j, 0)].to_bits(),
                    b.beta[(j, 0)].to_bits(),
                    "compaction changed beta at feature {j}"
                );
            }
            let reps = common::reps(3);
            let (_, t_full) = common::time_it(reps, || {
                let mut rule = Rule::GapSafeFull.build();
                std::hint::black_box(solve_fixed_lambda(&prob, lam, rule.as_mut(), &mk(false)));
            });
            let (_, t_comp) = common::time_it(reps, || {
                let mut rule = Rule::GapSafeFull.build();
                std::hint::black_box(solve_fixed_lambda(&prob, lam, rule.as_mut(), &mk(true)));
            });
            let speedup = t_full / t_comp.max(1e-12);
            println!(
                "  lam/lmax={r:>5.2}: full {t_full:>8.4}s  compact {t_comp:>8.4}s  \
                 speedup {speedup:>5.2}x  (epochs {}, final active {}/{})",
                a.epochs,
                a.active.n_active_feats(),
                prob.p()
            );
            let rtag = format!("r{:03}", (r * 100.0).round() as usize);
            metrics.push((format!("seconds_full_{label}_{rtag}"), t_full));
            metrics.push((format!("seconds_compact_{label}_{rtag}"), t_comp));
            metrics.push((format!("speedup_{label}_{rtag}"), speedup));
        }
    }
    let borrowed: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    common::record_bench_json("compaction", &borrowed);
}
