//! Micro-benchmarks of the numerical hot spots (used by the §Perf pass):
//! correlation kernel X^T v (native vs PJRT artifact), CD epochs,
//! epsilon-norm evaluation (sorting vs bisection), gap passes, and the
//! per-backend kernel-engine sweep (scalar vs AVX2 GFLOP/s, recorded to
//! `results/BENCH_kernels.json` per the BENCH_*.json convention).

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::linalg::kernels;
use gapsafe::linalg::Mat;
use gapsafe::penalty::epsilon_norm::{epsilon_norm, epsilon_norm_bisect};
use gapsafe::penalty::ActiveSet;
use gapsafe::runtime::PjrtEngine;
use gapsafe::util::prng::Prng;
use gapsafe::util::write_csv;
use gapsafe::{build_problem, Task};

fn main() {
    common::banner("kernels", "hot-spot micro-benchmarks (native + PJRT)");
    let mut rows = Vec::new();

    // ---- X^T v (the screening hot spot) -----------------------------------
    let ds = if common::smoke() {
        synth::leukemia_like_scaled(40, 500, 42, false)
    } else {
        synth::leukemia_like(42, false)
    };
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let (n, p) = (prob.n(), prob.p());
    let mut rng = Prng::new(1);
    let v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let mut out = vec![0.0; p];
    let (mean, min) = common::time_it(20, || {
        prob.x.xtv(&v, &mut out);
        std::hint::black_box(&out);
    });
    let flops = 2.0 * n as f64 * p as f64;
    println!(
        "xtv native     (n={n}, p={p}): mean {:.3} ms  ({:.2} GFLOP/s)",
        mean * 1e3,
        flops / min / 1e9
    );
    rows.push(vec!["xtv_native".into(), format!("{mean}"), format!("{min}")]);

    // ---- kernel engine: per-backend GFLOP/s (scalar vs AVX2) ---------------
    // Every backend is bitwise identical (linalg::kernels contract), so
    // this table is purely a speed comparison at the leukemia-like shape.
    {
        let xd = prob.x.to_dense();
        let mut bench_metrics: Vec<(String, f64)> = vec![
            ("n".to_string(), n as f64),
            ("p".to_string(), p as f64),
            ("avx2_supported".to_string(), if kernels::avx2_supported() { 1.0 } else { 0.0 }),
        ];
        let reps = common::reps(20);
        // dense xtv (the acceptance metric), dot, gemv, CSC-style gather
        let dot_len = 4096.min(xd.as_slice().len().max(4));
        let mut rng_k = Prng::new(9);
        let dv: Vec<f64> = (0..dot_len).map(|_| rng_k.gaussian()).collect();
        let dw: Vec<f64> = (0..dot_len).map(|_| rng_k.gaussian()).collect();
        let bvec: Vec<f64> = (0..p).map(|_| rng_k.gaussian()).collect();
        let nnz = (n * p / 10).max(64);
        let gidx: Vec<usize> = (0..nnz).map(|k| (k * 7 + 3) % n).collect();
        let gval: Vec<f64> = (0..nnz).map(|_| rng_k.gaussian()).collect();
        // cache-resident xtv shape (~1 MiB): isolates SIMD throughput from
        // DRAM bandwidth, which bounds the full leukemia-size sweep
        let (n2, p2) = (256usize, 480usize);
        let mut x2 = Mat::zeros(n2, p2);
        for w in x2.as_mut_slice() {
            *w = rng_k.gaussian();
        }
        let v2: Vec<f64> = (0..n2).map(|_| rng_k.gaussian()).collect();
        let mut out2 = vec![0.0; p2];
        for table in kernels::available() {
            let label = table.kind.label();
            let (_, min_xtv) = common::time_it(reps, || {
                (table.xtv)(&xd, &v, &mut out);
                std::hint::black_box(&out);
            });
            let xtv_gflops = 2.0 * n as f64 * p as f64 / min_xtv / 1e9;
            let (_, min_xtv2) = common::time_it(reps, || {
                (table.xtv)(&x2, &v2, &mut out2);
                std::hint::black_box(&out2);
            });
            let xtv_l2_gflops = 2.0 * n2 as f64 * p2 as f64 / min_xtv2 / 1e9;
            bench_metrics.push((format!("xtv_l2_gflops_{label}"), xtv_l2_gflops));
            let (_, min_dot) = common::time_it(reps, || {
                std::hint::black_box((table.dot)(&dv, &dw));
            });
            let dot_gflops = 2.0 * dot_len as f64 / min_dot / 1e9;
            let mut z = vec![0.0; n];
            let (_, min_gemv) = common::time_it(reps, || {
                (table.gemv)(&xd, &bvec, &mut z);
                std::hint::black_box(&z);
            });
            let gemv_gflops = 2.0 * n as f64 * p as f64 / min_gemv / 1e9;
            let (_, min_gather) = common::time_it(reps, || {
                std::hint::black_box((table.gather_dot)(&gidx, &gval, &v));
            });
            let gather_gflops = 2.0 * nnz as f64 / min_gather / 1e9;
            println!(
                "kernel backend {label:>6}: xtv {xtv_gflops:6.2} GFLOP/s \
                 (L2-resident {xtv_l2_gflops:6.2}) | dot {dot_gflops:6.2} \
                 | gemv {gemv_gflops:6.2} | gather {gather_gflops:6.2}"
            );
            bench_metrics.push((format!("xtv_gflops_{label}"), xtv_gflops));
            bench_metrics.push((format!("dot_gflops_{label}"), dot_gflops));
            bench_metrics.push((format!("gemv_gflops_{label}"), gemv_gflops));
            bench_metrics.push((format!("gather_gflops_{label}"), gather_gflops));
            rows.push(vec![format!("xtv_{label}"), String::new(), format!("{min_xtv}")]);
        }
        let find = |key: &str| bench_metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
        if let (Some(s), Some(a)) = (find("xtv_gflops_scalar"), find("xtv_gflops_avx2")) {
            let speedup = a / s;
            bench_metrics.push(("xtv_avx2_speedup".to_string(), speedup));
            println!("kernel engine: AVX2 xtv speedup over scalar (n={n}, p={p}): {speedup:.2}x");
            if speedup < 2.0 && !common::smoke() {
                println!(
                    "WARNING: AVX2 xtv speedup {speedup:.2}x is below the 2x target — \
                     likely a memory-bandwidth-bound host or a noisy shared runner"
                );
            }
        }
        let refs: Vec<(&str, f64)> = bench_metrics.iter().map(|(k, m)| (k.as_str(), *m)).collect();
        common::record_bench_json("kernels", &refs);
    }

    // ---- full gap pass native ---------------------------------------------
    let beta = Mat::zeros(p, 1);
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let lam = 0.1 * prob.lambda_max();
    let (mean, min) = common::time_it(20, || {
        std::hint::black_box(prob.gap_pass(&beta, &z, lam, &active));
    });
    println!("gap pass native (full active): mean {:.3} ms", mean * 1e3);
    rows.push(vec!["gap_native_full".into(), format!("{mean}"), format!("{min}")]);

    // restricted active set (the Sec. 2.2.2 trick)
    let mut restricted = ActiveSet::full(prob.pen.groups());
    for g in 0..prob.n_groups() {
        if g % 20 != 0 {
            restricted.kill_group(prob.pen.groups(), g);
        }
    }
    let (mean, _) = common::time_it(20, || {
        std::hint::black_box(prob.gap_pass(&beta, &z, lam, &restricted));
    });
    println!(
        "gap pass native (5% active):   mean {:.3} ms (active-set trick, Sec. 2.2.2)",
        mean * 1e3
    );
    rows.push(vec!["gap_native_5pct".into(), format!("{mean}"), String::new()]);

    // ---- PJRT gap pass ------------------------------------------------------
    match PjrtEngine::new(std::path::Path::new("artifacts"))
        .and_then(|e| e.bind(&prob, "lasso").map(|exe| (e, exe)))
    {
        Ok((_engine, exe)) => {
            let (mean, min) = common::time_it(10, || {
                std::hint::black_box(exe.gap_pass(&prob, &beta, lam).unwrap());
            });
            println!("gap pass PJRT  (artifact {}): mean {:.3} ms", exe.name(), mean * 1e3);
            rows.push(vec!["gap_pjrt_full".into(), format!("{mean}"), format!("{min}")]);
        }
        Err(e) => println!("PJRT gap pass skipped ({e:#}) — run `make artifacts`"),
    }

    // ---- CD epoch -----------------------------------------------------------
    use gapsafe::screening::NoScreening;
    use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
    let opts = SolveOptions { eps: 0.0, max_epochs: 10, screen_every: 11, ..Default::default() };
    let (mean, _) = common::time_it(5, || {
        let mut r = NoScreening;
        std::hint::black_box(solve_fixed_lambda(&prob, lam, &mut r, &opts));
    });
    println!("10 CD epochs (full active set): mean {:.3} ms", mean * 1e3);
    rows.push(vec!["cd_10_epochs_full".into(), format!("{mean}"), String::new()]);

    // ---- multi-task gap pass (q-fold column traffic) -------------------------
    {
        let ds = synth::meg_like(120, 1500, 10, 3);
        let probm = build_problem(ds, Task::MultiTask).unwrap();
        let b = Mat::zeros(probm.p(), probm.q());
        let z = probm.predict(&b);
        let act = ActiveSet::full(probm.pen.groups());
        let lamm = 0.2 * probm.lambda_max();
        let (mean, _) = common::time_it(10, || {
            std::hint::black_box(probm.gap_pass(&b, &z, lamm, &act));
        });
        println!("gap pass multitask (n=120, p=1500, q=10): mean {:.3} ms", mean * 1e3);
        rows.push(vec!["gap_multitask".into(), format!("{mean}"), String::new()]);
    }

    // ---- SGL gap pass (epsilon-norm heavy) -----------------------------------
    {
        let ds = synth::climate_like(120, 300, 3);
        let probs = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
        let b = Mat::zeros(probs.p(), 1);
        let z = probs.predict(&b);
        let act = ActiveSet::full(probs.pen.groups());
        let lams = 0.2 * probs.lambda_max();
        let (mean, _) = common::time_it(10, || {
            std::hint::black_box(probs.gap_pass(&b, &z, lams, &act));
        });
        println!("gap pass SGL (n=120, 300 groups of 7): mean {:.3} ms", mean * 1e3);
        rows.push(vec!["gap_sgl".into(), format!("{mean}"), String::new()]);
    }

    // ---- epsilon norm --------------------------------------------------------
    let xs: Vec<Vec<f64>> = (0..10_000)
        .map(|i| {
            let mut r = Prng::new(i as u64);
            (0..7).map(|_| r.gaussian()).collect()
        })
        .collect();
    let (mean_sort, _) = common::time_it(10, || {
        let mut acc = 0.0;
        for x in &xs {
            acc += epsilon_norm(x, 0.6);
        }
        std::hint::black_box(acc);
    });
    let (mean_bis, _) = common::time_it(10, || {
        let mut acc = 0.0;
        for x in &xs {
            acc += epsilon_norm_bisect(x, 0.6);
        }
        std::hint::black_box(acc);
    });
    println!(
        "epsilon-norm 10k groups of 7: sorting {:.3} ms vs bisection {:.3} ms ({:.1}x)",
        mean_sort * 1e3,
        mean_bis * 1e3,
        mean_bis / mean_sort
    );
    rows.push(vec!["epsnorm_sort_10k".into(), format!("{mean_sort}"), String::new()]);
    rows.push(vec!["epsnorm_bisect_10k".into(), format!("{mean_bis}"), String::new()]);

    write_csv(
        &common::results_dir().join("kernels_micro.csv"),
        &["kernel", "mean_seconds", "min_seconds"],
        &rows,
    )
    .unwrap();
}
