//! Micro-benchmarks of the numerical hot spots (used by the §Perf pass):
//! correlation kernel X^T v (native vs PJRT artifact), CD epochs,
//! epsilon-norm evaluation (sorting vs bisection), and gap passes.

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::linalg::Mat;
use gapsafe::penalty::epsilon_norm::{epsilon_norm, epsilon_norm_bisect};
use gapsafe::penalty::ActiveSet;
use gapsafe::runtime::PjrtEngine;
use gapsafe::util::prng::Prng;
use gapsafe::util::write_csv;
use gapsafe::{build_problem, Task};

fn main() {
    common::banner("kernels", "hot-spot micro-benchmarks (native + PJRT)");
    let mut rows = Vec::new();

    // ---- X^T v (the screening hot spot) -----------------------------------
    let ds = if common::smoke() {
        synth::leukemia_like_scaled(40, 500, 42, false)
    } else {
        synth::leukemia_like(42, false)
    };
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let (n, p) = (prob.n(), prob.p());
    let mut rng = Prng::new(1);
    let v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let mut out = vec![0.0; p];
    let (mean, min) = common::time_it(20, || {
        prob.x.xtv(&v, &mut out);
        std::hint::black_box(&out);
    });
    let flops = 2.0 * n as f64 * p as f64;
    println!(
        "xtv native     (n={n}, p={p}): mean {:.3} ms  ({:.2} GFLOP/s)",
        mean * 1e3,
        flops / min / 1e9
    );
    rows.push(vec!["xtv_native".into(), format!("{mean}"), format!("{min}")]);

    // ---- full gap pass native ---------------------------------------------
    let beta = Mat::zeros(p, 1);
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let lam = 0.1 * prob.lambda_max();
    let (mean, min) = common::time_it(20, || {
        std::hint::black_box(prob.gap_pass(&beta, &z, lam, &active));
    });
    println!("gap pass native (full active): mean {:.3} ms", mean * 1e3);
    rows.push(vec!["gap_native_full".into(), format!("{mean}"), format!("{min}")]);

    // restricted active set (the Sec. 2.2.2 trick)
    let mut restricted = ActiveSet::full(prob.pen.groups());
    for g in 0..prob.n_groups() {
        if g % 20 != 0 {
            restricted.kill_group(prob.pen.groups(), g);
        }
    }
    let (mean, _) = common::time_it(20, || {
        std::hint::black_box(prob.gap_pass(&beta, &z, lam, &restricted));
    });
    println!(
        "gap pass native (5% active):   mean {:.3} ms (active-set trick, Sec. 2.2.2)",
        mean * 1e3
    );
    rows.push(vec!["gap_native_5pct".into(), format!("{mean}"), String::new()]);

    // ---- PJRT gap pass ------------------------------------------------------
    match PjrtEngine::new(std::path::Path::new("artifacts"))
        .and_then(|e| e.bind(&prob, "lasso").map(|exe| (e, exe)))
    {
        Ok((_engine, exe)) => {
            let (mean, min) = common::time_it(10, || {
                std::hint::black_box(exe.gap_pass(&prob, &beta, lam).unwrap());
            });
            println!("gap pass PJRT  (artifact {}): mean {:.3} ms", exe.name(), mean * 1e3);
            rows.push(vec!["gap_pjrt_full".into(), format!("{mean}"), format!("{min}")]);
        }
        Err(e) => println!("PJRT gap pass skipped ({e:#}) — run `make artifacts`"),
    }

    // ---- CD epoch -----------------------------------------------------------
    use gapsafe::screening::NoScreening;
    use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
    let opts = SolveOptions { eps: 0.0, max_epochs: 10, screen_every: 11, ..Default::default() };
    let (mean, _) = common::time_it(5, || {
        let mut r = NoScreening;
        std::hint::black_box(solve_fixed_lambda(&prob, lam, &mut r, &opts));
    });
    println!("10 CD epochs (full active set): mean {:.3} ms", mean * 1e3);
    rows.push(vec!["cd_10_epochs_full".into(), format!("{mean}"), String::new()]);

    // ---- multi-task gap pass (q-fold column traffic) -------------------------
    {
        let ds = synth::meg_like(120, 1500, 10, 3);
        let probm = build_problem(ds, Task::MultiTask).unwrap();
        let b = Mat::zeros(probm.p(), probm.q());
        let z = probm.predict(&b);
        let act = ActiveSet::full(probm.pen.groups());
        let lamm = 0.2 * probm.lambda_max();
        let (mean, _) = common::time_it(10, || {
            std::hint::black_box(probm.gap_pass(&b, &z, lamm, &act));
        });
        println!("gap pass multitask (n=120, p=1500, q=10): mean {:.3} ms", mean * 1e3);
        rows.push(vec!["gap_multitask".into(), format!("{mean}"), String::new()]);
    }

    // ---- SGL gap pass (epsilon-norm heavy) -----------------------------------
    {
        let ds = synth::climate_like(120, 300, 3);
        let probs = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
        let b = Mat::zeros(probs.p(), 1);
        let z = probs.predict(&b);
        let act = ActiveSet::full(probs.pen.groups());
        let lams = 0.2 * probs.lambda_max();
        let (mean, _) = common::time_it(10, || {
            std::hint::black_box(probs.gap_pass(&b, &z, lams, &act));
        });
        println!("gap pass SGL (n=120, 300 groups of 7): mean {:.3} ms", mean * 1e3);
        rows.push(vec!["gap_sgl".into(), format!("{mean}"), String::new()]);
    }

    // ---- epsilon norm --------------------------------------------------------
    let xs: Vec<Vec<f64>> = (0..10_000)
        .map(|i| {
            let mut r = Prng::new(i as u64);
            (0..7).map(|_| r.gaussian()).collect()
        })
        .collect();
    let (mean_sort, _) = common::time_it(10, || {
        let mut acc = 0.0;
        for x in &xs {
            acc += epsilon_norm(x, 0.6);
        }
        std::hint::black_box(acc);
    });
    let (mean_bis, _) = common::time_it(10, || {
        let mut acc = 0.0;
        for x in &xs {
            acc += epsilon_norm_bisect(x, 0.6);
        }
        std::hint::black_box(acc);
    });
    println!(
        "epsilon-norm 10k groups of 7: sorting {:.3} ms vs bisection {:.3} ms ({:.1}x)",
        mean_sort * 1e3,
        mean_bis * 1e3,
        mean_bis / mean_sort
    );
    rows.push(vec!["epsnorm_sort_10k".into(), format!("{mean_sort}"), String::new()]);
    rows.push(vec!["epsnorm_bisect_10k".into(), format!("{mean_bis}"), String::new()]);

    write_csv(
        &common::results_dir().join("kernels_micro.csv"),
        &["kernel", "mean_seconds", "min_seconds"],
        &rows,
    )
    .unwrap();
}
