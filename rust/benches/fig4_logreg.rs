//! Reproduces Fig. 4: l1 binary logistic regression on the Leukemia-shaped
//! workload. Same two panels as Fig. 3; the paper reports up to 30x
//! (vs sequential) and 50x (vs no screening) speed-ups at tight tolerances.

#[path = "common.rs"]
mod common;

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let full = common::full_size();
    let smoke = common::smoke();
    // n < p logistic data is linearly separable, so solutions blow up at the
    // smallest lambdas of a delta=3 grid; the default (single-core) bench
    // uses delta=2 and a tighter epoch cap — the relative ordering of the
    // strategies is unchanged (the paper's own Fig. 4 runs fixed-iteration
    // budgets for the left panel for the same reason).
    let (ds, n_lambdas, eps_list, delta, cap): (_, usize, Vec<f64>, f64, usize) = if smoke {
        (synth::leukemia_like_scaled(30, 150, 42, true), 8, vec![1e-2], 1.5, 3000)
    } else if full {
        (synth::leukemia_like(42, true), 100, vec![1e-2, 1e-4, 1e-6, 1e-8], 3.0, 50_000)
    } else {
        (
            synth::leukemia_like_scaled(72, 1000, 42, true),
            30,
            vec![1e-2, 1e-4, 1e-6],
            2.0,
            8_000,
        )
    };
    common::banner(
        "fig4_logreg",
        &format!("l1 logistic path on {} ({} lambdas, delta={delta})", ds.name, n_lambdas),
    );
    let prob = build_problem(ds, Task::Logreg).unwrap();

    let budgets: Vec<usize> = (1..=9).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("Fig4-left (Gap Safe dynamic)", &lambdas, &rows);
    report::write_active_fraction_csv(
        &common::results_dir().join("fig4_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    // Regression-only rules are excluded (Remark 9).
    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::StaticGap, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
        (Rule::Strong, WarmStart::Strong),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, cap);
    report::print_timing("Fig4-right", &cells);
    report::write_timing_csv(&common::results_dir().join("fig4_timing.csv"), &cells).unwrap();
}
