//! Observability overhead benchmark: the same `solve_path` run with no
//! trace sink, and again with a JSONL [`FileSink`] installed.
//!
//! The contract (see `gapsafe::obs`): with no sink the entire layer costs
//! one relaxed atomic load per instrumented region — no clock reads, no
//! event construction — so the disabled runs must sit inside the
//! run-to-run noise floor (two independent disabled timings are recorded
//! so the floor itself is visible in the JSON). With a sink installed the
//! run pays for clocks and serialization, but stays bitwise identical:
//! this bench asserts every path beta bit-for-bit before timing anything.
//!
//! Records results/BENCH_obs.json (see docs/BENCHMARKS.md).

#[path = "common.rs"]
mod common;

use gapsafe::data::synth;
use gapsafe::obs;
use gapsafe::obs::trace::FileSink;
use gapsafe::solver::path::{solve_path, PathConfig};
use gapsafe::{build_problem, Task};

fn main() {
    let smoke = common::smoke();
    let full = common::full_size();
    let (n, p) = if smoke {
        (24, 200)
    } else if full {
        (72, 7000)
    } else {
        (48, 2000)
    };
    common::banner(
        "obs",
        "solve_path with tracing disabled vs a JSONL FileSink installed \
         (disabled must be inside the noise floor; enabled must be bitwise identical)",
    );
    let ds = synth::leukemia_like_scaled(n, p, 42, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig {
        n_lambdas: if smoke { 10 } else { 40 },
        delta: 2.5,
        eps: 1e-6,
        max_epochs: 10_000,
        ..Default::default()
    };
    let trace_path =
        std::env::temp_dir().join(format!("gapsafe_bench_obs_{}.jsonl", std::process::id()));
    let trace_str = trace_path.to_string_lossy().to_string();

    // Transparency gate before timing: tracing on/off must not change an
    // output bit anywhere along the path.
    obs::uninstall();
    let base = solve_path(&prob, &cfg);
    obs::install(Box::new(FileSink::create(&trace_str).unwrap()));
    let traced = solve_path(&prob, &cfg);
    obs::uninstall();
    assert_eq!(base.betas.len(), traced.betas.len());
    for (t, (a, b)) in base.betas.iter().zip(&traced.betas).enumerate() {
        for j in 0..a.rows() {
            for c in 0..a.cols() {
                assert_eq!(
                    a[(j, c)].to_bits(),
                    b[(j, c)].to_bits(),
                    "tracing changed beta at lambda {t}, ({j},{c})"
                );
            }
        }
    }
    let events = std::fs::read_to_string(&trace_path)
        .map(|s| s.lines().filter(|l| !l.trim().is_empty()).count())
        .unwrap_or(0);
    println!("bitwise gate passed ({events} events traced)");

    let reps = common::reps(3);
    // Two independent disabled timings: their delta is the measurement
    // noise floor the disabled-path overhead must hide under.
    let (_, t_off_a) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    let (_, t_off_b) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    obs::install(Box::new(FileSink::create(&trace_str).unwrap()));
    let (_, t_on) = common::time_it(reps, || {
        std::hint::black_box(solve_path(&prob, &cfg));
    });
    obs::uninstall();
    let _ = std::fs::remove_file(&trace_path);

    let noise_pct = 100.0 * (t_off_a - t_off_b).abs() / t_off_a.min(t_off_b).max(1e-12);
    let on_pct = 100.0 * (t_on - t_off_a.min(t_off_b)) / t_off_a.min(t_off_b).max(1e-12);
    println!(
        "disabled {t_off_a:.4}s / {t_off_b:.4}s (noise floor {noise_pct:.2}%)  \
         file sink {t_on:.4}s ({on_pct:+.2}% vs best disabled)"
    );
    common::record_bench_json(
        "obs",
        &[
            ("seconds_disabled_a", t_off_a),
            ("seconds_disabled_b", t_off_b),
            ("seconds_file_sink", t_on),
            ("noise_floor_pct", noise_pct),
            ("file_sink_overhead_pct", on_pct),
            ("events_per_path", events as f64),
        ],
    );
}
