//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. Loads the AOT artifacts (L1 Pallas kernel + L2 JAX gap graph lowered
//!    to HLO text by `make artifacts`) through the PJRT runtime.
//! 2. Cross-checks the PJRT gap pass against the native Rust gap pass to
//!    1e-9 relative accuracy on the Leukemia-shaped workload.
//! 3. Runs the full pathwise solver (L3, Alg. 1+2) with the PJRT backend in
//!    the screening loop at the exact Fig. 3 shape (n=72, p=7129), and
//!    reports the paper's headline metric: speed-up of dynamic Gap Safe
//!    (+ active warm start) over no screening at eps = 1e-6.
//!
//! Run: make artifacts && cargo run --release --example e2e_driver

use gapsafe::data::synth;
use gapsafe::penalty::ActiveSet;
use gapsafe::runtime::{GapBackend, PjrtEngine};
use gapsafe::screening::Rule;
use gapsafe::solver::path::{scaled_eps, solve_path, PathConfig, WarmStart};
use gapsafe::solver::SolveOptions;
use gapsafe::util::Stopwatch;
use gapsafe::{build_problem, Task};
use gapsafe::linalg::Mat;

fn main() {
    let artifacts = std::env::var("GAPSAFE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = match PjrtEngine::new(std::path::Path::new(&artifacts)) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot initialise PJRT engine: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("[1/3] PJRT platform: {}", engine.platform());

    // --- Layer check: PJRT vs native gap pass at the Fig. 3 shape --------
    let ds = synth::leukemia_like(42, false);
    println!("      dataset: {}", ds.name);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let exe = engine.bind(&prob, "lasso").expect("bind lasso_leukemia artifact");
    let lam = 0.1 * prob.lambda_max();
    let mut beta = Mat::zeros(prob.p(), 1);
    for j in (0..prob.p()).step_by(997) {
        beta[(j, 0)] = 0.3;
    }
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let native = prob.gap_pass(&beta, &z, lam, &active);
    let sw = Stopwatch::start();
    let pjrt = exe.gap_pass(&prob, &beta, lam).expect("pjrt gap pass");
    let t_pjrt = sw.secs();
    let rel = |a: f64, b: f64| (a - b).abs() / (1.0 + a.abs());
    assert!(rel(native.primal, pjrt.primal) < 1e-9, "primal mismatch");
    assert!(rel(native.dual, pjrt.dual) < 1e-9, "dual mismatch");
    assert!(rel(native.gap, pjrt.gap) < 1e-9, "gap mismatch");
    println!(
        "[2/3] PJRT gap pass == native gap pass (gap = {:.6e}, pjrt exec {:.1} ms)",
        pjrt.gap,
        t_pjrt * 1e3
    );

    // Run a dynamic-screening solve whose gap/screen events go through the
    // PJRT backend (Alg. 2 with the artifact in the loop).
    let backend = GapBackend::Pjrt(exe);
    let opts = SolveOptions { eps: scaled_eps(&prob, 1e-6), ..Default::default() };
    let sw = Stopwatch::start();
    let res = solve_one_with_backend(&prob, lam, &backend, &opts);
    println!(
        "      solve @ lam/lmax=0.1 via {} backend: gap={:.2e} epochs={} active={}/{} in {:.2}s",
        backend.label(),
        res.0,
        res.1,
        res.2,
        prob.p(),
        sw.secs()
    );

    // --- Headline: path speed-up, screening vs none ----------------------
    println!("[3/3] pathwise benchmark (100 lambdas, lmax -> lmax/1e3, eps=1e-6)");
    let mut rows = Vec::new();
    for (rule, warm) in [
        (Rule::None, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
    ] {
        let cfg = PathConfig {
            n_lambdas: 100,
            delta: 3.0,
            rule,
            warm,
            eps: 1e-6,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let res = solve_path(&prob, &cfg);
        let secs = sw.secs();
        println!(
            "      {:<24} {:>8.2}s  (all converged: {})",
            format!("{}+{}", rule.label(), warm.label()),
            secs,
            res.points.iter().all(|p| p.converged)
        );
        rows.push((rule.label(), warm.label(), secs));
    }
    let base = rows[0].2;
    let best = rows.iter().map(|r| r.2).fold(f64::INFINITY, f64::min);
    println!(
        "      headline speed-up (gap safe + active warm start vs no screening): {:.1}x",
        base / best
    );
    gapsafe::util::write_csv(
        std::path::Path::new("results/e2e_driver.csv"),
        &["rule", "warm", "seconds"],
        &rows.iter().map(|r| vec![r.0.into(), r.1.into(), format!("{}", r.2)]).collect::<Vec<_>>(),
    )
    .unwrap();
    println!("e2e driver OK");
}

/// Minimal Alg. 2 loop with a pluggable gap backend (the library solver uses
/// the native path internally; this demonstrates the PJRT one end-to-end).
fn solve_one_with_backend(
    prob: &gapsafe::problem::Problem,
    lam: f64,
    backend: &GapBackend,
    opts: &SolveOptions,
) -> (f64, usize, usize) {
    use gapsafe::screening::{GapSafeRule, GapSafeVariant, ScreeningRule};
    let mut beta = Mat::zeros(prob.p(), 1);
    let mut active = ActiveSet::full(prob.pen.groups());
    let mut rule = GapSafeRule::new(GapSafeVariant::Dynamic);
    let mut gap = f64::INFINITY;
    let mut epochs = 0;
    // plain CD epochs between backend gap passes
    for k in 0..opts.max_epochs {
        if k % opts.screen_every == 0 {
            let z = prob.predict(&beta);
            let res = backend.gap_pass(prob, &beta, &z, lam, &active).expect("gap pass");
            gap = res.gap;
            if gap <= opts.eps {
                break;
            }
            rule.on_gap_pass(prob, lam, &res, &mut active);
        }
        cd_epoch_l1(prob, &mut beta, &active, lam);
        epochs += 1;
    }
    (gap, epochs, active.n_active_feats())
}

/// Textbook Lasso CD epoch (example-local; the library's solver has the
/// production version with residual maintenance).
fn cd_epoch_l1(
    prob: &gapsafe::problem::Problem,
    beta: &mut Mat,
    active: &ActiveSet,
    lam: f64,
) {
    let y: Vec<f64> = prob.fit.targets().as_slice().to_vec();
    let mut z = vec![0.0; prob.n()];
    let bvec: Vec<f64> = (0..prob.p()).map(|j| beta[(j, 0)]).collect();
    prob.x.gemv(&bvec, &mut z);
    let mut rho: Vec<f64> = y.iter().zip(&z).map(|(a, b)| a - b).collect();
    for j in 0..prob.p() {
        if !active.feat[j] {
            continue;
        }
        let l = prob.col_norms_sq[j];
        if l == 0.0 {
            continue;
        }
        let old = beta[(j, 0)];
        let raw = old + prob.x.col_dot(j, &rho) / l;
        let new = gapsafe::linalg::st(raw, lam / l);
        if new != old {
            prob.x.col_axpy(j, old - new, &mut rho);
            beta[(j, 0)] = new;
        }
    }
}
