//! Quickstart: fit a Lasso path with dynamic Gap Safe screening and show
//! the screening benefit on a single lambda.
//!
//! Run: cargo run --release --example quickstart

use gapsafe::prelude::*;
use gapsafe::screening::NoScreening;
use gapsafe::solver::path::scaled_eps;
use gapsafe::util::Stopwatch;

fn main() {
    // 1. A synthetic regression workload (100 samples, 500 features,
    //    20-sparse planted signal). Swap in your own data with
    //    gapsafe::data::io::load_csv.
    let ds = synth::leukemia_like_scaled(100, 500, 42, false);
    println!("dataset: {}", ds.name);

    // 2. Assemble the problem and inspect lambda_max (Prop. 3).
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let lam_max = prob.lambda_max();
    println!("lambda_max = {lam_max:.4e}");

    // 3. Solve one lambda with and without screening.
    let lam = 0.05 * lam_max;
    let opts = SolveOptions {
        eps: scaled_eps(&prob, 1e-8),
        ..Default::default()
    };
    let sw = Stopwatch::start();
    let mut none = NoScreening;
    let base = solve_fixed_lambda(&prob, lam, &mut none, &opts);
    let t_none = sw.secs();

    let sw = Stopwatch::start();
    let mut rule = Rule::GapSafeDyn.build();
    let fast = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    let t_gap = sw.secs();

    println!(
        "no screening : {:>8.4}s  gap={:.2e} epochs={} nnz={}",
        t_none, base.gap, base.epochs, base.beta.nnz()
    );
    println!(
        "gap safe dyn : {:>8.4}s  gap={:.2e} epochs={} nnz={} active={}/{} ({:.1}x)",
        t_gap,
        fast.gap,
        fast.epochs,
        fast.beta.nnz(),
        fast.active.n_active_feats(),
        prob.p(),
        t_none / t_gap.max(1e-12)
    );
    // Safety: both solutions coincide.
    let max_diff = (0..prob.p())
        .map(|j| (base.beta[(j, 0)] - fast.beta[(j, 0)]).abs())
        .fold(0.0_f64, f64::max);
    println!("max |beta_none - beta_gap| = {max_diff:.2e}");
    assert!(max_diff < 1e-6);

    // 4. Full path with active warm start (Alg. 1).
    let cfg = PathConfig {
        n_lambdas: 50,
        delta: 2.0,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Active,
        eps: 1e-6,
        ..Default::default()
    };
    let sw = Stopwatch::start();
    let res = solve_path(&prob, &cfg);
    println!(
        "path: {} lambdas in {:.3}s; support sizes {:?} ...",
        res.points.len(),
        sw.secs(),
        res.points.iter().map(|p| p.nnz_rows).take(10).collect::<Vec<_>>()
    );
}
