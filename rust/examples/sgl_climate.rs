//! Fig. 6 workload as a runnable example: Sparse-Group Lasso on the
//! NCEP/NCAR-like climate dataset (groups of 7 physical variables per grid
//! point), including the tau selection protocol of Sec. 5.4.
//!
//! Run: cargo run --release --example sgl_climate [-- --small]

use gapsafe::coordinator::{
    active_fraction_experiment, cv, report, time_to_convergence,
};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, PathConfig, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let ds = if small {
        synth::climate_like(60, 60, 42)
    } else {
        synth::climate_like(200, 1000, 42)
    };
    println!("dataset: {} (p = {})", ds.name, ds.p());

    // Sec. 5.4 protocol: choose tau on a 50% split.
    let sel_cfg = PathConfig {
        n_lambdas: if small { 8 } else { 15 },
        delta: 2.0,
        rule: Rule::GapSafeFull,
        warm: WarmStart::Standard,
        eps: 1e-4,
        ..Default::default()
    };
    let sel = cv::select_tau_sgl(&ds, &sel_cfg, 7);
    println!("tau selection (50% split): best tau = {}", sel.best_tau);
    for (t, m) in sel.taus.iter().zip(&sel.test_mse) {
        println!("  tau={t:.1}  test MSE={m:.4}");
    }

    // Figure panels at the paper's tau = 0.4 (or the selected one if small).
    let tau = if small { sel.best_tau } else { 0.4 };
    let prob = build_problem(ds, Task::SparseGroupLasso { tau }).unwrap();
    let n_lambdas = if small { 20 } else { 100 };
    let delta = 2.5;

    let budgets: Vec<usize> = (1..=8).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction(
        &format!("SGL tau={tau} / climate-like (feature level)"),
        &lambdas,
        &rows,
    );
    // Fig. 6(b): group-level fractions are in the CSV's frac_groups column.
    report::write_active_fraction_csv(
        std::path::Path::new("results/example_sgl_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    let eps_list = if small { vec![1e-2, 1e-4] } else { vec![1e-2, 1e-4, 1e-6, 1e-8] };
    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::StaticGap, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 10_000);
    report::print_timing(&format!("SGL tau={tau} / climate-like"), &cells);
    report::write_timing_csv(std::path::Path::new("results/example_sgl_timing.csv"), &cells)
        .unwrap();
}
