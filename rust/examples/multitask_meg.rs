//! Fig. 5 workload as a runnable example: multi-task Lasso on the MEG/EEG-
//! like dataset (n = 360, p = 5000, q = 20 time instants by default; the
//! paper's full p = 22494 via --full).
//!
//! Run: cargo run --release --example multitask_meg [-- --small|--full]

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let full = std::env::args().any(|a| a == "--full");
    let ds = if small {
        synth::meg_like(60, 400, 8, 42)
    } else if full {
        synth::meg_like(360, 22_494, 20, 42)
    } else {
        synth::meg_like(360, 5000, 20, 42)
    };
    println!("dataset: {}", ds.name);
    let prob = build_problem(ds, Task::MultiTask).unwrap();
    let n_lambdas = if small { 20 } else { 60 };
    let delta = 2.0;

    let budgets: Vec<usize> = (1..=8).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("multi-task / MEG-like", &lambdas, &rows);
    report::write_active_fraction_csv(
        std::path::Path::new("results/example_meg_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    let eps_list = if small { vec![1e-2, 1e-4] } else { vec![1e-2, 1e-4, 1e-6] };
    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::DynamicBonnefoy, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
    ];
    let cells = time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 10_000);
    report::print_timing("multi-task / MEG-like", &cells);
    report::write_timing_csv(std::path::Path::new("results/example_meg_timing.csv"), &cells)
        .unwrap();
}
