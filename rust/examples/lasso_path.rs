//! Fig. 3 workload as a runnable example: Lasso path on the Leukemia-shaped
//! synthetic dataset (n = 72, p = 7129), comparing screening strategies.
//!
//! Run: cargo run --release --example lasso_path [-- --small]

use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence};
use gapsafe::data::synth;
use gapsafe::screening::Rule;
use gapsafe::solver::path::{lambda_grid, WarmStart};
use gapsafe::{build_problem, Task};

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let ds = if small {
        synth::leukemia_like_scaled(48, 800, 42, false)
    } else {
        synth::leukemia_like(42, false)
    };
    println!("dataset: {}", ds.name);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let n_lambdas = if small { 30 } else { 100 };
    let delta = 3.0;

    // Left panel: fraction of active variables per (K, lambda).
    let budgets: Vec<usize> = (1..=9).map(|e| 1usize << e).collect();
    let rows =
        active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction("Lasso / leukemia-like", &lambdas, &rows);
    report::write_active_fraction_csv(
        std::path::Path::new("results/example_lasso_active_fraction.csv"),
        &lambdas,
        &rows,
    )
    .unwrap();

    // Right panel: path time per strategy and tolerance.
    let eps_list = if small { vec![1e-2, 1e-4, 1e-6] } else { vec![1e-2, 1e-4, 1e-6, 1e-8] };
    let strategies = [
        (Rule::None, WarmStart::Standard),
        (Rule::StaticElGhaoui, WarmStart::Standard),
        (Rule::Dst3, WarmStart::Standard),
        (Rule::GapSafeSeq, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Standard),
        (Rule::GapSafeFull, WarmStart::Active),
        (Rule::Strong, WarmStart::Strong),
    ];
    let cells =
        time_to_convergence(&prob, &strategies, &eps_list, n_lambdas, delta, 20_000);
    report::print_timing("Lasso / leukemia-like", &cells);
    report::write_timing_csv(std::path::Path::new("results/example_lasso_timing.csv"), &cells)
        .unwrap();
}
