//! The parallel engine end to end: chunked path solve, K-fold CV over the
//! pool, and batch serving of many independent path requests.
//!
//! Run: cargo run --release --example parallel_serving [-- --small]

use gapsafe::prelude::*;
use gapsafe::util::Stopwatch;

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let (n, p) = if small { (48, 500) } else { (72, 2000) };
    let cores = effective_threads(0);
    println!("pool: {cores} cores available");

    // 1. Chunked path: same grid, same certificates, more workers.
    let ds = synth::leukemia_like_scaled(n, p, 42, false);
    let prob = build_problem(ds, Task::Lasso).unwrap();
    let cfg = PathConfig { n_lambdas: 60, eps: 1e-6, ..Default::default() };
    let sw = Stopwatch::start();
    let serial = solve_path(&prob, &PathConfig { threads: 1, ..cfg.clone() });
    let t1 = sw.secs();
    let sw = Stopwatch::start();
    let par = solve_path(&prob, &PathConfig { threads: 0, ..cfg.clone() });
    let tp = sw.secs();
    println!(
        "path: serial {t1:.3}s vs {} workers {tp:.3}s ({:.2}x), both converged: {}",
        cores,
        t1 / tp.max(1e-12),
        serial.points.iter().all(|q| q.converged) && par.points.iter().all(|q| q.converged)
    );

    // 2. K-fold CV with folds fanned out (bitwise equal to the serial run).
    let ds = synth::leukemia_like_scaled(n, p / 4, 7, false);
    let cv = CvConfig { folds: 5, seed: 7, threads: 0 };
    let cv_cfg = PathConfig { n_lambdas: 30, eps: 1e-6, ..Default::default() };
    let sw = Stopwatch::start();
    let res = kfold_cv(&ds, Task::Lasso, &cv_cfg, &cv).unwrap();
    println!(
        "cv: best lambda = {:.4e} (index {}/{}) in {:.3}s",
        res.best_lambda,
        res.best_index,
        res.lambdas.len(),
        sw.secs()
    );

    // 3. Batch serving: one runner absorbs independent requests.
    let jobs = 6;
    let requests: Vec<(Problem, PathConfig)> = (0..jobs)
        .map(|s| {
            let ds = synth::leukemia_like_scaled(n, p / 2, 100 + s as u64, false);
            (
                build_problem(ds, Task::Lasso).unwrap(),
                PathConfig { n_lambdas: 30, eps: 1e-6, ..Default::default() },
            )
        })
        .collect();
    let runner = BatchRunner::new(0);
    let sw = Stopwatch::start();
    let results = runner.run(requests);
    let wall = sw.secs();
    let cpu: f64 = results.iter().map(|r| r.total_seconds).sum();
    println!(
        "batch: {jobs} path requests on {} workers in {wall:.3}s wall \
         (sum of per-request solve time {cpu:.3}s, pool efficiency {:.1}x)",
        runner.threads(),
        cpu / wall.max(1e-12)
    );
}
