//! Preprocessing used by the paper's experiments (Sec. 5.4): month-wise
//! centering (seasonality removal), least-squares linear detrending, and
//! unit-variance standardization.
//!
//! Sparse designs are first-class citizens here, not silent no-ops:
//! [`standardize`] scales sparse columns to unit variance *without
//! centering* (centering would densify every column — the standard
//! sparse-regression treatment, as in glmnet's `standardize` on sparse
//! input), and [`deseasonalize_detrend`] refuses sparse designs with an
//! explicit error instead of quietly returning un-processed data.

use super::Dataset;
use crate::linalg::sparse::Design;

/// Remove month-of-year means and the least-squares linear trend from every
/// column (rows are assumed to be consecutive monthly observations, as in
/// the NCEP/NCAR workload).
///
/// Dense designs only: both steps subtract per-row offsets from every
/// column, which turns structural zeros into nonzeros and would densify a
/// sparse design in place. Sparse callers get an explicit error (the
/// historical behavior was to silently skip X and deseasonalize only `y`
/// — a sparse climate workload then ran on raw, seasonal features with no
/// warning).
pub fn deseasonalize_detrend(ds: &mut Dataset) -> Result<(), String> {
    let n = ds.n();
    match &mut ds.x {
        Design::Dense(x) => {
            for j in 0..x.cols() {
                let col = x.col_mut(j);
                // month-wise centering
                for m in 0..12usize {
                    let idx: Vec<usize> = (m..n).step_by(12).collect();
                    if idx.is_empty() {
                        continue;
                    }
                    let mean: f64 = idx.iter().map(|&i| col[i]).sum::<f64>() / idx.len() as f64;
                    for &i in &idx {
                        col[i] -= mean;
                    }
                }
                detrend(col);
            }
        }
        Design::Sparse(_) => {
            return Err(format!(
                "deseasonalize_detrend needs a dense design ({}: month-wise centering and \
                 detrending subtract per-row offsets, which densifies every sparse column); \
                 densify the dataset first",
                ds.name
            ));
        }
    }
    // same treatment for the target
    for k in 0..ds.y.cols() {
        let col = ds.y.col_mut(k);
        for m in 0..12usize {
            let idx: Vec<usize> = (m..n).step_by(12).collect();
            if idx.is_empty() {
                continue;
            }
            let mean: f64 = idx.iter().map(|&i| col[i]).sum::<f64>() / idx.len() as f64;
            for &i in &idx {
                col[i] -= mean;
            }
        }
        detrend(col);
    }
    Ok(())
}

/// Remove the least-squares line a + b*t in place.
fn detrend(col: &mut [f64]) {
    let n = col.len();
    if n < 2 {
        return;
    }
    let tm = (n as f64 - 1.0) / 2.0;
    let mut sty = 0.0;
    let mut stt = 0.0;
    let mean: f64 = col.iter().sum::<f64>() / n as f64;
    for (i, v) in col.iter().enumerate() {
        let t = i as f64 - tm;
        sty += t * (v - mean);
        stt += t * t;
    }
    let slope = if stt > 0.0 { sty / stt } else { 0.0 };
    for (i, v) in col.iter_mut().enumerate() {
        *v -= mean + slope * (i as f64 - tm);
    }
}

/// Standardize every column of X to unit variance and center y.
///
/// * Dense designs: center **and** scale (the classical treatment).
/// * Sparse designs: **scale only** — each column is divided by its
///   standard deviation (computed about the true mean, zeros included),
///   so the variance is exactly 1 while every structural zero stays zero
///   and the nonzero pattern is untouched. Centering is deliberately
///   skipped: subtracting a nonzero mean from a sparse column would
///   materialize all n entries. Columns that are numerically constant
///   (sd below 1e-12 of their own rms — empty and exactly-constant
///   columns included) are left as-is: without centering, dividing by a
///   rounding-residue sd would explode the column rather than degrade
///   gracefully like the dense arm.
pub fn standardize(ds: &mut Dataset) {
    let n = ds.n();
    match &mut ds.x {
        Design::Dense(x) => {
            for j in 0..x.cols() {
                let col = x.col_mut(j);
                let mean: f64 = col.iter().sum::<f64>() / n as f64;
                col.iter_mut().for_each(|v| *v -= mean);
                let sd = (col.iter().map(|v| v * v).sum::<f64>() / n as f64).sqrt();
                if sd > 0.0 {
                    col.iter_mut().for_each(|v| *v /= sd);
                }
            }
        }
        Design::Sparse(x) => {
            for j in 0..x.cols() {
                // Moments over all n rows, visiting only the stored
                // values. The variance is accumulated from *centered*
                // deviations (nonzeros contribute (v - mean)^2, the
                // n - nnz structural zeros contribute mean^2 each) — the
                // E[x^2] - mean^2 shortcut cancels catastrophically on a
                // near-constant column. Even centered, a fully-stored
                // constant column leaves ~ulp rounding residue in `var`
                // (the mean of n equal floats is not exactly the value),
                // and scale-only division by that residue would blow the
                // column up by ~1e15 — unlike the dense arm, which
                // centers first and therefore degrades gracefully. So a
                // column only counts as varying when its sd is
                // meaningfully large *relative to its own magnitude*
                // (rms); below that it is constant for every numerical
                // purpose and is left untouched.
                let (_, vals) = x.col(j);
                let nnz = vals.len();
                let mean = vals.iter().sum::<f64>() / n as f64;
                let dev_sq: f64 = vals.iter().map(|v| (v - mean) * (v - mean)).sum();
                let var = (dev_sq + (n - nnz) as f64 * mean * mean) / n as f64;
                let second_moment = vals.iter().map(|v| v * v).sum::<f64>() / n as f64;
                // sd > 1e-12 * rms — rounding residue sits ~1e-16 * rms.
                if var > second_moment * 1e-24 {
                    let sd = var.sqrt();
                    x.col_values_mut(j).iter_mut().for_each(|v| *v /= sd);
                }
            }
        }
    }
    for k in 0..ds.y.cols() {
        let col = ds.y.col_mut(k);
        let mean: f64 = col.iter().sum::<f64>() / n as f64;
        col.iter_mut().for_each(|v| *v -= mean);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::Csc;
    use crate::linalg::Mat;

    #[test]
    fn detrend_removes_line() {
        let mut v: Vec<f64> = (0..20).map(|i| 3.0 + 0.5 * i as f64).collect();
        detrend(&mut v);
        assert!(v.iter().all(|x| x.abs() < 1e-9), "{v:?}");
    }

    #[test]
    fn deseasonalize_removes_periodic_signal() {
        let n = 48;
        let mut x = Mat::zeros(n, 1);
        for i in 0..n {
            x[(i, 0)] = ((i % 12) as f64) * 2.0 + 0.1 * i as f64;
        }
        let mut ds = Dataset {
            x: Design::Dense(x),
            y: Mat::zeros(n, 1),
            group_size: None,
            name: "t".into(),
        };
        // original signal has average magnitude ~13; after removing the
        // monthly means and the trend only a small staircase-vs-line
        // residual survives (the two components interact).
        let before: f64 = if let Design::Dense(x) = &ds.x {
            x.col(0).iter().map(|v| v.abs()).sum::<f64>() / n as f64
        } else {
            unreachable!()
        };
        deseasonalize_detrend(&mut ds).unwrap();
        if let Design::Dense(x) = &ds.x {
            let resid: f64 = x.col(0).iter().map(|v| v.abs()).sum::<f64>() / n as f64;
            assert!(resid < 0.1 * before, "seasonal residual {resid} vs before {before}");
        }
    }

    #[test]
    fn deseasonalize_rejects_sparse_designs_without_touching_y() {
        // Regression: the sparse arm used to silently skip X (and still
        // deseasonalize y!), leaving the workload half-processed. Now it
        // is an explicit error and the dataset is untouched.
        let x = Csc::from_triplets(24, 2, vec![(0, 3, 1.0), (1, 7, -2.0)]);
        let y: Vec<f64> = (0..24).map(|i| (i % 12) as f64).collect();
        let mut ds = Dataset {
            x: Design::Sparse(x),
            y: Mat::col_vec(&y),
            group_size: None,
            name: "sparse-seasonal".into(),
        };
        let err = deseasonalize_detrend(&mut ds).unwrap_err();
        assert!(err.contains("dense"), "unhelpful error: {err}");
        assert!(err.contains("sparse-seasonal"), "error should name the dataset: {err}");
        // y must not be half-processed on the error path
        assert_eq!(ds.y.col(0), &y[..], "y was mutated despite the error");
    }

    #[test]
    fn standardize_unit_variance() {
        let mut ds = Dataset {
            x: Design::Dense(Mat::from_row_major(4, 1, &[1.0, 2.0, 3.0, 10.0])),
            y: Mat::col_vec(&[5.0, 5.0, 5.0, 5.0]),
            group_size: None,
            name: "t".into(),
        };
        standardize(&mut ds);
        if let Design::Dense(x) = &ds.x {
            let mean: f64 = x.col(0).iter().sum::<f64>() / 4.0;
            let var: f64 = x.col(0).iter().map(|v| v * v).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
        assert!(ds.y.as_slice().iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn standardize_sparse_scales_to_unit_variance_preserving_sparsity() {
        // Regression: the sparse arm used to be a silent no-op. Scale-only
        // standardization must leave the nonzero pattern identical and the
        // per-column variance (about the true mean, zeros included) at 1.
        let trip = vec![
            (0, 0, 3.0),
            (0, 2, -1.0),
            (0, 5, 4.0),
            (1, 1, 2.0),
            (1, 4, 2.0),
            // column 2 stays empty (zero variance — untouched)
        ];
        let x = Csc::from_triplets(6, 3, trip);
        let dense_before = x.to_dense();
        let y: Vec<f64> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut ds = Dataset {
            x: Design::Sparse(x),
            y: Mat::col_vec(&y),
            group_size: None,
            name: "t".into(),
        };
        standardize(&mut ds);
        let Design::Sparse(xs) = &ds.x else { unreachable!() };
        assert_eq!(xs.nnz(), 5, "standardization changed the nonzero count");
        let dense_after = xs.to_dense();
        let n = 6.0;
        for j in 0..2 {
            let dense_col: Vec<f64> = (0..6).map(|i| dense_after[(i, j)]).collect();
            let mean = dense_col.iter().sum::<f64>() / n;
            let var = dense_col.iter().map(|v| v * v).sum::<f64>() / n - mean * mean;
            assert!((var - 1.0).abs() < 1e-12, "col {j} variance {var} != 1");
            // zeros stayed zeros, nonzeros stayed where they were
            for i in 0..6 {
                assert_eq!(
                    dense_before[(i, j)] == 0.0,
                    dense_col[i] == 0.0,
                    "sparsity pattern changed at ({i},{j})"
                );
            }
        }
        // the scale factor is uniform per column: ratios are preserved
        let d = xs.to_dense();
        assert!((d[(0, 0)] / d[(2, 0)] - (3.0 / -1.0)).abs() < 1e-12);
        // empty column untouched
        assert_eq!(xs.col(2).0.len(), 0);
        // y is centered exactly like the dense path
        let ym: f64 = ds.y.as_slice().iter().sum::<f64>() / n;
        assert!(ym.abs() < 1e-12);
    }

    #[test]
    fn standardize_sparse_leaves_constant_columns_untouched() {
        // A fully-stored constant column has variance exactly 0; the
        // naive E[x^2] - mean^2 formula leaves ~ulp cancellation residue
        // that would slip past the `sd > 0` guard and scale the column by
        // ~1e8. The centered accumulation must yield var == 0 exactly.
        let c = 0.1; // non-dyadic on purpose
        let trip: Vec<(usize, usize, f64)> = (0..6).map(|i| (0, i, c)).collect();
        let x = Csc::from_triplets(6, 1, trip);
        let mut ds = Dataset {
            x: Design::Sparse(x),
            y: Mat::col_vec(&[0.0; 6]),
            group_size: None,
            name: "const".into(),
        };
        standardize(&mut ds);
        let Design::Sparse(xs) = &ds.x else { unreachable!() };
        for &v in xs.col(0).1 {
            assert_eq!(v, c, "constant column was rescaled (sd residue slipped through)");
        }
    }

    #[test]
    fn standardize_sparse_matches_dense_scale_factor() {
        // On the same data, the sparse scale-only path must apply exactly
        // the sd the dense path computes (the dense path then also
        // centers; compare variances, which centering does not change).
        let trip = vec![(0, 0, 1.0), (0, 3, 5.0), (1, 2, -2.0), (1, 4, 7.0)];
        let x = Csc::from_triplets(5, 2, trip);
        let dense = x.to_dense();
        let mut sp = Dataset {
            x: Design::Sparse(x),
            y: Mat::col_vec(&[0.0; 5]),
            group_size: None,
            name: "sp".into(),
        };
        let mut de = Dataset {
            x: Design::Dense(dense),
            y: Mat::col_vec(&[0.0; 5]),
            group_size: None,
            name: "de".into(),
        };
        standardize(&mut sp);
        standardize(&mut de);
        let Design::Sparse(xs) = &sp.x else { unreachable!() };
        let Design::Dense(xd) = &de.x else { unreachable!() };
        let sparse_after = xs.to_dense();
        for j in 0..2 {
            let sc: Vec<f64> = (0..5).map(|i| sparse_after[(i, j)]).collect();
            let sparse_mean = sc.iter().sum::<f64>() / 5.0;
            let sparse_var = sc.iter().map(|v| v * v).sum::<f64>() / 5.0 - sparse_mean.powi(2);
            let dense_var = xd.col(j).iter().map(|v| v * v).sum::<f64>() / 5.0;
            assert!(
                (sparse_var - dense_var).abs() < 1e-12,
                "col {j}: sparse var {sparse_var} vs dense var {dense_var}"
            );
        }
    }
}
