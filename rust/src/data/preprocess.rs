//! Preprocessing used by the paper's experiments (Sec. 5.4): month-wise
//! centering (seasonality removal), least-squares linear detrending, and
//! unit-variance standardization.

use super::Dataset;
use crate::linalg::sparse::Design;

/// Remove month-of-year means and the least-squares linear trend from every
/// column (rows are assumed to be consecutive monthly observations, as in
/// the NCEP/NCAR workload).
pub fn deseasonalize_detrend(ds: &mut Dataset) {
    let n = ds.n();
    if let Design::Dense(x) = &mut ds.x {
        for j in 0..x.cols() {
            let col = x.col_mut(j);
            // month-wise centering
            for m in 0..12usize {
                let idx: Vec<usize> = (m..n).step_by(12).collect();
                if idx.is_empty() {
                    continue;
                }
                let mean: f64 = idx.iter().map(|&i| col[i]).sum::<f64>() / idx.len() as f64;
                for &i in &idx {
                    col[i] -= mean;
                }
            }
            detrend(col);
        }
    }
    // same treatment for the target
    for k in 0..ds.y.cols() {
        let col = ds.y.col_mut(k);
        for m in 0..12usize {
            let idx: Vec<usize> = (m..n).step_by(12).collect();
            if idx.is_empty() {
                continue;
            }
            let mean: f64 = idx.iter().map(|&i| col[i]).sum::<f64>() / idx.len() as f64;
            for &i in &idx {
                col[i] -= mean;
            }
        }
        detrend(col);
    }
}

/// Remove the least-squares line a + b*t in place.
fn detrend(col: &mut [f64]) {
    let n = col.len();
    if n < 2 {
        return;
    }
    let tm = (n as f64 - 1.0) / 2.0;
    let mut sty = 0.0;
    let mut stt = 0.0;
    let mean: f64 = col.iter().sum::<f64>() / n as f64;
    for (i, v) in col.iter().enumerate() {
        let t = i as f64 - tm;
        sty += t * (v - mean);
        stt += t * t;
    }
    let slope = if stt > 0.0 { sty / stt } else { 0.0 };
    for (i, v) in col.iter_mut().enumerate() {
        *v -= mean + slope * (i as f64 - tm);
    }
}

/// Center and scale every column of X to unit variance (and center y).
pub fn standardize(ds: &mut Dataset) {
    let n = ds.n();
    if let Design::Dense(x) = &mut ds.x {
        for j in 0..x.cols() {
            let col = x.col_mut(j);
            let mean: f64 = col.iter().sum::<f64>() / n as f64;
            col.iter_mut().for_each(|v| *v -= mean);
            let sd = (col.iter().map(|v| v * v).sum::<f64>() / n as f64).sqrt();
            if sd > 0.0 {
                col.iter_mut().for_each(|v| *v /= sd);
            }
        }
    }
    for k in 0..ds.y.cols() {
        let col = ds.y.col_mut(k);
        let mean: f64 = col.iter().sum::<f64>() / n as f64;
        col.iter_mut().for_each(|v| *v -= mean);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn detrend_removes_line() {
        let mut v: Vec<f64> = (0..20).map(|i| 3.0 + 0.5 * i as f64).collect();
        detrend(&mut v);
        assert!(v.iter().all(|x| x.abs() < 1e-9), "{v:?}");
    }

    #[test]
    fn deseasonalize_removes_periodic_signal() {
        let n = 48;
        let mut x = Mat::zeros(n, 1);
        for i in 0..n {
            x[(i, 0)] = ((i % 12) as f64) * 2.0 + 0.1 * i as f64;
        }
        let mut ds = Dataset {
            x: Design::Dense(x),
            y: Mat::zeros(n, 1),
            group_size: None,
            name: "t".into(),
        };
        // original signal has average magnitude ~13; after removing the
        // monthly means and the trend only a small staircase-vs-line
        // residual survives (the two components interact).
        let before: f64 = if let Design::Dense(x) = &ds.x {
            x.col(0).iter().map(|v| v.abs()).sum::<f64>() / n as f64
        } else {
            unreachable!()
        };
        deseasonalize_detrend(&mut ds);
        if let Design::Dense(x) = &ds.x {
            let resid: f64 = x.col(0).iter().map(|v| v.abs()).sum::<f64>() / n as f64;
            assert!(resid < 0.1 * before, "seasonal residual {resid} vs before {before}");
        }
    }

    #[test]
    fn standardize_unit_variance() {
        let mut ds = Dataset {
            x: Design::Dense(Mat::from_row_major(4, 1, &[1.0, 2.0, 3.0, 10.0])),
            y: Mat::col_vec(&[5.0, 5.0, 5.0, 5.0]),
            group_size: None,
            name: "t".into(),
        };
        standardize(&mut ds);
        if let Design::Dense(x) = &ds.x {
            let mean: f64 = x.col(0).iter().sum::<f64>() / 4.0;
            let var: f64 = x.col(0).iter().map(|v| v * v).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
        assert!(ds.y.as_slice().iter().all(|v| v.abs() < 1e-12));
    }
}
