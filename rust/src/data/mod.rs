//! Datasets: containers, synthetic generators standing in for the paper's
//! workloads (see DESIGN.md §4 Substitutions), preprocessing, and CSV I/O.

pub mod io;
pub mod preprocess;
pub mod synth;

use crate::linalg::sparse::Design;
use crate::linalg::Mat;

/// A supervised dataset: design matrix + targets (+ optional group size).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Design,
    /// Targets: (n, 1) for scalar tasks, (n, q) for multi-task/multinomial.
    pub y: Mat,
    /// Uniform group size when the features have group structure (SGL).
    pub group_size: Option<usize>,
    /// Human-readable provenance for reports.
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    pub fn q(&self) -> usize {
        self.y.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_dims() {
        let d = Dataset {
            x: Design::Dense(Mat::zeros(5, 7)),
            y: Mat::zeros(5, 2),
            group_size: Some(7),
            name: "t".into(),
        };
        assert_eq!((d.n(), d.p(), d.q()), (5, 7, 2));
    }
}
