//! Datasets: containers, synthetic generators standing in for the paper's
//! workloads (see DESIGN.md §4 Substitutions), preprocessing, and CSV I/O.

pub mod io;
pub mod preprocess;
pub mod synth;

use crate::linalg::sparse::Design;
use crate::linalg::Mat;

/// A supervised dataset: design matrix + targets (+ optional group size).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Design,
    /// Targets: (n, 1) for scalar tasks, (n, q) for multi-task/multinomial.
    pub y: Mat,
    /// Uniform group size when the features have group structure (SGL).
    pub group_size: Option<usize>,
    /// Human-readable provenance for reports.
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    pub fn q(&self) -> usize {
        self.y.cols()
    }
}

/// Resolve a dataset spec string to a [`Dataset`] — the shared vocabulary
/// of the CLI (`--data`) and the serving layer (`ModelKey::data`):
///
/// * `synth:leukemia` / `synth:leukemia-binary` — the paper's leukemia
///   shape (scaled down when `small`);
/// * `synth:meg` — the multi-task MEG shape;
/// * `synth:climate` — the SGL climate shape;
/// * `synth:reg:<n>x<p>` — generic correlated regression;
/// * `synth:counts` / `synth:counts:<n>x<p>` — Poisson count data with a
///   sparse log-linear truth;
/// * `csv:<path>` — load from disk.
///
/// Specs are pure functions of `(spec, seed, small)` — two calls with the
/// same triple produce bitwise-identical data, which is what lets the
/// model registry key fitted artifacts on the spec string instead of the
/// data itself.
pub fn load_spec(spec: &str, seed: u64, small: bool) -> Result<Dataset, String> {
    match spec {
        "synth:leukemia" => Ok(if small {
            synth::leukemia_like_scaled(48, 500, seed, false)
        } else {
            synth::leukemia_like(seed, false)
        }),
        "synth:leukemia-binary" => Ok(if small {
            synth::leukemia_like_scaled(48, 500, seed, true)
        } else {
            synth::leukemia_like(seed, true)
        }),
        "synth:meg" => Ok(if small {
            synth::meg_like(60, 400, 8, seed)
        } else {
            synth::meg_like(360, 5000, 20, seed)
        }),
        "synth:climate" => Ok(if small {
            synth::climate_like(60, 100, seed)
        } else {
            synth::climate_like(200, 1000, seed)
        }),
        s if s.starts_with("csv:") => {
            io::load_csv(std::path::Path::new(&s[4..])).map_err(|e| e.to_string())
        }
        s if s.starts_with("synth:reg:") => {
            let (n, p) = parse_reg_dims(s).ok_or("use synth:reg:<n>x<p>")?;
            let cfg = synth::SynthConfig { n, p, k_sparse: 20, corr: 0.5, noise: 0.5, seed };
            Ok(synth::regression(&cfg).0)
        }
        "synth:counts" => Ok(if small {
            synth::poisson_like(60, 300, seed)
        } else {
            synth::poisson_like(500, 3000, seed)
        }),
        s if s.starts_with("synth:counts:") => {
            let (n, p) = parse_counts_dims(s).ok_or("use synth:counts:<n>x<p>")?;
            Ok(synth::poisson_like(n, p, seed))
        }
        other => Err(format!("unknown data spec '{other}'")),
    }
}

/// Parse the `(n, p)` of a `synth:reg:<n>x<p>` spec — the single home of
/// that grammar, shared by [`load_spec`] and the serving layer's request
/// validation. `None` when the spec is not `synth:reg:*` or malformed.
pub fn parse_reg_dims(spec: &str) -> Option<(usize, usize)> {
    let dims = spec.strip_prefix("synth:reg:")?;
    let (n, p) = dims.split_once('x')?;
    Some((n.parse().ok()?, p.parse().ok()?))
}

/// Same grammar for `synth:counts:<n>x<p>`.
pub fn parse_counts_dims(spec: &str) -> Option<(usize, usize)> {
    let dims = spec.strip_prefix("synth:counts:")?;
    let (n, p) = dims.split_once('x')?;
    Some((n.parse().ok()?, p.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_reg_dims_grammar() {
        assert_eq!(parse_reg_dims("synth:reg:10x20"), Some((10, 20)));
        assert_eq!(parse_reg_dims("synth:reg:10"), None);
        assert_eq!(parse_reg_dims("synth:reg:ax2"), None);
        assert_eq!(parse_reg_dims("synth:leukemia"), None);
        assert_eq!(parse_counts_dims("synth:counts:30x40"), Some((30, 40)));
        assert_eq!(parse_counts_dims("synth:counts:30"), None);
        assert_eq!(parse_counts_dims("synth:reg:10x20"), None);
    }

    #[test]
    fn load_spec_counts() {
        let a = load_spec("synth:counts:15x25", 2, false).unwrap();
        assert_eq!((a.n(), a.p(), a.q()), (15, 25, 1));
        let b = load_spec("synth:counts", 2, true).unwrap();
        assert_eq!((b.n(), b.p()), (60, 300));
        assert!(b.y.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn load_spec_is_deterministic() {
        let a = load_spec("synth:reg:10x20", 3, false).unwrap();
        let b = load_spec("synth:reg:10x20", 3, false).unwrap();
        assert_eq!((a.n(), a.p(), a.q()), (10, 20, 1));
        assert_eq!(a.y.as_slice(), b.y.as_slice());
        assert!(load_spec("nope", 0, false).is_err());
    }

    #[test]
    fn dataset_dims() {
        let d = Dataset {
            x: Design::Dense(Mat::zeros(5, 7)),
            y: Mat::zeros(5, 2),
            group_size: Some(7),
            name: "t".into(),
        };
        assert_eq!((d.n(), d.p(), d.q()), (5, 7, 2));
    }
}
