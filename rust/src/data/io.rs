//! Dataset I/O: dense CSV (feature columns then target column(s)) and a
//! binary f64 dump used to hand matrices to external tools.

use super::Dataset;
use crate::linalg::sparse::Design;
use crate::linalg::Mat;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Save a dense dataset as CSV: one row per sample, feature columns then
/// `q` target columns (header encodes the split).
pub fn save_csv(ds: &Dataset, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let x = ds.x.to_dense();
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    let mut header: Vec<String> = (0..ds.p()).map(|j| format!("x{j}")).collect();
    header.extend((0..ds.q()).map(|k| format!("y{k}")));
    writeln!(f, "{}", header.join(","))?;
    for i in 0..ds.n() {
        let mut row: Vec<String> = (0..ds.p()).map(|j| format!("{}", x[(i, j)])).collect();
        row.extend((0..ds.q()).map(|k| format!("{}", ds.y[(i, k)])));
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Load a CSV produced by [`save_csv`] (header mandatory).
pub fn load_csv(path: &Path) -> std::io::Result<Dataset> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut lines = f.lines();
    let header = lines
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "empty csv"))??;
    let cols: Vec<&str> = header.split(',').collect();
    let p = cols.iter().filter(|c| c.starts_with('x')).count();
    let q = cols.iter().filter(|c| c.starts_with('y')).count();
    if p + q != cols.len() || q == 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "header must be x0..x{p-1},y0..y{q-1}",
        ));
    }
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut n = 0usize;
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> = line.split(',').map(|s| s.trim().parse()).collect();
        let vals = vals
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{e}")))?;
        if vals.len() != p + q {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("row {n} has {} cells, want {}", vals.len(), p + q),
            ));
        }
        xs.extend_from_slice(&vals[..p]);
        ys.extend_from_slice(&vals[p..]);
        n += 1;
    }
    // xs is row-major; convert
    let mut x = Mat::zeros(n, p);
    let mut y = Mat::zeros(n, q);
    for i in 0..n {
        for j in 0..p {
            x[(i, j)] = xs[i * p + j];
        }
        for k in 0..q {
            y[(i, k)] = ys[i * q + k];
        }
    }
    Ok(Dataset {
        x: Design::Dense(x),
        y,
        group_size: None,
        name: path.display().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn csv_roundtrip() {
        let ds = synth::leukemia_like_scaled(6, 4, 1, false);
        let dir = std::env::temp_dir().join("gapsafe_io_test");
        let path = dir.join("ds.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!((back.n(), back.p(), back.q()), (6, 4, 1));
        let a = ds.x.to_dense();
        let b = back.x.to_dense();
        for i in 0..6 {
            for j in 0..4 {
                assert!((a[(i, j)] - b[(i, j)]).abs() < 1e-12);
            }
        }
        for i in 0..6 {
            assert!((ds.y[(i, 0)] - back.y[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("gapsafe_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "x0,y0\n1.0\n").unwrap();
        assert!(load_csv(&path).is_err());
    }
}
