//! Synthetic workload generators standing in for the paper's datasets.
//!
//! The paper's experiments use three real datasets we cannot ship on an
//! offline testbed; each generator below reproduces the *structural*
//! properties screening dynamics depend on (shape, correlation, sparsity
//! of the planted signal, preprocessing) — see DESIGN.md §4 for the
//! substitution rationale.

use super::Dataset;
use crate::datafit::sigmoid;
use crate::linalg::sparse::{Csc, Design};
use crate::linalg::Mat;
use crate::util::prng::Prng;

/// Generic sparse-regression generator: X has `rho`-correlated columns
/// (AR(1)-style mixing), beta* is `k`-sparse with +-1/amplitude entries,
/// y = X beta* + sigma * noise.
pub struct SynthConfig {
    pub n: usize,
    pub p: usize,
    pub k_sparse: usize,
    pub corr: f64,
    pub noise: f64,
    pub seed: u64,
}

fn correlated_design(rng: &mut Prng, n: usize, p: usize, corr: f64) -> Mat {
    // AR(1) across columns: X_j = corr * X_{j-1} + sqrt(1-corr^2) * fresh.
    let mut x = Mat::zeros(n, p);
    let root = (1.0 - corr * corr).sqrt();
    for j in 0..p {
        if j == 0 || corr == 0.0 {
            for i in 0..n {
                x[(i, j)] = rng.gaussian();
            }
        } else {
            for i in 0..n {
                x[(i, j)] = corr * x[(i, j - 1)] + root * rng.gaussian();
            }
        }
    }
    x
}

fn standardize_cols(x: &mut Mat) {
    let n = x.rows();
    for j in 0..x.cols() {
        let col = x.col_mut(j);
        let mean: f64 = col.iter().sum::<f64>() / n as f64;
        col.iter_mut().for_each(|v| *v -= mean);
        let sd = (col.iter().map(|v| v * v).sum::<f64>() / n as f64).sqrt();
        if sd > 0.0 {
            col.iter_mut().for_each(|v| *v /= sd);
        }
    }
}

fn planted_beta(rng: &mut Prng, p: usize, k: usize, amp: f64) -> Vec<f64> {
    let mut beta = vec![0.0; p];
    for j in rng.sample_indices(p, k.min(p)) {
        beta[j] = amp * if rng.bernoulli(0.5) { 1.0 } else { -1.0 } * (0.5 + rng.uniform());
    }
    beta
}

/// Plain regression dataset from a config.
pub fn regression(cfg: &SynthConfig) -> (Dataset, Vec<f64>) {
    let mut rng = Prng::new(cfg.seed);
    let mut x = correlated_design(&mut rng, cfg.n, cfg.p, cfg.corr);
    standardize_cols(&mut x);
    let beta = planted_beta(&mut rng, cfg.p, cfg.k_sparse, 1.0);
    let mut y = vec![0.0; cfg.n];
    crate::linalg::gemv(&x, &beta, &mut y);
    for v in y.iter_mut() {
        *v += cfg.noise * rng.gaussian();
    }
    (
        Dataset {
            x: Design::Dense(x),
            y: Mat::col_vec(&y),
            group_size: None,
            name: format!("synth-reg(n={},p={},k={})", cfg.n, cfg.p, cfg.k_sparse),
        },
        beta,
    )
}

/// Leukemia-like workload (Figs. 3-4): dense standardized design of the
/// exact Leukemia shape n = 72, p = 7129 with moderate column correlation
/// and a 20-sparse signal; `binary` converts targets to Bernoulli labels
/// through a logistic link for Fig. 4.
pub fn leukemia_like(seed: u64, binary: bool) -> Dataset {
    leukemia_like_scaled(72, 7129, seed, binary)
}

/// Same generator with adjustable shape (unit tests use small instances).
pub fn leukemia_like_scaled(n: usize, p: usize, seed: u64, binary: bool) -> Dataset {
    let cfg = SynthConfig { n, p, k_sparse: 20.min(p), corr: 0.5, noise: 0.5, seed };
    let (mut ds, _) = regression(&cfg);
    if binary {
        let mut rng = Prng::new(seed ^ 0xBEEF);
        // Normalize the latent score so labels are informative but noisy.
        let scale = {
            let s: f64 = ds.y.as_slice().iter().map(|v| v * v).sum();
            (s / n as f64).sqrt().max(1e-12)
        };
        let y2: Vec<f64> = ds
            .y
            .as_slice()
            .iter()
            .map(|&v| if rng.bernoulli(sigmoid(2.0 * v / scale)) { 1.0 } else { 0.0 })
            .collect();
        ds.y = Mat::col_vec(&y2);
        ds.name = format!("leukemia-like-binary(n={n},p={p})");
    } else {
        ds.name = format!("leukemia-like(n={n},p={p})");
    }
    ds
}

/// MEG/EEG-like multi-task workload (Fig. 5): leadfield-style design with
/// strong local column correlation (sources mix into nearby sensors), a
/// row-sparse coefficient matrix with smooth temporal profiles over the
/// q time instants, Y = X B + noise.
pub fn meg_like(n: usize, p: usize, q: usize, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed);
    let mut x = correlated_design(&mut rng, n, p, 0.7);
    standardize_cols(&mut x);
    // Row-sparse B: a handful of active sources with sinusoidal time courses.
    let k = 15.min(p);
    let mut b = Mat::zeros(p, q);
    for j in rng.sample_indices(p, k) {
        let amp = 1.0 + rng.uniform();
        let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
        let freq = rng.uniform_in(0.5, 2.0);
        for t in 0..q {
            let s = t as f64 / q.max(1) as f64;
            b[(j, t)] = amp * (std::f64::consts::TAU * freq * s + phase).sin();
        }
    }
    let mut y = Mat::zeros(n, q);
    for t in 0..q {
        let bt: Vec<f64> = (0..p).map(|j| b[(j, t)]).collect();
        let mut yt = vec![0.0; n];
        crate::linalg::gemv(&x, &bt, &mut yt);
        for v in yt.iter_mut() {
            *v += 0.3 * rng.gaussian();
        }
        y.col_mut(t).copy_from_slice(&yt);
    }
    Dataset {
        x: Design::Dense(x),
        y,
        group_size: None,
        name: format!("meg-like(n={n},p={p},q={q})"),
    }
}

/// NCEP/NCAR-like climate workload (Fig. 6): `p/7` grid points, each
/// contributing 7 physical variables (the paper's Air Temperature,
/// Precipitable water, Relative humidity, Pressure, Sea Level Pressure and
/// two wind components). Raw series carry seasonality + trend, which
/// `preprocess::deseasonalize_detrend` removes exactly as the paper does;
/// the returned dataset is already preprocessed. Target = linear function
/// of a few predictive groups + noise (group-sparse truth).
pub fn climate_like(n: usize, grid_points: usize, seed: u64) -> Dataset {
    let gs = 7;
    let p = grid_points * gs;
    let mut rng = Prng::new(seed);
    let mut x = Mat::zeros(n, p);
    // Each grid point has a latent climate driver; its 7 variables are noisy
    // affine functions of it, plus month seasonality and a linear trend.
    for gp in 0..grid_points {
        let trend = rng.uniform_in(-0.01, 0.01);
        let season_amp = rng.uniform_in(0.2, 1.0);
        let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
        let mut driver = vec![0.0; n];
        for i in 0..n {
            let month = (i % 12) as f64;
            driver[i] = season_amp * (std::f64::consts::TAU * month / 12.0 + phase).sin()
                + trend * i as f64
                + rng.gaussian();
        }
        for v in 0..gs {
            let jcol = gp * gs + v;
            let mix = rng.uniform_in(0.3, 1.0);
            for i in 0..n {
                x[(i, jcol)] = mix * driver[i] + 0.5 * rng.gaussian();
            }
        }
    }
    // group-sparse signal over a few predictive grid points
    let k_groups = 8.min(grid_points);
    let mut beta = vec![0.0; p];
    for gp in rng.sample_indices(grid_points, k_groups) {
        for v in 0..gs {
            if rng.bernoulli(0.7) {
                beta[gp * gs + v] =
                    (0.5 + rng.uniform()) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            }
        }
    }
    let mut y = vec![0.0; n];
    crate::linalg::gemv(&x, &beta, &mut y);
    for v in y.iter_mut() {
        *v += 0.5 * rng.gaussian();
    }
    let mut ds = Dataset {
        x: Design::Dense(x),
        y: Mat::col_vec(&y),
        group_size: Some(gs),
        name: format!("climate-like(n={n},groups={grid_points})"),
    };
    // The design is dense by construction, so this cannot fail; if it
    // ever could (sparse climate designs), the columns simply stay
    // raw-seasonal and the standardize below still normalizes them.
    let _ = super::preprocess::deseasonalize_detrend(&mut ds);
    super::preprocess::standardize(&mut ds);
    ds
}

/// Multinomial classification workload: q classes, class-dependent sparse
/// score rows.
pub fn multinomial_like(n: usize, p: usize, q: usize, seed: u64) -> (Dataset, Vec<usize>) {
    let mut rng = Prng::new(seed);
    let mut x = correlated_design(&mut rng, n, p, 0.3);
    standardize_cols(&mut x);
    let k = 10.min(p);
    let mut b = Mat::zeros(p, q);
    for j in rng.sample_indices(p, k) {
        for c in 0..q {
            b[(j, c)] = rng.gaussian();
        }
    }
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        // argmax of noisy score
        let mut best = (0usize, f64::NEG_INFINITY);
        for c in 0..q {
            let mut s = 0.3 * rng.gaussian();
            for j in 0..p {
                if b[(j, c)] != 0.0 {
                    s += x[(i, j)] * b[(j, c)];
                }
            }
            if s > best.1 {
                best = (c, s);
            }
        }
        labels.push(best.0);
    }
    let mut y = Mat::zeros(n, q);
    for (i, &l) in labels.iter().enumerate() {
        y[(i, l)] = 1.0;
    }
    (
        Dataset {
            x: Design::Dense(x),
            y,
            group_size: None,
            name: format!("multinomial-like(n={n},p={p},q={q})"),
        },
        labels,
    )
}

/// Draw one count from Poisson(`rate`) by Knuth's product-of-uniforms
/// method (exact for the bounded rates the generator below produces).
fn poisson_draw(rng: &mut Prng, rate: f64) -> f64 {
    let l = (-rate).exp();
    let mut k = 0u64;
    let mut prod = rng.uniform();
    while prod > l {
        prod *= rng.uniform();
        k += 1;
    }
    k as f64
}

/// Sample `y_i ~ Poisson(rate_i)` for a whole rate vector. Rejects
/// non-finite or negative rates loudly instead of producing garbage
/// counts (NaN rates would otherwise sample an infinite loop or zeros).
pub fn poisson_counts(rng: &mut Prng, rates: &[f64]) -> Vec<f64> {
    for (i, r) in rates.iter().enumerate() {
        assert!(
            r.is_finite() && *r >= 0.0,
            "poisson rate[{i}] = {r}: rates must be finite and >= 0"
        );
    }
    rates.iter().map(|&r| poisson_draw(rng, r)).collect()
}

/// Count-data workload for the Poisson/KL fit: correlated standardized
/// design, `k`-sparse planted signal, rates `exp(latent)` with the latent
/// score clamped so the rates stay bounded (the screening dynamics only
/// need a sparse log-linear truth, not heavy tails).
pub fn poisson_like(n: usize, p: usize, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed);
    let mut x = correlated_design(&mut rng, n, p, 0.5);
    standardize_cols(&mut x);
    let beta = planted_beta(&mut rng, p, 10.min(p), 1.0);
    let mut z = vec![0.0; n];
    crate::linalg::gemv(&x, &beta, &mut z);
    let rms = (z.iter().map(|v| v * v).sum::<f64>() / n as f64).sqrt().max(1e-12);
    let rates: Vec<f64> =
        z.iter().map(|&v| (0.3 + (0.8 * v / rms).clamp(-3.0, 3.0)).exp()).collect();
    let y = poisson_counts(&mut rng, &rates);
    Dataset {
        x: Design::Dense(x),
        y: Mat::col_vec(&y),
        group_size: None,
        name: format!("poisson-like(n={n},p={p})"),
    }
}

/// Sparse bag-of-words-like design (CSC) for the sparse-matrix code path.
pub fn sparse_regression(n: usize, p: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed);
    let mut trip = Vec::new();
    for j in 0..p {
        for i in 0..n {
            if rng.bernoulli(density) {
                trip.push((j, i, rng.uniform_in(0.5, 2.0)));
            }
        }
    }
    let x = Csc::from_triplets(n, p, trip);
    let beta = planted_beta(&mut rng, p, 10.min(p), 1.0);
    let mut y = vec![0.0; n];
    for j in 0..p {
        if beta[j] != 0.0 {
            x.col_axpy(j, beta[j], &mut y);
        }
    }
    for v in y.iter_mut() {
        *v += 0.2 * rng.gaussian();
    }
    Dataset {
        x: Design::Sparse(x),
        y: Mat::col_vec(&y),
        group_size: None,
        name: format!("sparse-bow(n={n},p={p},density={density})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_shapes_and_standardization() {
        let cfg = SynthConfig { n: 30, p: 50, k_sparse: 5, corr: 0.5, noise: 0.1, seed: 1 };
        let (ds, beta) = regression(&cfg);
        assert_eq!((ds.n(), ds.p()), (30, 50));
        assert_eq!(beta.iter().filter(|&&b| b != 0.0).count(), 5);
        // standardized: unit column norms / sqrt(n)
        if let Design::Dense(x) = &ds.x {
            for j in 0..50 {
                let nsq: f64 = x.col(j).iter().map(|v| v * v).sum();
                assert!((nsq / 30.0 - 1.0).abs() < 1e-9);
                let mean: f64 = x.col(j).iter().sum::<f64>() / 30.0;
                assert!(mean.abs() < 1e-9);
            }
        }
    }

    #[test]
    fn leukemia_binary_labels() {
        let ds = leukemia_like_scaled(20, 60, 7, true);
        assert!(ds.y.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = ds.y.as_slice().iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 0 && ones < 20, "degenerate labels");
    }

    #[test]
    fn meg_like_rows() {
        let ds = meg_like(12, 30, 5, 3);
        assert_eq!((ds.n(), ds.p(), ds.q()), (12, 30, 5));
    }

    #[test]
    fn climate_like_grouped_and_preprocessed() {
        let ds = climate_like(48, 10, 5);
        assert_eq!(ds.group_size, Some(7));
        assert_eq!(ds.p(), 70);
        // preprocessing left unit variance
        if let Design::Dense(x) = &ds.x {
            for j in 0..ds.p() {
                let var: f64 = x.col(j).iter().map(|v| v * v).sum::<f64>() / 48.0;
                assert!((var - 1.0).abs() < 1e-6, "col {j} var {var}");
            }
        }
    }

    #[test]
    fn multinomial_labels_in_range() {
        let (ds, labels) = multinomial_like(25, 12, 4, 9);
        assert_eq!(ds.q(), 4);
        assert!(labels.iter().all(|&l| l < 4));
        // one-hot rows
        for i in 0..25 {
            let s: f64 = (0..4).map(|k| ds.y[(i, k)]).sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn poisson_like_counts_are_nonneg_integers() {
        let ds = poisson_like(40, 25, 5);
        assert_eq!((ds.n(), ds.p(), ds.q()), (40, 25, 1));
        for &v in ds.y.as_slice() {
            assert!(v >= 0.0 && v.fract() == 0.0, "not a count: {v}");
        }
        let total: f64 = ds.y.as_slice().iter().sum();
        assert!(total > 0.0, "degenerate all-zero counts");
        let b = poisson_like(40, 25, 5);
        assert_eq!(ds.y.as_slice(), b.y.as_slice());
    }

    #[test]
    fn poisson_counts_match_rates_on_average() {
        let mut rng = Prng::new(17);
        let rates = vec![4.0; 4000];
        let y = poisson_counts(&mut rng, &rates);
        let mean: f64 = y.iter().sum::<f64>() / y.len() as f64;
        assert!((mean - 4.0).abs() < 0.2, "mean {mean} far from rate 4");
    }

    #[test]
    #[should_panic(expected = "rates must be finite")]
    fn poisson_counts_reject_negative_rates() {
        let mut rng = Prng::new(1);
        poisson_counts(&mut rng, &[1.0, -0.5]);
    }

    #[test]
    #[should_panic(expected = "rates must be finite")]
    fn poisson_counts_reject_nan_rates() {
        let mut rng = Prng::new(1);
        poisson_counts(&mut rng, &[f64::NAN]);
    }

    #[test]
    fn sparse_regression_is_sparse() {
        let ds = sparse_regression(20, 40, 0.1, 11);
        if let Design::Sparse(s) = &ds.x {
            assert!(s.nnz() < 20 * 40 / 2);
        } else {
            panic!("expected sparse design");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = leukemia_like_scaled(10, 20, 42, false);
        let b = leukemia_like_scaled(10, 20, 42, false);
        assert_eq!(a.y.as_slice(), b.y.as_slice());
    }
}
