//! `gapsafe audit` — static enforcement of the repo's reproducibility,
//! containment, and no-panic contracts (zero dependencies, std-only).
//!
//! The Gap Safe guarantee (a screening rule may never wrongly discard a
//! variable) and this repo's stronger bitwise-transparency contracts are
//! enforced at runtime by parity tests — but a parity test only fails
//! *after* someone has introduced the drift. This module rejects the
//! drift at the source level: a hand-rolled lexer ([`lexer`]) feeds an
//! item parser ([`parser`]) and a conservative crate-wide call graph
//! ([`callgraph`]); seven per-file lints ([`lints`]) and two
//! call-graph-aware lints ([`flow`]) walk every file under `rust/src/`.
//!
//! # Lints
//!
//! The registry is the single [`lints::LINTS`] table; the nine entries:
//!
//! | lint | contract |
//! |---|---|
//! | `float-determinism` | no `mul_add`/FMA/libm shortcuts outside `linalg/kernels/` |
//! | `simd-containment` | intrinsics only in `kernels/avx2.rs`, inside `#[target_feature]` fns |
//! | `trace-transparency` | clock reads in solver code must be tracing-guarded |
//! | `unsafe-hygiene` | every `unsafe` carries `// SAFETY:` and lives in an allowlisted module |
//! | `determinism` | no `HashMap`/`HashSet` in `solver/`, `screening/`, `problem.rs` |
//! | `serve-no-panic` | no `unwrap`/`expect`/`panic!` in `serve/` itself |
//! | `screening-soundness` | radius math outside `datafit/` routes through `DataFit::gap_safe_radius` |
//! | `panic-reachability` | no panic-family call transitively reachable from a `serve/` entry point |
//! | `lock-order` | the global lock-acquisition-order graph stays acyclic |
//!
//! Reports render as text, compact JSON, or SARIF 2.1.0
//! (`gapsafe audit --format sarif`), and `--lint a,b` narrows a run to
//! named lints.
//!
//! # Suppression
//!
//! A finding is suppressed by a pragma comment on the same line or the
//! line directly above:
//!
//! ```text
//! // audit-allow(determinism): keyed lookup only, never iterated
//! ```
//!
//! The reason after the colon is mandatory; a pragma without one (or
//! naming an unknown lint) is itself reported as `audit-pragma` and
//! cannot be suppressed. `docs/ANALYSIS.md` has the full catalogue,
//! rationale, and the dynamic-analysis legs (TSan, Miri) that cover what
//! a lexer cannot see.

pub mod callgraph;
pub mod flow;
pub mod lexer;
pub mod lints;
pub mod parser;

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One audit finding, pinned to a file and line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the audited source root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Lint name (one of [`lints::LINT_NAMES`] or `audit-pragma`).
    pub lint: &'static str,
    pub message: String,
    /// True when an `audit-allow` pragma covers this finding.
    pub suppressed: bool,
}

/// Result of auditing a tree: every finding plus the file count.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
}

impl Report {
    pub fn suppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    pub fn unsuppressed(&self) -> usize {
        self.findings.len() - self.suppressed()
    }

    /// Machine-readable report (`gapsafe audit --format json`). Keys are
    /// sorted and the serialisation is compact, so CI can grep
    /// `"unsuppressed":0` as a hard gate.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj([
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("lint", Json::Str(f.lint.to_string())),
                    ("message", Json::Str(f.message.clone())),
                    ("suppressed", Json::Bool(f.suppressed)),
                ])
            })
            .collect();
        Json::obj([
            ("files", Json::Num(self.files as f64)),
            ("findings", Json::Arr(findings)),
            ("suppressed", Json::Num(self.suppressed() as f64)),
            ("unsuppressed", Json::Num(self.unsuppressed() as f64)),
        ])
    }

    /// SARIF 2.1.0 report (`gapsafe audit --format sarif`): one run,
    /// rule metadata straight from the [`lints::LINTS`] registry, one
    /// result per finding, suppressed findings carried as
    /// `suppressions: [{kind: "inSource"}]` so SARIF viewers show them
    /// greyed out instead of dropping them.
    pub fn to_sarif(&self) -> Json {
        let mut rules: Vec<Json> = lints::LINTS
            .iter()
            .map(|l| {
                Json::obj([
                    ("id", Json::Str(l.name.to_string())),
                    ("shortDescription", Json::obj([("text", Json::Str(l.summary.to_string()))])),
                ])
            })
            .collect();
        rules.push(Json::obj([
            ("id", Json::Str("audit-pragma".to_string())),
            (
                "shortDescription",
                Json::obj([(
                    "text",
                    Json::Str("audit-allow pragmas must name a known lint and carry a reason".to_string()),
                )]),
            ),
        ]));
        let results: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let location = Json::obj([(
                    "physicalLocation",
                    Json::obj([
                        ("artifactLocation", Json::obj([("uri", Json::Str(f.file.clone()))])),
                        ("region", Json::obj([("startLine", Json::Num(f.line as f64))])),
                    ]),
                )]);
                let mut fields: Vec<(&str, Json)> = vec![
                    ("level", Json::Str("error".to_string())),
                    ("locations", Json::Arr(vec![location])),
                    ("message", Json::obj([("text", Json::Str(f.message.clone()))])),
                    ("ruleId", Json::Str(f.lint.to_string())),
                ];
                if f.suppressed {
                    fields.push((
                        "suppressions",
                        Json::Arr(vec![Json::obj([("kind", Json::Str("inSource".to_string()))])]),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        let driver = Json::obj([
            ("name", Json::Str("gapsafe-audit".to_string())),
            ("rules", Json::Arr(rules)),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
        ]);
        Json::obj([
            (
                "$schema",
                Json::Str(
                    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json"
                        .to_string(),
                ),
            ),
            (
                "runs",
                Json::Arr(vec![Json::obj([
                    ("results", Json::Arr(results)),
                    ("tool", Json::obj([("driver", driver)])),
                ])]),
            ),
            ("version", Json::Str("2.1.0".to_string())),
        ])
    }

    /// Keep only findings of the named lints (`--lint a,b`).
    /// `audit-pragma` findings always survive: a malformed pragma must
    /// not become invisible just because its lint was filtered out.
    pub fn retain_lints(&mut self, names: &[String]) {
        self.findings
            .retain(|f| f.lint == "audit-pragma" || names.iter().any(|n| n == f.lint));
    }

    /// Human-readable report (the default `gapsafe audit` output).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let tag = if f.suppressed { " [suppressed]" } else { "" };
            s.push_str(&format!("{}:{}: {}: {}{}\n", f.file, f.line, f.lint, f.message, tag));
        }
        s.push_str(&format!(
            "audit: {} file(s), {} finding(s), {} unsuppressed\n",
            self.files,
            self.findings.len(),
            self.unsuppressed()
        ));
        s
    }
}

/// Audit one file's source in isolation. `rel` is its path relative to
/// the source root with `/` separators — the lint scopes key off it.
/// Cross-file lints see a one-file crate, which is exactly what the
/// fixture tests want; real runs go through [`audit_sources`] /
/// [`audit_tree`].
pub fn audit_source(rel: &str, src: &str) -> Vec<Finding> {
    audit_sources(&[(rel.to_string(), src.to_string())]).findings
}

/// Audit a set of files as one crate: per-file lints on each file, then
/// the call-graph lints across all of them, then pragma validation and
/// suppression. Findings are sorted by (file, line, lint).
pub fn audit_sources(files: &[(String, String)]) -> Report {
    let parsed: Vec<parser::ParsedFile> =
        files.iter().map(|(rel, src)| parser::parse(rel, src)).collect();
    let mut findings = Vec::new();
    for pf in &parsed {
        findings.extend(lints::run(&pf.rel, &pf.lexed));
    }
    let graph = callgraph::CallGraph::build(&parsed);
    findings.extend(flow::run(&parsed, &graph));

    // Validate pragmas per file: `audit-allow(<lint>): <reason>` must
    // name a known lint and carry a non-empty reason. A valid pragma on
    // line L suppresses findings of its lint (from any lint layer) on
    // line L (trailing comment) or L + 1 (comment above) of that file.
    for pf in &parsed {
        let mut pragmas: Vec<(u32, String)> = Vec::new();
        for c in &pf.lexed.comments {
            let Some(pos) = c.text.find("audit-allow(") else { continue };
            let rest = &c.text[pos + "audit-allow(".len()..];
            let Some(close) = rest.find(')') else {
                findings.push(Finding {
                    file: pf.rel.clone(),
                    line: c.line,
                    lint: "audit-pragma",
                    message: "malformed audit-allow pragma: missing ')'".to_string(),
                    suppressed: false,
                });
                continue;
            };
            let name = rest[..close].trim().to_string();
            let after = rest[close + 1..].trim_start();
            let reason_ok = after.starts_with(':') && !after[1..].trim().is_empty();
            if !lints::LINT_NAMES.contains(&name.as_str()) {
                findings.push(Finding {
                    file: pf.rel.clone(),
                    line: c.line,
                    lint: "audit-pragma",
                    message: format!("audit-allow names unknown lint `{name}`"),
                    suppressed: false,
                });
            } else if !reason_ok {
                findings.push(Finding {
                    file: pf.rel.clone(),
                    line: c.line,
                    lint: "audit-pragma",
                    message: format!("audit-allow({name}) needs a `: <reason>`"),
                    suppressed: false,
                });
            } else {
                pragmas.push((c.line, name));
            }
        }
        for f in &mut findings {
            if f.lint == "audit-pragma" || f.file != pf.rel {
                continue;
            }
            if pragmas.iter().any(|(l, name)| name == f.lint && (*l == f.line || *l + 1 == f.line))
            {
                f.suppressed = true;
            }
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    Report { findings, files: files.len() }
}

/// Audit every `.rs` file under `root` (deterministic sorted walk).
pub fn audit_tree(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)
        .map_err(|e| format!("audit: cannot walk {}: {e}", root.display()))?;
    files.sort();
    let mut sources: Vec<(String, String)> = Vec::with_capacity(files.len());
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("audit: cannot read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        sources.push((rel, src));
    }
    Ok(audit_sources(&sources))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(rel: &str, src: &str, lint: &str) -> Vec<Finding> {
        audit_source(rel, src).into_iter().filter(|f| f.lint == lint).collect()
    }

    // --- one fixture per lint: a hit, and an audit-allow suppression ---

    #[test]
    fn float_determinism_fires_and_suppresses() {
        let bad = "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }";
        let got = hits("solver/mod.rs", bad, "float-determinism");
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(!got[0].suppressed);
        assert_eq!(got[0].line, 1);

        let ok = "// audit-allow(float-determinism): documented exception\n\
                  fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }";
        let got = hits("solver/mod.rs", ok, "float-determinism");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // allowed inside the kernel engine
        assert!(hits("linalg/kernels/scalar.rs", bad, "float-determinism").is_empty());
    }

    #[test]
    fn fma_intrinsics_forbidden_even_in_kernels() {
        let bad = "fn f() { let x = _mm256_fmadd_pd(a, b, c); }";
        let got = hits("linalg/kernels/avx2.rs", bad, "float-determinism");
        assert_eq!(got.len(), 1, "{got:?}");
    }

    #[test]
    fn simd_containment_fires_and_suppresses() {
        let bad = "fn f() { let v = _mm256_setzero_pd(); }";
        let got = hits("solver/mod.rs", bad, "simd-containment");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn f() {\n    // audit-allow(simd-containment): migration shim\n    let v = _mm256_setzero_pd();\n}";
        let got = hits("solver/mod.rs", ok, "simd-containment");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // in avx2.rs an intrinsic requires #[target_feature] on the fn
        let ungated = "fn f() { let v = _mm256_setzero_pd(); }";
        let got = hits("linalg/kernels/avx2.rs", ungated, "simd-containment");
        assert_eq!(got.len(), 1, "{got:?}");
        let gated = "#[target_feature(enable = \"avx2\")]\nunsafe fn f() { let v = _mm256_setzero_pd(); }";
        assert!(hits("linalg/kernels/avx2.rs", gated, "simd-containment").is_empty());
        // item-level use imports are fine
        let import = "use std::arch::x86_64::{_mm256_setzero_pd};";
        assert!(hits("linalg/kernels/avx2.rs", import, "simd-containment").is_empty());
    }

    #[test]
    fn trace_transparency_fires_and_suppresses() {
        let bad = "fn f() { let t0 = Instant::now(); }";
        let got = hits("solver/mod.rs", bad, "trace-transparency");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn f() { let t0 = Instant::now(); // audit-allow(trace-transparency): coarse span\n}";
        let got = hits("solver/mod.rs", ok, "trace-transparency");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // the sanctioned guard shapes pass
        let guarded = "fn f() { let t0 = tracing.then(Instant::now); }";
        assert!(hits("solver/mod.rs", guarded, "trace-transparency").is_empty());
        let guarded2 = "fn f() { let t0 = crate::obs::enabled().then(Instant::now); }";
        assert!(hits("solver/mod.rs", guarded2, "trace-transparency").is_empty());
        let import = "use std::time::Instant;\nfn noop() {}";
        assert!(hits("solver/mod.rs", import, "trace-transparency").is_empty());
        // obs/, serve/ and util/ own clocks by contract
        assert!(hits("obs/trace.rs", bad, "trace-transparency").is_empty());
        assert!(hits("serve/http.rs", bad, "trace-transparency").is_empty());
    }

    #[test]
    fn unsafe_hygiene_fires_and_suppresses() {
        let bad = "fn f(p: *const f64) -> f64 { unsafe { *p } }";
        let got = hits("solver/mod.rs", bad, "unsafe-hygiene");
        // outside the allowlist AND missing // SAFETY:
        assert_eq!(got.len(), 2, "{got:?}");

        let ok = "// audit-allow(unsafe-hygiene): FFI shim pending rework\n\
                  fn f(p: *const f64) -> f64 { unsafe { *p } }";
        let got = hits("solver/mod.rs", ok, "unsafe-hygiene");
        assert!(got.iter().all(|f| f.suppressed), "{got:?}");

        // in an allowlisted module with a SAFETY comment: clean
        let clean = "fn f(p: *const f64) -> f64 {\n    // SAFETY: p is valid per caller contract\n    unsafe { *p }\n}";
        assert!(hits("linalg/kernels/avx2.rs", clean, "unsafe-hygiene").is_empty());
        // allowlisted but uncommented still fires the comment check
        let nocomment = "fn f(p: *const f64) -> f64 { unsafe { *p } }";
        assert_eq!(hits("obs/mod.rs", nocomment, "unsafe-hygiene").len(), 1);
    }

    #[test]
    fn determinism_fires_and_suppresses() {
        let bad = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, f64> = HashMap::new(); }";
        let got = hits("screening/mod.rs", bad, "determinism");
        assert_eq!(got.len(), 3, "{got:?}"); // use + type + ctor

        let ok = "fn f() {\n    // audit-allow(determinism): keyed lookups only, never iterated\n    let m: HashMap<u32, f64> = HashMap::new();\n}";
        let got = hits("problem.rs", ok, "determinism");
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|f| f.suppressed));

        // fine outside float-order-sensitive modules
        assert!(hits("serve/jobs.rs", bad, "determinism").is_empty());
    }

    #[test]
    fn serve_no_panic_fires_and_suppresses() {
        let bad = "fn handler(req: &Request) -> Response { req.body.parse().unwrap() }";
        let got = hits("serve/http.rs", bad, "serve-no-panic");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn handler() {\n    // audit-allow(serve-no-panic): startup-only path, no client data\n    let x: u32 = \"7\".parse().unwrap();\n}";
        let got = hits("serve/mod.rs", ok, "serve-no-panic");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        let macros = "fn h() { panic!(\"boom\"); unreachable!() }";
        assert_eq!(hits("serve/registry.rs", macros, "serve-no-panic").len(), 2);
        // unwrap in non-serve code is out of scope
        assert!(hits("solver/mod.rs", bad, "serve-no-panic").is_empty());
        // field access `.expect` without call parens is not flagged,
        // and neither is test code
        let test_code = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}";
        assert!(hits("serve/http.rs", test_code, "serve-no-panic").is_empty());
    }

    // --- engine-level behaviors ---

    #[test]
    fn test_code_is_exempt_from_all_lints() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let t0 = Instant::now(); let m = HashMap::new(); x.unwrap(); }\n}";
        assert!(audit_source("solver/mod.rs", src).is_empty());
        assert!(audit_source("serve/mod.rs", src).is_empty());
    }

    #[test]
    fn pragma_requires_known_lint_and_reason() {
        let unknown = "// audit-allow(no-such-lint): whatever\nfn f() {}";
        let got = hits("solver/mod.rs", unknown, "audit-pragma");
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("unknown lint"));

        let no_reason = "// audit-allow(determinism)\nfn f() {}";
        let got = hits("solver/mod.rs", no_reason, "audit-pragma");
        assert_eq!(got.len(), 1);
        assert!(got[0].message.contains("reason"));

        // a malformed pragma does not suppress
        let src = "// audit-allow(determinism)\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        let det = hits("solver/mod.rs", src, "determinism");
        assert!(det.iter().all(|f| !f.suppressed), "{det:?}");
    }

    #[test]
    fn pragma_on_wrong_line_does_not_suppress() {
        let src = "// audit-allow(determinism): too far away\n\nfn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n}";
        let det = hits("solver/mod.rs", src, "determinism");
        assert!(det.iter().all(|f| !f.suppressed), "{det:?}");
    }

    #[test]
    fn report_counts_and_json_shape() {
        let report = Report {
            files: 2,
            findings: audit_source("solver/mod.rs", "fn f() { let t0 = Instant::now(); }\n"),
        };
        assert_eq!(report.unsuppressed(), 1);
        let js = report.to_json().to_string();
        assert!(js.contains("\"unsuppressed\":1"), "{js}");
        assert!(js.contains("\"lint\":\"trace-transparency\""), "{js}");
        let text = report.render_text();
        assert!(text.contains("solver/mod.rs:1: trace-transparency"), "{text}");
    }

    #[test]
    fn findings_are_sorted_and_deterministic() {
        let src = "fn a() { let t0 = Instant::now(); }\nfn b() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let f1 = audit_source("solver/mod.rs", src);
        let f2 = audit_source("solver/mod.rs", src);
        let lines1: Vec<_> = f1.iter().map(|f| (f.line, f.lint)).collect();
        let lines2: Vec<_> = f2.iter().map(|f| (f.line, f.lint)).collect();
        assert_eq!(lines1, lines2);
        assert!(lines1.windows(2).all(|w| w[0] <= w[1]), "{lines1:?}");
    }

    #[test]
    fn screening_soundness_fires_and_suppresses() {
        // the sqrt-bearing form
        let bad = "fn radius(gap: f64, lam: f64) -> f64 { (2.0 * gap / 3.0).sqrt() / lam }";
        let got = hits("screening/mod.rs", bad, "screening-soundness");
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(!got[0].suppressed);

        let ok = "// audit-allow(screening-soundness): reference impl for the parity test\n\
                  fn radius(gap: f64, lam: f64) -> f64 { (2.0 * gap / 3.0).sqrt() / lam }";
        let got = hits("screening/mod.rs", ok, "screening-soundness");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // the staged form without a sqrt in the same statement
        let staged = "fn f(gap: f64, g: f64) { let r2 = 2.0 * gap / g; use_it(r2); }";
        assert_eq!(hits("solver/mod.rs", staged, "screening-soundness").len(), 1);

        // routed through the trait: clean
        let routed = "fn f(prob: &P) -> f64 { prob.fit.gap_safe_radius(gap, lam, &theta) }";
        assert!(hits("screening/gap_safe.rs", routed, "screening-soundness").is_empty());
        // sqrt without a gap operand: clean
        let norm = "fn f(x: &[f64]) -> f64 { x.iter().map(|v| v * v).sum::<f64>().sqrt() }";
        assert!(hits("solver/mod.rs", norm, "screening-soundness").is_empty());
        // the datafit owns the formula
        assert!(hits("datafit/poisson.rs", bad, "screening-soundness").is_empty());
        // out-of-scope modules are exempt
        assert!(hits("obs/trace.rs", bad, "screening-soundness").is_empty());
    }

    #[test]
    fn cross_file_lints_run_and_suppress_through_audit_sources() {
        let serve = ("serve/http.rs".to_string(), "pub fn handle() { crate::solver::solve(); }".to_string());
        let solver = (
            "solver/mod.rs".to_string(),
            "pub fn solve() { x.unwrap(); }".to_string(),
        );
        let report = audit_sources(&[serve.clone(), solver]);
        let hit: Vec<_> =
            report.findings.iter().filter(|f| f.lint == "panic-reachability").collect();
        assert_eq!(hit.len(), 1, "{:?}", report.findings);
        assert_eq!(hit[0].file, "solver/mod.rs");
        assert!(hit[0].message.contains("serve::http::handle"), "{}", hit[0].message);

        // pragma at the panic site (in the *callee's* file) suppresses
        let solver_ok = (
            "solver/mod.rs".to_string(),
            "pub fn solve() {\n    // audit-allow(panic-reachability): startup-only, no request data\n    x.unwrap();\n}".to_string(),
        );
        let report = audit_sources(&[serve, solver_ok]);
        let hit: Vec<_> =
            report.findings.iter().filter(|f| f.lint == "panic-reachability").collect();
        assert_eq!(hit.len(), 1);
        assert!(hit[0].suppressed, "{:?}", hit[0]);
        assert_eq!(report.unsuppressed(), 0);
    }

    #[test]
    fn lock_order_suppresses_via_pragma() {
        let src = "fn a(x: &S) { let g1 = lock_ok(&x.alpha);\n    // audit-allow(lock-order): fixture proves the suppression path\n    let g2 = lock_ok(&x.beta); }\n\
                   fn b(x: &S) {\n    let g1 = lock_ok(&x.beta);\n    // audit-allow(lock-order): fixture proves the suppression path\n    let g2 = lock_ok(&x.alpha); }";
        let got = hits("serve/jobs.rs", src, "lock-order");
        assert_eq!(got.len(), 2, "{got:?}");
        assert!(got.iter().all(|f| f.suppressed), "{got:?}");
    }

    #[test]
    fn sarif_output_is_well_formed() {
        let mut report = Report {
            files: 1,
            findings: audit_source(
                "solver/mod.rs",
                "fn f() { let t0 = Instant::now(); // audit-allow(trace-transparency): fixture\n}\nfn g() { let t1 = Instant::now(); }\n",
            ),
        };
        let s = report.to_sarif().to_string();
        assert!(s.contains("\"version\":\"2.1.0\""), "{s}");
        assert!(s.contains("sarif-schema-2.1.0.json"), "{s}");
        assert!(s.contains("\"name\":\"gapsafe-audit\""), "{s}");
        assert!(s.contains("\"ruleId\":\"trace-transparency\""), "{s}");
        assert!(s.contains("\"uri\":\"solver/mod.rs\""), "{s}");
        assert!(s.contains("\"startLine\":1"), "{s}");
        // the suppressed finding carries an inSource suppression object
        assert!(s.contains("\"suppressions\":[{\"kind\":\"inSource\"}]"), "{s}");
        // rule metadata is emitted for every registered lint + audit-pragma
        for name in lints::LINT_NAMES {
            assert!(s.contains(&format!("\"id\":\"{name}\"")), "missing rule {name}");
        }
        assert!(s.contains("\"id\":\"audit-pragma\""), "{s}");
        // SARIF round-trips through the crate's own JSON parser
        assert!(crate::util::json::Json::parse(&s).is_ok());

        // filtering keeps pragma findings but drops everything else
        report.retain_lints(&["determinism".to_string()]);
        assert!(report.findings.iter().all(|f| f.lint == "audit-pragma"), "{:?}", report.findings);
    }

    #[test]
    fn lint_names_derive_from_the_registry() {
        assert_eq!(lints::LINT_NAMES.len(), lints::LINTS.len());
        for (name, spec) in lints::LINT_NAMES.iter().zip(lints::LINTS.iter()) {
            assert_eq!(*name, spec.name);
            assert!(!spec.summary.is_empty());
        }
        assert!(lints::LINT_NAMES.contains(&"panic-reachability"));
        assert!(lints::LINT_NAMES.contains(&"lock-order"));
        assert!(lints::LINT_NAMES.contains(&"screening-soundness"));
    }
}
