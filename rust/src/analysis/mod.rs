//! `gapsafe audit` — static enforcement of the repo's reproducibility,
//! containment, and no-panic contracts (zero dependencies, std-only).
//!
//! The Gap Safe guarantee (a screening rule may never wrongly discard a
//! variable) and this repo's stronger bitwise-transparency contracts are
//! enforced at runtime by parity tests — but a parity test only fails
//! *after* someone has introduced the drift. This module rejects the
//! drift at the source level: a hand-rolled lexer ([`lexer`]) feeds six
//! named lints ([`lints`]) that walk every file under `rust/src/`.
//!
//! # Lints
//!
//! | lint | contract |
//! |---|---|
//! | `float-determinism` | no `mul_add`/FMA/libm shortcuts outside `linalg/kernels/` |
//! | `simd-containment` | intrinsics only in `kernels/avx2.rs`, inside `#[target_feature]` fns |
//! | `trace-transparency` | clock reads in solver code must be tracing-guarded |
//! | `unsafe-hygiene` | every `unsafe` carries `// SAFETY:` and lives in an allowlisted module |
//! | `determinism` | no `HashMap`/`HashSet` in `solver/`, `screening/`, `problem.rs` |
//! | `serve-no-panic` | no `unwrap`/`expect`/`panic!` reachable from the `serve/` request path |
//!
//! # Suppression
//!
//! A finding is suppressed by a pragma comment on the same line or the
//! line directly above:
//!
//! ```text
//! // audit-allow(determinism): keyed lookup only, never iterated
//! ```
//!
//! The reason after the colon is mandatory; a pragma without one (or
//! naming an unknown lint) is itself reported as `audit-pragma` and
//! cannot be suppressed. `docs/ANALYSIS.md` has the full catalogue,
//! rationale, and the dynamic-analysis legs (TSan, Miri) that cover what
//! a lexer cannot see.

pub mod lexer;
pub mod lints;

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One audit finding, pinned to a file and line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the audited source root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Lint name (one of [`lints::LINT_NAMES`] or `audit-pragma`).
    pub lint: &'static str,
    pub message: String,
    /// True when an `audit-allow` pragma covers this finding.
    pub suppressed: bool,
}

/// Result of auditing a tree: every finding plus the file count.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
}

impl Report {
    pub fn suppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    pub fn unsuppressed(&self) -> usize {
        self.findings.len() - self.suppressed()
    }

    /// Machine-readable report (`gapsafe audit --format json`). Keys are
    /// sorted and the serialisation is compact, so CI can grep
    /// `"unsuppressed":0` as a hard gate.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj([
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("lint", Json::Str(f.lint.to_string())),
                    ("message", Json::Str(f.message.clone())),
                    ("suppressed", Json::Bool(f.suppressed)),
                ])
            })
            .collect();
        Json::obj([
            ("files", Json::Num(self.files as f64)),
            ("findings", Json::Arr(findings)),
            ("suppressed", Json::Num(self.suppressed() as f64)),
            ("unsuppressed", Json::Num(self.unsuppressed() as f64)),
        ])
    }

    /// Human-readable report (the default `gapsafe audit` output).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let tag = if f.suppressed { " [suppressed]" } else { "" };
            s.push_str(&format!("{}:{}: {}: {}{}\n", f.file, f.line, f.lint, f.message, tag));
        }
        s.push_str(&format!(
            "audit: {} file(s), {} finding(s), {} unsuppressed\n",
            self.files,
            self.findings.len(),
            self.unsuppressed()
        ));
        s
    }
}

/// Audit one file's source. `rel` is its path relative to the source
/// root with `/` separators — the lint scopes key off it.
pub fn audit_source(rel: &str, src: &str) -> Vec<Finding> {
    let lx = lexer::lex(src);
    let mut findings = lints::run(rel, &lx);

    // Validate pragmas first: `audit-allow(<lint>): <reason>` must name
    // a known lint and carry a non-empty reason.
    let mut pragmas: Vec<(u32, String)> = Vec::new();
    for c in &lx.comments {
        let Some(pos) = c.text.find("audit-allow(") else { continue };
        let rest = &c.text[pos + "audit-allow(".len()..];
        let Some(close) = rest.find(')') else {
            findings.push(Finding {
                file: rel.to_string(),
                line: c.line,
                lint: "audit-pragma",
                message: "malformed audit-allow pragma: missing ')'".to_string(),
                suppressed: false,
            });
            continue;
        };
        let name = rest[..close].trim().to_string();
        let after = rest[close + 1..].trim_start();
        let reason_ok = after.starts_with(':') && !after[1..].trim().is_empty();
        if !lints::LINT_NAMES.contains(&name.as_str()) {
            findings.push(Finding {
                file: rel.to_string(),
                line: c.line,
                lint: "audit-pragma",
                message: format!("audit-allow names unknown lint `{name}`"),
                suppressed: false,
            });
        } else if !reason_ok {
            findings.push(Finding {
                file: rel.to_string(),
                line: c.line,
                lint: "audit-pragma",
                message: format!("audit-allow({name}) needs a `: <reason>`"),
                suppressed: false,
            });
        } else {
            pragmas.push((c.line, name));
        }
    }

    // Apply suppression: a pragma on line L covers findings of its lint
    // on line L (trailing comment) or L + 1 (comment above).
    for f in &mut findings {
        if f.lint == "audit-pragma" {
            continue;
        }
        if pragmas.iter().any(|(l, name)| name == f.lint && (*l == f.line || *l + 1 == f.line)) {
            f.suppressed = true;
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    findings
}

/// Audit every `.rs` file under `root` (deterministic sorted walk).
pub fn audit_tree(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)
        .map_err(|e| format!("audit: cannot walk {}: {e}", root.display()))?;
    files.sort();
    let mut report = Report::default();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("audit: cannot read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        report.findings.extend(audit_source(&rel, &src));
        report.files += 1;
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(rel: &str, src: &str, lint: &str) -> Vec<Finding> {
        audit_source(rel, src).into_iter().filter(|f| f.lint == lint).collect()
    }

    // --- one fixture per lint: a hit, and an audit-allow suppression ---

    #[test]
    fn float_determinism_fires_and_suppresses() {
        let bad = "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }";
        let got = hits("solver/mod.rs", bad, "float-determinism");
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(!got[0].suppressed);
        assert_eq!(got[0].line, 1);

        let ok = "// audit-allow(float-determinism): documented exception\n\
                  fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }";
        let got = hits("solver/mod.rs", ok, "float-determinism");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // allowed inside the kernel engine
        assert!(hits("linalg/kernels/scalar.rs", bad, "float-determinism").is_empty());
    }

    #[test]
    fn fma_intrinsics_forbidden_even_in_kernels() {
        let bad = "fn f() { let x = _mm256_fmadd_pd(a, b, c); }";
        let got = hits("linalg/kernels/avx2.rs", bad, "float-determinism");
        assert_eq!(got.len(), 1, "{got:?}");
    }

    #[test]
    fn simd_containment_fires_and_suppresses() {
        let bad = "fn f() { let v = _mm256_setzero_pd(); }";
        let got = hits("solver/mod.rs", bad, "simd-containment");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn f() {\n    // audit-allow(simd-containment): migration shim\n    let v = _mm256_setzero_pd();\n}";
        let got = hits("solver/mod.rs", ok, "simd-containment");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // in avx2.rs an intrinsic requires #[target_feature] on the fn
        let ungated = "fn f() { let v = _mm256_setzero_pd(); }";
        let got = hits("linalg/kernels/avx2.rs", ungated, "simd-containment");
        assert_eq!(got.len(), 1, "{got:?}");
        let gated = "#[target_feature(enable = \"avx2\")]\nunsafe fn f() { let v = _mm256_setzero_pd(); }";
        assert!(hits("linalg/kernels/avx2.rs", gated, "simd-containment").is_empty());
        // item-level use imports are fine
        let import = "use std::arch::x86_64::{_mm256_setzero_pd};";
        assert!(hits("linalg/kernels/avx2.rs", import, "simd-containment").is_empty());
    }

    #[test]
    fn trace_transparency_fires_and_suppresses() {
        let bad = "fn f() { let t0 = Instant::now(); }";
        let got = hits("solver/mod.rs", bad, "trace-transparency");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn f() { let t0 = Instant::now(); // audit-allow(trace-transparency): coarse span\n}";
        let got = hits("solver/mod.rs", ok, "trace-transparency");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        // the sanctioned guard shapes pass
        let guarded = "fn f() { let t0 = tracing.then(Instant::now); }";
        assert!(hits("solver/mod.rs", guarded, "trace-transparency").is_empty());
        let guarded2 = "fn f() { let t0 = crate::obs::enabled().then(Instant::now); }";
        assert!(hits("solver/mod.rs", guarded2, "trace-transparency").is_empty());
        let import = "use std::time::Instant;\nfn noop() {}";
        assert!(hits("solver/mod.rs", import, "trace-transparency").is_empty());
        // obs/, serve/ and util/ own clocks by contract
        assert!(hits("obs/trace.rs", bad, "trace-transparency").is_empty());
        assert!(hits("serve/http.rs", bad, "trace-transparency").is_empty());
    }

    #[test]
    fn unsafe_hygiene_fires_and_suppresses() {
        let bad = "fn f(p: *const f64) -> f64 { unsafe { *p } }";
        let got = hits("solver/mod.rs", bad, "unsafe-hygiene");
        // outside the allowlist AND missing // SAFETY:
        assert_eq!(got.len(), 2, "{got:?}");

        let ok = "// audit-allow(unsafe-hygiene): FFI shim pending rework\n\
                  fn f(p: *const f64) -> f64 { unsafe { *p } }";
        let got = hits("solver/mod.rs", ok, "unsafe-hygiene");
        assert!(got.iter().all(|f| f.suppressed), "{got:?}");

        // in an allowlisted module with a SAFETY comment: clean
        let clean = "fn f(p: *const f64) -> f64 {\n    // SAFETY: p is valid per caller contract\n    unsafe { *p }\n}";
        assert!(hits("linalg/kernels/avx2.rs", clean, "unsafe-hygiene").is_empty());
        // allowlisted but uncommented still fires the comment check
        let nocomment = "fn f(p: *const f64) -> f64 { unsafe { *p } }";
        assert_eq!(hits("obs/mod.rs", nocomment, "unsafe-hygiene").len(), 1);
    }

    #[test]
    fn determinism_fires_and_suppresses() {
        let bad = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, f64> = HashMap::new(); }";
        let got = hits("screening/mod.rs", bad, "determinism");
        assert_eq!(got.len(), 3, "{got:?}"); // use + type + ctor

        let ok = "fn f() {\n    // audit-allow(determinism): keyed lookups only, never iterated\n    let m: HashMap<u32, f64> = HashMap::new();\n}";
        let got = hits("problem.rs", ok, "determinism");
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|f| f.suppressed));

        // fine outside float-order-sensitive modules
        assert!(hits("serve/jobs.rs", bad, "determinism").is_empty());
    }

    #[test]
    fn serve_no_panic_fires_and_suppresses() {
        let bad = "fn handler(req: &Request) -> Response { req.body.parse().unwrap() }";
        let got = hits("serve/http.rs", bad, "serve-no-panic");
        assert_eq!(got.len(), 1, "{got:?}");

        let ok = "fn handler() {\n    // audit-allow(serve-no-panic): startup-only path, no client data\n    let x: u32 = \"7\".parse().unwrap();\n}";
        let got = hits("serve/mod.rs", ok, "serve-no-panic");
        assert_eq!(got.len(), 1);
        assert!(got[0].suppressed);

        let macros = "fn h() { panic!(\"boom\"); unreachable!() }";
        assert_eq!(hits("serve/registry.rs", macros, "serve-no-panic").len(), 2);
        // unwrap in non-serve code is out of scope
        assert!(hits("solver/mod.rs", bad, "serve-no-panic").is_empty());
        // field access `.expect` without call parens is not flagged,
        // and neither is test code
        let test_code = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}";
        assert!(hits("serve/http.rs", test_code, "serve-no-panic").is_empty());
    }

    // --- engine-level behaviors ---

    #[test]
    fn test_code_is_exempt_from_all_lints() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let t0 = Instant::now(); let m = HashMap::new(); x.unwrap(); }\n}";
        assert!(audit_source("solver/mod.rs", src).is_empty());
        assert!(audit_source("serve/mod.rs", src).is_empty());
    }

    #[test]
    fn pragma_requires_known_lint_and_reason() {
        let unknown = "// audit-allow(no-such-lint): whatever\nfn f() {}";
        let got = hits("solver/mod.rs", unknown, "audit-pragma");
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("unknown lint"));

        let no_reason = "// audit-allow(determinism)\nfn f() {}";
        let got = hits("solver/mod.rs", no_reason, "audit-pragma");
        assert_eq!(got.len(), 1);
        assert!(got[0].message.contains("reason"));

        // a malformed pragma does not suppress
        let src = "// audit-allow(determinism)\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        let det = hits("solver/mod.rs", src, "determinism");
        assert!(det.iter().all(|f| !f.suppressed), "{det:?}");
    }

    #[test]
    fn pragma_on_wrong_line_does_not_suppress() {
        let src = "// audit-allow(determinism): too far away\n\nfn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n}";
        let det = hits("solver/mod.rs", src, "determinism");
        assert!(det.iter().all(|f| !f.suppressed), "{det:?}");
    }

    #[test]
    fn report_counts_and_json_shape() {
        let report = Report {
            files: 2,
            findings: audit_source("solver/mod.rs", "fn f() { let t0 = Instant::now(); }\n"),
        };
        assert_eq!(report.unsuppressed(), 1);
        let js = report.to_json().to_string();
        assert!(js.contains("\"unsuppressed\":1"), "{js}");
        assert!(js.contains("\"lint\":\"trace-transparency\""), "{js}");
        let text = report.render_text();
        assert!(text.contains("solver/mod.rs:1: trace-transparency"), "{text}");
    }

    #[test]
    fn findings_are_sorted_and_deterministic() {
        let src = "fn a() { let t0 = Instant::now(); }\nfn b() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let f1 = audit_source("solver/mod.rs", src);
        let f2 = audit_source("solver/mod.rs", src);
        let lines1: Vec<_> = f1.iter().map(|f| (f.line, f.lint)).collect();
        let lines2: Vec<_> = f2.iter().map(|f| (f.line, f.lint)).collect();
        assert_eq!(lines1, lines2);
        assert!(lines1.windows(2).all(|w| w[0] <= w[1]), "{lines1:?}");
    }
}
