//! The audit lint catalogue: the single registry of every named lint,
//! plus the seven per-file lints checked over the token stream of
//! [`super::lexer`]. The two cross-file lints (`panic-reachability`,
//! `lock-order`) are registered here but implemented in [`super::flow`]
//! on top of the call graph.
//!
//! Each lint encodes a contract the runtime test suite can only observe
//! *after* a violation has already changed behavior — here they are
//! rejected at the source level. `docs/ANALYSIS.md` carries the full
//! rationale per lint; short versions live on each check below.
//!
//! Scope notes that apply to every lint:
//!
//! * Tokens inside `#[cfg(test)]` / `#[test]` items are skipped — tests
//!   legitimately unwrap, read clocks, and build hash maps.
//! * String/char literal *contents* never produce tokens (see the
//!   lexer), so messages naming forbidden identifiers don't fire.

use super::lexer::{Lexed, Tok, TokKind};
use super::Finding;

/// One registered lint: its pragma/CLI name and a one-line contract
/// (surfaced as the SARIF rule description and in `--help`).
#[derive(Debug, Clone, Copy)]
pub struct LintSpec {
    pub name: &'static str,
    pub summary: &'static str,
}

/// The single lint registry. Everything else — pragma validation,
/// `--lint` filtering, SARIF rule metadata, docs — derives from this
/// table, so adding a lint here cannot desync the names.
pub const LINTS: [LintSpec; 9] = [
    LintSpec {
        name: "float-determinism",
        summary: "no mul_add/FMA/libm shortcuts outside linalg/kernels/ \
                  (bitwise-reproducibility contract)",
    },
    LintSpec {
        name: "simd-containment",
        summary: "SIMD intrinsics only in kernels/avx2.rs, inside \
                  #[target_feature] fns behind the dispatch table",
    },
    LintSpec {
        name: "trace-transparency",
        summary: "clock reads in solver code must be tracing-guarded \
                  (zero syscalls with tracing off)",
    },
    LintSpec {
        name: "unsafe-hygiene",
        summary: "every unsafe block carries // SAFETY: and lives in an \
                  allowlisted module",
    },
    LintSpec {
        name: "determinism",
        summary: "no HashMap/HashSet in float-order-sensitive modules \
                  (solver/, screening/, problem.rs)",
    },
    LintSpec {
        name: "serve-no-panic",
        summary: "no unwrap/expect/panic! in serve/ itself (the request \
                  path returns JSON errors)",
    },
    LintSpec {
        name: "screening-soundness",
        summary: "sphere radii outside datafit/ must route through \
                  DataFit::gap_safe_radius, not ad-hoc sqrt(2*gap/..) \
                  arithmetic",
    },
    LintSpec {
        name: "panic-reachability",
        summary: "no panic-family call transitively reachable from a \
                  serve/ entry point, crate-wide (call-graph closure)",
    },
    LintSpec {
        name: "lock-order",
        summary: "lock acquisition order must be globally acyclic across \
                  all functions (deadlock freedom)",
    },
];

/// Names of every lint, in reporting order, derived from [`LINTS`].
/// Pragmas must use one of these exact names.
pub const LINT_NAMES: [&str; LINTS.len()] = {
    let mut names = [""; LINTS.len()];
    let mut i = 0;
    while i < LINTS.len() {
        names[i] = LINTS[i].name;
        i += 1;
    }
    names
};

/// How far above an `unsafe` token a `// SAFETY:` comment may sit
/// (lines). Covers a comment above doc/attribute lines on fn items.
const SAFETY_WINDOW: u32 = 4;

/// Float methods whose results depend on libm / FMA contraction rather
/// than pure IEEE-754 ops — forbidden outside `linalg/kernels/`, where
/// the bitwise-parity contract is enforced by dedicated tests.
const FLOAT_FORBIDDEN: [&str; 3] = ["mul_add", "to_degrees", "to_radians"];

/// Run every lint over one lexed file. `rel` is the path relative to the
/// source root, `/`-separated. Findings come back unsuppressed;
/// [`super::audit_source`] applies `audit-allow` pragmas.
pub fn run(rel: &str, lx: &Lexed) -> Vec<Finding> {
    let toks = &lx.toks;
    let tests = test_spans(toks);
    let fns = fn_regions(toks);
    let mut out: Vec<Finding> = Vec::new();

    let in_kernels = rel.starts_with("linalg/kernels/");
    let in_avx2 = rel == "linalg/kernels/avx2.rs";
    let in_serve = rel.starts_with("serve/");
    let det_scope =
        rel.starts_with("solver/") || rel.starts_with("screening/") || rel == "problem.rs";
    // obs/ reads clocks by design; serve/ stamps request deadlines and
    // latency metrics unconditionally (that is its contract); util/ owns
    // the sanctioned Stopwatch wrapper.
    let clock_exempt =
        rel.starts_with("obs/") || in_serve || rel.starts_with("util/");
    let unsafe_allowed = in_kernels || rel == "obs/mod.rs";

    let mut add = |lint: &'static str, line: u32, message: String| {
        out.push(Finding { file: rel.to_string(), line, lint, message, suppressed: false });
    };

    for (idx, tok) in toks.iter().enumerate() {
        if tok.kind != TokKind::Ident || in_spans(idx, &tests) {
            continue;
        }
        let t = tok.text.as_str();

        // float-determinism: keep every float op a plain IEEE-754
        // mul/add/div so solver trajectories cannot drift between hosts
        // or backends. FMA fusions are forbidden *everywhere* — even the
        // AVX2 kernels must not fuse (bitwise parity with scalar).
        if FLOAT_FORBIDDEN.contains(&t) && !in_kernels {
            add(
                "float-determinism",
                tok.line,
                format!("`{t}` outside linalg/kernels/ breaks the bitwise-reproducibility contract"),
            );
        }
        if t.contains("fmadd") || t.contains("fmsub") || t.contains("fnmadd") {
            add(
                "float-determinism",
                tok.line,
                format!("FMA intrinsic `{t}` is forbidden everywhere: kernels must stay bit-identical to the scalar tree"),
            );
        }

        // simd-containment: intrinsics live in kernels/avx2.rs only, and
        // only inside #[target_feature]-gated fns the dispatch layer
        // hands out after runtime detection.
        if t.starts_with("_mm") && !in_kernels {
            add(
                "simd-containment",
                tok.line,
                format!("SIMD intrinsic `{t}` outside linalg/kernels/"),
            );
        }
        if (t == "std" || t == "core")
            && toks.get(idx + 1).is_some_and(|x| x.text == ":")
            && toks.get(idx + 2).is_some_and(|x| x.text == ":")
            && toks.get(idx + 3).is_some_and(|x| x.text == "arch")
            && !in_kernels
        {
            add(
                "simd-containment",
                tok.line,
                format!("`{t}::arch` outside linalg/kernels/"),
            );
        }
        if t == "is_x86_feature_detected" && !in_kernels {
            add(
                "simd-containment",
                tok.line,
                "CPU feature detection outside linalg/kernels/ (use the dispatch table)".to_string(),
            );
        }
        if t.starts_with("_mm") && in_avx2 {
            // Inside a fn body the fn must carry #[target_feature];
            // outside any fn body the token is a `use` import — fine.
            if let Some(&(_, _, has_tf)) = fns
                .iter()
                .filter(|&&(s, e, _)| s <= idx && idx <= e)
                .max_by_key(|&&(s, _, _)| s)
            {
                if !has_tf {
                    add(
                        "simd-containment",
                        tok.line,
                        format!("`{t}` in a fn without #[target_feature(enable = ...)]"),
                    );
                }
            }
        }

        // trace-transparency: a raw clock read in solver code must be
        // dominated by a tracing/timing guard in the same statement, so
        // that with tracing off the solver performs no clock syscalls
        // (the obs overhead contract: one relaxed load per region).
        if !clock_exempt {
            let is_clock = t == "SystemTime"
                || (t == "Instant"
                    && toks.get(idx + 1).is_some_and(|x| x.text == ":")
                    && toks.get(idx + 2).is_some_and(|x| x.text == ":")
                    && toks.get(idx + 3).is_some_and(|x| x.text == "now"));
            if is_clock {
                let pre = stmt_prefix(toks, idx);
                let guarded = pre.first().is_some_and(|s| s == "use")
                    || (pre.iter().any(|s| s == "tracing" || s == "timing")
                        && pre.iter().any(|s| s == "then"))
                    || pre.iter().any(|s| s == "enabled");
                if !guarded {
                    add(
                        "trace-transparency",
                        tok.line,
                        format!("unguarded clock read `{t}` (gate with obs::enabled() / tracing.then)"),
                    );
                }
            }
        }

        // unsafe-hygiene: every unsafe site carries a // SAFETY: comment
        // and lives in a module allowlisted for unsafe code.
        if t == "unsafe" {
            if !unsafe_allowed {
                add(
                    "unsafe-hygiene",
                    tok.line,
                    "`unsafe` outside the allowlisted modules (linalg/kernels/, obs/mod.rs)"
                        .to_string(),
                );
            }
            let has_safety = lx.comments.iter().any(|c| {
                c.text.contains("SAFETY:")
                    && c.line <= tok.line
                    && c.line + SAFETY_WINDOW >= tok.line
            });
            if !has_safety {
                add(
                    "unsafe-hygiene",
                    tok.line,
                    "`unsafe` without a `// SAFETY:` comment stating the invariant".to_string(),
                );
            }
        }

        // determinism: hash containers have a randomized iteration order
        // that would leak into float accumulation order in solver code.
        if det_scope && (t == "HashMap" || t == "HashSet") {
            add(
                "determinism",
                tok.line,
                format!("`{t}` in a float-order-sensitive module (use BTreeMap/Vec)"),
            );
        }

        // screening-soundness: the Gap Safe sphere radius is a proof
        // obligation — its validity depends on the datafit's curvature
        // bound, so the *only* place allowed to spell the radius formula
        // is the DataFit impl. Ad-hoc `sqrt(2.0 * gap / ..)` arithmetic
        // in screening/solver code silently breaks the safety proof the
        // moment a datafit without a global bound (Poisson) is plugged
        // in. Everything outside datafit/ must route through
        // `DataFit::gap_safe_radius`.
        if det_scope && t == "sqrt" {
            let stmt = stmt_tokens(toks, idx);
            let names = |p: fn(&str) -> bool| stmt.iter().any(|s| p(s));
            let routed = names(|s| s == "gap_safe_radius");
            let gapish = names(|s| s.starts_with("gap"));
            if gapish && !routed {
                add(
                    "screening-soundness",
                    tok.line,
                    "ad-hoc Gap Safe radius arithmetic (sqrt over a duality gap) — \
                     route through DataFit::gap_safe_radius"
                        .to_string(),
                );
            }
        }

        // serve-no-panic: nothing in serve/ itself may panic — a
        // panicking worker tears down the whole resident server. The
        // transitive version of this contract (callees *outside* serve/)
        // is `panic-reachability` in super::flow.
        if in_serve {
            let next = toks.get(idx + 1).map(|x| x.text.as_str());
            if (t == "unwrap" || t == "expect") && next == Some("(") {
                add(
                    "serve-no-panic",
                    tok.line,
                    format!("`{t}` reachable from the request path (return a 4xx/5xx JSON error)"),
                );
            }
            if matches!(t, "panic" | "unreachable" | "todo" | "unimplemented") && next == Some("!")
            {
                add(
                    "serve-no-panic",
                    tok.line,
                    format!("`{t}!` reachable from the request path"),
                );
            }
        }
    }

    // screening-soundness, staged form: `2.0 * gap / ..` radius
    // arithmetic built up without a `sqrt` in the same statement still
    // spells the radius formula outside the datafit (the sqrt-bearing
    // statement is caught above; this catches the split-across-lets
    // variant at its source).
    if det_scope {
        for (idx, tok) in toks.iter().enumerate() {
            if tok.kind != TokKind::Num
                || !(tok.text == "2.0" || tok.text == "2")
                || in_spans(idx, &tests)
            {
                continue;
            }
            let times_gap = toks.get(idx + 1).is_some_and(|x| x.text == "*")
                && toks.get(idx + 2).is_some_and(|x| {
                    x.kind == TokKind::Ident && x.text.starts_with("gap")
                });
            if !times_gap {
                continue;
            }
            let stmt = stmt_tokens(toks, idx);
            if stmt.iter().any(|s| s == "gap_safe_radius" || s == "sqrt") {
                continue; // routed, or already reported via the sqrt form
            }
            add(
                "screening-soundness",
                tok.line,
                "ad-hoc Gap Safe radius arithmetic (`2 * gap` scaling) — \
                 route through DataFit::gap_safe_radius"
                    .to_string(),
            );
        }
    }
    out
}

/// Token texts of the whole statement containing `idx`: from the
/// nearest `;`/`{`/`}` boundary on the left to the nearest on the right.
fn stmt_tokens(toks: &[Tok], idx: usize) -> Vec<String> {
    let mut stmt = stmt_prefix(toks, idx);
    let mut j = idx;
    while j < toks.len() {
        let t = &toks[j].text;
        if j > idx && (t == ";" || t == "{" || t == "}") {
            break;
        }
        stmt.push(t.clone());
        j += 1;
    }
    stmt
}

/// Token-index ranges covered by `#[cfg(test)]` / `#[test]` items.
/// Shared with [`super::parser`] so the call graph agrees with the
/// per-file lints about what counts as test code.
pub(super) fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].text == "#" && toks.get(i + 1).is_some_and(|t| t.text == "[")) {
            i += 1;
            continue;
        }
        // Collect the attribute's identifiers up to its closing bracket.
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut idents: Vec<&str> = Vec::new();
        while j < toks.len() {
            match toks[j].text.as_str() {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {
                    if toks[j].kind == TokKind::Ident {
                        idents.push(toks[j].text.as_str());
                    }
                }
            }
            j += 1;
        }
        let is_test = (idents.contains(&"cfg") && idents.contains(&"test"))
            || idents == ["test"];
        if is_test {
            if let Some(end) = item_body_end(toks, j + 1) {
                spans.push((i, end));
                i = end + 1;
                continue;
            }
        }
        i = j + 1;
    }
    spans
}

/// From `start`, find the end of the next item: skip to the first `{` or
/// `;` at bracket depth 0, then (for `{`) to its matching `}`. Returns
/// the index of the closing token.
pub(super) fn item_body_end(toks: &[Tok], start: usize) -> Option<usize> {
    let mut m = start;
    let mut bd = 0i32;
    while m < toks.len() {
        match toks[m].text.as_str() {
            "(" | "[" => bd += 1,
            ")" | "]" => bd -= 1,
            "{" | ";" if bd == 0 => break,
            _ => {}
        }
        m += 1;
    }
    if m >= toks.len() {
        return None;
    }
    if toks[m].text == ";" {
        return Some(m);
    }
    let mut d = 0i32;
    let mut e = m;
    while e < toks.len() {
        if toks[e].text == "{" {
            d += 1;
        } else if toks[e].text == "}" {
            d -= 1;
            if d == 0 {
                return Some(e);
            }
        }
        e += 1;
    }
    None
}

/// Body spans of every `fn`, with whether the fn carries a
/// `#[target_feature(...)]` attribute. `(body_open, body_close, has_tf)`.
fn fn_regions(toks: &[Tok]) -> Vec<(usize, usize, bool)> {
    let mut regions = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].kind == TokKind::Ident && toks[i].text == "fn") {
            continue;
        }
        // Scan backwards over qualifiers and attributes for
        // #[target_feature].
        let mut has_tf = false;
        let mut j = i as i64 - 1;
        loop {
            if j < 0 {
                break;
            }
            let ju = j as usize;
            let t = toks[ju].text.as_str();
            if toks[ju].kind == TokKind::Ident
                && matches!(t, "pub" | "crate" | "unsafe" | "const" | "extern" | "async")
            {
                j -= 1;
                continue;
            }
            if t == ")" || t == "]" {
                // Match the bracketed group backwards: either an
                // attribute `#[...]` or a visibility `pub(crate)`.
                let close = t;
                let open = if close == ")" { "(" } else { "[" };
                let mut d = 0i32;
                let mut saw_tf = false;
                while j >= 0 {
                    let tt = toks[j as usize].text.as_str();
                    if tt == close {
                        d += 1;
                    } else if tt == open {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    } else if tt == "target_feature" {
                        saw_tf = true;
                    }
                    j -= 1;
                }
                if close == "]" {
                    if saw_tf {
                        has_tf = true;
                    }
                    j -= 1; // past '['
                    if j >= 0 && toks[j as usize].text == "#" {
                        j -= 1;
                    }
                } else {
                    j -= 1; // past '(' of pub(crate)
                }
                continue;
            }
            break;
        }
        // Forward: the fn's body braces (None for trait method decls).
        if let Some(end) = item_body_end(toks, i) {
            if toks[end].text == "}" {
                // Find the opening brace that `end` matched.
                let mut m = i;
                let mut bd = 0i32;
                while m < toks.len() {
                    match toks[m].text.as_str() {
                        "(" | "[" => bd += 1,
                        ")" | "]" => bd -= 1,
                        "{" if bd == 0 => break,
                        _ => {}
                    }
                    m += 1;
                }
                regions.push((m, end, has_tf));
            }
        }
    }
    regions
}

/// Token texts from the start of the statement containing `idx` (the
/// nearest `;`/`{`/`}` boundary) up to, not including, `idx`.
fn stmt_prefix(toks: &[Tok], idx: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut j = idx as i64 - 1;
    while j >= 0 {
        let t = &toks[j as usize].text;
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        out.push(t.clone());
        j -= 1;
    }
    out.reverse();
    out
}

/// Is token `idx` inside any of `spans` (inclusive)?
pub(super) fn in_spans(idx: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(a, b)| a <= idx && idx <= b)
}
