//! A conservative, over-approximating call graph over the whole crate,
//! built from [`super::parser`] output.
//!
//! # Approximation contract
//!
//! The graph may only ever have *extra* edges, never missing ones, for
//! calls that target crate-local fns (see `docs/ANALYSIS.md`):
//!
//! * A bare call `foo(..)` or method call `x.foo(..)` links to **every**
//!   crate fn named `foo`, regardless of type — name-based resolution
//!   without type inference over-approximates dynamic dispatch and
//!   trait impls by construction.
//! * A path call `a::b::foo(..)` links to every crate fn whose
//!   qualified path ends with the written segments, after expanding the
//!   file's `use` aliases and the `crate`/`self`/`super`/`Self`
//!   prefixes. If no crate fn matches the full suffix, the call is
//!   external (std or a primitive method) and contributes no edge.
//! * Calls through fn pointers / closures and macro-generated calls are
//!   *not* resolved — lints downstream must not rely on the graph for
//!   std-level panics (the panic lint separately inspects panic-family
//!   tokens in every reachable body, which covers `unwrap()` regardless
//!   of resolution).
//!
//! Everything is ordered: nodes in (file, source) order, edges sorted by
//! (callee, line), BFS in queue order over sorted edges — two builds of
//! the same tree are byte-identical.

use super::lexer::TokKind;
use super::parser::ParsedFile;
use std::collections::BTreeMap;

/// One fn in the crate-wide graph.
#[derive(Debug, Clone)]
pub struct Node {
    /// Index into the `files` slice the graph was built from.
    pub file_idx: usize,
    /// File path relative to the source root.
    pub file: String,
    /// Bare fn name.
    pub name: String,
    /// Crate-qualified path (`serve::registry::Registry::fit`).
    pub qual: String,
    /// `impl`/`trait` owner, if any (for `Self::` resolution).
    pub owner: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token span of the body in the owning file, braces inclusive.
    pub body: (usize, usize),
    pub is_test: bool,
}

/// Outgoing edge: resolved callee node plus the call site's line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub callee: usize,
    pub line: u32,
}

#[derive(Debug)]
pub struct CallGraph {
    pub nodes: Vec<Node>,
    /// `edges[i]` — sorted, deduped outgoing edges of `nodes[i]`.
    pub edges: Vec<Vec<Edge>>,
    /// bare name → node indices (ascending), for shadow checks.
    by_name: BTreeMap<String, Vec<usize>>,
}

/// Keywords that look like `ident (` but are not calls.
const NON_CALL: [&str; 12] = [
    "if", "while", "for", "match", "return", "loop", "fn", "move", "in", "as", "let", "else",
];

impl CallGraph {
    /// Build the graph over every fn in `files`.
    pub fn build(files: &[ParsedFile]) -> CallGraph {
        let mut nodes: Vec<Node> = Vec::new();
        for (fi, pf) in files.iter().enumerate() {
            for f in &pf.fns {
                nodes.push(Node {
                    file_idx: fi,
                    file: pf.rel.clone(),
                    name: f.name.clone(),
                    qual: f.qual.clone(),
                    owner: f.owner.clone(),
                    line: f.line,
                    body: f.body,
                    is_test: f.is_test,
                });
            }
        }
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, n) in nodes.iter().enumerate() {
            by_name.entry(n.name.clone()).or_default().push(i);
        }
        let qual_segs: Vec<Vec<&str>> =
            nodes.iter().map(|n| n.qual.split("::").collect()).collect();

        let mut edges: Vec<Vec<Edge>> = vec![Vec::new(); nodes.len()];
        for (i, n) in nodes.iter().enumerate() {
            let pf = &files[n.file_idx];
            let toks = &pf.lexed.toks;
            let (lo, hi) = n.body;
            for j in lo..=hi.min(toks.len() - 1) {
                if toks[j].kind != TokKind::Ident || NON_CALL.contains(&toks[j].text.as_str()) {
                    continue;
                }
                // `name(` directly, or `name::<T>(` with a turbofish.
                let direct = toks.get(j + 1).is_some_and(|t| t.text == "(");
                let turbofish = !direct
                    && toks.get(j + 1).is_some_and(|t| t.text == ":")
                    && toks.get(j + 2).is_some_and(|t| t.text == ":")
                    && toks.get(j + 3).is_some_and(|t| t.text == "<")
                    && {
                        let mut d = 0i32;
                        let mut m = j + 3;
                        loop {
                            match toks.get(m).map(|t| t.text.as_str()) {
                                Some("<") => d += 1,
                                Some(">") => {
                                    d -= 1;
                                    if d == 0 {
                                        break toks.get(m + 1).is_some_and(|t| t.text == "(");
                                    }
                                }
                                Some(_) => {}
                                None => break false,
                            }
                            m += 1;
                        }
                    };
                if !direct && !turbofish {
                    continue;
                }
                // Walk the `a :: b :: name` path backwards from `name`.
                let mut segs: Vec<String> = vec![toks[j].text.clone()];
                let mut k = j;
                while k >= 3
                    && toks[k - 1].text == ":"
                    && toks[k - 2].text == ":"
                    && toks[k - 3].kind == TokKind::Ident
                {
                    segs.insert(0, toks[k - 3].text.clone());
                    k -= 3;
                }
                // `<T as Trait>::name` / turbofish land here with a `>`
                // before the `::`; treat as a bare name (conservative).
                let candidates: Vec<usize> = if segs.len() == 1 {
                    by_name.get(&segs[0]).cloned().unwrap_or_default()
                } else {
                    resolve_path(&segs, n, pf, &qual_segs, &by_name)
                };
                for c in candidates {
                    edges[i].push(Edge { callee: c, line: toks[j].line });
                }
            }
            edges[i].sort_by_key(|e| (e.callee, e.line));
            edges[i].dedup();
        }
        CallGraph { nodes, edges, by_name }
    }

    /// Does any crate fn carry this bare name? (Used by the panic lint
    /// to tell crate-local `expect`-alikes from std's panicking ones.)
    pub fn has_fn_named(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// BFS over non-test fns from `roots` (deterministic: queue order
    /// over edges already sorted by callee). `parent[v]` is the BFS
    /// predecessor, `None` for roots and unreached nodes.
    pub fn reach_from(&self, roots: &[usize]) -> Reach {
        let mut visited = vec![false; self.nodes.len()];
        let mut parent: Vec<Option<usize>> = vec![None; self.nodes.len()];
        let mut queue: std::collections::VecDeque<usize> = Default::default();
        for &r in roots {
            if !visited[r] && !self.nodes[r].is_test {
                visited[r] = true;
                queue.push_back(r);
            }
        }
        while let Some(v) = queue.pop_front() {
            for e in &self.edges[v] {
                let c = e.callee;
                if !visited[c] && !self.nodes[c].is_test {
                    visited[c] = true;
                    parent[c] = Some(v);
                    queue.push_back(c);
                }
            }
        }
        Reach { visited, parent }
    }
}

/// Resolve a multi-segment path call from fn `n` in file `pf`:
/// expand `use` aliases and `crate`/`self`/`super`/`Self`, then match
/// crate fns whose qualified path ends with the written segments.
fn resolve_path(
    segs: &[String],
    n: &Node,
    pf: &ParsedFile,
    qual_segs: &[Vec<&str>],
    by_name: &BTreeMap<String, Vec<usize>>,
) -> Vec<usize> {
    let mut segs: Vec<String> = segs.to_vec();
    // `use` alias on the leading segment (`sync::lock_ok` after
    // `use crate::util::sync;`).
    if let Some((_, path)) = pf.uses.iter().find(|(alias, _)| *alias == segs[0]) {
        segs.splice(0..1, path.iter().cloned());
    }
    // Normalize the leading keyword, if any (it only appears once).
    match segs.first().map(String::as_str) {
        Some("crate") => {
            segs.remove(0);
        }
        Some("self") => {
            segs.remove(0);
            for (d, m) in pf.mod_path.iter().enumerate() {
                segs.insert(d, m.clone());
            }
        }
        Some("super") => {
            segs.remove(0);
            let mut path = pf.mod_path.clone();
            path.pop();
            // further `super`s pop further
            while segs.first().is_some_and(|s| s == "super") {
                segs.remove(0);
                path.pop();
            }
            for (d, m) in path.iter().enumerate() {
                segs.insert(d, m.clone());
            }
        }
        Some("Self") => match &n.owner {
            Some(o) => segs[0] = o.clone(),
            None => {
                segs.remove(0);
            }
        },
        _ => {}
    }
    let Some(name) = segs.last() else { return Vec::new() };
    let Some(cands) = by_name.get(name) else { return Vec::new() };
    let want: Vec<&str> = segs.iter().map(String::as_str).collect();
    cands
        .iter()
        .copied()
        .filter(|&c| qual_segs[c].ends_with(&want))
        .collect()
}

/// Result of a reachability walk.
#[derive(Debug)]
pub struct Reach {
    pub visited: Vec<bool>,
    pub parent: Vec<Option<usize>>,
}

impl Reach {
    /// The BFS chain root → .. → `v` as node indices.
    pub fn chain(&self, v: usize) -> Vec<usize> {
        let mut chain = vec![v];
        let mut cur = v;
        while let Some(p) = self.parent[cur] {
            chain.push(p);
            cur = p;
        }
        chain.reverse();
        chain
    }
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    fn graph(files: &[(&str, &str)]) -> (Vec<ParsedFile>, CallGraph) {
        let parsed: Vec<ParsedFile> =
            files.iter().map(|(rel, src)| parse(rel, src)).collect();
        let g = CallGraph::build(&parsed);
        (parsed, g)
    }

    fn idx(g: &CallGraph, qual: &str) -> usize {
        g.nodes.iter().position(|n| n.qual == qual).unwrap_or_else(|| {
            panic!("no node {qual}; have {:?}", g.nodes.iter().map(|n| &n.qual).collect::<Vec<_>>())
        })
    }

    fn callees(g: &CallGraph, from: &str) -> Vec<String> {
        g.edges[idx(g, from)].iter().map(|e| g.nodes[e.callee].qual.clone()).collect()
    }

    #[test]
    fn bare_and_path_calls_resolve() {
        let (_, g) = graph(&[
            ("serve/mod.rs", "pub fn serve() { crate::solver::solve(); helper(); }\nfn helper() {}"),
            ("solver/mod.rs", "pub fn solve() { inner_step(); }\nfn inner_step() {}"),
        ]);
        assert_eq!(callees(&g, "serve::serve"), vec!["serve::helper", "solver::solve"]);
        assert_eq!(callees(&g, "solver::solve"), vec!["solver::inner_step"]);
    }

    #[test]
    fn method_calls_link_to_every_same_named_fn() {
        // The adversarial case from ISSUE.md: two types with a method
        // of the same name — a call through either receiver must be
        // conservatively linked to BOTH impls.
        let src = "struct A; struct B;\n\
                   impl A { fn run(&self) {} }\n\
                   impl B { fn run(&self) { panic!(\"b\") } }\n\
                   fn go(a: &A) { a.run(); }";
        let (_, g) = graph(&[("solver/mod.rs", src)]);
        assert_eq!(
            callees(&g, "solver::go"),
            vec!["solver::A::run", "solver::B::run"],
            "method call must over-approximate to both candidates"
        );
    }

    #[test]
    fn unmatched_paths_are_external() {
        let (_, g) = graph(&[(
            "solver/mod.rs",
            "fn f() { std::mem::take(&mut x); Vec::new(); y.unwrap(); }",
        )]);
        assert!(callees(&g, "solver::f").is_empty());
    }

    #[test]
    fn self_and_use_alias_resolution() {
        let files = [
            (
                "serve/registry.rs",
                "use crate::util::sync::lock_ok;\n\
                 struct Registry;\n\
                 impl Registry {\n\
                   fn fit(&self) { Self::validate(); lock_ok(); }\n\
                   fn validate() {}\n\
                 }",
            ),
            ("util/sync.rs", "pub fn lock_ok() {}"),
        ];
        let (_, g) = graph(&files);
        assert_eq!(
            callees(&g, "serve::registry::Registry::fit"),
            vec!["serve::registry::Registry::validate", "util::sync::lock_ok"]
        );
    }

    #[test]
    fn reachability_skips_tests_and_yields_chains() {
        let files = [
            ("serve/mod.rs", "pub fn entry() { crate::solver::solve(); }"),
            (
                "solver/mod.rs",
                "pub fn solve() { helper(); }\nfn helper() {}\nfn dead() { helper(); }\n\
                 #[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { super::dead(); }\n}",
            ),
        ];
        let (_, g) = graph(&files);
        let roots: Vec<usize> = (0..g.nodes.len())
            .filter(|&i| g.nodes[i].file.starts_with("serve/") && !g.nodes[i].is_test)
            .collect();
        let r = g.reach_from(&roots);
        assert!(r.visited[idx(&g, "solver::helper")]);
        assert!(!r.visited[idx(&g, "solver::dead")], "only a test calls dead()");
        let chain: Vec<String> =
            r.chain(idx(&g, "solver::helper")).iter().map(|&i| g.nodes[i].qual.clone()).collect();
        assert_eq!(chain, vec!["serve::entry", "solver::solve", "solver::helper"]);
    }

    #[test]
    fn two_walks_are_byte_identical() {
        let files = [
            ("serve/mod.rs", "pub fn entry() { a(); b(); }"),
            ("solver/mod.rs", "pub fn a() { b(); }\npub fn b() { a(); }"),
        ];
        let parsed: Vec<ParsedFile> =
            files.iter().map(|(rel, src)| parse(rel, src)).collect();
        let g1 = CallGraph::build(&parsed);
        let g2 = CallGraph::build(&parsed);
        let dump = |g: &CallGraph| {
            let mut s = String::new();
            for (i, n) in g.nodes.iter().enumerate() {
                s.push_str(&format!("{i} {} <- {:?}\n", n.qual, g.edges[i]));
            }
            s
        };
        assert_eq!(dump(&g1), dump(&g2));
        let roots = [0usize];
        let r1 = g1.reach_from(&roots);
        let r2 = g2.reach_from(&roots);
        assert_eq!(format!("{:?}", r1), format!("{:?}", r2));
    }
}
