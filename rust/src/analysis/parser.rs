//! Lightweight item parser on top of [`super::lexer`]: modules, `fn`
//! items with bracket-matched body spans, `impl`/`trait` owners, and
//! `use` aliases — just enough structure for the conservative call graph
//! in [`super::callgraph`].
//!
//! This is *not* a Rust parser. It recovers exactly the shape the
//! cross-file lints need — which fn owns which token range, what its
//! crate-qualified path is, and how local names map to paths — and it is
//! deliberately forgiving: anything it cannot classify becomes an
//! anonymous scope, which can only make the call graph *more*
//! conservative (see the approximation contract in `docs/ANALYSIS.md`).

use super::lexer::{lex, Lexed, TokKind};
use super::lints::{in_spans, item_body_end, test_spans};

/// One `fn` item with its bracket-matched body span.
#[derive(Debug, Clone)]
pub struct FnItem {
    /// Bare name (`fit`).
    pub name: String,
    /// Crate-qualified path (`serve::registry::Registry::fit`): the
    /// module path implied by the file, inline modules, then the
    /// `impl`/`trait` owner when there is one.
    pub qual: String,
    /// `impl`/`trait` owner type name, if any.
    pub owner: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token-index range of the body, inclusive of both braces.
    pub body: (usize, usize),
    /// Inside a `#[cfg(test)]` / `#[test]` item.
    pub is_test: bool,
}

/// A parsed file: the lexed stream plus its item structure.
#[derive(Debug)]
pub struct ParsedFile {
    /// Path relative to the source root, `/`-separated.
    pub rel: String,
    pub lexed: Lexed,
    /// Module path implied by `rel` (`serve/jobs.rs` → `["serve", "jobs"]`).
    pub mod_path: Vec<String>,
    /// Every fn item, in source order.
    pub fns: Vec<FnItem>,
    /// `use` aliases visible in this file: local name → full segment
    /// path as written (globs and `use ... as _` are skipped).
    pub uses: Vec<(String, Vec<String>)>,
}

/// Module path implied by a file's location under the source root.
fn mod_path_of(rel: &str) -> Vec<String> {
    let mut segs: Vec<String> =
        rel.trim_end_matches(".rs").split('/').map(str::to_string).collect();
    if segs.last().is_some_and(|s| s == "mod") {
        segs.pop();
    }
    if segs.len() == 1 && (segs[0] == "lib" || segs[0] == "main") {
        segs.clear();
    }
    segs
}

/// Scope a `{` opens: a named module/owner, or anything else.
#[derive(Debug, Clone)]
enum Scope {
    Mod(String),
    Owner(String),
    Anon,
}

/// Parse one file. Never fails: unparseable stretches degrade to
/// anonymous scopes and missing items, not errors.
pub fn parse(rel: &str, src: &str) -> ParsedFile {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let tests = test_spans(toks);
    let mod_path = mod_path_of(rel);

    // First pass: map each scope-opening `{` to the scope it opens, by
    // scanning item headers (`mod N {`, `impl ... {`, `trait N ... {`).
    let mut scope_at: Vec<Option<Scope>> = vec![None; toks.len()];
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        match toks[i].text.as_str() {
            "mod" => {
                // `mod name {` (file modules `mod name;` open nothing).
                if toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident)
                    && toks.get(i + 2).is_some_and(|t| t.text == "{")
                {
                    scope_at[i + 2] = Some(Scope::Mod(toks[i + 1].text.clone()));
                }
            }
            "trait" => {
                if let Some(name_tok) = toks.get(i + 1).filter(|t| t.kind == TokKind::Ident) {
                    if let Some(open) = header_body_open(toks, i + 2) {
                        scope_at[open] = Some(Scope::Owner(name_tok.text.clone()));
                    }
                }
            }
            "impl" => {
                // Only item-position `impl` (skip `-> impl Trait` and
                // `(impl Trait` argument types).
                let prev = i.checked_sub(1).map(|p| toks[p].text.as_str());
                let item_pos = match prev {
                    None | Some(";") | Some("{") | Some("}") | Some("]") => true,
                    Some("unsafe") | Some("pub") => true,
                    _ => false,
                };
                if !item_pos {
                    continue;
                }
                if let Some((owner, open)) = impl_owner(toks, i + 1) {
                    scope_at[open] = Some(Scope::Owner(owner));
                }
            }
            _ => {}
        }
    }

    // Second pass: walk the brace structure, collecting fns and uses.
    let mut stack: Vec<Scope> = Vec::new();
    let mut fns: Vec<FnItem> = Vec::new();
    let mut uses: Vec<(String, Vec<String>)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        match t.text.as_str() {
            "{" => stack.push(scope_at[i].clone().unwrap_or(Scope::Anon)),
            "}" => {
                stack.pop();
            }
            "fn" if t.kind == TokKind::Ident => {
                if let Some(name_tok) = toks.get(i + 1).filter(|t| t.kind == TokKind::Ident) {
                    if let Some(end) = item_body_end(toks, i + 2) {
                        if toks[end].text == "}" {
                            let open = body_open_for(toks, i + 2, end);
                            let mut mods: Vec<&str> =
                                mod_path.iter().map(String::as_str).collect();
                            let mut owner: Option<String> = None;
                            for s in &stack {
                                match s {
                                    Scope::Mod(m) => mods.push(m),
                                    Scope::Owner(o) => owner = Some(o.clone()),
                                    Scope::Anon => {}
                                }
                            }
                            let mut qual_segs: Vec<String> =
                                mods.iter().map(|s| s.to_string()).collect();
                            if let Some(o) = &owner {
                                qual_segs.push(o.clone());
                            }
                            qual_segs.push(name_tok.text.clone());
                            fns.push(FnItem {
                                name: name_tok.text.clone(),
                                qual: qual_segs.join("::"),
                                owner,
                                line: t.line,
                                body: (open, end),
                                is_test: in_spans(i, &tests) || in_spans(end, &tests),
                            });
                        }
                    }
                }
            }
            "use" if t.kind == TokKind::Ident => {
                i = parse_use(toks, i + 1, &mut uses);
                continue;
            }
            _ => {}
        }
        i += 1;
    }

    ParsedFile { rel: rel.to_string(), lexed, mod_path, fns, uses }
}

/// Find the `{` that opens an item body declared at `start`, skipping
/// bounds/generics (`(`/`[` bracketed groups never contain a body brace).
fn header_body_open(toks: &[super::lexer::Tok], start: usize) -> Option<usize> {
    let mut bd = 0i32;
    let mut m = start;
    while m < toks.len() {
        match toks[m].text.as_str() {
            "(" | "[" => bd += 1,
            ")" | "]" => bd -= 1,
            "{" if bd == 0 => return Some(m),
            ";" if bd == 0 => return None,
            _ => {}
        }
        m += 1;
    }
    None
}

/// From the token after `impl`, extract the implemented-on type name and
/// the index of the body `{`. Handles `impl<T> Type<T>`,
/// `impl Trait for Type`, `&`/`dyn`/`mut` sigils, and `->` inside
/// generic bounds (`impl<F: Fn(usize) -> f64> ...`).
fn impl_owner(toks: &[super::lexer::Tok], start: usize) -> Option<(String, usize)> {
    let mut angle = 0i32;
    let mut bd = 0i32;
    let mut after_for = false;
    let mut first: Option<String> = None;
    let mut first_after_for: Option<String> = None;
    let mut m = start;
    while m < toks.len() {
        let txt = toks[m].text.as_str();
        match txt {
            "(" | "[" => bd += 1,
            ")" | "]" => bd -= 1,
            "<" => angle += 1,
            ">" => {
                // `->` does not close a generic angle.
                if !(m > 0 && toks[m - 1].text == "-") {
                    angle -= 1;
                }
            }
            "{" if bd == 0 && angle <= 0 => {
                let owner = if after_for { first_after_for } else { first };
                return owner.map(|o| (o, m));
            }
            ";" if bd == 0 && angle <= 0 => return None,
            "for" if bd == 0 && angle <= 0 => after_for = true,
            _ => {
                if toks[m].kind == TokKind::Ident
                    && bd == 0
                    && angle <= 0
                    && !matches!(txt, "dyn" | "mut" | "where" | "Send" | "Sync" | "unsafe")
                {
                    if after_for {
                        first_after_for.get_or_insert_with(|| txt.to_string());
                    } else {
                        first.get_or_insert_with(|| txt.to_string());
                    }
                }
            }
        }
        m += 1;
    }
    None
}

/// The `{` a fn body's closing brace `end` matches, scanning from the
/// signature at `start`.
fn body_open_for(toks: &[super::lexer::Tok], start: usize, end: usize) -> usize {
    let mut bd = 0i32;
    let mut m = start;
    while m < end {
        match toks[m].text.as_str() {
            "(" | "[" => bd += 1,
            ")" | "]" => bd -= 1,
            "{" if bd == 0 => return m,
            _ => {}
        }
        m += 1;
    }
    end
}

/// Parse one `use` declaration starting after the `use` keyword; pushes
/// `(alias, path)` pairs and returns the index just past the closing `;`.
fn parse_use(
    toks: &[super::lexer::Tok],
    start: usize,
    out: &mut Vec<(String, Vec<String>)>,
) -> usize {
    fn tree(
        toks: &[super::lexer::Tok],
        mut i: usize,
        prefix: &[String],
        out: &mut Vec<(String, Vec<String>)>,
    ) -> usize {
        let mut path: Vec<String> = prefix.to_vec();
        loop {
            let Some(t) = toks.get(i) else { return i };
            match t.text.as_str() {
                "{" => {
                    // group: recurse per comma-separated subtree
                    i += 1;
                    loop {
                        i = tree(toks, i, &path, out);
                        match toks.get(i).map(|t| t.text.as_str()) {
                            Some(",") => i += 1,
                            Some("}") => return i + 1,
                            _ => return i,
                        }
                    }
                }
                "*" => return i + 1, // glob: unsupported, skipped
                ":" => i += 1,       // path separator (lexed as two ':')
                "as" => {
                    // rename: alias is the next ident
                    if let Some(alias) = toks.get(i + 1) {
                        if alias.kind == TokKind::Ident && alias.text != "_" {
                            out.push((alias.text.clone(), path.clone()));
                        }
                        return i + 2;
                    }
                    return i + 1;
                }
                _ if t.kind == TokKind::Ident => {
                    path.push(t.text.clone());
                    i += 1;
                    // end of a leaf path?
                    match toks.get(i).map(|t| t.text.as_str()) {
                        Some(":") => {}
                        Some("as") => {}
                        _ => {
                            if let Some(last) = path.last() {
                                out.push((last.clone(), path.clone()));
                            }
                            return i;
                        }
                    }
                }
                _ => return i,
            }
        }
    }
    let mut i = tree(toks, start, &[], out);
    // consume through the terminating `;`
    while i < toks.len() && toks[i].text != ";" {
        i += 1;
    }
    i + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quals(rel: &str, src: &str) -> Vec<String> {
        parse(rel, src).fns.iter().map(|f| f.qual.clone()).collect()
    }

    #[test]
    fn mod_paths_from_file_location() {
        assert_eq!(mod_path_of("serve/jobs.rs"), vec!["serve", "jobs"]);
        assert_eq!(mod_path_of("serve/mod.rs"), vec!["serve"]);
        assert!(mod_path_of("lib.rs").is_empty());
        assert!(mod_path_of("main.rs").is_empty());
        assert_eq!(mod_path_of("problem.rs"), vec!["problem"]);
    }

    #[test]
    fn free_fns_and_inline_modules() {
        let src = "fn top() {}\nmod inner {\n    pub fn nested() {}\n}";
        assert_eq!(quals("util/mod.rs", src), vec!["util::top", "util::inner::nested"]);
    }

    #[test]
    fn impl_and_trait_owners() {
        let src = "struct Registry;\n\
                   impl Registry {\n    pub fn fit(&self) {}\n}\n\
                   trait DataFit: Send + Sync {\n    fn gamma(&self) -> f64 { 1.0 }\n}\n\
                   impl DataFit for Registry {\n    fn gamma(&self) -> f64 { 2.0 }\n}";
        assert_eq!(
            quals("serve/registry.rs", src),
            vec![
                "serve::registry::Registry::fit",
                "serve::registry::DataFit::gamma",
                "serve::registry::Registry::gamma",
            ]
        );
    }

    #[test]
    fn generic_impl_headers_and_lifetimes() {
        let src = "impl<'a, T: Fn(usize) -> f64> Wrapper<'a, T> {\n    fn call(&self) {}\n}\n\
                   impl Drop for Guard<'_> {\n    fn drop(&mut self) {}\n}";
        assert_eq!(quals("solver/mod.rs", src), vec![
            "solver::Wrapper::call",
            "solver::Guard::drop",
        ]);
    }

    #[test]
    fn return_position_impl_is_not_an_owner() {
        let src = "fn make() -> impl Iterator<Item = usize> { 0..3 }\nfn after() {}";
        assert_eq!(quals("lib.rs", src), vec!["make", "after"]);
    }

    #[test]
    fn body_spans_cover_the_braces() {
        let src = "fn f() { inner(); }";
        let pf = parse("lib.rs", src);
        let f = &pf.fns[0];
        assert_eq!(pf.lexed.toks[f.body.0].text, "{");
        assert_eq!(pf.lexed.toks[f.body.1].text, "}");
        let names: Vec<_> = pf.lexed.toks[f.body.0..=f.body.1]
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(names, vec!["inner"]);
    }

    #[test]
    fn trait_method_decls_without_bodies_are_skipped() {
        let src = "trait T {\n    fn decl(&self);\n    fn with_default(&self) {}\n}";
        assert_eq!(quals("lib.rs", src), vec!["T::with_default"]);
    }

    #[test]
    fn test_items_are_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {}\n}";
        let pf = parse("lib.rs", src);
        assert_eq!(pf.fns.len(), 2);
        assert!(!pf.fns[0].is_test);
        assert!(pf.fns[1].is_test);
    }

    #[test]
    fn use_trees_flatten_to_aliases() {
        let src = "use crate::util::sync::{lock_ok, wait_ok as wok};\nuse std::sync::Mutex;\nfn f() {}";
        let pf = parse("lib.rs", src);
        let find = |a: &str| {
            pf.uses.iter().find(|(alias, _)| alias == a).map(|(_, p)| p.join("::"))
        };
        assert_eq!(find("lock_ok").as_deref(), Some("crate::util::sync::lock_ok"));
        assert_eq!(find("wok").as_deref(), Some("crate::util::sync::wait_ok"));
        assert_eq!(find("Mutex").as_deref(), Some("std::sync::Mutex"));
    }
}
