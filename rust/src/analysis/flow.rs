//! Cross-file, call-graph-aware lints: `panic-reachability` and
//! `lock-order`. Both run over the whole parsed crate at once (unlike
//! the per-file lints in [`super::lints`]) and both over-approximate —
//! see the contract in [`super::callgraph`] and `docs/ANALYSIS.md`.

use super::callgraph::CallGraph;
use super::lexer::{Tok, TokKind};
use super::parser::ParsedFile;
use super::Finding;
use std::collections::BTreeMap;

/// Run both cross-file lints. Findings come back unsuppressed;
/// [`super::audit_sources`] applies pragmas afterwards.
pub fn run(files: &[ParsedFile], graph: &CallGraph) -> Vec<Finding> {
    let mut out = Vec::new();
    panic_reachability(files, graph, &mut out);
    lock_order(files, graph, &mut out);
    out
}

// ---------------------------------------------------------------------
// panic-reachability
// ---------------------------------------------------------------------

/// Panic-family macros: `name!(..)`.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// The transitive closure of `serve-no-panic`: starting from every
/// non-test fn in `serve/` (the HTTP entry points and everything the
/// router can invoke), walk the conservative call graph and flag
/// panic-family tokens in every reachable fn *outside* `serve/`
/// (`serve/` itself stays covered — once, not twice — by the per-file
/// `serve-no-panic` lint). The finding carries the full BFS call chain
/// so the report shows *why* the solver-side `unwrap` is a server
/// liability.
fn panic_reachability(files: &[ParsedFile], graph: &CallGraph, out: &mut Vec<Finding>) {
    let roots: Vec<usize> = (0..graph.nodes.len())
        .filter(|&i| graph.nodes[i].file.starts_with("serve/") && !graph.nodes[i].is_test)
        .collect();
    if roots.is_empty() {
        return;
    }
    let reach = graph.reach_from(&roots);
    for (v, node) in graph.nodes.iter().enumerate() {
        if !reach.visited[v] || node.file.starts_with("serve/") {
            continue;
        }
        let toks = &files[node.file_idx].lexed.toks;
        let (lo, hi) = node.body;
        for j in lo..=hi.min(toks.len().saturating_sub(1)) {
            if toks[j].kind != TokKind::Ident {
                continue;
            }
            let t = toks[j].text.as_str();
            let next = toks.get(j + 1).map(|x| x.text.as_str());
            let is_panic = if (t == "unwrap" || t == "expect") && next == Some("(") {
                // A crate-local fn of the same name shadows the std
                // panicking method: the call is then an ordinary edge
                // whose target body is scanned on its own.
                !graph.has_fn_named(t)
            } else {
                PANIC_MACROS.contains(&t) && next == Some("!")
            };
            if !is_panic {
                continue;
            }
            let chain = render_chain(graph, &reach.chain(v));
            let shape = if next == Some("!") { format!("{t}!") } else { format!("{t}()") };
            out.push(Finding {
                file: node.file.clone(),
                line: toks[j].line,
                lint: "panic-reachability",
                message: format!(
                    "`{shape}` in `{}` is reachable from a serve/ entry point \
                     (chain: {chain}) — a panic here tears down the server",
                    node.qual
                ),
                suppressed: false,
            });
        }
    }
}

/// `root -> .. -> leaf` as qualified names; long chains elide the
/// middle so messages stay one line.
fn render_chain(graph: &CallGraph, chain: &[usize]) -> String {
    let quals: Vec<&str> = chain.iter().map(|&i| graph.nodes[i].qual.as_str()).collect();
    if quals.len() <= 6 {
        quals.join(" -> ")
    } else {
        format!(
            "{} -> {} -> .. {} hops .. -> {} -> {}",
            quals[0],
            quals[1],
            quals.len() - 4,
            quals[quals.len() - 2],
            quals[quals.len() - 1]
        )
    }
}

// ---------------------------------------------------------------------
// lock-order
// ---------------------------------------------------------------------

/// One lock acquisition inside a fn body.
struct Acq {
    /// Token index of the acquisition call.
    idx: usize,
    line: u32,
    /// Normalized lock identity (dotted receiver path, `self.` stripped,
    /// indices collapsed to `[_]`).
    id: String,
    /// Last token index at which the guard is conservatively live.
    live_end: usize,
}

/// Where one ordered pair `first -> second` was observed.
#[derive(Clone)]
struct PairSite {
    file: String,
    /// Line of the *second* acquisition (taken while the first is held).
    line: u32,
    first_line: u32,
    fn_qual: String,
}

/// Per-fn lock-acquisition sequences feed a global lock-order graph;
/// any cycle in that graph is a potential deadlock: two threads can
/// each hold one lock of the cycle and block on the next. Guard
/// liveness is over-approximated (a `let`-bound guard lives to the end
/// of its block unless `drop(guard)` intervenes; a temporary guard to
/// the end of its statement), and lock identity is syntactic — both
/// choices only ever *add* edges.
fn lock_order(files: &[ParsedFile], graph: &CallGraph, out: &mut Vec<Finding>) {
    let mut edges: BTreeMap<(String, String), PairSite> = BTreeMap::new();

    for node in &graph.nodes {
        if node.is_test {
            continue;
        }
        let pf = &files[node.file_idx];
        let toks = &pf.lexed.toks;
        let has_rwlock = toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "RwLock");
        let (lo, hi) = node.body;
        let hi = hi.min(toks.len().saturating_sub(1));
        let mut acqs: Vec<Acq> = Vec::new();
        for j in lo..=hi {
            if toks[j].kind != TokKind::Ident
                || !toks.get(j + 1).is_some_and(|t| t.text == "(")
            {
                continue;
            }
            let t = toks[j].text.as_str();
            let id = if t == "lock_ok" {
                // lock_ok(&self.inner.state) — identity from the argument.
                receiver_forward(toks, j + 2)
            } else if t == "lock" || (has_rwlock && (t == "read" || t == "write")) {
                // x.lock() — identity from the receiver, if the token
                // before the name is the method dot.
                if j >= 2 && toks[j - 1].text == "." {
                    receiver_backward(toks, j - 2)
                } else {
                    None
                }
            } else {
                None
            };
            let Some(id) = id else { continue };
            let live_end = guard_live_end(toks, j, hi);
            acqs.push(Acq { idx: j, line: toks[j].line, id, live_end });
        }

        for a in 0..acqs.len() {
            for b in (a + 1)..acqs.len() {
                if acqs[b].idx > acqs[a].live_end {
                    break;
                }
                if acqs[a].id == acqs[b].id {
                    out.push(Finding {
                        file: node.file.clone(),
                        line: acqs[b].line,
                        lint: "lock-order",
                        message: format!(
                            "`{}` re-acquired in `{}` while already held since line {} \
                             — std::sync locks are not reentrant (self-deadlock)",
                            acqs[b].id, node.qual, acqs[a].line
                        ),
                        suppressed: false,
                    });
                    continue;
                }
                edges
                    .entry((acqs[a].id.clone(), acqs[b].id.clone()))
                    .or_insert_with(|| PairSite {
                        file: node.file.clone(),
                        line: acqs[b].line,
                        first_line: acqs[a].line,
                        fn_qual: node.qual.clone(),
                    });
            }
        }
    }

    // Global cycle check: flag every edge whose reverse direction is
    // reachable in the order graph (each such site is one constituent
    // of a deadlock cycle, so each gets its own finding).
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.as_str()).or_default().push(to.as_str());
    }
    for ((from, to), site) in &edges {
        let Some(path) = order_path(&adj, to, from) else { continue };
        // Witness: where the first reverse step was observed.
        let witness = edges
            .get(&(to.clone(), path[1].to_string()))
            .map(|w| format!(" (reverse order at {}:{} in `{}`)", w.file, w.line, w.fn_qual))
            .unwrap_or_default();
        let cycle: Vec<&str> =
            std::iter::once(from.as_str()).chain(path.iter().copied()).collect();
        out.push(Finding {
            file: site.file.clone(),
            line: site.line,
            lint: "lock-order",
            message: format!(
                "lock-order cycle: `{}` (line {}) is held while acquiring `{}` in `{}`, \
                 but the lock-order graph also orders {} — potential deadlock{}",
                from,
                site.first_line,
                to,
                site.fn_qual,
                cycle.join(" -> "),
                witness
            ),
            suppressed: false,
        });
    }
}

/// Shortest path `from -> .. -> to` in the order graph (BFS over sorted
/// adjacency), as lock ids including both endpoints. `None` if
/// unreachable.
fn order_path<'a>(
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    from: &'a str,
    to: &str,
) -> Option<Vec<&'a str>> {
    let mut parent: BTreeMap<&str, &str> = BTreeMap::new();
    let mut queue = std::collections::VecDeque::from([from]);
    parent.insert(from, from);
    while let Some(v) = queue.pop_front() {
        if v == to {
            let mut path = vec![v];
            let mut cur = v;
            while parent[cur] != cur {
                cur = parent[cur];
                path.push(cur);
            }
            path.reverse();
            return Some(path);
        }
        for &w in adj.get(v).into_iter().flatten() {
            parent.entry(w).or_insert_with(|| {
                queue.push_back(w);
                v
            });
        }
    }
    None
}

/// Identity of `lock_ok(&self.a.b[i])`'s argument, scanning forward
/// from just past the `(`.
fn receiver_forward(toks: &[Tok], mut j: usize) -> Option<String> {
    while toks.get(j).is_some_and(|t| t.text == "&" || t.text == "mut") {
        j += 1;
    }
    let mut segs: Vec<String> = Vec::new();
    loop {
        match toks.get(j) {
            Some(t) if t.kind == TokKind::Ident => {
                segs.push(t.text.clone());
                j += 1;
            }
            _ => break,
        }
        match toks.get(j).map(|t| t.text.as_str()) {
            Some(".") => j += 1,
            Some("[") => {
                let mut d = 0i32;
                while let Some(t) = toks.get(j) {
                    if t.text == "[" {
                        d += 1;
                    } else if t.text == "]" {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
                segs.push("[_]".to_string());
                j += 1;
                if toks.get(j).is_some_and(|t| t.text == ".") {
                    j += 1;
                } else {
                    break;
                }
            }
            _ => break,
        }
    }
    normalize_id(segs)
}

/// Identity of the receiver of `recv.lock()`, scanning backward from
/// the token before the method dot.
fn receiver_backward(toks: &[Tok], end: usize) -> Option<String> {
    let mut segs: Vec<String> = Vec::new();
    let mut j = end as i64;
    loop {
        if j < 0 {
            break;
        }
        let ju = j as usize;
        if toks[ju].text == "]" {
            let mut d = 0i32;
            while j >= 0 {
                let t = toks[j as usize].text.as_str();
                if t == "]" {
                    d += 1;
                } else if t == "[" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                j -= 1;
            }
            segs.push("[_]".to_string());
            j -= 1;
            if !(j >= 0 && toks[j as usize].kind == TokKind::Ident) {
                break;
            }
            continue;
        }
        if toks[ju].kind == TokKind::Ident {
            segs.push(toks[ju].text.clone());
            if ju >= 1 && toks[ju - 1].text == "." {
                j -= 2;
                continue;
            }
        }
        break;
    }
    segs.reverse();
    normalize_id(segs)
}

/// Join segments, dropping a leading `self` (so `self.state` in a
/// method and `state` on a local borrow of the same field agree).
fn normalize_id(mut segs: Vec<String>) -> Option<String> {
    if segs.first().is_some_and(|s| s == "self") {
        segs.remove(0);
    }
    if segs.is_empty() || segs == ["[_]"] {
        return None;
    }
    Some(segs.join("."))
}

/// Last token index at which the guard produced at `idx` is
/// conservatively live: end of the enclosing block for `let`-bound
/// guards (or the `drop(name)` that releases it early), end of the
/// statement for temporaries.
fn guard_live_end(toks: &[Tok], idx: usize, hi: usize) -> usize {
    // Is the containing statement a `let`?
    let mut b = idx as i64 - 1;
    while b >= 0 {
        let t = toks[b as usize].text.as_str();
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        b -= 1;
    }
    let mut first_ident = None;
    for t in toks.iter().take(idx).skip((b + 1).max(0) as usize) {
        if t.kind == TokKind::Ident {
            first_ident = Some(t.text.as_str());
            break;
        }
    }
    let let_bound = first_ident == Some("let");
    // Guard name: first ident after `let`, skipping `mut` (patterns like
    // `let Some(x) = ..` yield a non-name — drop() tracking then simply
    // never fires, which only extends liveness, i.e. stays conservative).
    let guard_name: Option<String> = if let_bound {
        let mut j = (b + 1).max(0) as usize;
        let mut name = None;
        let mut seen_let = false;
        while j < idx {
            if toks[j].kind == TokKind::Ident {
                match toks[j].text.as_str() {
                    "let" => seen_let = true,
                    "mut" => {}
                    other if seen_let => {
                        name = Some(other.to_string());
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        name
    } else {
        None
    };

    let mut depth = 0i32;
    let mut stmt_end: Option<usize> = None;
    let mut j = idx;
    while j <= hi {
        let t = toks[j].text.as_str();
        match t {
            "{" | "(" | "[" => depth += 1,
            "}" | ")" | "]" => {
                if depth == 0 && t == "}" {
                    // enclosing block closes here
                    return if let_bound { j } else { stmt_end.unwrap_or(j) };
                }
                depth -= 1;
            }
            ";" if depth == 0 => {
                if !let_bound {
                    return j;
                }
                stmt_end.get_or_insert(j);
            }
            "drop" if toks[j].kind == TokKind::Ident && let_bound => {
                let dropped = toks.get(j + 1).is_some_and(|t| t.text == "(")
                    && toks.get(j + 2).map(|t| Some(&t.text) == guard_name.as_ref())
                        == Some(true)
                    && toks.get(j + 3).is_some_and(|t| t.text == ")");
                if dropped {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::super::parser::{parse, ParsedFile};
    use super::*;

    fn findings(files: &[(&str, &str)]) -> Vec<Finding> {
        let parsed: Vec<ParsedFile> =
            files.iter().map(|(rel, src)| parse(rel, src)).collect();
        let graph = CallGraph::build(&parsed);
        run(&parsed, &graph)
    }

    #[test]
    fn seeded_panic_outside_serve_is_caught_with_chain() {
        // The ISSUE.md acceptance fixture: a panic in a serve-reachable
        // callee *outside* serve/ must be caught, with the chain shown.
        let got = findings(&[
            ("serve/http.rs", "pub fn handle() { crate::solver::solve(); }"),
            ("solver/mod.rs", "pub fn solve() { step(); }\nfn step() { x.unwrap(); }"),
        ]);
        let hits: Vec<_> = got.iter().filter(|f| f.lint == "panic-reachability").collect();
        assert_eq!(hits.len(), 1, "{got:?}");
        assert_eq!(hits[0].file, "solver/mod.rs");
        assert_eq!(hits[0].line, 2);
        assert!(
            hits[0].message.contains("serve::http::handle -> solver::solve -> solver::step"),
            "chain missing: {}",
            hits[0].message
        );
    }

    #[test]
    fn unreachable_panics_and_serve_files_are_not_double_reported() {
        let got = findings(&[
            ("serve/http.rs", "pub fn handle() { helper(); }\nfn helper() {}"),
            // never called from serve/: out of reach
            ("solver/mod.rs", "pub fn offline() { x.unwrap(); }"),
        ]);
        assert!(got.iter().all(|f| f.lint != "panic-reachability"), "{got:?}");

        // a panic inside serve/ itself belongs to serve-no-panic only
        let got = findings(&[("serve/http.rs", "pub fn handle() { x.unwrap(); }")]);
        assert!(got.iter().all(|f| f.lint != "panic-reachability"), "{got:?}");
    }

    #[test]
    fn panic_macros_count_and_tests_do_not() {
        let got = findings(&[
            ("serve/http.rs", "pub fn handle() { crate::solver::go(); }"),
            (
                "solver/mod.rs",
                "pub fn go() { if bad { panic!(\"boom\") } }\n\
                 #[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { go(); x.unwrap(); }\n}",
            ),
        ]);
        let hits: Vec<_> = got.iter().filter(|f| f.lint == "panic-reachability").collect();
        assert_eq!(hits.len(), 1, "{got:?}");
        assert!(hits[0].message.contains("panic!"), "{}", hits[0].message);
    }

    #[test]
    fn inverted_two_mutex_order_is_a_cycle() {
        // The ISSUE.md acceptance fixture: fn a takes A then B, fn b
        // takes B then A.
        let src = "use crate::util::sync::lock_ok;\n\
                   fn a(x: &S) {\n  let g1 = lock_ok(&x.alpha);\n  let g2 = lock_ok(&x.beta);\n}\n\
                   fn b(x: &S) {\n  let g1 = lock_ok(&x.beta);\n  let g2 = lock_ok(&x.alpha);\n}";
        let got = findings(&[("solver/parallel.rs", src)]);
        let hits: Vec<_> = got.iter().filter(|f| f.lint == "lock-order").collect();
        assert_eq!(hits.len(), 2, "one finding per direction: {got:?}");
        assert!(hits[0].message.contains("cycle"), "{}", hits[0].message);
        assert!(
            hits.iter().any(|f| f.line == 4) && hits.iter().any(|f| f.line == 8),
            "anchored at the second acquisition of each fn: {hits:?}"
        );
        assert!(
            hits.iter().any(|f| f.message.contains("reverse order at")),
            "counterpart site cited: {hits:?}"
        );
    }

    #[test]
    fn consistent_order_and_dropped_guards_are_clean() {
        // Same order in both fns: no cycle.
        let consistent = "fn a(x: &S) { let g1 = lock_ok(&x.alpha); let g2 = lock_ok(&x.beta); }\n\
                          fn b(x: &S) { let g1 = lock_ok(&x.alpha); let g2 = lock_ok(&x.beta); }";
        let got = findings(&[("serve/jobs.rs", consistent)]);
        assert!(got.iter().all(|f| f.lint != "lock-order"), "{got:?}");

        // drop() between inverted acquisitions: never held together.
        let dropped = "fn a(x: &S) { let g1 = lock_ok(&x.alpha); drop(g1); let g2 = lock_ok(&x.beta); }\n\
                       fn b(x: &S) { let g1 = lock_ok(&x.beta); drop(g1); let g2 = lock_ok(&x.alpha); }";
        let got = findings(&[("serve/jobs.rs", dropped)]);
        assert!(got.iter().all(|f| f.lint != "lock-order"), "{got:?}");
    }

    #[test]
    fn method_lock_receivers_and_self_normalize() {
        // `self.state.lock()` in one fn and `lock_ok(&self.state)` in
        // another must agree on the identity `state`.
        let src = "impl R {\n\
                     fn a(&self) { let g = self.state.lock(); let h = lock_ok(&self.aux); }\n\
                     fn b(&self) { let g = lock_ok(&self.aux); let h = lock_ok(&self.state); }\n\
                   }";
        let got = findings(&[("serve/registry.rs", src)]);
        let hits: Vec<_> = got.iter().filter(|f| f.lint == "lock-order").collect();
        assert_eq!(hits.len(), 2, "state->aux vs aux->state: {got:?}");
    }

    #[test]
    fn reacquiring_a_held_lock_is_flagged() {
        let src = "fn a(x: &S) { let g1 = lock_ok(&x.state); let g2 = lock_ok(&x.state); }";
        let got = findings(&[("serve/jobs.rs", src)]);
        let hits: Vec<_> = got.iter().filter(|f| f.lint == "lock-order").collect();
        assert_eq!(hits.len(), 1, "{got:?}");
        assert!(hits[0].message.contains("not reentrant"), "{}", hits[0].message);
    }
}
