//! A small hand-rolled Rust lexer — just enough syntax for the audit
//! lints in [`super::lints`], zero dependencies.
//!
//! The lexer understands exactly the constructs that would otherwise
//! make naive text matching lie about source code:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments,
//!   collected separately so lints can search them for `// SAFETY:` and
//!   `// audit-allow(...)` pragmas without them shadowing real tokens;
//! * string literals, including escapes, byte strings and raw strings
//!   (`r"…"`, `r#"…"#` with any hash count) — their contents produce no
//!   tokens, so an identifier *named* in a message cannot trip a lint;
//! * char literals vs. lifetimes (`'a'` vs. `'a`);
//! * identifiers, numbers, and single-char punctuation.
//!
//! Everything else (operators, generics, attributes) comes out as
//! punctuation tokens; the lints do their own lightweight structural
//! matching (attribute spans, fn bodies, statement prefixes) on top of
//! this stream.

/// Lexical class of a [`Tok`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    /// Any string literal (normal, raw, byte). Contents are dropped.
    Str,
    /// A char literal. Contents are dropped.
    Char,
    /// A lifetime (`'a`). Text includes the leading quote.
    Lifetime,
    /// One punctuation character.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line or block, doc or plain) with the 1-based line it
/// *starts* on and its full text including the `//` / `/*` markers.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// A lexed source file: the token stream plus the comment side channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Lex `src`. Never fails: unterminated constructs consume to EOF, which
/// is good enough for an auditor (rustc rejects such files anyway).
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment { line, text: cs[start..i].iter().collect() });
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment { line: start_line, text: cs[start..i].iter().collect() });
            continue;
        }
        // Raw (and raw byte) strings: r"…", r#"…"#, br##"…"##, …
        if c == 'r' || c == 'b' {
            let mut k = i;
            if cs[k] == 'b' {
                k += 1;
            }
            if k < n && cs[k] == 'r' {
                k += 1;
                let mut hashes = 0usize;
                while k < n && cs[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && cs[k] == '"' {
                    let mut j = k + 1;
                    while j < n {
                        if cs[j] == '\n' {
                            line += 1;
                        }
                        if cs[j] == '"'
                            && j + hashes < n
                            && cs[j + 1..j + 1 + hashes].iter().all(|&h| h == '#')
                        {
                            j += 1 + hashes;
                            break;
                        }
                        j += 1;
                    }
                    out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
                    i = j;
                    continue;
                }
            }
        }
        // Normal (and byte) strings.
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                if cs[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            i = j;
            continue;
        }
        // Char literal vs. lifetime.
        if c == '\'' {
            if i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_') {
                // 'x' is a char literal iff a closing quote follows the
                // ident-ish run ('a' vs. the lifetime 'a).
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if j < n && cs[j] == '\'' {
                    out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                    i = j + 1;
                } else {
                    out.toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: cs[i..j].iter().collect(),
                        line,
                    });
                    i = j;
                }
                continue;
            }
            // Escaped or non-alphabetic char literal: '\n', '\u{..}', '0'.
            let mut j = i + 1;
            if j < n && cs[j] == '\\' {
                j += 2;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                i = j + 1;
            } else {
                i = j + 2;
            }
            out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: cs[start..j].iter().collect(), line });
            i = j;
            continue;
        }
        // Number (incl. 1e-6-style floats minus the sign, underscores,
        // and suffixes; `..` is left to punctuation).
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '.' || cs[j] == '_') {
                if cs[j] == '.' && j + 1 < n && cs[j + 1] == '.' {
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Num, text: cs[start..j].iter().collect(), line });
            i = j;
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn plain_tokens_and_lines() {
        let lx = lex("fn main() {\n    let x = 1;\n}\n");
        let fn_tok = &lx.toks[0];
        assert_eq!(fn_tok.kind, TokKind::Ident);
        assert_eq!(fn_tok.text, "fn");
        assert_eq!(fn_tok.line, 1);
        let x = lx.toks.iter().find(|t| t.text == "x").unwrap();
        assert_eq!(x.line, 2);
        let num = lx.toks.iter().find(|t| t.kind == TokKind::Num).unwrap();
        assert_eq!(num.text, "1");
    }

    #[test]
    fn string_contents_produce_no_tokens() {
        // "unwrap" only appears inside string literals — no Ident token.
        let ids = idents(r#"let msg = "please unwrap me"; call(msg);"#);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"call".to_string()));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let a = r#\"has \"quotes\" and unwrap()\"#; next();";
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"next".to_string()));
        // any hash count, and byte-raw too
        let src2 = "let b = br##\"x \"# y\"##; tail();";
        assert!(idents(src2).contains(&"tail".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner unwrap() */ still comment */ fn f() {}";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("inner"));
        let ids: Vec<_> = lx.toks.iter().filter(|t| t.kind == TokKind::Ident).collect();
        assert_eq!(ids[0].text, "fn");
    }

    #[test]
    fn line_comments_are_collected_with_lines() {
        let src = "let a = 1; // first\n// SAFETY: fine\nlet b = 2;\n";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 2);
        assert_eq!(lx.comments[0].line, 1);
        assert_eq!(lx.comments[1].line, 2);
        assert!(lx.comments[1].text.contains("SAFETY:"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let lx = lex("fn f<'a>(x: &'a str) { let c = 'a'; let d = '\\n'; let e = '0'; }");
        let lifetimes: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2, "{lifetimes:?}");
        assert!(lifetimes.iter().all(|t| t.text == "'a"));
        let chars = lx.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 3);
    }

    #[test]
    fn attributes_tokenize_structurally() {
        let lx = lex("#[cfg(test)]\nmod tests {}\n#[target_feature(enable = \"avx2\")]\nunsafe fn g() {}");
        let texts: Vec<_> = lx.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.windows(2).any(|w| w == ["#", "["]));
        assert!(texts.contains(&"target_feature"));
        // the "avx2" literal is a Str token with no text
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn multiline_strings_track_lines() {
        let src = "let s = \"line one\nline two\";\nfinal_ident();";
        let lx = lex(src);
        let f = lx.toks.iter().find(|t| t.text == "final_ident").unwrap();
        assert_eq!(f.line, 3);
    }
}
