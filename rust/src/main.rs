//! `gapsafe` — launcher / CLI for the Gap Safe screening framework.
//!
//! Subcommands (arg parsing is hand-rolled: the offline registry has no clap):
//!
//!   gapsafe path      --task lasso --data synth:leukemia --rule gap --warm active --eps 1e-6
//!                     [--threads 4]   (chunked parallel path engine)
//!   gapsafe solve     --task lasso --data synth:leukemia --lam-ratio 0.1 --rule gap-dyn
//!                     [--threads 4]   (parallel screening sweep)
//!   gapsafe cv        --task lasso --data ... --folds 5 [--threads auto]   (K-fold CV)
//!   gapsafe batch     --jobs 8 [--threads auto]   (BatchRunner serving demo)
//!   gapsafe serve     --port 7878 --threads auto --cache-mb 256   (resident HTTP model server)
//!   gapsafe fig3|fig4|fig5|fig6    [--small] [--out results/]
//!   gapsafe selftest  [--artifacts artifacts/]   (PJRT vs native gap check)
//!   gapsafe artifacts [--artifacts artifacts/]   (list + validate manifest)
//!   gapsafe lmax      --task ... --data ...
//!   gapsafe audit     [--src rust/src] [--format text|json|sarif] [--lint a,b]
//!                     (static-analysis lint gate: per-file + call-graph lints)

use gapsafe::coordinator::cv::{kfold_cv, CvConfig};
use gapsafe::coordinator::{active_fraction_experiment, report, time_to_convergence, BatchRunner};
use gapsafe::data::{load_spec, synth};
use gapsafe::penalty::ActiveSet;
use gapsafe::runtime::{artifact, PjrtEngine};
use gapsafe::screening::{DualStrategy, Rule};
use gapsafe::serve::{ServeConfig, Server};
use gapsafe::solver::path::{lambda_grid, lambda_grid_checked, solve_path, PathConfig, WarmStart};
use gapsafe::solver::{solve_fixed_lambda, SolveOptions};
use gapsafe::{build_problem, Task};

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        usage();
        return ExitCode::FAILURE;
    };
    let opts = parse_flags(rest);
    // Fail fast on a bad GAPSAFE_KERNEL before any work: the lazy kernel
    // initializer itself degrades to scalar (it is serve-reachable and
    // must not panic), so the CLI owns the strict check.
    let setup = gapsafe::linalg::kernels::validate_env()
        .and_then(|()| apply_kernel_flag(&opts))
        .and_then(|()| apply_trace_flag(&opts));
    let r = setup.and_then(|()| match cmd.as_str() {
        "path" => cmd_path(&opts),
        "solve" => cmd_solve(&opts),
        "cv" => cmd_cv(&opts),
        "batch" => cmd_batch(&opts),
        "serve" => cmd_serve(&opts),
        "fig3" => cmd_fig(&opts, 3),
        "fig4" => cmd_fig(&opts, 4),
        "fig5" => cmd_fig(&opts, 5),
        "fig6" => cmd_fig(&opts, 6),
        "selftest" => cmd_selftest(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "lmax" => cmd_lmax(&opts),
        "trace" => cmd_trace(rest, &opts),
        "audit" => cmd_audit(&opts),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    });
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() {
    eprintln!(
        "gapsafe — Gap Safe screening rules (Ndiaye et al., 2017)\n\
         usage: gapsafe <subcommand> [flags]\n\
         subcommands:\n\
           path       solve a full lambda path (chunked parallel engine with --threads)\n\
           solve      one fixed-lambda solve (--lam-ratio; parallel screening sweep)\n\
           cv         K-fold cross-validation over the path grid (--folds, --threads)\n\
           batch      BatchRunner demo: --jobs independent path requests over the pool\n\
           serve      resident HTTP model server (see below)\n\
           fig3..fig6 regenerate the paper's figure protocols into --out\n\
           selftest   PJRT-vs-native duality-gap consistency check\n\
           artifacts  list + validate the AOT artifact manifest\n\
           lmax       print lambda_max for a (task, data) pair\n\
           trace      analyze a --trace-out JSONL file (summarize | lambda-table | flame),\n\
                      or re-check its screening ledger against the data (verify)\n\
           audit      static-analysis lint pass over rust/src (exit 1 on findings)\n\
           help       this text\n\
         common flags:\n\
           --task lasso|group-lasso|sgl[:tau]|logreg|multitask|multinomial|poisson\n\
           --data synth:leukemia | synth:meg | synth:climate | csv:<path> |\n\
                      synth:reg:<n>x<p> | synth:counts[:<n>x<p>]\n\
           --datafit quadratic|logistic|poisson (family shorthand: picks the task and\n\
                      a matching default dataset; --task / --data still override)\n\
           --rule none|static|elghaoui|dst3|bonnefoy|gap-seq|gap-dyn|gap|strong\n\
           --warm standard|active|strong     --eps 1e-6   --grid 100 (>= 1)   --delta 3\n\
           --threads N|auto (>= 1 workers, auto = all cores; path chunks / CV folds /\n\
                      batch jobs; path/solve default 1 = exact serial, cv/batch default auto)\n\
           --dual rescale|best|refine (dual-point strategy of the gap passes; default\n\
                      best = monotone Gap Safe radii, rescale = historical bitwise output)\n\
           --seed 42   --small (shrink synthetic workloads)   --out results\n\
           --max-epochs 10000   --fce 10 (gap/screening cadence)\n\
           --kernel scalar|avx2|auto (SIMD kernel backend, default auto = best\n\
                      supported; GAPSAFE_KERNEL env equivalent. All backends are\n\
                      bitwise identical — a pure performance knob)\n\
           --no-compact (path/solve/cv/batch/serve: disable active-set compaction;\n\
                         bitwise-identical, slower — fig3..fig6 always compact)\n\
           --trace-out FILE (write structured solver/serve trace events as JSONL;\n\
                         bitwise-transparent — read it back with `gapsafe trace`)\n\
         per-subcommand flags:\n\
           cv:        --folds 5\n\
           batch:     --jobs 8\n\
           solve:     --lam-ratio 0.1\n\
           serve:     --port 7878   --host 127.0.0.1   --threads auto (HTTP workers)\n\
                      --workers auto (fit workers)   --cache-mb 256 (registry budget)\n\
                      --max-body-mb 16 (reject larger request bodies with 413)\n\
                      endpoints: GET /healthz | GET /metrics | POST /v1/fit\n\
                                 GET /v1/jobs/<id> | POST /v1/predict   (docs/SERVING.md)\n\
           selftest/artifacts: --artifacts artifacts (manifest dir)\n\
           trace:     --in trace.jsonl (a file produced by --trace-out)\n\
                      --strict (hard-error on a truncated trailing trace line)\n\
                      verify: --task/--data/--datafit/--seed/--small pick the dataset\n\
                      the trace was recorded against; exit 1 on any violation\n\
           audit:     --src rust/src (source root)   --format text|json|sarif\n\
                      --lint a,b (run only the named lints)\n\
                      lints: float-determinism simd-containment trace-transparency\n\
                             unsafe-hygiene determinism serve-no-panic\n\
                             screening-soundness panic-reachability lock-order\n\
                             (docs/ANALYSIS.md has the catalogue + call-graph contract)"
    );
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Flags {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn flag<'a>(o: &'a Flags, k: &str, default: &'a str) -> &'a str {
    o.get(k).map(String::as_str).unwrap_or(default)
}

fn flag_f64(o: &Flags, k: &str, default: f64) -> Result<f64, String> {
    match o.get(k) {
        Some(v) => v.parse().map_err(|e| format!("--{k}: {e}")),
        None => Ok(default),
    }
}

fn flag_usize(o: &Flags, k: &str, default: usize) -> Result<usize, String> {
    match o.get(k) {
        Some(v) => v.parse().map_err(|e| format!("--{k}: {e}")),
        None => Ok(default),
    }
}

/// `--grid` validated at parse time: `lambda_grid` requires at least one
/// point, so `--grid 0` must be a clean CLI error, not a panic (the serve
/// fit endpoint applies the same rule in `ModelKey::from_json`).
fn flag_grid(o: &Flags, default: usize) -> Result<usize, String> {
    let n = flag_usize(o, "grid", default)?;
    if n == 0 {
        return Err("--grid must be >= 1 (the lambda grid needs at least one point)".into());
    }
    Ok(n)
}

/// Resolve `(task, data spec)` from `--task` / `--data`, honoring
/// `--datafit quadratic|logistic|poisson` as a family shorthand: it picks
/// both the task and a matching default dataset, each still overridable
/// by the explicit flag.
fn flag_task_data(
    o: &Flags,
    default_task: &str,
    default_data: &str,
) -> Result<(Task, String), String> {
    let (task_s, data_s) = match o.get("datafit").map(String::as_str) {
        None => (default_task, default_data),
        Some("quadratic") | Some("ls") => ("lasso", "synth:leukemia"),
        Some("logistic") => ("logreg", "synth:leukemia-binary"),
        Some("poisson") => ("poisson", "synth:counts"),
        Some(other) => {
            return Err(format!(
                "--datafit: unknown family '{other}' (quadratic | logistic | poisson)"
            ))
        }
    };
    Ok((Task::parse(flag(o, "task", task_s))?, flag(o, "data", data_s).to_string()))
}

/// Active-set compaction toggle (on unless `--no-compact`; bitwise
/// transparent either way — see `linalg::compact`).
fn flag_compact(o: &Flags) -> bool {
    !o.contains_key("no-compact")
}

/// Dual-point strategy for the gap passes (`--dual rescale|best|refine`,
/// default `best` — see the `screening::dual` module docs).
fn flag_dual(o: &Flags) -> Result<DualStrategy, String> {
    DualStrategy::parse(flag(o, "dual", "best")).map_err(|e| format!("--dual: {e}"))
}

/// Worker-count flag (`--threads`, `--workers`): `auto` / `all` resolve
/// to every available core *at parse time*, a positive integer is taken
/// literally, and a literal `0` is rejected with a pointer to `auto` —
/// a zero-worker pool is never what the user meant, and letting it
/// through historically made downstream layers silently reinterpret it
/// (mirrors the `--grid 0` fix; `PathConfig::validate` backstops this).
fn flag_workers(o: &Flags, key: &str, default: usize) -> Result<usize, String> {
    match o.get(key).map(String::as_str) {
        None => Ok(default),
        Some("auto") | Some("all") => {
            Ok(gapsafe::solver::parallel::effective_threads(0))
        }
        Some(v) => {
            let n: usize = v.parse().map_err(|e| format!("--{key}: {e}"))?;
            if n == 0 {
                return Err(format!(
                    "--{key} must be >= 1 (use --{key} auto, or omit the flag, for all cores)"
                ));
            }
            Ok(n)
        }
    }
}

/// All-cores default for the subcommands whose historical default was
/// "use the whole machine" (cv / batch / serve).
fn auto_workers() -> usize {
    gapsafe::solver::parallel::effective_threads(0)
}

/// `--kernel scalar|avx2|auto`: select the SIMD kernel backend for the
/// whole process (overrides `GAPSAFE_KERNEL`; every backend is bitwise
/// identical — see `linalg::kernels` — so this is purely a perf knob).
/// Applied before any subcommand runs so even `lambda_max` at parse time
/// uses the requested backend.
fn apply_kernel_flag(o: &Flags) -> Result<(), String> {
    if let Some(spec) = o.get("kernel") {
        gapsafe::linalg::kernels::select_str(spec).map_err(|e| format!("--kernel: {e}"))?;
    }
    Ok(())
}

/// `--trace-out <file>`: install a process-wide JSONL trace sink before
/// the subcommand runs, so every solver span and serve request lands in
/// the file (`gapsafe trace` reads it back). Absent flag = no sink = the
/// zero-overhead fast path (see `obs`).
fn apply_trace_flag(o: &Flags) -> Result<(), String> {
    if let Some(path) = o.get("trace-out") {
        let sink =
            gapsafe::obs::trace::FileSink::create(path).map_err(|e| format!("--trace-out: {e}"))?;
        gapsafe::obs::install(Box::new(sink));
    }
    Ok(())
}

/// `gapsafe trace [summarize|lambda-table|flame|verify] --in <trace.jsonl>`:
/// offline analysis of a `--trace-out` file. `verify` additionally needs
/// the data the trace was recorded against (`--task`/`--data`/`--datafit`
/// /`--seed`/`--small`, same resolution as `path`) and exits nonzero if
/// any recorded screening decision fails its independent re-check.
fn cmd_trace(rest: &[String], o: &Flags) -> Result<(), String> {
    let mode = rest
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .unwrap_or("summarize");
    let path = o
        .get("in")
        .map(String::as_str)
        .ok_or("trace needs --in <trace.jsonl> (write one with --trace-out)")?;
    let strict = o.contains_key("strict");
    let events = gapsafe::obs::analyze::load_opts(path, strict)?;
    let out = match mode {
        "summarize" => gapsafe::obs::analyze::summarize(&events),
        "lambda-table" => gapsafe::obs::analyze::lambda_table(&events),
        "flame" => gapsafe::obs::analyze::flame(&events),
        "verify" => {
            let seed = flag_usize(o, "seed", 42)? as u64;
            let small = o.contains_key("small");
            let (task, data) = flag_task_data(o, "lasso", "synth:leukemia")?;
            let ds = load_spec(&data, seed, small)?;
            let prob = build_problem(ds, task)?;
            let rep = gapsafe::obs::analyze::verify(&events, &prob);
            let text = rep.render();
            if !rep.ok() {
                return Err(format!("trace verify FAILED:\n{text}"));
            }
            text
        }
        other => {
            return Err(format!(
                "unknown trace mode '{other}' (summarize | lambda-table | flame | verify)"
            ))
        }
    };
    println!("{out}");
    Ok(())
}

/// `gapsafe audit [--src DIR] [--format text|json|sarif] [--lint a,b]`:
/// run the static invariant lints over the source tree; non-zero exit
/// on any unsuppressed finding (the CI hard gate — see
/// `docs/ANALYSIS.md`).
fn cmd_audit(o: &Flags) -> Result<(), String> {
    let root = match o.get("src") {
        Some(p) => PathBuf::from(p),
        None => default_src_root()?,
    };
    let mut report = gapsafe::analysis::audit_tree(&root)?;
    if let Some(spec) = o.get("lint") {
        let names: Vec<String> =
            spec.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        if names.is_empty() {
            return Err("audit: --lint needs at least one lint name".to_string());
        }
        for n in &names {
            if !gapsafe::analysis::lints::LINT_NAMES.contains(&n.as_str()) {
                return Err(format!(
                    "audit: unknown lint '{n}' (have: {})",
                    gapsafe::analysis::lints::LINT_NAMES.join(", ")
                ));
            }
        }
        report.retain_lints(&names);
    }
    match flag(o, "format", "text") {
        "json" => println!("{}", report.to_json()),
        "sarif" => println!("{}", report.to_sarif()),
        "text" => print!("{}", report.render_text()),
        other => return Err(format!("unknown --format '{other}' (text | json | sarif)")),
    }
    let unsuppressed = report.unsuppressed();
    if unsuppressed > 0 {
        return Err(format!("audit: {unsuppressed} unsuppressed finding(s)"));
    }
    Ok(())
}

/// Where the crate sources live when `--src` is not given: `rust/src`
/// from the repo root, `src` from the crate dir, else the build-time
/// manifest dir (works for `cargo run` from anywhere on the CI host).
fn default_src_root() -> Result<PathBuf, String> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    if manifest.is_dir() {
        return Ok(manifest);
    }
    Err("audit: cannot locate the source tree (pass --src <dir>)".to_string())
}

fn cmd_serve(o: &Flags) -> Result<(), String> {
    let host = flag(o, "host", "127.0.0.1");
    let port = flag_usize(o, "port", 7878)?;
    let max_body_mb = flag_usize(o, "max-body-mb", 16)?;
    if max_body_mb == 0 {
        return Err("--max-body-mb must be >= 1".into());
    }
    let cfg = ServeConfig {
        addr: format!("{host}:{port}"),
        http_threads: flag_workers(o, "threads", auto_workers())?,
        fit_workers: flag_workers(o, "workers", auto_workers())?,
        cache_mb: flag_usize(o, "cache-mb", 256)?,
        compact: flag_compact(o),
        dual: flag_dual(o)?,
        max_body_mb,
    };
    let server = Server::bind(&cfg)?;
    println!(
        "gapsafe serve: listening on {host}:{} (cache {} MiB, kernel backend {})",
        server.port(),
        cfg.cache_mb,
        gapsafe::linalg::kernels::active_kind().label()
    );
    println!("endpoints: /healthz /metrics /v1/fit /v1/jobs/<id> /v1/predict  (docs/SERVING.md)");
    // Runs until the process is killed.
    server.run()
}

fn cmd_path(o: &Flags) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let small = o.contains_key("small");
    let (task, data) = flag_task_data(o, "lasso", "synth:leukemia")?;
    let ds = load_spec(&data, seed, small)?;
    let prob = build_problem(ds, task)?;
    let cfg = PathConfig {
        n_lambdas: flag_grid(o, 100)?,
        delta: flag_f64(o, "delta", 3.0)?,
        rule: Rule::parse(flag(o, "rule", "gap"))?,
        warm: WarmStart::parse(flag(o, "warm", "standard"))?,
        eps: flag_f64(o, "eps", 1e-6)?,
        eps_is_absolute: false,
        max_epochs: flag_usize(o, "max-epochs", 10_000)?,
        screen_every: flag_usize(o, "fce", 10)?,
        threads: flag_workers(o, "threads", 1)?,
        compact: flag_compact(o),
        dual: flag_dual(o)?,
    };
    cfg.validate()?;
    // Degenerate anchors (e.g. Poisson lambda_max = 0 on all-zero counts)
    // must fail here with a message, not produce a NaN-filled grid.
    lambda_grid_checked(prob.lambda_max(), cfg.n_lambdas, cfg.delta)?;
    let res = solve_path(&prob, &cfg);
    println!(
        "{:>4} {:>12} {:>10} {:>8} {:>8} {:>9} {:>9} {:>10}",
        "t", "lambda", "gap", "epochs", "active", "nnz_rows", "nnz_coef", "seconds"
    );
    for (t, p) in res.points.iter().enumerate() {
        println!(
            "{:>4} {:>12.5e} {:>10.2e} {:>8} {:>8} {:>9} {:>9} {:>10.4}",
            t, p.lam, p.gap, p.epochs, p.n_active_feats, p.nnz_rows, p.nnz_coefs, p.seconds
        );
    }
    println!(
        "path: {} lambdas in {:.3}s (rule={}, warm={}, threads={}, kernel={})",
        res.points.len(),
        res.total_seconds,
        cfg.rule.label(),
        cfg.warm.label(),
        gapsafe::solver::parallel::effective_threads(cfg.threads),
        gapsafe::linalg::kernels::active_kind().label()
    );
    Ok(())
}

fn cmd_cv(o: &Flags) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let small = o.contains_key("small");
    let ds = load_spec(flag(o, "data", "synth:leukemia"), seed, small)?;
    let task = Task::parse(flag(o, "task", "lasso"))?;
    let cfg = PathConfig {
        n_lambdas: flag_grid(o, 50)?,
        delta: flag_f64(o, "delta", 3.0)?,
        rule: Rule::parse(flag(o, "rule", "gap"))?,
        warm: WarmStart::parse(flag(o, "warm", "standard"))?,
        eps: flag_f64(o, "eps", 1e-6)?,
        eps_is_absolute: false,
        max_epochs: flag_usize(o, "max-epochs", 10_000)?,
        screen_every: flag_usize(o, "fce", 10)?,
        threads: 1,
        compact: flag_compact(o),
        dual: flag_dual(o)?,
    };
    cfg.validate()?;
    let cv = CvConfig {
        folds: flag_usize(o, "folds", 5)?,
        seed,
        threads: flag_workers(o, "threads", auto_workers())?,
    };
    let sw = gapsafe::util::Stopwatch::start();
    let res = kfold_cv(&ds, task, &cfg, &cv)?;
    let secs = sw.secs();
    println!("{:>4} {:>12} {:>12}", "t", "lambda", "mean CV MSE");
    let step = (res.lambdas.len() / 10).max(1);
    for t in (0..res.lambdas.len()).step_by(step) {
        let mark = if t == res.best_index { "  <- best" } else { "" };
        println!("{:>4} {:>12.5e} {:>12.6}{}", t, res.lambdas[t], res.mean_mse[t], mark);
    }
    println!(
        "cv: {} folds x {} lambdas in {:.3}s; best lambda = {:.5e} (index {}, MSE {:.6})",
        cv.folds,
        res.lambdas.len(),
        secs,
        res.best_lambda,
        res.best_index,
        res.mean_mse[res.best_index]
    );
    Ok(())
}

fn cmd_batch(o: &Flags) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let small = o.contains_key("small");
    let jobs = flag_usize(o, "jobs", 8)?;
    let threads = flag_workers(o, "threads", auto_workers())?;
    let task = Task::parse(flag(o, "task", "lasso"))?;
    let spec = flag(o, "data", "synth:reg:100x2000");
    let cfg = PathConfig {
        n_lambdas: flag_grid(o, 50)?,
        delta: flag_f64(o, "delta", 2.5)?,
        rule: Rule::parse(flag(o, "rule", "gap"))?,
        warm: WarmStart::parse(flag(o, "warm", "active"))?,
        eps: flag_f64(o, "eps", 1e-6)?,
        eps_is_absolute: false,
        max_epochs: flag_usize(o, "max-epochs", 10_000)?,
        screen_every: flag_usize(o, "fce", 10)?,
        threads: 1,
        compact: flag_compact(o),
        dual: flag_dual(o)?,
    };
    cfg.validate()?;
    let mut requests = Vec::with_capacity(jobs);
    for j in 0..jobs {
        let ds = load_spec(spec, seed + j as u64, small)?;
        requests.push((build_problem(ds, task)?, cfg.clone()));
    }
    let runner = BatchRunner::new(threads);
    println!("batch: {} requests on {} workers ...", jobs, runner.threads());
    let sw = gapsafe::util::Stopwatch::start();
    let results = runner.run(requests);
    let wall = sw.secs();
    let mut cpu = 0.0;
    for (j, r) in results.iter().enumerate() {
        cpu += r.total_seconds;
        println!(
            "  job {j:>3}: {} lambdas, converged={}, {:.3}s",
            r.points.len(),
            r.points.iter().all(|p| p.converged),
            r.total_seconds
        );
    }
    println!(
        "batch: {jobs} paths in {wall:.3}s wall ({:.2} jobs/s, pool efficiency {:.1}x)",
        jobs as f64 / wall.max(1e-12),
        cpu / wall.max(1e-12)
    );
    Ok(())
}

fn cmd_solve(o: &Flags) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let (task, data) = flag_task_data(o, "lasso", "synth:leukemia")?;
    let ds = load_spec(&data, seed, o.contains_key("small"))?;
    let prob = build_problem(ds, task)?;
    // Fan the O(np) screening-sweep correlations out over the pool.
    prob.set_screen_threads(flag_workers(o, "threads", 1)?);
    let lam = flag_f64(o, "lam-ratio", 0.1)? * prob.lambda_max();
    let mut rule = Rule::parse(flag(o, "rule", "gap-dyn"))?.build();
    let opts = SolveOptions {
        eps: gapsafe::solver::path::scaled_eps(&prob, flag_f64(o, "eps", 1e-6)?),
        max_epochs: flag_usize(o, "max-epochs", 10_000)?,
        screen_every: flag_usize(o, "fce", 10)?,
        max_kkt_rounds: 20,
        compact: flag_compact(o),
        dual: flag_dual(o)?,
    };
    let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
    println!(
        "lam={lam:.5e} gap={:.3e} epochs={} active={}/{} nnz={} converged={}",
        res.gap,
        res.epochs,
        res.active.n_active_feats(),
        prob.p(),
        res.beta.nnz(),
        res.converged
    );
    Ok(())
}

fn fig_strategies(fig: u8) -> Vec<(Rule, WarmStart)> {
    match fig {
        3 => vec![
            (Rule::None, WarmStart::Standard),
            (Rule::StaticElGhaoui, WarmStart::Standard),
            (Rule::Dst3, WarmStart::Standard),
            (Rule::GapSafeSeq, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Active),
            (Rule::Strong, WarmStart::Strong),
        ],
        4 => vec![
            (Rule::None, WarmStart::Standard),
            (Rule::GapSafeSeq, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Active),
            (Rule::Strong, WarmStart::Strong),
        ],
        5 => vec![
            (Rule::None, WarmStart::Standard),
            (Rule::DynamicBonnefoy, WarmStart::Standard),
            (Rule::GapSafeSeq, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Active),
        ],
        _ => vec![
            (Rule::None, WarmStart::Standard),
            (Rule::StaticGap, WarmStart::Standard),
            (Rule::GapSafeSeq, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Standard),
            (Rule::GapSafeFull, WarmStart::Active),
        ],
    }
}

fn cmd_fig(o: &Flags, fig: u8) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let small = o.contains_key("small");
    let out = PathBuf::from(flag(o, "out", "results"));
    let (title, ds, task, delta) = match fig {
        3 => (
            "Fig3 Lasso (leukemia-like)",
            load_spec("synth:leukemia", seed, small)?,
            Task::Lasso,
            3.0,
        ),
        4 => (
            "Fig4 logistic (leukemia-like)",
            load_spec("synth:leukemia-binary", seed, small)?,
            Task::Logreg,
            3.0,
        ),
        5 => (
            "Fig5 multi-task (MEG-like)",
            load_spec("synth:meg", seed, small)?,
            Task::MultiTask,
            3.0,
        ),
        6 => (
            "Fig6 SGL (climate-like)",
            load_spec("synth:climate", seed, small)?,
            Task::SparseGroupLasso { tau: 0.4 },
            2.5,
        ),
        other => return Err(format!("fig: no figure {other} (have fig3..fig6)")),
    };
    let prob = build_problem(ds, task)?;
    let n_lambdas = flag_grid(o, if small { 30 } else { 100 })?;
    // Left panel: active fractions for K = 2 .. 2^9.
    let budgets: Vec<usize> = (1..=9).map(|e| 1usize << e).collect();
    let rows = active_fraction_experiment(&prob, Rule::GapSafeFull, &budgets, n_lambdas, delta, 10);
    let lambdas = lambda_grid(prob.lambda_max(), n_lambdas, delta);
    report::print_active_fraction(title, &lambdas, &rows);
    report::write_active_fraction_csv(
        &out.join(format!("fig{fig}_active_fraction.csv")),
        &lambdas,
        &rows,
    )
    .map_err(|e| e.to_string())?;
    // Right panel: time-to-convergence per strategy.
    let eps_list = if small {
        vec![1e-2, 1e-4, 1e-6]
    } else {
        vec![1e-2, 1e-4, 1e-6, 1e-8]
    };
    let cells = time_to_convergence(
        &prob,
        &fig_strategies(fig),
        &eps_list,
        n_lambdas,
        delta,
        flag_usize(o, "max-epochs", 10_000)?,
    );
    report::print_timing(title, &cells);
    report::write_timing_csv(&out.join(format!("fig{fig}_timing.csv")), &cells)
        .map_err(|e| e.to_string())?;
    Ok(())
}

fn cmd_selftest(o: &Flags) -> Result<(), String> {
    let dir = PathBuf::from(flag(o, "artifacts", "artifacts"));
    let engine = PjrtEngine::new(&dir).map_err(|e| format!("{e:#}"))?;
    println!("PJRT platform: {}", engine.platform());
    // lasso_small artifact vs native gap pass
    let ds = synth::leukemia_like_scaled(16, 40, 7, false);
    let prob = build_problem(ds, Task::Lasso)?;
    let exe = engine.bind(&prob, "lasso").map_err(|e| format!("{e:#}"))?;
    let lam = 0.5 * prob.lambda_max();
    let mut beta = gapsafe::linalg::Mat::zeros(40, 1);
    beta[(3, 0)] = 0.7;
    beta[(11, 0)] = -0.2;
    let z = prob.predict(&beta);
    let active = ActiveSet::full(prob.pen.groups());
    let native = prob.gap_pass(&beta, &z, lam, &active);
    let pjrt = exe.gap_pass(&prob, &beta, lam).map_err(|e| format!("{e:#}"))?;
    let rel = |a: f64, b: f64| (a - b).abs() / (1.0 + a.abs());
    println!(
        "native  primal={:.12e} dual={:.12e} gap={:.6e} r={:.6e}",
        native.primal, native.dual, native.gap, native.radius
    );
    println!(
        "pjrt    primal={:.12e} dual={:.12e} gap={:.6e} r={:.6e}",
        pjrt.primal, pjrt.dual, pjrt.gap, pjrt.radius
    );
    for (name, a, b) in [
        ("primal", native.primal, pjrt.primal),
        ("dual", native.dual, pjrt.dual),
        ("gap", native.gap, pjrt.gap),
        ("radius", native.radius, pjrt.radius),
    ] {
        if rel(a, b) > 1e-9 {
            return Err(format!("{name} mismatch: native {a} vs pjrt {b}"));
        }
    }
    println!("selftest OK (artifact {} on {})", exe.name(), engine.platform());
    Ok(())
}

fn cmd_artifacts(o: &Flags) -> Result<(), String> {
    let dir = PathBuf::from(flag(o, "artifacts", "artifacts"));
    let m = artifact::Manifest::load(&dir)?;
    m.validate()?;
    println!("{:<24} {:<10} {:>6} {:>7} {:>4} {:>4} {:>9}", "name", "task", "n", "p", "q", "gs", "outputs");
    for e in &m.entries {
        println!(
            "{:<24} {:<10} {:>6} {:>7} {:>4} {:>4} {:>9}",
            e.name, e.task, e.n, e.p, e.q, e.group_size, e.n_outputs
        );
    }
    println!("{} artifacts OK in {}", m.entries.len(), dir.display());
    Ok(())
}

fn cmd_lmax(o: &Flags) -> Result<(), String> {
    let seed = flag_usize(o, "seed", 42)? as u64;
    let (task, data) = flag_task_data(o, "lasso", "synth:leukemia")?;
    let ds = load_spec(&data, seed, o.contains_key("small"))?;
    let prob = build_problem(ds, task)?;
    println!("lambda_max = {:.10e}", prob.lambda_max());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> Flags {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn flag_workers_rejects_zero_and_resolves_auto() {
        let err = flag_workers(&flags(&[("threads", "0")]), "threads", 1).unwrap_err();
        assert!(err.contains("auto"), "unhelpful error: {err}");
        assert!(flag_workers(&flags(&[("workers", "0")]), "workers", 1).is_err());
        assert_eq!(flag_workers(&flags(&[("threads", "3")]), "threads", 1).unwrap(), 3);
        // omitted flag takes the subcommand default untouched
        assert_eq!(flag_workers(&flags(&[]), "threads", 7).unwrap(), 7);
        // auto / all resolve to a concrete positive worker count
        for spelled in ["auto", "all"] {
            let n = flag_workers(&flags(&[("threads", spelled)]), "threads", 1).unwrap();
            assert!(n >= 1, "--threads {spelled} resolved to {n}");
        }
        assert!(flag_workers(&flags(&[("threads", "many")]), "threads", 1).is_err());
    }

    #[test]
    fn kernel_flag_selects_and_rejects() {
        use gapsafe::linalg::kernels;
        // restore on exit so a GAPSAFE_KERNEL-forced run stays forced for
        // the co-resident tests in this binary
        let entry = kernels::active_kind();
        // no flag: no-op, keeps whatever GAPSAFE_KERNEL / detection chose
        assert!(apply_kernel_flag(&flags(&[])).is_ok());
        assert_eq!(kernels::active_kind(), entry);
        // scalar is available on every host
        assert!(apply_kernel_flag(&flags(&[("kernel", "scalar")])).is_ok());
        assert_eq!(kernels::active_kind(), kernels::BackendKind::Scalar);
        let err = apply_kernel_flag(&flags(&[("kernel", "bogus")])).unwrap_err();
        assert!(err.starts_with("--kernel:"), "{err}");
        kernels::select(entry).unwrap();
        assert_eq!(kernels::active_kind(), entry);
    }

    #[test]
    fn flag_task_data_resolves_datafit_families() {
        let (t, d) = flag_task_data(&flags(&[]), "lasso", "synth:leukemia").unwrap();
        assert_eq!((t, d.as_str()), (Task::Lasso, "synth:leukemia"));
        let (t, d) =
            flag_task_data(&flags(&[("datafit", "poisson")]), "lasso", "synth:leukemia").unwrap();
        assert_eq!((t, d.as_str()), (Task::Poisson, "synth:counts"));
        let (t, d) =
            flag_task_data(&flags(&[("datafit", "logistic")]), "lasso", "synth:leukemia")
                .unwrap();
        assert_eq!((t, d.as_str()), (Task::Logreg, "synth:leukemia-binary"));
        // explicit flags still win over the shorthand's defaults
        let (t, d) = flag_task_data(
            &flags(&[("datafit", "poisson"), ("data", "synth:counts:10x20")]),
            "lasso",
            "synth:leukemia",
        )
        .unwrap();
        assert_eq!((t, d.as_str()), (Task::Poisson, "synth:counts:10x20"));
        let err = flag_task_data(&flags(&[("datafit", "bogus")]), "lasso", "x").unwrap_err();
        assert!(err.starts_with("--datafit:"), "{err}");
    }

    #[test]
    fn flag_dual_parses_strategies() {
        assert_eq!(flag_dual(&flags(&[])).unwrap(), DualStrategy::BestKept);
        assert_eq!(
            flag_dual(&flags(&[("dual", "rescale")])).unwrap(),
            DualStrategy::Rescale
        );
        assert_eq!(flag_dual(&flags(&[("dual", "refine")])).unwrap(), DualStrategy::Refine);
        let err = flag_dual(&flags(&[("dual", "bogus")])).unwrap_err();
        assert!(err.starts_with("--dual:"), "{err}");
    }
}
