//! Blitz-like working-set comparator (Johnson & Guestrin 2015; Sec. 5.1).
//!
//! Instead of *removing* provably-inactive features (screening), a working
//! set solver *selects* a small set of promising features, solves the
//! restricted subproblem to tolerance, and grows the set until the full
//! duality gap certifies optimality. Gap Safe screening guards every
//! subproblem, so the method is safe end-to-end.

use crate::linalg::Mat;
use crate::penalty::{gather_block, ActiveSet};
use crate::problem::Problem;
use crate::screening::NoScreening;

use super::{solve_fixed_lambda_with, SolveOptions, SolveResult};
use crate::obs;

/// Working-set options.
#[derive(Debug, Clone)]
pub struct WorkingSetOptions {
    /// Initial working-set size.
    pub initial_size: usize,
    /// Growth factor between outer rounds.
    pub growth: f64,
    /// Max outer rounds.
    pub max_rounds: usize,
    /// Inner solve options (eps is the *final* target).
    pub inner: SolveOptions,
}

impl Default for WorkingSetOptions {
    fn default() -> Self {
        WorkingSetOptions {
            initial_size: 10,
            growth: 2.0,
            max_rounds: 30,
            inner: SolveOptions::default(),
        }
    }
}

/// Solve one lambda with a Blitz-style working set.
pub fn solve_working_set(
    prob: &Problem,
    lam: f64,
    opts: &WorkingSetOptions,
) -> SolveResult {
    let lam_max = prob.lambda_max();
    let groups = prob.pen.groups();
    let ng = groups.len();
    let mut beta = Mat::zeros(prob.p(), prob.q());
    let mut ws_size = opts.initial_size.min(ng);
    let mut rule = NoScreening;
    let mut rounds = 0usize;
    let mut total_epochs = 0usize;
    let mut total_gap_passes = 0usize;
    let mut result: Option<SolveResult> = None;

    while rounds < opts.max_rounds {
        rounds += 1;
        // Priority of each group: dual-norm statistic of the current
        // residual-rescaled point (groups already in the support first).
        // Deliberately a *fresh* rescale, not the best-kept point: the
        // priorities must rank violators of the current iterate — a kept
        // point from an earlier round would hide groups that only started
        // violating after the last restricted solve. The dual-point
        // engine still applies inside every restricted subsolve through
        // `opts.inner.dual`.
        let z = prob.predict(&beta);
        let full = ActiveSet::full(groups);
        let gap = prob.gap_pass(&beta, &z, lam, &full);
        total_gap_passes += 1;
        if gap.gap <= opts.inner.eps {
            let mut res = solve_fixed_lambda_with(
                prob,
                lam,
                lam_max,
                Some(&beta),
                None,
                &mut rule,
                None,
                &SolveOptions { max_epochs: 0, ..opts.inner.clone() },
            );
            res.epochs = total_epochs;
            res.gap_passes = total_gap_passes;
            res.converged = true;
            result = Some(res);
            break;
        }
        let mut order: Vec<usize> = (0..ng).collect();
        let mut blk = Vec::new();
        let in_support: Vec<bool> = (0..ng)
            .map(|g| {
                gather_block(&beta, groups.feats(g), &mut blk);
                blk.iter().any(|&v| v != 0.0)
            })
            .collect();
        order.sort_by(|&a, &b| {
            // support first, then by decreasing statistic
            match (in_support[a], in_support[b]) {
                (true, false) => std::cmp::Ordering::Less,
                (false, true) => std::cmp::Ordering::Greater,
                _ => gap.stats.group_dual[b]
                    .partial_cmp(&gap.stats.group_dual[a])
                    .unwrap_or(std::cmp::Ordering::Equal),
            }
        });
        let mut ws = ActiveSet::full(groups);
        for &g in order.iter().skip(ws_size) {
            ws.kill_group(groups, g);
        }
        if obs::enabled() {
            obs::emit(&obs::Event::WsRound {
                lam,
                round: rounds,
                ws_feats: ws.n_active_feats(),
                gap: gap.gap,
            });
        }
        // Solve the restricted subproblem to the final tolerance.
        let res = solve_fixed_lambda_with(
            prob,
            lam,
            lam_max,
            Some(&beta),
            Some(&ws),
            &mut rule,
            None,
            &opts.inner,
        );
        total_epochs += res.epochs;
        total_gap_passes += res.gap_passes;
        beta = res.beta.clone();
        result = Some(res);
        ws_size = ((ws_size as f64 * opts.growth).ceil() as usize).min(ng);
    }

    // `max_rounds == 0` (or a degenerate config) is the one way the loop
    // body never runs; fall back to a direct full-problem solve instead
    // of unwrapping — same contract, no reachable panic.
    let mut res = match result {
        Some(res) => res,
        None => solve_fixed_lambda_with(
            prob,
            lam,
            lam_max,
            Some(&beta),
            None,
            &mut rule,
            None,
            &opts.inner,
        ),
    };
    // Final certification on the full problem (fresh point, like the
    // round passes above — Thm. 2 needs nothing stronger here).
    let z = prob.predict(&beta);
    let full = ActiveSet::full(groups);
    let gap = prob.gap_pass(&beta, &z, lam, &full);
    res.converged = gap.gap <= opts.inner.eps;
    res.primal = gap.primal;
    res.dual = gap.dual;
    res.gap = gap.gap;
    res.theta = gap.theta;
    res.beta = beta;
    res.z = z;
    res.epochs = total_epochs;
    res.gap_passes = total_gap_passes;
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::screening::NoScreening;
    use crate::solver::solve_fixed_lambda;
    use crate::{build_problem, Task};

    #[test]
    fn working_set_matches_cd() {
        let ds = synth::leukemia_like_scaled(24, 80, 21, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = 0.2 * prob.lambda_max();
        let inner = SolveOptions { eps: 1e-10, ..Default::default() };
        let ws = solve_working_set(
            &prob,
            lam,
            &WorkingSetOptions { inner: inner.clone(), ..Default::default() },
        );
        assert!(ws.converged, "gap={}", ws.gap);
        let mut rule = NoScreening;
        let cd = solve_fixed_lambda(&prob, lam, &mut rule, &inner);
        for j in 0..prob.p() {
            assert!(
                (ws.beta[(j, 0)] - cd.beta[(j, 0)]).abs() < 1e-5,
                "mismatch at {j}"
            );
        }
    }

    #[test]
    fn working_set_visits_fewer_coordinates() {
        let ds = synth::leukemia_like_scaled(20, 200, 22, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let inner = SolveOptions { eps: 1e-8, ..Default::default() };
        let ws = solve_working_set(
            &prob,
            lam,
            &WorkingSetOptions { inner, ..Default::default() },
        );
        assert!(ws.converged);
    }
}
