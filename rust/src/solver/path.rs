//! Pathwise solver (Alg. 1): logarithmic lambda grid, sequential screening,
//! and the three warm-start strategies of Sec. 3.4 / 3.6:
//!
//! * `Standard` — initialize at the previous solution;
//! * `Active`   — first (approximately) solve Eq. (22) restricted to the
//!                previous *safe active set*, then solve the full problem;
//! * `Strong`   — same two-phase scheme but restricted to the (un-safe)
//!                strong active set of Eq. (24), with KKT repair.

use super::{solve_fixed_lambda_with, SolveOptions, SolveResult};
use crate::linalg::Mat;
use crate::obs;
use crate::problem::Problem;
use crate::screening::{DualStrategy, PrevSolution, Rule, StrongRule};
use crate::util::Stopwatch;

/// Warm-start strategy across the path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarmStart {
    Standard,
    Active,
    Strong,
}

impl WarmStart {
    pub fn parse(s: &str) -> Result<WarmStart, String> {
        match s {
            "standard" | "warm" => Ok(WarmStart::Standard),
            "active" => Ok(WarmStart::Active),
            "strong" => Ok(WarmStart::Strong),
            other => Err(format!("unknown warm start '{other}'")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            WarmStart::Standard => "standard",
            WarmStart::Active => "active",
            WarmStart::Strong => "strong",
        }
    }
}

/// Path configuration (defaults follow Sec. 5: 100 lambdas from lambda_max
/// down to lambda_max / 10^delta with delta = 3).
#[derive(Debug, Clone)]
pub struct PathConfig {
    pub n_lambdas: usize,
    /// Grid decade span delta: lambda_t = lambda_max 10^{-delta t/(T-1)}.
    pub delta: f64,
    pub rule: Rule,
    pub warm: WarmStart,
    /// Raw tolerance; scaled as in Sec. 5 unless `eps_is_absolute`.
    pub eps: f64,
    pub eps_is_absolute: bool,
    pub max_epochs: usize,
    pub screen_every: usize,
    /// Worker threads for the chunked path engine
    /// ([`crate::solver::parallel`]): `1` = the exact serial path
    /// (default), `t > 1` = that many chunk workers. Programmatic callers
    /// may pass `0` as the "all available cores" sentinel (resolved by
    /// [`solve_path`] via
    /// [`effective_threads`](crate::solver::parallel::effective_threads));
    /// user-facing layers resolve `auto` to a concrete count at parse
    /// time and reject a literal `0` — [`PathConfig::validate`] enforces
    /// that, mirroring the `--grid 0` guard.
    pub threads: usize,
    /// Active-set compaction ([`crate::linalg::compact`], default on):
    /// repack the surviving columns into a contiguous working matrix as
    /// screening shrinks the problem. Bitwise-transparent — toggling it
    /// changes speed only, never an output bit.
    pub compact: bool,
    /// Dual-point strategy for every gap pass along the path
    /// ([`crate::screening::dual`]; CLI `--dual`, default `best`):
    /// `rescale` reproduces the historical output bit for bit, `best` /
    /// `refine` keep the best dual point per lambda so reported gaps and
    /// Gap Safe radii are monotone — and the `PrevSolution::theta` each
    /// path point hands its successor's sequential sphere is the best
    /// point, not the last one.
    pub dual: DualStrategy,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            n_lambdas: 100,
            delta: 3.0,
            rule: Rule::GapSafeFull,
            warm: WarmStart::Standard,
            eps: 1e-6,
            eps_is_absolute: false,
            max_epochs: 10_000,
            screen_every: 10,
            threads: 1,
            compact: true,
            dual: DualStrategy::default(),
        }
    }
}

impl PathConfig {
    /// Validate user-facing grid parameters, returning a proper error
    /// instead of letting [`lambda_grid`]'s internal assertion panic. The
    /// CLI calls this at parse time; the serving layer enforces its own
    /// (stricter) bounds in `ModelKey::from_json`. `eps = 0` stays legal —
    /// it is the "run the full epoch budget" mode the experiment
    /// coordinator relies on — and `delta = 0` is a degenerate but valid
    /// constant grid; only non-finite or negative values are rejected.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_lambdas == 0 {
            return Err("lambda grid must have at least 1 point (--grid >= 1)".into());
        }
        if !(self.delta.is_finite() && self.delta >= 0.0) {
            return Err("grid decade span delta must be finite and >= 0".into());
        }
        if !(self.eps.is_finite() && self.eps >= 0.0) {
            return Err("tolerance eps must be finite and >= 0".into());
        }
        if self.threads == 0 {
            // A zero-worker pool is never what a user meant: the CLI
            // resolves `--threads auto` to a concrete core count before
            // building the config, so a literal 0 surviving to this point
            // is a request for an empty pool — reject it like `--grid 0`
            // instead of silently reinterpreting it downstream.
            return Err(
                "--threads must be >= 1 (use --threads auto, or omit the flag, for all cores)"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Per-lambda record.
#[derive(Debug, Clone)]
pub struct PathPoint {
    pub lam: f64,
    pub gap: f64,
    pub epochs: usize,
    pub n_active_groups: usize,
    pub n_active_feats: usize,
    /// Nonzero *coefficients* of beta (entries, over all q tasks).
    pub nnz_coefs: usize,
    /// Nonzero *rows* of beta (features with any nonzero task — the
    /// support size; equals `nnz_coefs` when q = 1). The old scalar `nnz`
    /// field reported rows, which mislabeled multi-task / multinomial
    /// sparsity; both counts are now carried explicitly.
    pub nnz_rows: usize,
    pub seconds: f64,
    pub converged: bool,
    pub kkt_violations: usize,
}

/// Whole-path outcome.
#[derive(Debug, Clone)]
pub struct PathResult {
    pub lambdas: Vec<f64>,
    pub points: Vec<PathPoint>,
    /// Final coefficients per lambda (kept for downstream model selection).
    pub betas: Vec<Mat>,
    pub total_seconds: f64,
    pub lam_max: f64,
}

/// The standard logarithmic grid of Sec. 3.2. `n = 0` is a caller bug —
/// user-facing layers validate it via [`PathConfig::validate`] before
/// reaching this assertion.
pub fn lambda_grid(lam_max: f64, n: usize, delta: f64) -> Vec<f64> {
    assert!(n >= 1, "lambda grid needs at least one point");
    if n == 1 {
        return vec![lam_max];
    }
    (0..n)
        .map(|t| lam_max * 10f64.powf(-delta * t as f64 / (n as f64 - 1.0)))
        .collect()
}

/// [`lambda_grid`] with the degenerate anchors rejected as a
/// [`PathConfig::validate`]-style error instead of propagating NaN (or an
/// all-zero grid whose solves divide by lambda = 0) downstream. The
/// classic trigger is Poisson on all-zero counts under a column-centered
/// design: rho(0) = y - 1 is constant, so X^T rho(0) = 0 and
/// lambda_max = 0 — a dataset with no signal to regularize against.
pub fn lambda_grid_checked(lam_max: f64, n: usize, delta: f64) -> Result<Vec<f64>, String> {
    if n == 0 {
        return Err("lambda grid must have at least 1 point (--grid >= 1)".into());
    }
    if !lam_max.is_finite() {
        return Err(format!("lambda_max is not finite ({lam_max}); check the data for NaN/inf"));
    }
    if lam_max <= 0.0 {
        return Err(format!(
            "lambda_max = {lam_max}: the null model is optimal at every lambda > 0 \
             (all-zero targets under a centered design?); there is no path to solve"
        ));
    }
    Ok(lambda_grid(lam_max, n, delta))
}

/// Tolerance scaling of Sec. 5: eps <- eps ||y||^2 for regression,
/// eps * min(n_1, n_2)/n for logistic (class counts), eps * n log(q) for
/// multinomial.
pub fn scaled_eps(prob: &Problem, eps: f64) -> f64 {
    use crate::datafit::FitKind;
    match prob.fit.kind() {
        FitKind::Quadratic => eps * prob.fit.targets().frob_sq().max(1e-300),
        FitKind::Logistic => {
            let y = prob.fit.targets().as_slice();
            let n1 = y.iter().filter(|&&v| v == 1.0).count().max(1);
            let n0 = (y.len() - n1).max(1);
            eps * (n1.min(n0) as f64) / y.len() as f64
        }
        FitKind::Multinomial => {
            let n = prob.n() as f64;
            let q = prob.q() as f64;
            eps * n * q.ln()
        }
        FitKind::Poisson => {
            // The KL loss scale is the total count mass ||y||_1 (the
            // quadratic analog of ||y||^2); floor at 1 so sparse-count
            // problems keep a usable tolerance.
            let mass: f64 = prob.fit.targets().as_slice().iter().sum();
            eps * mass.max(1.0)
        }
    }
}

/// Run the full path (Alg. 1). Dispatches to the chunked parallel engine
/// ([`crate::solver::parallel::solve_path_parallel`]) when
/// `cfg.threads` resolves to more than one worker; `threads = 1` takes the
/// serial path byte-for-byte.
pub fn solve_path(prob: &Problem, cfg: &PathConfig) -> PathResult {
    let threads = super::parallel::effective_threads(cfg.threads);
    if threads > 1 && cfg.n_lambdas > 1 {
        return super::parallel::solve_path_parallel(prob, cfg, threads);
    }
    solve_path_serial(prob, cfg)
}

/// The reference serial path (Alg. 1 exactly as written): the standard
/// grid handed to [`solve_path_on_grid`]. Exposed so tests can pin
/// `solve_path` with `threads = 1` against it bitwise.
pub fn solve_path_serial(prob: &Problem, cfg: &PathConfig) -> PathResult {
    let lambdas = lambda_grid(prob.lambda_max(), cfg.n_lambdas, cfg.delta);
    solve_path_on_grid(prob, cfg, &lambdas)
}

/// Solve an explicit lambda grid serially (cross-validation folds share one
/// grid computed from the full dataset, so their own `lambda_max` must not
/// regenerate it). The grid must be decreasing; entries above the problem's
/// own `lambda_max` simply resolve to the null solution.
pub fn solve_path_on_grid(prob: &Problem, cfg: &PathConfig, lambdas: &[f64]) -> PathResult {
    let lam_max = prob.lambda_max();
    let eps = if cfg.eps_is_absolute { cfg.eps } else { scaled_eps(prob, cfg.eps) };
    let opts = SolveOptions {
        max_epochs: cfg.max_epochs,
        screen_every: cfg.screen_every,
        eps,
        max_kkt_rounds: 20,
        compact: cfg.compact,
        dual: cfg.dual,
    };
    let mut rule = cfg.rule.build();
    let tracing = obs::enabled();
    if tracing {
        obs::emit(&obs::Event::PathStart {
            n_lambdas: lambdas.len(),
            lam_max,
            threads: 1,
            kernel: crate::linalg::kernels::active_kind().label(),
        });
    }
    let sw_total = Stopwatch::start();
    let (points, betas, _) =
        run_grid_segment(prob, lambdas, lam_max, cfg, &opts, rule.as_mut(), None);
    let total_seconds = sw_total.secs();
    if tracing {
        obs::emit(&obs::Event::PathEnd {
            n_lambdas: points.len(),
            total_epochs: points.iter().map(|p| p.epochs).sum(),
            secs: total_seconds,
        });
    }
    PathResult { lambdas: lambdas.to_vec(), points, betas, total_seconds, lam_max }
}

/// One contiguous run of lambdas with sequential warm starts — the body of
/// Alg. 1, shared between the serial path (whole grid, cold start) and the
/// parallel engine (one chunk per call, seeded by the coarse pre-pass).
/// Returns the per-lambda records plus the final [`PrevSolution`] so a
/// caller can chain further segments.
pub(crate) fn run_grid_segment(
    prob: &Problem,
    lambdas: &[f64],
    lam_max: f64,
    cfg: &PathConfig,
    opts: &SolveOptions,
    rule: &mut dyn crate::screening::ScreeningRule,
    mut prev: Option<PrevSolution>,
) -> (Vec<PathPoint>, Vec<Mat>, Option<PrevSolution>) {
    let mut points = Vec::with_capacity(lambdas.len());
    let mut betas = Vec::with_capacity(lambdas.len());

    for &lam in lambdas {
        let sw = Stopwatch::start();
        let beta0 = prev.as_ref().map(|p| p.beta.clone());
        // Phase 1 (active / strong warm start): approximately solve the
        // restricted problem (22) at lambda_t.
        let phase1_beta = match (cfg.warm, prev.as_ref()) {
            (WarmStart::Active, Some(pv)) => {
                let res = solve_fixed_lambda_with(
                    prob,
                    lam,
                    lam_max,
                    beta0.as_ref(),
                    Some(&pv.active),
                    &mut *rule,
                    Some(pv),
                    opts,
                );
                Some(res.beta)
            }
            (WarmStart::Strong, Some(pv)) => {
                let strong = StrongRule::strong_active_set(prob, pv, lam);
                // intersect with safe knowledge from the previous lambda is
                // NOT valid here (supports grow as lambda decreases), so the
                // restriction is the strong set alone.
                let res = solve_fixed_lambda_with(
                    prob,
                    lam,
                    lam_max,
                    beta0.as_ref(),
                    Some(&strong),
                    &mut *rule,
                    Some(pv),
                    opts,
                );
                Some(res.beta)
            }
            _ => None,
        };
        let init = phase1_beta.as_ref().or(beta0.as_ref());
        let res: SolveResult = solve_fixed_lambda_with(
            prob,
            lam,
            lam_max,
            init,
            None,
            &mut *rule,
            prev.as_ref(),
            opts,
        );
        let secs = sw.secs();
        let point = point_from_result(lam, &res, res.epochs, secs);
        if obs::enabled() {
            obs::emit(&obs::Event::PathPoint {
                lam,
                epochs: point.epochs,
                gap: point.gap,
                active_feats: point.n_active_feats,
                nnz_coefs: point.nnz_coefs,
                converged: point.converged,
                secs,
            });
        }
        points.push(point);
        let (pv, beta) = prev_from_result(prob, lam, res);
        prev = Some(pv);
        betas.push(beta);
    }

    (points, betas, prev)
}

/// Per-lambda record assembled from one fixed-lambda solve. `epochs` is
/// passed in (not read from `res`) so callers running a two-phase warm
/// start can fold the phase-1 work into the count.
pub(crate) fn point_from_result(
    lam: f64,
    res: &SolveResult,
    epochs: usize,
    seconds: f64,
) -> PathPoint {
    PathPoint {
        lam,
        gap: res.gap,
        epochs,
        n_active_groups: res.active.n_active_groups(),
        n_active_feats: res.active.n_active_feats(),
        nnz_coefs: count_nnz_coefs(&res.beta),
        nnz_rows: count_nnz_rows(&res.beta),
        seconds,
        converged: res.converged,
        kkt_violations: res.kkt_violations,
    }
}

/// Chainable warm-start snapshot of a finished solve at `lam`; returns
/// the [`PrevSolution`] plus the coefficient matrix for the path record.
pub(crate) fn prev_from_result(
    prob: &Problem,
    lam: f64,
    res: SolveResult,
) -> (PrevSolution, Mat) {
    let beta = res.beta;
    let prev = PrevSolution {
        lam,
        loss: prob.fit.loss(&res.z),
        pen_value: prob.pen.value(&beta),
        z: res.z,
        theta: res.theta,
        active: res.active,
        beta: beta.clone(),
    };
    (prev, beta)
}

/// Nonzero entries of beta (over all q tasks).
fn count_nnz_coefs(beta: &Mat) -> usize {
    beta.as_slice().iter().filter(|&&v| v != 0.0).count()
}

/// Rows of beta with at least one nonzero task (the feature support).
fn count_nnz_rows(beta: &Mat) -> usize {
    (0..beta.rows()).filter(|&j| (0..beta.cols()).any(|k| beta[(j, k)] != 0.0)).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::{build_problem, Task};

    #[test]
    fn grid_endpoints() {
        let g = lambda_grid(10.0, 5, 2.0);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 10.0).abs() < 1e-12);
        assert!((g[4] - 0.1).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    fn quick_cfg(rule: Rule, warm: WarmStart) -> PathConfig {
        PathConfig {
            n_lambdas: 12,
            delta: 2.0,
            rule,
            warm,
            eps: 1e-8,
            eps_is_absolute: false,
            max_epochs: 3000,
            screen_every: 10,
            threads: 1,
            compact: true,
            dual: DualStrategy::default(),
        }
    }

    #[test]
    fn path_converges_all_points_and_monotone_support() {
        let ds = synth::leukemia_like_scaled(30, 80, 2, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let res = solve_path(&prob, &quick_cfg(Rule::GapSafeFull, WarmStart::Standard));
        assert_eq!(res.points.len(), 12);
        assert!(res.points.iter().all(|p| p.converged));
        // support at lambda_max is empty
        assert_eq!(res.points[0].nnz_rows, 0);
        assert_eq!(res.points[0].nnz_coefs, 0);
        // support grows (weakly, statistically) along the path
        assert!(res.points.last().unwrap().nnz_rows >= res.points[0].nnz_rows);
    }

    #[test]
    fn nnz_counts_distinguish_coefs_and_rows() {
        // Multi-task: q > 1 means a support row can hold several nonzero
        // coefficients; the per-lambda record must report both counts.
        let ds = synth::meg_like(16, 24, 4, 5);
        let prob = build_problem(ds, Task::MultiTask).unwrap();
        let res = solve_path(&prob, &quick_cfg(Rule::GapSafeFull, WarmStart::Standard));
        let last = res.points.last().unwrap();
        assert!(last.nnz_rows > 0, "trivial path end");
        // row groups (l1/l2): supported rows carry several tasks, so the
        // coefficient count must exceed the row count (the old scalar nnz
        // conflated the two)
        assert!(
            last.nnz_coefs > last.nnz_rows,
            "coefs {} rows {}",
            last.nnz_coefs,
            last.nnz_rows
        );
        for (p, b) in res.points.iter().zip(&res.betas) {
            let rows = (0..b.rows())
                .filter(|&j| (0..b.cols()).any(|k| b[(j, k)] != 0.0))
                .count();
            let coefs = b.as_slice().iter().filter(|&&v| v != 0.0).count();
            assert_eq!(p.nnz_rows, rows);
            assert_eq!(p.nnz_coefs, coefs);
            assert!(p.nnz_coefs >= p.nnz_rows);
        }
    }

    #[test]
    fn compaction_is_bitwise_transparent_along_path() {
        // The acceptance gate of this PR: whole-path solves with the
        // packed working view must reproduce the full-scan path to the bit
        // — betas and gaps — for dense and sparse designs.
        for ds in [
            synth::leukemia_like_scaled(28, 90, 11, false),
            synth::sparse_regression(36, 150, 0.12, 13),
        ] {
            let prob = build_problem(ds, Task::Lasso).unwrap();
            let on = quick_cfg(Rule::GapSafeFull, WarmStart::Standard);
            let off = PathConfig { compact: false, ..on.clone() };
            let a = solve_path(&prob, &on);
            let b = solve_path(&prob, &off);
            for (t, (ba, bb)) in a.betas.iter().zip(&b.betas).enumerate() {
                for j in 0..prob.p() {
                    assert_eq!(
                        ba[(j, 0)].to_bits(),
                        bb[(j, 0)].to_bits(),
                        "beta diverged at lambda {t}, feature {j}"
                    );
                }
            }
            for (pa, pb) in a.points.iter().zip(&b.points) {
                assert_eq!(pa.gap.to_bits(), pb.gap.to_bits());
                assert_eq!(pa.epochs, pb.epochs);
                assert_eq!(pa.n_active_feats, pb.n_active_feats);
            }
        }
    }

    #[test]
    fn validate_rejects_empty_grid() {
        let mut cfg = PathConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.n_lambdas = 0;
        assert!(cfg.validate().is_err());
        cfg.n_lambdas = 5;
        // eps = 0 (full-budget mode) and delta = 0 (constant grid) stay legal
        cfg.delta = 0.0;
        cfg.eps = 0.0;
        assert!(cfg.validate().is_ok());
        cfg.delta = -1.0;
        assert!(cfg.validate().is_err());
        cfg.delta = 2.0;
        cfg.eps = f64::NAN;
        assert!(cfg.validate().is_err());
        // a zero-worker pool is rejected like a zero-point grid; the CLI
        // resolves `auto` to a concrete count before validation
        cfg.eps = 1e-6;
        cfg.threads = 0;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("auto"), "unhelpful --threads 0 error: {err}");
        cfg.threads = 4;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn lambda_grid_checked_rejects_degenerate_anchors() {
        let g = lambda_grid_checked(10.0, 5, 2.0).unwrap();
        assert_eq!(g, lambda_grid(10.0, 5, 2.0));
        assert!(lambda_grid_checked(10.0, 0, 2.0).is_err());
        let err = lambda_grid_checked(0.0, 5, 2.0).unwrap_err();
        assert!(err.contains("lambda_max"), "unhelpful error: {err}");
        assert!(lambda_grid_checked(-1.0, 5, 2.0).is_err());
        assert!(lambda_grid_checked(f64::NAN, 5, 2.0).is_err());
        assert!(lambda_grid_checked(f64::INFINITY, 5, 2.0).is_err());
    }

    #[test]
    fn dual_strategies_agree_along_path() {
        // All three dual-point strategies certify the same duality-gap
        // tolerance, so the paths must agree; best/refine may only spend
        // fewer or equal gap passes getting there.
        let ds = synth::leukemia_like_scaled(26, 70, 3, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let base_cfg = PathConfig {
            dual: DualStrategy::Rescale,
            ..quick_cfg(Rule::GapSafeFull, WarmStart::Standard)
        };
        let base = solve_path(&prob, &base_cfg);
        for dual in [DualStrategy::BestKept, DualStrategy::Refine] {
            let other = solve_path(&prob, &PathConfig { dual, ..base_cfg.clone() });
            for (t, (a, b)) in base.betas.iter().zip(&other.betas).enumerate() {
                for j in 0..prob.p() {
                    assert!(
                        (a[(j, 0)] - b[(j, 0)]).abs() < 1e-4,
                        "dual={} diverged at lambda {t}, feature {j}",
                        dual.label()
                    );
                }
            }
            assert!(other.points.iter().all(|p| p.converged));
        }
    }

    #[test]
    fn warm_start_variants_agree() {
        let ds = synth::leukemia_like_scaled(24, 60, 4, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let base = solve_path(&prob, &quick_cfg(Rule::GapSafeFull, WarmStart::Standard));
        for warm in [WarmStart::Active, WarmStart::Strong] {
            let other = solve_path(&prob, &quick_cfg(Rule::GapSafeFull, warm));
            for (a, b) in base.betas.iter().zip(&other.betas) {
                for j in 0..prob.p() {
                    assert!(
                        (a[(j, 0)] - b[(j, 0)]).abs() < 1e-4,
                        "warm start {warm:?} diverged at feature {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn rules_produce_identical_paths() {
        // Safety across the whole rule zoo on a regression path.
        let ds = synth::leukemia_like_scaled(20, 40, 6, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let base = solve_path(&prob, &quick_cfg(Rule::None, WarmStart::Standard));
        for rule in [
            Rule::StaticGap,
            Rule::StaticElGhaoui,
            Rule::Dst3,
            Rule::DynamicBonnefoy,
            Rule::GapSafeSeq,
            Rule::GapSafeDyn,
            Rule::GapSafeFull,
            Rule::Strong,
        ] {
            let other = solve_path(&prob, &quick_cfg(rule, WarmStart::Standard));
            for (t, (a, b)) in base.betas.iter().zip(&other.betas).enumerate() {
                for j in 0..prob.p() {
                    assert!(
                        (a[(j, 0)] - b[(j, 0)]).abs() < 1e-4,
                        "rule {} diverged at lambda index {t}, feature {j}: {} vs {}",
                        rule.label(),
                        a[(j, 0)],
                        b[(j, 0)]
                    );
                }
            }
        }
    }

    #[test]
    fn scaled_eps_families() {
        let ds = synth::leukemia_like_scaled(20, 10, 1, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let e = scaled_eps(&prob, 1e-6);
        assert!(e > 0.0);
        let dsb = synth::leukemia_like_scaled(20, 10, 1, true);
        let probb = build_problem(dsb, Task::Logreg).unwrap();
        let eb = scaled_eps(&probb, 1e-6);
        assert!(eb > 0.0 && eb < 1e-6);
    }
}
