//! Proximal-gradient comparator (ISTA / FISTA, Beck & Teboulle 2009).
//!
//! Screening is solver-agnostic (Sec. 3.3): this solver plugs into the same
//! `ScreeningRule` machinery and is used (a) as an independent oracle in
//! tests and (b) in the ablation bench showing Gap Safe also accelerates
//! first-order methods, not just CD.

use crate::datafit::FitKind;
use crate::linalg::Mat;
use crate::penalty::{gather_block, scatter_block, ActiveSet};
use crate::problem::Problem;
use crate::screening::dual::DualPoint;
use crate::screening::ScreeningRule;

use super::{ScreenEvent, SolveOptions, SolveResult};
use crate::obs::{self, ledger};

/// Global Lipschitz constant of grad F: scale * ||X||_2^2 via power iteration
/// over all (active) columns.
fn global_lipschitz(prob: &Problem) -> f64 {
    let cols: Vec<usize> = (0..prob.p()).collect();
    let s = prob.x.block_spectral_norm(&cols, 100);
    (prob.fit.lipschitz_scale() * s * s).max(1e-300)
}

/// Solve one lambda by FISTA with screening every `opts.screen_every`
/// iterations.
pub fn solve_fista(
    prob: &Problem,
    lam: f64,
    rule: &mut dyn ScreeningRule,
    opts: &SolveOptions,
) -> SolveResult {
    let (p, q) = (prob.p(), prob.q());
    let lam_max = prob.lambda_max();
    let mut active = ActiveSet::full(prob.pen.groups());
    // Provenance ledger: FISTA solves get their own sid/certificate just
    // like CD (screening — and its audit trail — is solver-agnostic).
    ledger::count_cols(p);
    let (sid, _ledger_scope) = ledger::begin_solve(lam);
    rule.begin_lambda(prob, lam, lam_max, None, &mut active);
    // Poisson has no global Lipschitz gradient: `l` is only a trial
    // constant there, validated per step by Beck-Teboulle backtracking
    // (the sufficient-decrease test below) and doubled on violation.
    let backtracks = prob.fit.kind() == FitKind::Poisson;
    let mut l = global_lipschitz(prob);
    let mut beta = Mat::zeros(p, q);
    let mut v = beta.clone(); // momentum point
    let mut t_k = 1.0f64;
    let mut epochs = 0;
    let mut gap_passes = 0;
    let mut converged = false;
    let mut trace = Vec::new();
    let mut gap_trace = Vec::new();
    let mut last = None;
    // Screening is solver-agnostic and so is the dual-point engine: FISTA
    // iterates are not even primal-monotone (momentum), so keeping the
    // best dual objective per lambda matters more here than under CD.
    let mut dual_pt = DualPoint::new(opts.dual);

    // Tracing (obs): captured once; timing never feeds the math.
    let tracing = obs::enabled();

    for k in 0..opts.max_epochs {
        if k % opts.screen_every == 0 {
            ledger::set_epoch(epochs);
            let t_pass = tracing.then(std::time::Instant::now);
            let z = prob.predict(&beta);
            let res = prob.gap_pass_dual(&beta, &z, lam, &active, None, &mut dual_pt);
            gap_passes += 1;
            gap_trace.push(res.gap);
            let active_before = active.n_active_feats();
            let stop = res.gap <= opts.eps;
            if !stop {
                rule.on_gap_pass(prob, lam, &res, &mut active);
                for j in 0..p {
                    if !active.feat[j] {
                        for c in 0..q {
                            beta[(j, c)] = 0.0;
                            v[(j, c)] = 0.0;
                        }
                    }
                }
            }
            let active_after = active.n_active_feats();
            trace.push(ScreenEvent { epoch: epochs, active_before, active_after });
            if let Some(t0) = t_pass {
                obs::emit(&obs::Event::GapPass {
                    lam,
                    epoch: epochs,
                    gap: res.gap,
                    radius: res.radius,
                    active_groups: active.n_active_groups(),
                    active_feats: active_after,
                    screened: active_before - active_after,
                    view_cols: p,
                    dual_choice: dual_pt.last_choice(),
                    secs: t0.elapsed().as_secs_f64(),
                });
            }
            last = Some(res);
            if stop {
                converged = true;
                break;
            }
        }
        // gradient step at v (restricted to active features)
        let zv = prob.predict(&v);
        let mut rho = Mat::zeros(prob.n(), q);
        prob.fit.neg_grad(&zv, &mut rho);
        let f_v = if backtracks { prob.fit.loss(&zv) } else { 0.0 };
        let next = loop {
            let mut next = v.clone();
            for j in 0..p {
                if !active.feat[j] {
                    continue;
                }
                for c in 0..q {
                    let g = -prob.x.col_dot(j, rho.col(c));
                    next[(j, c)] -= g / l;
                }
            }
            // prox per group
            let groups = prob.pen.groups();
            let mut blk = Vec::new();
            for g in 0..groups.len() {
                if !active.group[g] {
                    continue;
                }
                gather_block(&next, groups.feats(g), &mut blk);
                prob.pen.prox_group(g, &mut blk, lam / l);
                scatter_block(&mut next, groups.feats(g), &blk);
            }
            if !backtracks {
                break next;
            }
            // sufficient decrease: f(next) <= f(v) + <grad, next - v>
            //                                + (l/2) ||next - v||^2,
            // with the inner product taken in prediction space
            // (<grad F(v), next - v> = <-rho, X next - X v>).
            let zn = prob.predict(&next);
            let f_n = prob.fit.loss(&zn);
            let mut lin = 0.0;
            for ((r, a), b) in rho.as_slice().iter().zip(zn.as_slice()).zip(zv.as_slice()) {
                lin += -r * (a - b);
            }
            let mut dsq = 0.0;
            for (a, b) in next.as_slice().iter().zip(v.as_slice()) {
                let d = a - b;
                dsq += d * d;
            }
            let bound = f_v + lin + 0.5 * l * dsq;
            if f_n <= bound + 1e-12 * (1.0 + bound.abs()) || l >= 1e300 {
                break next;
            }
            l *= 2.0;
        };
        // FISTA momentum
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let coef = (t_k - 1.0) / t_next;
        for j in 0..p {
            for c in 0..q {
                let nb = next[(j, c)];
                v[(j, c)] = nb + coef * (nb - beta[(j, c)]);
                beta[(j, c)] = nb;
            }
        }
        t_k = t_next;
        epochs += 1;
    }

    let res = match last {
        Some(r) => r,
        None => {
            let z = prob.predict(&beta);
            let r = prob.gap_pass_dual(&beta, &z, lam, &active, None, &mut dual_pt);
            gap_trace.push(r.gap);
            r
        }
    };
    if tracing && ledger::emit_enabled() {
        let support: Vec<usize> = (0..p).filter(|&j| active.feat[j]).collect();
        obs::emit(&obs::Event::Certificate {
            sid,
            lam,
            gap: res.gap,
            radius: res.radius,
            n: res.theta.rows(),
            q: res.theta.cols(),
            p,
            theta: res.theta.as_slice().to_vec(),
            support,
            initial: None,
            rule: rule.name(),
            fit: prob.fit.kind().label(),
        });
    }
    SolveResult {
        z: prob.predict(&beta),
        beta,
        primal: res.primal,
        dual: res.dual,
        gap: res.gap,
        theta: res.theta,
        epochs,
        gap_passes,
        converged,
        active,
        screen_trace: trace,
        gap_trace,
        kkt_violations: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::screening::{NoScreening, Rule};
    use crate::solver::solve_fixed_lambda;
    use crate::{build_problem, Task};

    #[test]
    fn fista_matches_cd_lasso() {
        let ds = synth::leukemia_like_scaled(20, 40, 12, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-10, max_epochs: 50_000, ..Default::default() };
        let mut r1 = NoScreening;
        let cd = solve_fixed_lambda(&prob, lam, &mut r1, &opts);
        let mut r2 = Rule::GapSafeDyn.build();
        let fista = solve_fista(&prob, lam, r2.as_mut(), &opts);
        assert!(fista.converged, "fista gap={}", fista.gap);
        for j in 0..prob.p() {
            assert!(
                (cd.beta[(j, 0)] - fista.beta[(j, 0)]).abs() < 1e-4,
                "j={j}: {} vs {}",
                cd.beta[(j, 0)],
                fista.beta[(j, 0)]
            );
        }
    }

    #[test]
    fn fista_with_screening_converges_group() {
        let ds = synth::meg_like(16, 24, 3, 5);
        let prob = build_problem(ds, Task::MultiTask).unwrap();
        let lam = 0.4 * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-8, max_epochs: 50_000, ..Default::default() };
        let mut r = Rule::GapSafeDyn.build();
        let res = solve_fista(&prob, lam, r.as_mut(), &opts);
        assert!(res.converged, "gap={}", res.gap);
    }
}
