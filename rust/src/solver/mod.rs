//! Solvers. The workhorse is block coordinate descent with Gap Safe
//! screening (Alg. 2); `ista` provides a proximal-gradient comparator
//! (screening is solver-agnostic, Sec. 3.3) and `working_set` a Blitz-like
//! aggressive working-set strategy (Sec. 5.1).

pub mod ista;
pub mod parallel;
pub mod path;
pub mod working_set;

use crate::datafit::{DataFit, FitKind};
use crate::linalg::compact::CompactDesign;
use crate::linalg::sparse::Design;
use crate::linalg::Mat;
use crate::obs::{self, ledger};
use crate::penalty::{gather_block, scatter_block, ActiveSet};
use crate::problem::{GapResult, Problem};
use crate::screening::dual::{DualPoint, DualStrategy};
use crate::screening::{PrevSolution, ScreeningRule};
use std::time::Instant;

/// Inner-solver options (Alg. 2 inputs).
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Max CD epochs K.
    pub max_epochs: usize,
    /// Gap / screening cadence f_ce (paper uses 10).
    pub screen_every: usize,
    /// Absolute duality-gap tolerance (callers pre-scale per Sec. 5).
    pub eps: f64,
    /// Max strong-rule KKT repair rounds.
    pub max_kkt_rounds: usize,
    /// Active-set compaction (`linalg::compact`): physically repack the
    /// surviving columns whenever screening kills a large fraction of the
    /// remaining features, so CD epochs and gap passes iterate a small
    /// contiguous working matrix. Bitwise-transparent — disabling it only
    /// changes speed, never a single output bit.
    pub compact: bool,
    /// Dual-point strategy for the gap passes
    /// ([`crate::screening::dual`]): `Rescale` reproduces the historical
    /// output bit for bit; `BestKept` (default) / `Refine` keep the best
    /// dual point seen per lambda so the reported gap — and the Gap Safe
    /// radius — never increase between passes.
    pub dual: DualStrategy,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_epochs: 10_000,
            screen_every: 10,
            eps: 1e-8,
            max_kkt_rounds: 20,
            compact: true,
            dual: DualStrategy::default(),
        }
    }
}

/// Repack when the surviving columns are at most this fraction of the
/// columns the current view still carries — i.e. a screening event killed
/// more than 25% of the remaining features. The geometric shrink bounds
/// the total packing cost of a solve by a small multiple of one full
/// column copy.
const COMPACT_REPACK_FRACTION: f64 = 0.75;

/// One screening event of a solve: the active-feature count around one
/// gap pass (`active_before - active_after` is what that pass killed).
/// This is the payload tracing serializes and the figures' "fraction of
/// active variables" protocols consume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScreenEvent {
    /// CD epochs completed when the pass ran.
    pub epoch: usize,
    /// Active features before the pass screened.
    pub active_before: usize,
    /// Active features after (the safe superset the next epoch iterates).
    pub active_after: usize,
}

/// Outcome of one fixed-lambda solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub beta: Mat,
    /// Prediction X beta.
    pub z: Mat,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    /// Final rescaled dual point.
    pub theta: Mat,
    pub epochs: usize,
    pub gap_passes: usize,
    pub converged: bool,
    /// Active set at exit (safe superset of the support).
    pub active: ActiveSet,
    /// One [`ScreenEvent`] per gap pass.
    pub screen_trace: Vec<ScreenEvent>,
    /// Reported duality gap at each gap pass (aligned with
    /// `screen_trace` plus any fallback pass). For the CD solver with
    /// `dual = best` / `refine` this sequence is non-increasing within a
    /// KKT round (non-decreasing dual, non-increasing primal); FISTA
    /// fills it too, but its momentum steps are not primal-monotone, so
    /// only the dual side of the invariant holds there.
    pub gap_trace: Vec<f64>,
    /// Strong-rule violations repaired.
    pub kkt_violations: usize,
}

/// Solve min F(beta) + lambda Omega(beta) at one lambda with screening
/// (Alg. 2), optionally warm-started and optionally restricted to an
/// initial active set (active warm start, Eq. 22).
pub fn solve_fixed_lambda_with(
    prob: &Problem,
    lam: f64,
    lam_max: f64,
    beta0: Option<&Mat>,
    init_active: Option<&ActiveSet>,
    rule: &mut dyn ScreeningRule,
    prev: Option<&PrevSolution>,
    opts: &SolveOptions,
) -> SolveResult {
    let (p, q) = (prob.p(), prob.q());
    let mut beta = match beta0 {
        Some(b) => b.clone(),
        None => Mat::zeros(p, q),
    };
    let mut active = match init_active {
        Some(a) => a.clone(),
        None => ActiveSet::full(prob.pen.groups()),
    };
    // Tracing (obs): captured once per solve. When false, no clock is
    // read and no event is built anywhere below; when true, timing values
    // never feed solver arithmetic — tracing is bitwise-transparent
    // (pinned by rust/tests/obs_trace.rs).
    let tracing = obs::enabled();
    // Provenance ledger (obs::ledger): this solve's sid becomes the
    // thread-local context every sphere site stamps its events with; the
    // scope guard restores the outer context on drop (working-set outer /
    // inner nesting). Ids and counters are not conditional on tracing —
    // only event emission is.
    ledger::count_cols(p);
    let (sid, _ledger_scope) = ledger::begin_solve(lam);
    let ledger_on = tracing && ledger::emit_enabled();
    // What the final certificate records as the starting active set
    // (None = the full design, the common case).
    let initial: Option<Vec<usize>> = match init_active {
        Some(a) if ledger_on && a.n_active_feats() < p => {
            Some((0..p).filter(|&j| a.feat[j]).collect())
        }
        _ => None,
    };
    rule.begin_lambda(prob, lam, lam_max, prev, &mut active);
    zero_screened(prob, &mut beta, &active);
    let t_solve = tracing.then(Instant::now);
    let mut t_cd = 0.0f64;
    let mut t_gap = 0.0f64;
    let mut state = CdState::new(prob, &beta, &active, opts.compact, tracing);
    // Dual-point tracker (screening::dual): keeps the best dual objective
    // seen at this lambda so the reported gap / Gap Safe radius cannot
    // oscillate upward between passes (strategy `rescale` = historical
    // behavior, tracker passes everything through untouched).
    let mut dual_pt = DualPoint::new(opts.dual);

    let mut epochs = 0usize;
    let mut gap_passes = 0usize;
    let mut converged = false;
    let mut screen_trace = Vec::new();
    let mut gap_trace = Vec::new();
    let mut kkt_violations = 0usize;
    let mut last: Option<GapResult> = None;

    let mut kkt_round = 0usize;
    'outer: loop {
        for k in 0..opts.max_epochs {
            if k % opts.screen_every == 0 {
                ledger::set_epoch(epochs);
                let t_pass = tracing.then(Instant::now);
                let z = state.z(prob);
                let res = prob.gap_pass_dual(&beta, &z, lam, &active, state.view(), &mut dual_pt);
                gap_passes += 1;
                let active_before = active.n_active_feats();
                // Screen before the stopping test (Alg. 2 performs both at
                // the same event; screening first makes the recorded active
                // set meaningful even when the gap already certifies
                // convergence, e.g. at lambda_max).
                rule.on_gap_pass(prob, lam, &res, &mut active);
                if zero_screened(prob, &mut beta, &active) {
                    state.resync(prob, &beta);
                }
                // Repack the working view when this screening event killed
                // a large enough fraction of the remaining columns.
                state.maybe_repack(prob, &active);
                let active_after = active.n_active_feats();
                screen_trace.push(ScreenEvent { epoch: epochs, active_before, active_after });
                gap_trace.push(res.gap);
                if let Some(t0) = t_pass {
                    let secs = t0.elapsed().as_secs_f64();
                    t_gap += secs;
                    obs::emit(&obs::Event::GapPass {
                        lam,
                        epoch: epochs,
                        gap: res.gap,
                        radius: res.radius,
                        active_groups: active.n_active_groups(),
                        active_feats: active_after,
                        screened: active_before - active_after,
                        view_cols: state.view_width,
                        dual_choice: dual_pt.last_choice(),
                        secs,
                    });
                }
                let stop = res.gap <= opts.eps;
                last = Some(res);
                if stop {
                    converged = true;
                    break;
                }
            }
            if let Some(t0) = tracing.then(Instant::now) {
                state.cd_epoch(prob, &mut beta, &active, lam);
                t_cd += t0.elapsed().as_secs_f64();
            } else {
                state.cd_epoch(prob, &mut beta, &active, lam);
            }
            epochs += 1;
        }
        if last.is_none() {
            let t_pass = tracing.then(Instant::now);
            let z = state.z(prob);
            let res = prob.gap_pass_dual(&beta, &z, lam, &active, state.view(), &mut dual_pt);
            gap_trace.push(res.gap);
            last = Some(res);
            gap_passes += 1;
            if let Some(t0) = t_pass {
                t_gap += t0.elapsed().as_secs_f64();
            }
        }
        // KKT post-convergence check for un-safe rules (Sec. 3.6): any
        // inactive group whose dual-norm statistic exceeds 1 was wrongly
        // discarded; reactivate and resume.
        if converged && rule.needs_kkt_check() && kkt_round < opts.max_kkt_rounds {
            let theta = match last.as_ref() {
                // Unreachable — the fill block above guarantees a pass —
                // but a break (skip the KKT recheck) degrades gracefully
                // where an unwrap would panic mid-path.
                None => break,
                Some(res) => &res.theta,
            };
            let full = ActiveSet::full(prob.pen.groups());
            let stats = prob.stats_for_center(theta, &full);
            let mut violated = false;
            let mut reactivated = 0usize;
            for g in 0..prob.n_groups() {
                if !active.group[g] && stats.group_dual[g] > 1.0 + 1e-12 {
                    active.group[g] = true;
                    for &j in prob.pen.groups().feats(g) {
                        active.feat[j] = true;
                    }
                    violated = true;
                    kkt_violations += 1;
                    reactivated += 1;
                    if ledger_on {
                        obs::emit(&obs::Event::Reactivate {
                            sid,
                            lam,
                            round: kkt_round + 1,
                            group: g,
                            feats: prob.pen.groups().feats(g).len(),
                            stat: stats.group_dual[g],
                        });
                    }
                }
            }
            if violated {
                if tracing {
                    obs::emit(&obs::Event::Kkt { lam, reactivated, round: kkt_round + 1 });
                }
                // Reactivation breaks the view's shrink-only contract:
                // drop it and let the next screening event repack. The
                // kept dual point's correlations are stale for the
                // reactivated groups for the same reason — drop it too.
                state.reset_compact(prob);
                dual_pt.invalidate();
                kkt_round += 1;
                converged = false;
                continue 'outer;
            }
        }
        break;
    }

    // Every 'outer iteration records a gap pass before it can break, so
    // the fallback arm never runs; computing a genuine pass there (rather
    // than unwrapping) keeps the solver panic-free at a serve-reachable
    // site without changing any recorded trajectory.
    let res = match last {
        Some(res) => res,
        None => {
            let z = state.z(prob);
            let res = prob.gap_pass_dual(&beta, &z, lam, &active, state.view(), &mut dual_pt);
            gap_trace.push(res.gap);
            gap_passes += 1;
            res
        }
    };
    if ledger_on {
        // Final safety certificate: the dual point, gap, radius and
        // support that `gapsafe trace verify` re-checks against the raw
        // design with an independent sphere-test implementation.
        let support: Vec<usize> = (0..p).filter(|&j| active.feat[j]).collect();
        obs::emit(&obs::Event::Certificate {
            sid,
            lam,
            gap: res.gap,
            radius: res.radius,
            n: res.theta.rows(),
            q: res.theta.cols(),
            p,
            theta: res.theta.as_slice().to_vec(),
            support,
            initial,
            rule: rule.name(),
            fit: prob.fit.kind().label(),
        });
    }
    if let Some(t0) = t_solve {
        obs::emit(&obs::Event::SolveSpan {
            lam,
            epochs,
            gap_passes,
            gap: res.gap,
            converged,
            kkt_violations,
            active_feats: active.n_active_feats(),
            cd_secs: t_cd,
            gap_secs: t_gap,
            link_secs: state.t_link,
            total_secs: t0.elapsed().as_secs_f64(),
            kernel: crate::linalg::kernels::active_kind().label(),
        });
    }
    SolveResult {
        z: state.z(prob),
        beta,
        primal: res.primal,
        dual: res.dual,
        gap: res.gap,
        theta: res.theta,
        epochs,
        gap_passes,
        converged,
        active,
        screen_trace,
        gap_trace,
        kkt_violations,
    }
}

/// Convenience wrapper with a fresh active set and no previous point.
pub fn solve_fixed_lambda(
    prob: &Problem,
    lam: f64,
    rule: &mut dyn ScreeningRule,
    opts: &SolveOptions,
) -> SolveResult {
    let lam_max = prob.lambda_max();
    solve_fixed_lambda_with(prob, lam, lam_max, None, None, rule, None, opts)
}

/// Zero coefficients of screened features (they are provably zero at the
/// optimum); returns true if anything changed (prediction must resync).
fn zero_screened(prob: &Problem, beta: &mut Mat, active: &ActiveSet) -> bool {
    let q = prob.q();
    let mut changed = false;
    for j in 0..prob.p() {
        if !active.feat[j] {
            for k in 0..q {
                if beta[(j, k)] != 0.0 {
                    beta[(j, k)] = 0.0;
                    changed = true;
                }
            }
        }
    }
    changed
}

/// Coordinate-descent state: for quadratic fits we maintain the residual
/// rho = Y - X B (classic CD); for logistic / multinomial we maintain the
/// linear predictor Z = X B and the per-row link values.
///
/// The state also owns the *compact working view*
/// ([`crate::linalg::compact::CompactDesign`]): once screening has killed
/// enough columns, the surviving ones are physically repacked so every
/// subsequent epoch and gap pass iterates a small contiguous matrix. The
/// view packs whole live groups (coarser than the feature bitmap — SGL
/// screens single features inside live groups, and `cd_epoch` visits every
/// feature of an active group either way), visits groups in the same
/// ascending order as the bitmap scan, and reads column data copied
/// verbatim, so packed and full paths are bitwise identical.
struct CdState {
    kind: FitKind,
    /// Quadratic: rho = Y - Z. Others: Z itself.
    buf: Mat,
    /// Logistic: sigma(z). Multinomial: softmax rows. Unused for quadratic.
    link: Mat,
    /// Scratch for block updates.
    blk: Vec<f64>,
    grad: Vec<f64>,
    /// Packed working view (None = full design).
    compact: Option<CompactDesign>,
    /// Surviving group ids at the last repack (ascending full ids).
    live_groups: Vec<usize>,
    /// Per-live-group Lipschitz constants (the same values as
    /// `prob.lipschitz[g]`, gathered at pack time for locality).
    live_lipschitz: Vec<f64>,
    /// Columns the current view carries (p when not packed).
    view_width: usize,
    /// Compaction enabled ([`SolveOptions::compact`]).
    enabled: bool,
    /// Scratch for the batched link refresh over touched rows.
    row_mark: Vec<bool>,
    rows_buf: Vec<usize>,
    /// Poisson only: persistent per-group step multipliers. The fit's
    /// `lipschitz_scale()` is the curvature at z = 0, not a global bound
    /// (e^z is unbounded), so each group's step is validated against the
    /// true loss change and the multiplier doubled on violation — and kept
    /// for later epochs, bounding the total backtracking work of a solve.
    step_mult: Vec<f64>,
    /// Saved pre-step block for the backtracking retries.
    blk0: Vec<f64>,
    /// Dense scratch w = X_g delta used by the majorization check.
    step_w: Vec<f64>,
    /// Tracing enabled for this solve (captured once; see [`crate::obs`]).
    timing: bool,
    /// Accumulated wall time inside link refreshes (timing only; never
    /// read by solver arithmetic).
    t_link: f64,
}

impl CdState {
    fn new(
        prob: &Problem,
        beta: &Mat,
        active: &ActiveSet,
        compact_enabled: bool,
        timing: bool,
    ) -> Self {
        let kind = prob.fit.kind();
        let (n, q) = (prob.n(), prob.q());
        let mut st = CdState {
            kind,
            buf: Mat::zeros(n, q),
            link: Mat::zeros(n, q),
            blk: Vec::new(),
            grad: Vec::new(),
            compact: None,
            live_groups: Vec::new(),
            live_lipschitz: Vec::new(),
            view_width: prob.p(),
            enabled: compact_enabled,
            row_mark: vec![false; n],
            rows_buf: Vec::new(),
            step_mult: if kind == FitKind::Poisson {
                vec![1.0; prob.n_groups()]
            } else {
                Vec::new()
            },
            blk0: Vec::new(),
            step_w: if kind == FitKind::Poisson { vec![0.0; n] } else { Vec::new() },
            timing,
            t_link: 0.0,
        };
        st.resync(prob, beta);
        // Sequential / static rules may have screened in begin_lambda
        // already — compact before the first epoch when they did.
        st.maybe_repack(prob, active);
        st
    }

    /// The current packed view, if any (handed to the gap passes).
    fn view(&self) -> Option<&CompactDesign> {
        self.compact.as_ref()
    }

    /// Repack when the surviving columns are at most
    /// [`COMPACT_REPACK_FRACTION`] of what the current view carries.
    /// Counting the prospective columns is O(G); the pack itself is
    /// O(nnz of the survivors).
    fn maybe_repack(&mut self, prob: &Problem, active: &ActiveSet) {
        if !self.enabled {
            return;
        }
        let groups = prob.pen.groups();
        let keep: usize = (0..groups.len())
            .filter(|&g| active.group[g])
            .map(|g| groups.feats(g).len())
            .sum();
        if keep < self.view_width
            && (keep as f64) <= COMPACT_REPACK_FRACTION * self.view_width as f64
        {
            self.repack(prob, active);
        }
    }

    fn repack(&mut self, prob: &Problem, active: &ActiveSet) {
        let groups = prob.pen.groups();
        let mut keep = vec![false; prob.p()];
        self.live_groups.clear();
        self.live_lipschitz.clear();
        for g in 0..groups.len() {
            if active.group[g] {
                self.live_groups.push(g);
                self.live_lipschitz.push(prob.lipschitz[g]);
                for &j in groups.feats(g) {
                    keep[j] = true;
                }
            }
        }
        let cd = CompactDesign::pack(&prob.x, &keep);
        self.view_width = cd.width();
        self.compact = Some(cd);
    }

    /// Drop the view (KKT repair re-activated groups, breaking the
    /// shrink-only contract); the next screening event may repack.
    fn reset_compact(&mut self, prob: &Problem) {
        self.compact = None;
        self.live_groups.clear();
        self.live_lipschitz.clear();
        self.view_width = prob.p();
    }

    /// Recompute state from beta (after screening zeroed coefficients).
    fn resync(&mut self, prob: &Problem, beta: &Mat) {
        let z = prob.predict(beta);
        match self.kind {
            FitKind::Quadratic => {
                // rho = Y - Z
                let y = prob.fit.targets();
                for ((b, zi), yi) in self
                    .buf
                    .as_mut_slice()
                    .iter_mut()
                    .zip(z.as_slice())
                    .zip(y.as_slice())
                {
                    *b = yi - zi;
                }
            }
            FitKind::Logistic | FitKind::Multinomial | FitKind::Poisson => {
                self.buf.copy_from(&z);
                // link = Y - neg_grad(Z): the mean parameter (sigma(z) /
                // softmax rows / e^z) stored directly.
                refresh_link_full(&*prob.fit, &self.buf, &mut self.link);
            }
        }
    }

    /// Current prediction Z = X B.
    fn z(&self, prob: &Problem) -> Mat {
        match self.kind {
            FitKind::Quadratic => {
                let y = prob.fit.targets();
                let mut z = Mat::zeros(self.buf.rows(), self.buf.cols());
                for ((zi, b), yi) in z
                    .as_mut_slice()
                    .iter_mut()
                    .zip(self.buf.as_slice())
                    .zip(y.as_slice())
                {
                    *zi = yi - b;
                }
                z
            }
            _ => self.buf.clone(),
        }
    }

    /// One (block) coordinate-descent epoch over the active set. With a
    /// packed view the loop visits only the surviving groups and reads
    /// columns from the contiguous working matrix; the link refresh for
    /// logistic / multinomial fits is batched over exactly the rows the
    /// changed columns touch (sparse designs) instead of a full O(n q)
    /// pass per group.
    fn cd_epoch(&mut self, prob: &Problem, beta: &mut Mat, active: &ActiveSet, lam: f64) {
        let groups = prob.pen.groups();
        let q = prob.q();
        let packed = self.compact.is_some();
        let n_visit = if packed { self.live_groups.len() } else { groups.len() };
        for t in 0..n_visit {
            let g = if packed { self.live_groups[t] } else { t };
            if !active.group[g] {
                continue;
            }
            let feats = groups.feats(g);
            let lg = if packed { self.live_lipschitz[t] } else { prob.lipschitz[g] };
            if lg <= 0.0 {
                continue;
            }
            let view = self.compact.as_ref();
            // gradient block: grad[(i,k)] = -X_j^T rho_k   (rho = -G(Z))
            self.grad.clear();
            match self.kind {
                FitKind::Quadratic => {
                    for &j in feats {
                        for k in 0..q {
                            self.grad.push(-design_col_dot(&prob.x, view, j, self.buf.col(k)));
                        }
                    }
                }
                FitKind::Logistic | FitKind::Multinomial | FitKind::Poisson => {
                    // grad = X_j^T (link - y)
                    let y = prob.fit.targets();
                    for &j in feats {
                        for k in 0..q {
                            self.grad.push(design_col_dot_diff(
                                &prob.x,
                                view,
                                j,
                                self.link.col(k),
                                y.col(k),
                            ));
                        }
                    }
                }
            }
            // v = beta_g - grad / L_g ; prox ; delta update
            gather_block(beta, feats, &mut self.blk);
            if self.kind == FitKind::Poisson {
                // The trial L_g only majorizes where e^z <= 1: validate the
                // step against the true loss change and backtrack.
                self.poisson_group_step(prob, g, feats, lam, lg);
            } else {
                for (b, gr) in self.blk.iter_mut().zip(&self.grad) {
                    *b -= gr / lg;
                }
                prob.pen.prox_group(g, &mut self.blk, lam / lg);
            }
            // Re-borrow: the Poisson step above took &mut self, which ends
            // the earlier view borrow.
            let view = self.compact.as_ref();
            // Apply the delta to the prediction state and collect the rows
            // the changed columns touch, so the link refresh below runs on
            // exactly those rows (a full pass is only needed when a dense
            // column — which touches every row — changed).
            let mut changed = false;
            let mut dense_touch = matches!(self.kind, FitKind::Quadratic);
            self.rows_buf.clear();
            for (i, &j) in feats.iter().enumerate() {
                let mut feat_changed = false;
                for k in 0..q {
                    let new = self.blk[i * q + k];
                    let old = beta[(j, k)];
                    let delta = new - old;
                    if delta != 0.0 {
                        feat_changed = true;
                        changed = true;
                        // Quadratic maintains rho = Y - Z (subtract the
                        // update); the others maintain Z itself (add it).
                        let alpha =
                            if matches!(self.kind, FitKind::Quadratic) { -delta } else { delta };
                        design_col_axpy(&prob.x, view, j, alpha, self.buf.col_mut(k));
                    }
                }
                if feat_changed && !dense_touch {
                    match design_col_rows(&prob.x, view, j) {
                        None => dense_touch = true,
                        Some(rows) => {
                            for &r in rows {
                                if !self.row_mark[r] {
                                    self.row_mark[r] = true;
                                    self.rows_buf.push(r);
                                }
                            }
                        }
                    }
                }
            }
            if changed {
                scatter_block(beta, feats, &self.blk);
                if !matches!(self.kind, FitKind::Quadratic) {
                    let t0 = self.timing.then(Instant::now);
                    if dense_touch {
                        for &r in &self.rows_buf {
                            self.row_mark[r] = false;
                        }
                        refresh_link_full(&*prob.fit, &self.buf, &mut self.link);
                    } else {
                        // Rows outside `rows_buf` have an unchanged linear
                        // predictor, and the link is a row-local function
                        // of Z — the restricted refresh is bitwise
                        // identical to the full pass.
                        prob.fit.refresh_link_rows(&self.buf, &self.rows_buf, &mut self.link);
                        for &r in &self.rows_buf {
                            self.row_mark[r] = false;
                        }
                    }
                    if let Some(t0) = t0 {
                        self.t_link += t0.elapsed().as_secs_f64();
                    }
                }
            }
        }
    }

    /// One Poisson block step with persistent backtracking. The trial
    /// constant `mult * lg` (curvature at z = 0) is accepted only when the
    /// true restricted loss change is majorized,
    ///
    ///   sum_i [e^{z_i + w_i} - e^{z_i} - y_i w_i]
    ///     <= grad_g^T delta + (L/2) ||delta||^2,       w = X_g delta,
    ///
    /// computable in O(nnz of the group's columns). On violation the
    /// multiplier doubles and *stays* doubled for the rest of the solve,
    /// so the total number of rejected trials is logarithmic in the final
    /// constant rather than per-epoch.
    fn poisson_group_step(&mut self, prob: &Problem, g: usize, feats: &[usize], lam: f64, lg: f64) {
        debug_assert_eq!(prob.q(), 1, "poisson is a scalar-count fit");
        self.blk0.clear();
        self.blk0.extend_from_slice(&self.blk);
        loop {
            let l_used = self.step_mult[g] * lg;
            for i in 0..self.blk.len() {
                self.blk[i] = self.blk0[i] - self.grad[i] / l_used;
            }
            prob.pen.prox_group(g, &mut self.blk, lam / l_used);
            let mut lin = 0.0;
            let mut dsq = 0.0;
            let mut moved = false;
            for i in 0..self.blk.len() {
                let d = self.blk[i] - self.blk0[i];
                lin += self.grad[i] * d;
                dsq += d * d;
                if d != 0.0 {
                    moved = true;
                }
            }
            if !moved {
                return; // zero step: nothing to validate
            }
            // w = X_g delta, accumulated over the rows the changed columns
            // touch (a dense column forces the full-row scan).
            let view = self.compact.as_ref();
            let mut dense_touch = false;
            self.rows_buf.clear();
            for (i, &j) in feats.iter().enumerate() {
                let d = self.blk[i] - self.blk0[i];
                if d == 0.0 {
                    continue;
                }
                design_col_axpy(&prob.x, view, j, d, &mut self.step_w);
                match design_col_rows(&prob.x, view, j) {
                    None => dense_touch = true,
                    Some(rows) => {
                        for &r in rows {
                            if !self.row_mark[r] {
                                self.row_mark[r] = true;
                                self.rows_buf.push(r);
                            }
                        }
                    }
                }
            }
            let zs = self.buf.col(0);
            let ys = prob.fit.targets().as_slice();
            let mut actual = 0.0;
            if dense_touch {
                for (i, &w) in self.step_w.iter().enumerate() {
                    if w != 0.0 {
                        actual += (zs[i] + w).exp() - zs[i].exp() - ys[i] * w;
                    }
                }
            } else {
                for &r in &self.rows_buf {
                    let w = self.step_w[r];
                    actual += (zs[r] + w).exp() - zs[r].exp() - ys[r] * w;
                }
            }
            // reset the scratch before either exit
            if dense_touch {
                self.step_w.iter_mut().for_each(|v| *v = 0.0);
            } else {
                for &r in &self.rows_buf {
                    self.step_w[r] = 0.0;
                }
            }
            for &r in &self.rows_buf {
                self.row_mark[r] = false;
            }
            self.rows_buf.clear();
            let bound = lin + 0.5 * l_used * dsq;
            // tiny relative slack so rounding at actual ~ bound cannot
            // force a spurious doubling; NaN/overflow trials compare false
            // and keep backtracking toward an accept-by-cap zero step.
            if actual <= bound + 1e-12 * (1.0 + bound.abs()) || self.step_mult[g] >= 1e15 {
                return;
            }
            self.step_mult[g] *= 2.0;
        }
    }
}

/// Column kernels routed through the packed working view when one exists.
/// Full-index addressing either way; the packed variants run on column
/// data copied verbatim, so results are bitwise identical.
#[inline]
fn design_col_dot(x: &Design, view: Option<&CompactDesign>, j: usize, v: &[f64]) -> f64 {
    match view {
        Some(cd) => cd.col_dot(j, v),
        None => x.col_dot(j, v),
    }
}

#[inline]
fn design_col_dot_diff(
    x: &Design,
    view: Option<&CompactDesign>,
    j: usize,
    a: &[f64],
    b: &[f64],
) -> f64 {
    match view {
        Some(cd) => cd.col_dot_diff(j, a, b),
        None => x.col_dot_diff(j, a, b),
    }
}

#[inline]
fn design_col_axpy(
    x: &Design,
    view: Option<&CompactDesign>,
    j: usize,
    alpha: f64,
    out: &mut [f64],
) {
    match view {
        Some(cd) => cd.col_axpy(j, alpha, out),
        None => x.col_axpy(j, alpha, out),
    }
}

#[inline]
fn design_col_rows<'a>(
    x: &'a Design,
    view: Option<&'a CompactDesign>,
    j: usize,
) -> Option<&'a [usize]> {
    match view {
        Some(cd) => cd.col_rows(j),
        None => x.col_rows(j),
    }
}

/// Full link refresh: link = Y - neg_grad(Z), elementwise over all rows.
fn refresh_link_full(fit: &dyn DataFit, z: &Mat, link: &mut Mat) {
    fit.neg_grad(z, link);
    let y = fit.targets();
    for (l, yi) in link.as_mut_slice().iter_mut().zip(y.as_slice()) {
        *l = yi - *l;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::screening::{NoScreening, Rule};
    use crate::{build_problem, Task};

    fn small_lasso() -> Problem {
        let ds = synth::leukemia_like_scaled(24, 60, 3, false);
        build_problem(ds, Task::Lasso).unwrap()
    }

    #[test]
    fn cd_converges_lasso() {
        let prob = small_lasso();
        let lam = 0.2 * prob.lambda_max();
        let mut rule = NoScreening;
        let opts = SolveOptions { eps: 1e-10, ..Default::default() };
        let res = solve_fixed_lambda(&prob, lam, &mut rule, &opts);
        assert!(res.converged, "gap={}", res.gap);
        assert!(res.gap <= 1e-10);
        // solution is sparse
        let nnz = res.beta.nnz();
        assert!(nnz < 60, "dense solution?");
        assert!(nnz > 0, "trivial solution");
    }

    #[test]
    fn screening_preserves_solution() {
        // Safety check: the Gap Safe solution equals the no-screening one.
        let prob = small_lasso();
        let lam = 0.15 * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-12, ..Default::default() };
        let mut r_none = NoScreening;
        let a = solve_fixed_lambda(&prob, lam, &mut r_none, &opts);
        let mut r_gap = Rule::GapSafeDyn.build();
        let b = solve_fixed_lambda(&prob, lam, r_gap.as_mut(), &opts);
        for j in 0..prob.p() {
            assert!(
                (a.beta[(j, 0)] - b.beta[(j, 0)]).abs() < 1e-6,
                "solutions diverge at {j}: {} vs {}",
                a.beta[(j, 0)],
                b.beta[(j, 0)]
            );
        }
        // screened features are exactly zero in both
        for j in 0..prob.p() {
            if !b.active.feat[j] {
                assert_eq!(b.beta[(j, 0)], 0.0);
            }
        }
    }

    #[test]
    fn screening_speeds_up_epoch_work() {
        let prob = small_lasso();
        let lam = 0.1 * prob.lambda_max();
        let opts = SolveOptions { eps: 1e-10, ..Default::default() };
        let mut r_gap = Rule::GapSafeDyn.build();
        let res = solve_fixed_lambda(&prob, lam, r_gap.as_mut(), &opts);
        assert!(res.converged);
        // by the end, active set should be well below p
        let last = res.screen_trace.last().unwrap();
        assert!(last.active_after < 60, "no screening at convergence: {last:?}");
        assert!(last.active_after <= last.active_before);
    }

    #[test]
    fn logistic_cd_converges() {
        let ds = synth::leukemia_like_scaled(30, 40, 5, true);
        let prob = build_problem(ds, Task::Logreg).unwrap();
        let lam = 0.2 * prob.lambda_max();
        let mut rule = Rule::GapSafeDyn.build();
        let opts = SolveOptions { eps: 1e-9, ..Default::default() };
        let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
        assert!(res.converged, "gap={}", res.gap);
    }

    #[test]
    fn multitask_cd_converges() {
        let ds = synth::meg_like(20, 30, 4, 7);
        let prob = build_problem(ds, Task::MultiTask).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let mut rule = Rule::GapSafeDyn.build();
        let opts = SolveOptions { eps: 1e-9, ..Default::default() };
        let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
        assert!(res.converged, "gap={}", res.gap);
        // row sparsity
        let active_rows = (0..30).filter(|&j| res.beta.row_norm(j) > 0.0).count();
        assert!(active_rows < 30);
    }

    #[test]
    fn sgl_cd_converges() {
        let mut ds = synth::leukemia_like_scaled(20, 36, 9, false);
        ds.group_size = Some(4);
        let prob = build_problem(ds, Task::SparseGroupLasso { tau: 0.4 }).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let mut rule = Rule::GapSafeFull.build();
        let opts = SolveOptions { eps: 1e-9, ..Default::default() };
        let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
        assert!(res.converged, "gap={}", res.gap);
    }

    #[test]
    fn multinomial_cd_converges() {
        let (ds, _) = synth::multinomial_like(24, 20, 3, 11);
        let prob = build_problem(ds, Task::Multinomial).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let mut rule = Rule::GapSafeDyn.build();
        let opts = SolveOptions { eps: 1e-7, max_epochs: 20_000, ..Default::default() };
        let res = solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
        assert!(res.converged, "gap={}", res.gap);
    }

    #[test]
    fn compaction_is_bitwise_transparent_fixed_lambda() {
        // The packed working view must not change a single output bit, for
        // dense and sparse designs and for every fit family the CD state
        // handles differently (residual vs link maintenance).
        let cases: Vec<(Problem, f64)> = vec![
            {
                let p = small_lasso();
                let l = 0.1 * p.lambda_max();
                (p, l)
            },
            {
                let ds = synth::sparse_regression(40, 120, 0.15, 3);
                let p = build_problem(ds, Task::Lasso).unwrap();
                let l = 0.1 * p.lambda_max();
                (p, l)
            },
            {
                let ds = synth::leukemia_like_scaled(30, 50, 5, true);
                let p = build_problem(ds, Task::Logreg).unwrap();
                let l = 0.2 * p.lambda_max();
                (p, l)
            },
            {
                let ds = synth::meg_like(18, 36, 3, 7);
                let p = build_problem(ds, Task::MultiTask).unwrap();
                let l = 0.3 * p.lambda_max();
                (p, l)
            },
        ];
        for (prob, lam) in &cases {
            let base = SolveOptions { eps: 1e-10, ..Default::default() };
            let on = SolveOptions { compact: true, ..base.clone() };
            let off = SolveOptions { compact: false, ..base };
            let mut r1 = Rule::GapSafeFull.build();
            let mut r2 = Rule::GapSafeFull.build();
            let a = solve_fixed_lambda(prob, *lam, r1.as_mut(), &on);
            let b = solve_fixed_lambda(prob, *lam, r2.as_mut(), &off);
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "gap diverged");
            for j in 0..prob.p() {
                for k in 0..prob.q() {
                    assert_eq!(
                        a.beta[(j, k)].to_bits(),
                        b.beta[(j, k)].to_bits(),
                        "beta diverged at ({j},{k})"
                    );
                }
            }
        }
    }

    #[test]
    fn strong_rule_kkt_repair_matches_safe_solution() {
        let prob = small_lasso();
        let lmax = prob.lambda_max();
        let lam = 0.5 * lmax;
        let opts = SolveOptions { eps: 1e-12, ..Default::default() };
        // build a prev at lambda_max
        let beta0 = Mat::zeros(prob.p(), 1);
        let z0 = prob.predict(&beta0);
        let full = ActiveSet::full(prob.pen.groups());
        let g0 = prob.gap_pass(&beta0, &z0, lmax, &full);
        let prev = PrevSolution {
            lam: lmax,
            beta: beta0.clone(),
            z: z0.clone(),
            theta: g0.theta,
            loss: prob.fit.loss(&z0),
            pen_value: 0.0,
            active: full,
        };
        let mut strong = Rule::Strong.build();
        let res = solve_fixed_lambda_with(
            &prob, lam, lmax, None, None, strong.as_mut(), Some(&prev), &opts,
        );
        let mut none = NoScreening;
        let want = solve_fixed_lambda(&prob, lam, &mut none, &opts);
        assert!(res.converged);
        for j in 0..prob.p() {
            assert!(
                (res.beta[(j, 0)] - want.beta[(j, 0)]).abs() < 1e-6,
                "j={j}: strong={} oracle={} active={} kkt_viol={}",
                res.beta[(j, 0)],
                want.beta[(j, 0)],
                res.active.feat[j],
                res.kkt_violations
            );
        }
    }
}
