//! Parallel execution subsystem: a std-only scoped-thread worker pool and
//! the chunked pathwise solver built on it.
//!
//! The offline registry ships no crates, so there is no rayon here — the
//! pool is [`std::thread::scope`] plus an atomic work cursor, which is all
//! the solver stack needs: every parallel site in the crate is a fork/join
//! over a finite, pre-known work list.
//!
//! Three layers fan out through [`parallel_map`]:
//!
//! * **paths** — [`solve_path_parallel`] chunks the lambda grid so chunks
//!   run concurrently while warm starts stay sequential *within* a chunk
//!   (chunk heads are seeded by a cheap coarse pre-pass; see below);
//! * **cross-validation / model selection** — `coordinator::cv` runs folds
//!   (and SGL tau candidates) as independent work items;
//! * **screening sweeps** — `Problem::corr_active` splits the O(np)
//!   correlation stage of a gap/screening pass over feature ranges (the
//!   per-group sphere tests themselves are O(p) and stay serial).
//!
//! Batch serving ([`crate::coordinator::BatchRunner`]) schedules whole
//! `(Problem, PathConfig)` requests over the same pool.
//!
//! # Determinism contract
//!
//! `threads = 1` always takes the exact serial code path (byte-for-byte
//! identical results). For `threads > 1`, work items are pure functions of
//! their inputs and results are re-assembled in input order, so fold-level
//! and request-level parallelism are bitwise deterministic; the chunked
//! path differs from the serial path only through the warm-start points of
//! chunk heads, and converges to the same duality-gap tolerance at every
//! lambda (tests pin the objectives to 1e-10 of the serial run).

use super::path::{lambda_grid, run_grid_segment, scaled_eps, PathConfig, PathResult};
use super::{solve_fixed_lambda_with, SolveOptions};
use crate::obs;
use crate::problem::Problem;
use crate::screening::PrevSolution;
use crate::util::sync::lock_ok;
use crate::util::Stopwatch;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

/// Resolve a requested thread count: `0` means "use all available cores".
pub fn effective_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Split `0..len` into at most `parts` contiguous, near-equal ranges.
pub fn split_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, len.max(1));
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for c in 0..parts {
        let sz = base + usize::from(c < rem);
        if sz == 0 {
            continue;
        }
        out.push((lo, lo + sz));
        lo += sz;
    }
    out
}

/// Apply `f` to every item on a scoped worker pool and return the results
/// in input order. `f(i, item)` receives the item's index so callers can
/// label work without capturing it in the item type.
///
/// With `threads <= 1` (or fewer than two items) this runs inline on the
/// calling thread — no pool, no synchronization, the exact serial path.
/// Workers pull items through an atomic cursor, so an expensive item does
/// not stall the queue behind it. A panic in any worker propagates to the
/// caller once the scope joins.
pub fn parallel_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                // Ordering: Relaxed — fetch_add is already a single
                // atomic RMW, so every worker gets a unique index; the
                // claimed item itself is handed over by the slot Mutex,
                // which supplies the happens-before edge for its data.
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // The cursor hands out each index exactly once, so the
                // slot always holds the item; an empty slot (impossible
                // unless the claim protocol itself is broken) is skipped
                // rather than unwrapped — the length check below would
                // then surface the loss loudly in debug builds.
                let Some(item) = lock_ok(&slots[i]).take() else { continue };
                let r = f(i, item);
                *lock_ok(&out[i]) = Some(r);
            });
        }
    });
    // A worker panic propagates at the scope join above, so reaching this
    // point means every index was claimed and completed; poison recovery
    // (rather than unwrap) keeps the collection itself panic-free.
    let mut results = Vec::with_capacity(n);
    for m in out {
        if let Some(r) = m.into_inner().unwrap_or_else(PoisonError::into_inner) {
            results.push(r);
        }
    }
    debug_assert_eq!(results.len(), n, "parallel_map dropped an item");
    results
}

/// Run `threads` long-lived scoped workers and join them all: each worker
/// runs `worker(w)` (its own loop) to completion. This is the resident
/// counterpart of [`parallel_map`] — same scoped-thread machinery, but the
/// workers own their loop instead of pulling from a finite work list. The
/// serving front end ([`crate::serve`]) runs its bounded accept pool on
/// it; a panic in any worker propagates to the caller once the scope
/// joins.
pub fn run_workers<F>(threads: usize, worker: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        worker(0);
        return;
    }
    std::thread::scope(|s| {
        for w in 0..threads {
            let f = &worker;
            s.spawn(move || f(w));
        }
    });
}

/// Chunk boundaries over the lambda grid, weighted so later (smaller-
/// lambda) chunks hold fewer grid points: supports densify and epochs grow
/// as lambda decreases, so equal-length chunks would leave the first
/// workers idle. The weight of grid index `t` is `1 + t`, a cheap proxy
/// for per-lambda cost that balances well on the paper's workloads.
fn weighted_chunk_bounds(n_lambdas: usize, chunks: usize) -> Vec<(usize, usize)> {
    let chunks = chunks.clamp(1, n_lambdas.max(1));
    let total: u64 = (n_lambdas as u64) * (n_lambdas as u64 + 1) / 2;
    let mut bounds = Vec::with_capacity(chunks);
    let mut lo = 0usize;
    let mut acc = 0u64;
    let mut next_target = total / chunks as u64;
    let mut c = 1usize;
    for t in 0..n_lambdas {
        acc += 1 + t as u64;
        let remaining_chunks = chunks - bounds.len();
        let remaining_points = n_lambdas - t - 1;
        // close the chunk at the weight target, but never starve the
        // remaining chunks of at least one point each
        if (acc >= next_target && remaining_points + 1 >= remaining_chunks)
            || remaining_points + 1 == remaining_chunks
        {
            bounds.push((lo, t + 1));
            lo = t + 1;
            c += 1;
            next_target = total * c as u64 / chunks as u64;
            if bounds.len() == chunks - 1 {
                break;
            }
        }
    }
    if lo < n_lambdas {
        bounds.push((lo, n_lambdas));
    }
    bounds
}

/// How much the coarse pre-pass relaxes the duality-gap tolerance. The
/// pre-pass only has to produce usable warm starts (beta, theta) for chunk
/// heads; its Gap Safe certificate is valid at *any* gap value, so safety
/// never depends on this constant.
const COARSE_RELAX: f64 = 1e3;

/// Parallel Alg. 1: split the lambda grid into `threads` contiguous chunks
/// and solve them concurrently, preserving sequential warm starts within
/// each chunk.
///
/// Chunk heads cannot warm-start from their true predecessor (it lives in
/// another chunk that is still running), so a cheap serial pre-pass first
/// solves *only the chunk-head lambdas* at a relaxed tolerance
/// (`eps * 1e3`), chaining warm starts between heads. Each head then hands
/// its chunk a [`PrevSolution`] whose dual point and active set are valid
/// Gap Safe inputs — screening stays *safe* regardless of how loose the
/// pre-pass was (Thm. 2 holds for any primal/dual pair).
///
/// Callers should use [`super::path::solve_path`], which dispatches here
/// when `PathConfig::threads` resolves to more than one worker.
pub fn solve_path_parallel(prob: &Problem, cfg: &PathConfig, threads: usize) -> PathResult {
    debug_assert!(threads > 1);
    let sw_total = Stopwatch::start();
    let lam_max = prob.lambda_max();
    let lambdas = lambda_grid(lam_max, cfg.n_lambdas, cfg.delta);
    let eps = if cfg.eps_is_absolute { cfg.eps } else { scaled_eps(prob, cfg.eps) };
    let opts = SolveOptions {
        max_epochs: cfg.max_epochs,
        screen_every: cfg.screen_every,
        eps,
        max_kkt_rounds: 20,
        compact: cfg.compact,
        dual: cfg.dual,
    };
    let n_chunks = threads.min(lambdas.len());
    let bounds = weighted_chunk_bounds(lambdas.len(), n_chunks);
    let tracing = obs::enabled();
    if tracing {
        obs::emit(&obs::Event::PathStart {
            n_lambdas: lambdas.len(),
            lam_max,
            threads: n_chunks,
            kernel: crate::linalg::kernels::active_kind().label(),
        });
    }

    // Coarse pre-pass: seed every chunk head (chunk 0 starts cold at
    // lambda_max, exactly like the serial path).
    let mut seeds: Vec<Option<PrevSolution>> = vec![None; bounds.len()];
    {
        let sw_pre = tracing.then(Stopwatch::start);
        let coarse_opts = SolveOptions { eps: eps * COARSE_RELAX, ..opts.clone() };
        let mut rule = cfg.rule.build();
        let mut prev: Option<PrevSolution> = None;
        for (c, &(lo, _)) in bounds.iter().enumerate().skip(1) {
            let lam = lambdas[lo];
            let beta0 = prev.as_ref().map(|p| p.beta.clone());
            let res = solve_fixed_lambda_with(
                prob,
                lam,
                lam_max,
                beta0.as_ref(),
                None,
                rule.as_mut(),
                prev.as_ref(),
                &coarse_opts,
            );
            let sol = PrevSolution {
                lam,
                loss: prob.fit.loss(&res.z),
                pen_value: prob.pen.value(&res.beta),
                z: res.z,
                theta: res.theta,
                active: res.active,
                beta: res.beta,
            };
            seeds[c] = Some(sol.clone());
            prev = Some(sol);
        }
        if let Some(sw) = sw_pre {
            obs::emit(&obs::Event::Chunk {
                kind: "pre-pass",
                lo: 0,
                hi: lambdas.len(),
                secs: sw.secs(),
            });
        }
    }

    // Fan the chunks out; results come back in grid order.
    let jobs: Vec<usize> = (0..bounds.len()).collect();
    let segments = parallel_map(n_chunks, jobs, |_, c| {
        let (lo, hi) = bounds[c];
        let sw_chunk = tracing.then(Stopwatch::start);
        let mut rule = cfg.rule.build();
        let seg = run_grid_segment(
            prob,
            &lambdas[lo..hi],
            lam_max,
            cfg,
            &opts,
            rule.as_mut(),
            seeds[c].clone(),
        );
        if let Some(sw) = sw_chunk {
            obs::emit(&obs::Event::Chunk { kind: "chunk", lo, hi, secs: sw.secs() });
        }
        seg
    });

    let mut points = Vec::with_capacity(lambdas.len());
    let mut betas = Vec::with_capacity(lambdas.len());
    for (pts, bs, _) in segments {
        points.extend(pts);
        betas.extend(bs);
    }
    let total_seconds = sw_total.secs();
    if tracing {
        obs::emit(&obs::Event::PathEnd {
            n_lambdas: points.len(),
            total_epochs: points.iter().map(|p| p.epochs).sum(),
            secs: total_seconds,
        });
    }
    PathResult { lambdas, points, betas, total_seconds, lam_max }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_zero_is_auto() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn split_ranges_covers_and_partitions() {
        for (len, parts) in [(10, 3), (7, 7), (5, 9), (1, 2), (0, 4), (100, 4)] {
            let r = split_ranges(len, parts);
            let mut covered = 0;
            let mut prev_end = 0;
            for &(lo, hi) in &r {
                assert_eq!(lo, prev_end);
                assert!(hi > lo);
                covered += hi - lo;
                prev_end = hi;
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn weighted_bounds_partition_the_grid() {
        for (n, c) in [(100, 4), (12, 3), (5, 5), (6, 4), (3, 8), (1, 2)] {
            let b = weighted_chunk_bounds(n, c);
            assert!(!b.is_empty());
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, n);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            assert!(b.len() <= c.min(n));
            // later chunks should never be longer than the first
            if b.len() > 1 {
                let first = b[0].1 - b[0].0;
                let last = b.last().unwrap().1 - b.last().unwrap().0;
                assert!(last <= first, "last chunk longer than first: {b:?}");
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order_and_values() {
        let items: Vec<usize> = (0..97).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [1, 2, 4, 16] {
            let got = parallel_map(threads, items.clone(), |i, x| {
                assert_eq!(i, x);
                x * x + 1
            });
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn run_workers_runs_every_worker() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for threads in [1, 2, 5] {
            let ran = AtomicUsize::new(0);
            run_workers(threads, |_| {
                ran.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(ran.load(Ordering::Relaxed), threads);
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let empty: Vec<u8> = Vec::new();
        assert!(parallel_map(4, empty, |_, x: u8| x).is_empty());
        assert_eq!(parallel_map(4, vec![7u8], |_, x| x + 1), vec![8]);
    }

    #[test]
    #[should_panic]
    fn parallel_map_propagates_panics() {
        let _ = parallel_map(2, vec![1, 2, 3, 4], |_, x: i32| {
            if x == 3 {
                panic!("boom");
            }
            x
        });
    }
}
