//! Problem assembly: design matrix + data fit + penalty, and the native
//! implementation of the Gap Safe quantities (Sec. 2):
//!
//! * lambda_max (Prop. 3),
//! * the dual rescaling Theta(z) (Eq. 9 / 18) with the active-set trick of
//!   Sec. 2.2.2 (the dual norm is evaluated on the safe active set only,
//!   turning the O(np) stopping-criterion cost into O(n q_active)),
//! * the duality gap and the Gap Safe radius (Thm. 2),
//! * screening statistics for an arbitrary sphere center (used by the
//!   static / DST3 / Bonnefoy rules of Sec. 3.6).
//!
//! The PJRT runtime (`runtime::PjrtGap`) computes exactly the same
//! quantities by executing the AOT artifact lowered from
//! `python/compile/model.py`; integration tests pin the two paths together.

use crate::datafit::DataFit;
use crate::linalg::compact::CompactDesign;
use crate::linalg::sparse::Design;
use crate::linalg::Mat;
use crate::penalty::{dual_norm_active, ActiveSet, GroupNorms, Penalty, ScreenStats};
use crate::screening::dual::{DualPoint, DualStrategy};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Below this many multiply-adds a screening sweep is not worth fanning
/// out: the pool spawns fresh scoped threads per call (~100us of
/// spawn/join), so the sweep must carry roughly a millisecond of
/// arithmetic before workers pay for themselves.
const PAR_SCREEN_MIN_WORK: usize = 1 << 20;

/// One estimator instance: min F(beta) + lambda * Omega(beta)   (Eq. 1).
pub struct Problem {
    pub x: Design,
    pub fit: Box<dyn DataFit>,
    pub pen: Box<dyn Penalty>,
    /// ||X_j||_2^2 per feature.
    pub col_norms_sq: Vec<f64>,
    /// Operator norms for the sphere tests.
    pub norms: GroupNorms,
    /// Per-group Lipschitz constants for the block-CD steps:
    /// L_g = fit.lipschitz_scale() * ||X_g||_2^2 (spectral).
    pub lipschitz: Vec<f64>,
    /// Worker threads for the screening-sweep correlations (the O(np)
    /// stage of every gap / screening pass). Interior-mutable so `&Problem`
    /// callers can tune it; 1 (the default) keeps the sweep serial.
    screen_threads: AtomicUsize,
}

/// Everything one gap / screening pass produces (Alg. 2 lines 3-4).
#[derive(Debug, Clone)]
pub struct GapResult {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    /// Gap Safe radius r_lambda(beta, theta) of Thm. 2.
    pub radius: f64,
    /// The rescaled dual feasible point Theta(-G(X beta)/lambda), (n, q).
    pub theta: Mat,
    /// Screening statistics of theta (only active groups are valid).
    pub stats: ScreenStats,
}

impl Problem {
    pub fn new(x: Design, fit: Box<dyn DataFit>, pen: Box<dyn Penalty>) -> Self {
        assert_eq!(x.rows(), fit.n(), "X rows must match number of samples");
        assert_eq!(x.cols(), pen.groups().p(), "X cols must match penalty features");
        let col_norms_sq = x.col_norms_sq();
        let norms = pen.op_norms(&x);
        let scale = fit.lipschitz_scale();
        let groups = pen.groups();
        let lipschitz = (0..groups.len())
            .map(|g| {
                let feats = groups.feats(g);
                let s = if feats.len() == 1 {
                    col_norms_sq[feats[0]]
                } else {
                    let sp = norms.spectral[g];
                    sp * sp
                };
                (scale * s).max(1e-300)
            })
            .collect();
        Problem {
            x,
            fit,
            pen,
            col_norms_sq,
            norms,
            lipschitz,
            screen_threads: AtomicUsize::new(1),
        }
    }

    /// Set the worker count for the parallel screening sweep (0 = all
    /// available cores, 1 = serial). Safe to call on a shared `&Problem`.
    pub fn set_screen_threads(&self, threads: usize) {
        let t = crate::solver::parallel::effective_threads(threads);
        // Ordering: Relaxed — a standalone tuning knob with no attached
        // data; sweeps that race a concurrent set see either the old or
        // the new count, both of which are valid (and bitwise-identical
        // in output, since thread count never changes results).
        self.screen_threads.store(t.max(1), Ordering::Relaxed);
    }

    /// Current screening-sweep worker count.
    pub fn screen_threads(&self) -> usize {
        self.screen_threads.load(Ordering::Relaxed).max(1)
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    pub fn q(&self) -> usize {
        self.fit.q()
    }

    pub fn n_groups(&self) -> usize {
        self.pen.groups().len()
    }

    /// Z = X B, (n, q).
    pub fn predict(&self, beta: &Mat) -> Mat {
        let mut z = Mat::zeros(self.n(), self.q());
        for k in 0..self.q() {
            let bk: Vec<f64> = (0..self.p()).map(|j| beta[(j, k)]).collect();
            let mut zk = vec![0.0; self.n()];
            self.x.gemv(&bk, &mut zk);
            z.col_mut(k).copy_from_slice(&zk);
        }
        z
    }

    /// Correlations corr[j, :] = X_j^T V for active features only
    /// (inactive rows left stale — callers must respect `active`).
    ///
    /// Perf (§Perf log): for q > 1 the naive loop reads each column of X q
    /// times (one per task). We transpose V into a row-major scratch once
    /// and accumulate all q partial sums in a single pass over the column,
    /// cutting X traffic q-fold — the multi-task gap pass is memory-bound
    /// on the paper's MEG shape (q = 20).
    pub fn corr_active(&self, v: &Mat, active: &ActiveSet, out: &mut Mat) {
        debug_assert_eq!(out.rows(), self.p());
        debug_assert_eq!(out.cols(), v.cols());
        let threads = self.screen_threads();
        if threads > 1 {
            let work = active.n_active_feats() * self.n() * v.cols();
            if work >= PAR_SCREEN_MIN_WORK {
                self.corr_active_parallel(v, active, out, threads);
                return;
            }
        }
        self.corr_active_serial(v, active, out);
    }

    /// Row-major transpose of V (vrm[i*q + k] = V[(i, k)]) shared by the
    /// serial and parallel q > 1 sweeps.
    fn transpose_to_row_major(v: &Mat) -> Vec<f64> {
        let (n, q) = (v.rows(), v.cols());
        let mut vrm = vec![0.0; n * q];
        for k in 0..q {
            let col = v.col(k);
            for i in 0..n {
                vrm[i * q + k] = col[i];
            }
        }
        vrm
    }

    /// One feature's correlation block: acc[k] = X_j^T V[:, k], with V in
    /// the row-major scratch layout. The single shared inner kernel of the
    /// q > 1 sweep — serial, parallel and compacted paths all call it, so
    /// they cannot drift apart numerically.
    #[inline]
    fn accumulate_feature(&self, j: usize, vrm: &[f64], q: usize, acc: &mut [f64]) {
        accumulate_col(&self.x, j, vrm, q, acc);
    }

    fn corr_active_serial(&self, v: &Mat, active: &ActiveSet, out: &mut Mat) {
        let q = v.cols();
        if q == 1 {
            if active.n_active_feats() == self.p() {
                // Nothing to mask: hand the whole sweep to the dispatched
                // xtv kernel (register-tiled on AVX2). Bitwise identical
                // to the per-column col_dot loop below by the kernel
                // contract (see linalg::kernels).
                self.x.xtv(v.col(0), out.col_mut(0));
                return;
            }
            for j in 0..self.p() {
                if active.feat[j] {
                    out[(j, 0)] = self.x.col_dot(j, v.col(0));
                }
            }
            return;
        }
        let vrm = Self::transpose_to_row_major(v);
        let mut acc = vec![0.0; q];
        for j in 0..self.p() {
            if !active.feat[j] {
                continue;
            }
            self.accumulate_feature(j, &vrm, q, &mut acc);
            for k in 0..q {
                out[(j, k)] = acc[k];
            }
        }
    }

    /// Fan the correlation sweep out over feature ranges (§Perf: the O(np)
    /// correlations dominate every gap / screening pass; the per-group
    /// sphere tests downstream are O(p) and stay serial). Workers fill
    /// private buffers that are scattered back on the calling thread, so
    /// no unsafe aliasing is needed; for q = 1 each entry is the same
    /// `col_dot` the serial path computes, bit-for-bit.
    fn corr_active_parallel(&self, v: &Mat, active: &ActiveSet, out: &mut Mat, threads: usize) {
        use crate::solver::parallel::{parallel_map, split_ranges};
        let (p, q) = (self.p(), v.cols());
        // Row-major copy of V shared read-only by all workers (same memory
        // trick as the serial q > 1 path); skipped for q = 1.
        let vrm: Vec<f64> = if q > 1 { Self::transpose_to_row_major(v) } else { Vec::new() };
        let ranges = split_ranges(p, threads * 4);
        let chunks = parallel_map(threads, ranges, |_, (lo, hi)| {
            let mut buf = vec![0.0; (hi - lo) * q];
            if q == 1 {
                for j in lo..hi {
                    if active.feat[j] {
                        buf[j - lo] = self.x.col_dot(j, v.col(0));
                    }
                }
                return (lo, hi, buf);
            }
            let mut acc = vec![0.0; q];
            for j in lo..hi {
                if !active.feat[j] {
                    continue;
                }
                self.accumulate_feature(j, &vrm, q, &mut acc);
                buf[(j - lo) * q..(j - lo) * q + q].copy_from_slice(&acc);
            }
            (lo, hi, buf)
        });
        for (lo, hi, buf) in chunks {
            for j in lo..hi {
                if active.feat[j] {
                    for k in 0..q {
                        out[(j, k)] = buf[(j - lo) * q + k];
                    }
                }
            }
        }
    }

    /// Compaction-aware correlation sweep: with a packed view the sweep
    /// iterates the view's contiguous columns instead of bitmap-skipping
    /// through the full design; with `None` it is exactly [`Self::corr_active`].
    ///
    /// Safety contract: every feature active in `active` must be present
    /// in the view (the solver packs by live group and only shrinks the
    /// active set between repacks). Each per-column kernel runs on data
    /// copied verbatim at pack time, so the filled entries are bitwise
    /// identical to the full sweep.
    pub fn corr_active_with(
        &self,
        v: &Mat,
        active: &ActiveSet,
        out: &mut Mat,
        view: Option<&CompactDesign>,
    ) {
        let Some(cd) = view else {
            self.corr_active(v, active, out);
            return;
        };
        debug_assert!(
            (0..self.p()).all(|j| !active.feat[j] || cd.compact_of(j).is_some()),
            "compact view is missing an active feature"
        );
        let threads = self.screen_threads();
        if threads > 1 {
            let work = active.n_active_feats() * self.n() * v.cols();
            if work >= PAR_SCREEN_MIN_WORK {
                self.corr_compact_parallel(v, active, out, cd, threads);
                return;
            }
        }
        self.corr_compact_serial(v, active, out, cd);
    }

    fn corr_compact_serial(
        &self,
        v: &Mat,
        active: &ActiveSet,
        out: &mut Mat,
        cd: &CompactDesign,
    ) {
        let q = v.cols();
        if q == 1 {
            if (0..cd.width()).all(|c| active.feat[cd.feat_of(c)]) {
                // Every packed column is live (always true right after a
                // repack): run the dispatched xtv kernel over the small
                // contiguous working matrix, then scatter by the index
                // map. Bitwise identical to the per-column loop below.
                let mut buf = vec![0.0; cd.width()];
                cd.design().xtv(v.col(0), &mut buf);
                for (c, s) in buf.into_iter().enumerate() {
                    out[(cd.feat_of(c), 0)] = s;
                }
                return;
            }
            for c in 0..cd.width() {
                let j = cd.feat_of(c);
                if active.feat[j] {
                    out[(j, 0)] = cd.design().col_dot(c, v.col(0));
                }
            }
            return;
        }
        let vrm = Self::transpose_to_row_major(v);
        let mut acc = vec![0.0; q];
        for c in 0..cd.width() {
            let j = cd.feat_of(c);
            if !active.feat[j] {
                continue;
            }
            accumulate_col(cd.design(), c, &vrm, q, &mut acc);
            for k in 0..q {
                out[(j, k)] = acc[k];
            }
        }
    }

    /// Parallel counterpart of [`Self::corr_compact_serial`]: ranges are
    /// split over the *packed* columns, so the per-worker stride is over
    /// the small contiguous working matrix.
    fn corr_compact_parallel(
        &self,
        v: &Mat,
        active: &ActiveSet,
        out: &mut Mat,
        cd: &CompactDesign,
        threads: usize,
    ) {
        use crate::solver::parallel::{parallel_map, split_ranges};
        let q = v.cols();
        let vrm: Vec<f64> = if q > 1 { Self::transpose_to_row_major(v) } else { Vec::new() };
        let ranges = split_ranges(cd.width(), threads * 4);
        let chunks = parallel_map(threads, ranges, |_, (lo, hi)| {
            let mut buf = vec![0.0; (hi - lo) * q];
            if q == 1 {
                for c in lo..hi {
                    let j = cd.feat_of(c);
                    if active.feat[j] {
                        buf[c - lo] = cd.design().col_dot(c, v.col(0));
                    }
                }
                return (lo, hi, buf);
            }
            let mut acc = vec![0.0; q];
            for c in lo..hi {
                let j = cd.feat_of(c);
                if !active.feat[j] {
                    continue;
                }
                accumulate_col(cd.design(), c, &vrm, q, &mut acc);
                buf[(c - lo) * q..(c - lo) * q + q].copy_from_slice(&acc);
            }
            (lo, hi, buf)
        });
        for (lo, hi, buf) in chunks {
            for c in lo..hi {
                let j = cd.feat_of(c);
                if active.feat[j] {
                    for k in 0..q {
                        out[(j, k)] = buf[(c - lo) * q + k];
                    }
                }
            }
        }
    }

    /// lambda_max = Omega^D(X^T G(0)) (Prop. 3): the smallest lambda for
    /// which 0 is optimal.
    pub fn lambda_max(&self) -> f64 {
        let z0 = Mat::zeros(self.n(), self.q());
        let mut rho = Mat::zeros(self.n(), self.q());
        self.fit.neg_grad(&z0, &mut rho);
        let active = ActiveSet::full(self.pen.groups());
        let mut corr = Mat::zeros(self.p(), self.q());
        self.corr_active(&rho, &active, &mut corr);
        let mut buf = Vec::new();
        dual_norm_active(self.pen.as_ref(), &corr, &active, &mut buf)
    }

    /// P_lambda(beta) given the cached prediction Z = X beta.
    pub fn primal(&self, beta: &Mat, z: &Mat, lam: f64) -> f64 {
        self.fit.loss(z) + lam * self.pen.value(beta)
    }

    /// One full gap / screening pass (Alg. 2): rescaled dual point, primal,
    /// dual, gap, Gap Safe radius, and screening statistics of theta.
    ///
    /// Cost: O(n * q_active) thanks to the active-set trick.
    pub fn gap_pass(&self, beta: &Mat, z: &Mat, lam: f64, active: &ActiveSet) -> GapResult {
        self.gap_pass_with(beta, z, lam, active, None)
    }

    /// [`Self::gap_pass`] with an optional compact working view: the O(np)
    /// correlation stage then sweeps the packed columns only (bitwise
    /// identical entries — see [`crate::linalg::compact`]). Reports the
    /// freshly rescaled dual point (strategy `rescale`); solvers that keep
    /// a [`DualPoint`] tracker call [`Self::gap_pass_dual`] instead.
    pub fn gap_pass_with(
        &self,
        beta: &Mat,
        z: &Mat,
        lam: f64,
        active: &ActiveSet,
        view: Option<&CompactDesign>,
    ) -> GapResult {
        let mut dual_pt = DualPoint::new(DualStrategy::Rescale);
        self.gap_pass_dual(beta, z, lam, active, view, &mut dual_pt)
    }

    /// [`Self::gap_pass_with`] consulting a [`DualPoint`] tracker: the
    /// freshly rescaled candidate (Eq. 18) is offered to the tracker,
    /// which may substitute (or mix in) the best dual point it has seen
    /// at this lambda — see [`crate::screening::dual`] for the strategy
    /// semantics and the safety argument. With a
    /// [`DualStrategy::Rescale`] tracker this is statement-for-statement
    /// the historical gap pass, so its output is bitwise identical.
    pub fn gap_pass_dual(
        &self,
        beta: &Mat,
        z: &Mat,
        lam: f64,
        active: &ActiveSet,
        view: Option<&CompactDesign>,
        dual_pt: &mut DualPoint,
    ) -> GapResult {
        let (n, q) = (self.n(), self.q());
        let mut rho = Mat::zeros(n, q);
        self.fit.neg_grad(z, &mut rho);
        let mut corr = Mat::zeros(self.p(), q);
        self.corr_active_with(&rho, active, &mut corr, view);
        let mut buf = Vec::new();
        let dnorm = dual_norm_active(self.pen.as_ref(), &corr, active, &mut buf);
        let alpha = lam.max(dnorm);
        // theta = rho / alpha  (Eq. 18; no-op rescale when already feasible)
        let mut theta = rho;
        theta.as_mut_slice().iter_mut().for_each(|v| *v /= alpha);
        // stats are functions of X^T theta = corr / alpha
        let mut corr_theta = corr;
        corr_theta.as_mut_slice().iter_mut().for_each(|v| *v /= alpha);
        let dual_new = self.fit.dual(&theta, lam);
        // The tracker picks the reported point (kept, fresh, or a convex
        // combination) and hands back its correlations alongside, so the
        // sphere statistics below never pay a second O(np) sweep.
        let (theta, corr_theta, dual) = dual_pt.select(self, lam, theta, corr_theta, dual_new);
        let stats = self.pen.stats(&corr_theta, active);
        let primal = self.primal(beta, z, lam);
        let gap = (primal - dual).max(0.0);
        // Radius through the datafit's curvature hook: the default is the
        // verbatim global-gamma formula (bitwise identical for the
        // Table-1 fits); locally-bounded duals (Poisson) use a per-center
        // bound instead.
        let radius = self.fit.gap_safe_radius(gap, lam, &theta);
        GapResult { primal, dual, gap, radius, theta, stats }
    }

    /// Screening statistics of an arbitrary dual-feasible center theta_c
    /// (static rule Eq. 12, Bonnefoy center y/lambda, DST3 projections).
    pub fn stats_for_center(&self, theta_c: &Mat, active: &ActiveSet) -> ScreenStats {
        self.stats_for_center_with(theta_c, active, None)
    }

    /// [`Self::stats_for_center`] over an optional compact working view.
    /// The caller's active set must be a subset of the view's — the KKT
    /// repair pass, which statistics *all* groups, must pass `None`, and
    /// the stock screening rules compute their center statistics over full
    /// active sets in `begin_lambda` (before any view exists), so today
    /// only the solver's gap passes and direct callers of this method run
    /// compacted; the hook is here for rules that statistic mid-lambda
    /// centers.
    pub fn stats_for_center_with(
        &self,
        theta_c: &Mat,
        active: &ActiveSet,
        view: Option<&CompactDesign>,
    ) -> ScreenStats {
        let mut corr = Mat::zeros(self.p(), theta_c.cols());
        self.corr_active_with(theta_c, active, &mut corr, view);
        self.pen.stats(&corr, active)
    }

    /// Rescale an arbitrary point z into the dual feasible set (Eq. 9).
    /// Returns (theta, alpha).
    pub fn rescale_dual(&self, z: &Mat, active: &ActiveSet, lam: f64) -> (Mat, f64) {
        let mut corr = Mat::zeros(self.p(), z.cols());
        self.corr_active(z, active, &mut corr);
        let mut buf = Vec::new();
        let dn = dual_norm_active(self.pen.as_ref(), &corr, active, &mut buf);
        // Theta(z): divide by Omega^D(X^T z) when > 1 — expressed here in the
        // lambda-scaled form used by Eq. (18): z is already rho / lambda.
        let scale = if dn > 1.0 { dn } else { 1.0 };
        let mut th = z.clone();
        th.as_mut_slice().iter_mut().for_each(|v| *v /= scale);
        let _ = lam;
        (th, scale)
    }
}

/// acc[k] = X_col^T V[:, k] with V in the row-major scratch layout — the
/// shared inner kernel of every q > 1 correlation sweep (full, parallel
/// and compacted), so no two paths can drift apart numerically.
#[inline]
fn accumulate_col(x: &Design, col: usize, vrm: &[f64], q: usize, acc: &mut [f64]) {
    acc.iter_mut().for_each(|a| *a = 0.0);
    match x {
        Design::Dense(m) => {
            let c = m.col(col);
            for (i, &xij) in c.iter().enumerate() {
                let row = &vrm[i * q..i * q + q];
                for k in 0..q {
                    acc[k] += xij * row[k];
                }
            }
        }
        Design::Sparse(s) => {
            let (idx, val) = s.col(col);
            for (&i, &xij) in idx.iter().zip(val) {
                let row = &vrm[i * q..i * q + q];
                for k in 0..q {
                    acc[k] += xij * row[k];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::{Logistic, Quadratic};
    use crate::penalty::{GroupL2, Groups, L1, SparseGroup};
    use crate::util::prng::Prng;

    fn rand_dense(rng: &mut Prng, n: usize, p: usize) -> Design {
        let mut m = Mat::zeros(n, p);
        for v in m.as_mut_slice() {
            *v = rng.gaussian();
        }
        Design::Dense(m)
    }

    fn lasso_problem(seed: u64, n: usize, p: usize) -> (Problem, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let x = rand_dense(&mut rng, n, p);
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let prob = Problem::new(
            x,
            Box::new(Quadratic::from_vec(&y)),
            Box::new(L1::new(p)),
        );
        (prob, y)
    }

    #[test]
    fn lambda_max_lasso_is_xty_inf() {
        let (prob, y) = lasso_problem(1, 10, 20);
        let mut want: f64 = 0.0;
        for j in 0..20 {
            want = want.max(prob.x.col_dot(j, &y).abs());
        }
        assert!((prob.lambda_max() - want).abs() < 1e-12);
    }

    #[test]
    fn zero_is_optimal_at_lambda_max() {
        let (prob, _) = lasso_problem(2, 12, 25);
        let lmax = prob.lambda_max();
        let beta = Mat::zeros(25, 1);
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lmax, &active);
        // theta = rho/lmax is exactly optimal: gap vanishes.
        assert!(res.gap < 1e-10, "gap={}", res.gap);
        assert!(res.radius < 1e-4);
    }

    #[test]
    fn gap_pass_weak_duality_and_feasibility() {
        let (prob, _) = lasso_problem(3, 15, 30);
        let mut rng = Prng::new(33);
        let lam = 0.5 * prob.lambda_max();
        let mut beta = Mat::zeros(30, 1);
        for j in 0..30 {
            if rng.bernoulli(0.2) {
                beta[(j, 0)] = rng.gaussian();
            }
        }
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        assert!(res.dual <= res.primal + 1e-10);
        assert!(res.gap >= 0.0);
        // theta feasible: max_j |X_j^T theta| <= 1
        let mut m: f64 = 0.0;
        for j in 0..30 {
            m = m.max(prob.x.col_dot(j, res.theta.col(0)).abs());
        }
        assert!(m <= 1.0 + 1e-10, "infeasible theta: {m}");
        // radius formula gamma = 1
        assert!((res.radius - (2.0 * res.gap).sqrt() / lam).abs() < 1e-12);
    }

    #[test]
    fn gap_pass_logistic_gamma4() {
        let mut rng = Prng::new(4);
        let x = rand_dense(&mut rng, 14, 22);
        let y: Vec<f64> = (0..14).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let prob = Problem::new(x, Box::new(Logistic::new(&y)), Box::new(L1::new(22)));
        let lam = 0.4 * prob.lambda_max();
        let beta = Mat::zeros(22, 1);
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        assert!(res.dual <= res.primal + 1e-10);
        assert!((res.radius - (2.0 * res.gap / 4.0).sqrt() / lam).abs() < 1e-12);
    }

    #[test]
    fn active_set_trick_matches_full_dual_norm() {
        // After one safe screen, the restricted dual norm must equal the full one.
        let (prob, _) = lasso_problem(5, 12, 40);
        let lam = 0.6 * prob.lambda_max();
        let beta = Mat::zeros(40, 1);
        let z = prob.predict(&beta);
        let mut active = ActiveSet::full(prob.pen.groups());
        let res = prob.gap_pass(&beta, &z, lam, &active);
        let (kg, _) =
            prob.pen.sphere_screen(&res.stats, res.radius, &prob.norms, &mut active, None);
        // Need at least one screen for the test to be meaningful.
        assert!(kg > 0, "no screening happened; pick another seed");
        let res2 = prob.gap_pass(&beta, &z, lam, &active);
        let full = ActiveSet::full(prob.pen.groups());
        let res_full = prob.gap_pass(&beta, &z, lam, &full);
        assert!((res2.dual - res_full.dual).abs() < 1e-12);
        assert!((res2.gap - res_full.gap).abs() < 1e-12);
    }

    #[test]
    fn group_lasso_lambda_max() {
        let mut rng = Prng::new(6);
        let x = rand_dense(&mut rng, 10, 12);
        let y: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let groups = Groups::contiguous(12, 3);
        let prob = Problem::new(
            x,
            Box::new(Quadratic::from_vec(&y)),
            Box::new(GroupL2::new(groups)),
        );
        let mut want: f64 = 0.0;
        for g in 0..4 {
            let mut nsq = 0.0;
            for j in 3 * g..3 * g + 3 {
                let d = prob.x.col_dot(j, &y);
                nsq += d * d;
            }
            want = want.max(nsq.sqrt());
        }
        assert!((prob.lambda_max() - want).abs() < 1e-12);
    }

    #[test]
    fn multitask_gap_consistency_with_lasso_q1() {
        let mut rng = Prng::new(7);
        let x = rand_dense(&mut rng, 9, 14);
        let y: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let p_lasso = Problem::new(
            x.clone(),
            Box::new(Quadratic::from_vec(&y)),
            Box::new(L1::new(14)),
        );
        let p_mt = Problem::new(
            x,
            Box::new(Quadratic::new(Mat::col_vec(&y))),
            Box::new(GroupL2::new(Groups::singletons(14))),
        );
        // Same lambda_max (|x| = ||x||_2 for scalars), same gap at beta=0.
        assert!((p_lasso.lambda_max() - p_mt.lambda_max()).abs() < 1e-12);
        let lam = 0.5 * p_lasso.lambda_max();
        let b = Mat::zeros(14, 1);
        let z = p_lasso.predict(&b);
        let a1 = ActiveSet::full(p_lasso.pen.groups());
        let a2 = ActiveSet::full(p_mt.pen.groups());
        let r1 = p_lasso.gap_pass(&b, &z, lam, &a1);
        let r2 = p_mt.gap_pass(&b, &z, lam, &a2);
        assert!((r1.gap - r2.gap).abs() < 1e-10);
    }

    #[test]
    fn parallel_screen_sweep_matches_serial_bitwise() {
        // q = 1: the fanned-out sweep computes the very same col_dot per
        // feature, so the correlations must agree to the bit. The private
        // kernels are exercised directly so the test stays fast while the
        // dispatch threshold targets millisecond-scale sweeps.
        let (prob, y) = lasso_problem(9, 40, 2000);
        let v = Mat::col_vec(&y);
        let mut active = ActiveSet::full(prob.pen.groups());
        active.kill_group(prob.pen.groups(), 7); // stale-row contract too
        let mut serial = Mat::zeros(2000, 1);
        let mut par = Mat::zeros(2000, 1);
        prob.corr_active_serial(&v, &active, &mut serial);
        prob.corr_active_parallel(&v, &active, &mut par, 4);
        for j in 0..2000 {
            if active.feat[j] {
                assert_eq!(
                    serial[(j, 0)].to_bits(),
                    par[(j, 0)].to_bits(),
                    "sweep diverged at feature {j}"
                );
            }
        }
        // the dispatch knob round-trips
        prob.set_screen_threads(4);
        assert_eq!(prob.screen_threads(), 4);
        prob.set_screen_threads(1);
        assert_eq!(prob.screen_threads(), 1);
    }

    #[test]
    fn parallel_screen_sweep_matches_serial_multitask() {
        // q > 1: serial and parallel share accumulate_feature, so they are
        // bitwise identical here as well.
        let mut rng = Prng::new(17);
        let x = rand_dense(&mut rng, 30, 800);
        let mut y = Mat::zeros(30, 4);
        for v in y.as_mut_slice() {
            *v = rng.gaussian();
        }
        let prob = Problem::new(
            x,
            Box::new(Quadratic::new(y.clone())),
            Box::new(GroupL2::new(Groups::singletons(800))),
        );
        let active = ActiveSet::full(prob.pen.groups());
        let mut serial = Mat::zeros(800, 4);
        let mut par = Mat::zeros(800, 4);
        prob.corr_active_serial(&y, &active, &mut serial);
        prob.corr_active_parallel(&y, &active, &mut par, 3);
        for j in 0..800 {
            for k in 0..4 {
                assert_eq!(serial[(j, k)].to_bits(), par[(j, k)].to_bits(), "({j},{k})");
            }
        }
    }

    #[test]
    fn compact_sweep_matches_full_bitwise() {
        use crate::linalg::compact::CompactDesign;
        // q = 1, serial and parallel: packing must not change a single bit
        // of the correlations.
        let (prob, y) = lasso_problem(12, 30, 400);
        let v = Mat::col_vec(&y);
        let mut active = ActiveSet::full(prob.pen.groups());
        for g in (0..400).step_by(3) {
            active.kill_group(prob.pen.groups(), g);
        }
        let cd = CompactDesign::pack(&prob.x, &active.feat);
        let mut full = Mat::zeros(400, 1);
        let mut compact = Mat::zeros(400, 1);
        prob.corr_active_with(&v, &active, &mut full, None);
        prob.corr_active_with(&v, &active, &mut compact, Some(&cd));
        for j in 0..400 {
            if active.feat[j] {
                assert_eq!(
                    full[(j, 0)].to_bits(),
                    compact[(j, 0)].to_bits(),
                    "compact sweep diverged at feature {j}"
                );
            }
        }
        let mut par = Mat::zeros(400, 1);
        prob.corr_compact_parallel(&v, &active, &mut par, &cd, 4);
        for j in 0..400 {
            if active.feat[j] {
                assert_eq!(full[(j, 0)].to_bits(), par[(j, 0)].to_bits(), "parallel {j}");
            }
        }
        // screening statistics through the view match the full sweep
        let sf = prob.stats_for_center_with(&v, &active, None);
        let sc = prob.stats_for_center_with(&v, &active, Some(&cd));
        for g in 0..prob.n_groups() {
            if active.group[g] {
                assert_eq!(sf.group_dual[g].to_bits(), sc.group_dual[g].to_bits(), "stats {g}");
            }
        }
        // q > 1 through the shared accumulate_col kernel.
        let mut rng = Prng::new(31);
        let x = rand_dense(&mut rng, 20, 120);
        let mut ym = Mat::zeros(20, 3);
        for v in ym.as_mut_slice() {
            *v = rng.gaussian();
        }
        let probm = Problem::new(
            x,
            Box::new(Quadratic::new(ym.clone())),
            Box::new(GroupL2::new(Groups::singletons(120))),
        );
        let mut am = ActiveSet::full(probm.pen.groups());
        for g in (0..120).step_by(4) {
            am.kill_group(probm.pen.groups(), g);
        }
        let cdm = CompactDesign::pack(&probm.x, &am.feat);
        let mut fm = Mat::zeros(120, 3);
        let mut cm = Mat::zeros(120, 3);
        probm.corr_active_with(&ym, &am, &mut fm, None);
        probm.corr_active_with(&ym, &am, &mut cm, Some(&cdm));
        for j in 0..120 {
            if am.feat[j] {
                for k in 0..3 {
                    assert_eq!(fm[(j, k)].to_bits(), cm[(j, k)].to_bits(), "({j},{k})");
                }
            }
        }
    }

    #[test]
    fn gap_pass_dual_rescale_is_bitwise_identical() {
        // A Rescale tracker must reproduce gap_pass_with exactly — every
        // float to the bit — across several iterates of the same solve.
        let (prob, _) = lasso_problem(21, 18, 40);
        let lam = 0.4 * prob.lambda_max();
        let active = ActiveSet::full(prob.pen.groups());
        let mut rng = Prng::new(77);
        let mut tracker = DualPoint::new(DualStrategy::Rescale);
        for _ in 0..4 {
            let mut beta = Mat::zeros(40, 1);
            for j in 0..40 {
                if rng.bernoulli(0.2) {
                    beta[(j, 0)] = rng.gaussian();
                }
            }
            let z = prob.predict(&beta);
            let a = prob.gap_pass_with(&beta, &z, lam, &active, None);
            let b = prob.gap_pass_dual(&beta, &z, lam, &active, None, &mut tracker);
            assert_eq!(a.primal.to_bits(), b.primal.to_bits());
            assert_eq!(a.dual.to_bits(), b.dual.to_bits());
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            assert_eq!(a.radius.to_bits(), b.radius.to_bits());
            for (x, y) in a.theta.as_slice().iter().zip(b.theta.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for g in 0..prob.n_groups() {
                assert_eq!(a.stats.group_dual[g].to_bits(), b.stats.group_dual[g].to_bits());
            }
        }
    }

    #[test]
    fn gap_pass_dual_best_kept_dual_is_monotone() {
        // Feed the tracker a good iterate, then a deliberately worse one:
        // the reported dual must not drop, the reported gap must shrink
        // (better beta) or use the kept dual point, and the kept stats /
        // radius must stay a consistent (center, radius) pair.
        let (prob, _) = lasso_problem(22, 16, 30);
        let lam = 0.5 * prob.lambda_max();
        let active = ActiveSet::full(prob.pen.groups());
        let mut tracker = DualPoint::new(DualStrategy::BestKept);
        // Iterate 1: beta = 0 (decent dual point at moderate lambda).
        let b0 = Mat::zeros(30, 1);
        let z0 = prob.predict(&b0);
        let r0 = prob.gap_pass_dual(&b0, &z0, lam, &active, None, &mut tracker);
        // Iterate 2: a large random beta — its rescaled dual point is much
        // worse, so the tracker must report the kept one.
        let mut rng = Prng::new(5);
        let mut b1 = Mat::zeros(30, 1);
        for j in 0..30 {
            b1[(j, 0)] = 3.0 * rng.gaussian();
        }
        let z1 = prob.predict(&b1);
        let r1 = prob.gap_pass_dual(&b1, &z1, lam, &active, None, &mut tracker);
        assert!(r1.dual >= r0.dual, "best-kept dual decreased: {} < {}", r1.dual, r0.dual);
        // compare against what plain rescaling would have reported for
        // the same iterate: best-kept dominates it by construction
        let fresh = prob.gap_pass_with(&b1, &z1, lam, &active, None);
        assert!(fresh.dual <= r1.dual);
        assert!(fresh.gap >= r1.gap, "best-kept widened the gap");
        if fresh.dual < r0.dual {
            // the fresh candidate lost: the kept point is returned verbatim
            for (x, y) in r0.theta.as_slice().iter().zip(r1.theta.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "kept theta was not returned");
            }
            assert_eq!(r0.dual.to_bits(), r1.dual.to_bits());
        }
        // the reported (gap, radius) pair stays consistent (Thm. 2 input)
        let want_r = (2.0 * r1.gap / prob.fit.gamma().unwrap()).sqrt() / lam;
        assert!((r1.radius - want_r).abs() < 1e-12);
    }

    #[test]
    fn sgl_lambda_max_between_lasso_and_group() {
        let mut rng = Prng::new(8);
        let x = rand_dense(&mut rng, 10, 12);
        let y: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let mk = |tau: f64| {
            Problem::new(
                x.clone(),
                Box::new(Quadratic::from_vec(&y)),
                Box::new(SparseGroup::with_unit_weights(Groups::contiguous(12, 3), tau)),
            )
        };
        let l_sgl = mk(0.5).lambda_max();
        let l_lasso = mk(1.0).lambda_max();
        let l_group = mk(0.0).lambda_max();
        // the epsilon-norm interpolates, so lambda_max is sandwiched
        let lo = l_lasso.min(l_group) * 0.5;
        let hi = l_lasso.max(l_group) * 2.0;
        assert!(l_sgl > lo && l_sgl < hi, "{l_sgl} vs [{lo}, {hi}]");
    }
}
