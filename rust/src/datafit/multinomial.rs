//! Multinomial logistic fit (Sec. 4.6, Table 1 col. 4):
//!   f_i(z) = log(sum_k e^{z_k}) - <Y_i, z>,  Y one-hot rows,
//!   f_i^*(u) = NH(u + Y_i)  (negative entropy on the simplex),  gamma = 1.

use super::{DataFit, FitKind};
use crate::linalg::Mat;

/// l1/l2 multinomial regression data fit with one-hot targets Y (n, q).
#[derive(Debug, Clone)]
pub struct Multinomial {
    y: Mat,
}

impl Multinomial {
    /// `labels[i] in [q]`; builds the one-hot matrix.
    pub fn from_labels(labels: &[usize], q: usize) -> Self {
        let n = labels.len();
        let mut y = Mat::zeros(n, q);
        for (i, &l) in labels.iter().enumerate() {
            assert!(l < q, "label out of range");
            y[(i, l)] = 1.0;
        }
        Multinomial { y }
    }

    /// From an explicit one-hot (or soft) target matrix with rows on the simplex.
    pub fn new(y: Mat) -> Self {
        for i in 0..y.rows() {
            let s: f64 = (0..y.cols()).map(|k| y[(i, k)]).sum();
            assert!((s - 1.0).abs() < 1e-9, "target rows must sum to 1");
        }
        Multinomial { y }
    }
}

/// Row-wise log-sum-exp (stable).
fn lse_row(z: &Mat, i: usize) -> f64 {
    let q = z.cols();
    let mut m = f64::NEG_INFINITY;
    for k in 0..q {
        m = m.max(z[(i, k)]);
    }
    let mut s = 0.0;
    for k in 0..q {
        s += (z[(i, k)] - m).exp();
    }
    m + s.ln()
}

impl DataFit for Multinomial {
    fn kind(&self) -> FitKind {
        FitKind::Multinomial
    }

    fn n(&self) -> usize {
        self.y.rows()
    }

    fn q(&self) -> usize {
        self.y.cols()
    }

    fn gamma(&self) -> Option<f64> {
        Some(1.0) // Table 1 (the softmax gradient is 1-Lipschitz w.r.t. ||.||_2)
    }

    fn loss(&self, z: &Mat) -> f64 {
        let (n, q) = (z.rows(), z.cols());
        let mut s = 0.0;
        for i in 0..n {
            let lse = lse_row(z, i);
            let mut dot = 0.0;
            for k in 0..q {
                dot += self.y[(i, k)] * z[(i, k)];
            }
            s += lse - dot;
        }
        s
    }

    fn neg_grad(&self, z: &Mat, out: &mut Mat) {
        // -G = Y - RowNorm(exp(Z))
        let (n, q) = (z.rows(), z.cols());
        for i in 0..n {
            let lse = lse_row(z, i);
            for k in 0..q {
                out[(i, k)] = self.y[(i, k)] - (z[(i, k)] - lse).exp();
            }
        }
    }

    fn dual(&self, theta: &Mat, lam: f64) -> f64 {
        // D = -sum_i NH(Y_i - lam Theta_i); arguments lie on the simplex by
        // the rescaling argument of Remark 14 — clamp rounding excursions.
        let (n, q) = (theta.rows(), theta.cols());
        let mut s = 0.0;
        for i in 0..n {
            for k in 0..q {
                let u = (self.y[(i, k)] - lam * theta[(i, k)]).clamp(0.0, 1.0);
                if u > 0.0 {
                    s += u * u.ln();
                }
            }
        }
        -s
    }

    fn lipschitz_scale(&self) -> f64 {
        0.5 // Hessian of lse is diag(pi) - pi pi^T <= (1/2) I
    }

    fn targets(&self) -> &Mat {
        &self.y
    }

    fn refresh_link_rows(&self, z: &Mat, rows: &[usize], link: &mut Mat) {
        // Row-local softmax: identical per-element arithmetic to the full
        // neg_grad + subtract pass, so the restricted refresh is bitwise
        // identical to it.
        let q = z.cols();
        for &i in rows {
            let lse = lse_row(z, i);
            for k in 0..q {
                let g = self.y[(i, k)] - (z[(i, k)] - lse).exp();
                link[(i, k)] = self.y[(i, k)] - g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_at_zero() {
        let fit = Multinomial::from_labels(&[0, 2, 1], 3);
        let z = Mat::zeros(3, 3);
        assert!((fit.loss(&z) - 3.0 * (3.0_f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn neg_grad_rows_sum_to_zero() {
        let fit = Multinomial::from_labels(&[1, 0], 3);
        let mut z = Mat::zeros(2, 3);
        z[(0, 0)] = 1.0;
        z[(1, 2)] = -0.5;
        let mut g = Mat::zeros(2, 3);
        fit.neg_grad(&z, &mut g);
        for i in 0..2 {
            let s: f64 = (0..3).map(|k| g[(i, k)]).sum();
            assert!(s.abs() < 1e-12, "row {i} sums to {s}");
        }
    }

    #[test]
    fn binary_case_matches_logistic() {
        use crate::datafit::{sigmoid, softplus, DataFit, Logistic};
        // q=2 multinomial with z = [0, t] equals binary logistic at t.
        let labels = [1usize, 0];
        let fit = Multinomial::from_labels(&labels, 2);
        let ylog = [1.0, 0.0];
        let lfit = Logistic::new(&ylog);
        let t = [0.7, -1.2];
        let mut z2 = Mat::zeros(2, 2);
        let mut z1 = Mat::zeros(2, 1);
        for i in 0..2 {
            z2[(i, 1)] = t[i];
            z1[(i, 0)] = t[i];
        }
        assert!((fit.loss(&z2) - lfit.loss(&z1)).abs() < 1e-12);
        let mut g2 = Mat::zeros(2, 2);
        fit.neg_grad(&z2, &mut g2);
        for i in 0..2 {
            let want = ylog[i] - sigmoid(t[i]);
            assert!((g2[(i, 1)] - want).abs() < 1e-12);
        }
        let _ = softplus(0.0);
    }

    #[test]
    fn dual_at_feasible_points() {
        let fit = Multinomial::from_labels(&[0, 1], 2);
        // theta = 0 -> D = -sum NH(Y_i) = 0 (one-hot rows have zero entropy).
        let th = Mat::zeros(2, 2);
        assert_eq!(fit.dual(&th, 0.5), 0.0);
    }

    #[test]
    fn refresh_link_rows_bitwise_matches_full_pass() {
        use crate::util::prng::Prng;
        let mut rng = Prng::new(11);
        let labels: Vec<usize> = (0..6).map(|i| i % 3).collect();
        let fit = Multinomial::from_labels(&labels, 3);
        let mut z = Mat::zeros(6, 3);
        for v in z.as_mut_slice() {
            *v = rng.gaussian();
        }
        let mut full = Mat::zeros(6, 3);
        fit.neg_grad(&z, &mut full);
        for (l, yi) in full.as_mut_slice().iter_mut().zip(fit.targets().as_slice()) {
            *l = yi - *l;
        }
        let mut part = full.clone();
        let rows = [4usize, 1, 2];
        for &i in &rows {
            for k in 0..3 {
                part[(i, k)] = f64::NAN;
            }
        }
        fit.refresh_link_rows(&z, &rows, &mut part);
        for i in 0..6 {
            for k in 0..3 {
                assert_eq!(
                    full[(i, k)].to_bits(),
                    part[(i, k)].to_bits(),
                    "({i},{k}) diverged"
                );
            }
        }
    }
}
