//! Smooth data-fitting terms F(beta) = sum_i f_i(x_i^T beta) (Table 1).
//!
//! Each fit provides the five ingredients of the Gap Safe framework:
//! the loss, the generalized residual rho = -G(X beta) (Remark 2), the dual
//! objective D_lambda(theta) = -sum_i f_i^*(-lambda theta_i), the strong
//! smoothness constant gamma (f_i has 1/gamma-Lipschitz gradient, Thm. 2),
//! and the per-coordinate Lipschitz scale used by the CD solver
//! (L_j = lipschitz_scale() * ||X_j||_2^2).
//!
//! All fits operate on matrices: Z = X B is (n, q) with q = 1 for scalar
//! tasks. Multi-task / multinomial problems use q > 1 without any special
//! casing downstream (Sec. 4.5-4.6 reformulations).

mod logistic;
mod multinomial;
mod poisson;
mod quadratic;

pub use logistic::Logistic;
pub use multinomial::Multinomial;
pub use poisson::Poisson;
pub use quadratic::Quadratic;

use crate::linalg::Mat;

/// Which family (used to gate regression-only screening rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitKind {
    Quadratic,
    Logistic,
    Multinomial,
    Poisson,
}

impl FitKind {
    /// Stable lowercase label (ledger certificates, trace tooling).
    pub fn label(self) -> &'static str {
        match self {
            FitKind::Quadratic => "quadratic",
            FitKind::Logistic => "logistic",
            FitKind::Multinomial => "multinomial",
            FitKind::Poisson => "poisson",
        }
    }
}

/// A smooth, separable data-fitting term.
pub trait DataFit: Send + Sync {
    fn kind(&self) -> FitKind;

    /// Number of samples.
    fn n(&self) -> usize;

    /// Output width q (1 for scalar regression / binary classification).
    fn q(&self) -> usize;

    /// gamma: each f_i has 1/gamma-Lipschitz gradient (Table 1 row 4).
    /// `None` when no *global* curvature bound exists (Poisson/KL — e^z
    /// is not globally Lipschitz); such fits must override
    /// [`DataFit::gap_safe_radius`] with a locally valid bound, and the
    /// default radius fails *open* (infinite radius, screens nothing)
    /// rather than unsafely (gamma = infinity would yield radius 0 and
    /// discard coordinates without a certificate).
    fn gamma(&self) -> Option<f64>;

    /// F at linear predictor Z = X B.
    fn loss(&self, z: &Mat) -> f64;

    /// Generalized residual rho = -G(Z), shape (n, q).
    fn neg_grad(&self, z: &Mat, out: &mut Mat);

    /// D_lambda(theta) = -sum_i f_i^*(-lambda theta_i).
    fn dual(&self, theta: &Mat, lam: f64) -> f64;

    /// Gap Safe sphere radius centred at `theta` for duality gap `gap`
    /// (Thm. 2). The default uses the *global* curvature bound gamma —
    /// `sqrt(2 gap / gamma) / lambda` — verbatim, so fits with a globally
    /// Lipschitz gradient keep their historical radii bit for bit. Fits
    /// whose conjugate curvature is only *locally* bounded (Poisson/KL —
    /// Dantas, Soubies & Fevotte 2021) override this with a per-center
    /// bound valid on the ball the radius itself defines; see the
    /// "Locally bounded duals" section of the `screening` module docs.
    fn gap_safe_radius(&self, gap: f64, lam: f64, theta: &Mat) -> f64 {
        let _ = theta;
        match self.gamma() {
            Some(g) => (2.0 * gap / g).sqrt() / lam,
            // No global bound: an infinite sphere contains every feasible
            // dual point, so the sphere test discards nothing — safe for
            // any fit that forgot to override with a local bound.
            None => f64::INFINITY,
        }
    }

    /// Per-coordinate Lipschitz factor: L_j = lipschitz_scale() * ||X_j||^2.
    fn lipschitz_scale(&self) -> f64;

    /// Targets (Y), shape (n, q).
    fn targets(&self) -> &Mat;

    /// Refresh the listed rows of the link (mean-parameter) matrix in
    /// place: `link[i, :] = Y[i, :] - neg_grad(Z)[i, :]` for each `i` in
    /// `rows`. Row-separable fits (logistic, multinomial) override this
    /// with a per-row computation that is bitwise identical to the full
    /// pass, which is what lets the CD solver batch link updates over only
    /// the rows touched by a packed sparse column instead of paying
    /// O(n q) per group. The default recomputes every row (ignoring
    /// `rows`) — correct for any fit, restricted for none.
    fn refresh_link_rows(&self, z: &Mat, rows: &[usize], link: &mut Mat) {
        let _ = rows;
        let mut g = Mat::zeros(z.rows(), z.cols());
        self.neg_grad(z, &mut g);
        // link = Y - G through the dispatched SIMD `sub` kernel (bitwise
        // identical under every backend — see `linalg::kernels`).
        crate::linalg::sub(self.targets().as_slice(), g.as_slice(), link.as_mut_slice());
    }
}

/// Binary negative entropy Nh (Eq. 28) with the 0 log 0 = 0 convention;
/// +infinity outside [0, 1].
pub fn neg_entropy(x: f64) -> f64 {
    if !(0.0..=1.0).contains(&x) {
        return f64::INFINITY;
    }
    let a = if x > 0.0 { x * x.ln() } else { 0.0 };
    let b = if x < 1.0 { (1.0 - x) * (1.0 - x).ln() } else { 0.0 };
    a + b
}

/// log(1 + exp(z)) computed stably.
pub fn softplus(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Logistic sigmoid.
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neg_entropy_basics() {
        assert_eq!(neg_entropy(0.0), 0.0);
        assert_eq!(neg_entropy(1.0), 0.0);
        assert!((neg_entropy(0.5) + std::f64::consts::LN_2).abs() < 1e-12);
        assert!(neg_entropy(-0.1).is_infinite());
        assert!(neg_entropy(1.1).is_infinite());
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!((softplus(800.0) - 800.0).abs() < 1e-9); // no overflow
        assert!(softplus(-800.0).abs() < 1e-12);
        // softplus(z) - softplus(-z) = z
        for z in [-3.0, -0.5, 0.7, 4.2] {
            assert!((softplus(z) - softplus(-z) - z).abs() < 1e-12);
        }
    }

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(800.0) <= 1.0);
        assert!(sigmoid(-800.0) >= 0.0);
        for z in [-5.0, -1.0, 0.3, 2.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-12);
        }
    }
}
