//! Quadratic fit (Table 1, col. 1-2): f_i(z) = ||y_i - z||^2 / 2.
//!
//! Covers the Lasso / Group Lasso / Sparse-Group Lasso (q = 1) and the
//! multi-task Lasso (q > 1, Sec. 4.5 — the vectorised Kronecker form is
//! never materialised; we work with the (n, q) matrices directly).

use super::{DataFit, FitKind};
use crate::linalg::Mat;

/// Least-squares data fit with targets Y of shape (n, q).
#[derive(Debug, Clone)]
pub struct Quadratic {
    y: Mat,
    /// ||Y||_F^2 / 2, cached for the dual objective.
    y_sq_half: f64,
}

impl Quadratic {
    pub fn new(y: Mat) -> Self {
        let y_sq_half = 0.5 * y.frob_sq();
        Quadratic { y, y_sq_half }
    }

    /// Scalar-target convenience constructor.
    pub fn from_vec(y: &[f64]) -> Self {
        Quadratic::new(Mat::col_vec(y))
    }
}

impl DataFit for Quadratic {
    fn kind(&self) -> FitKind {
        FitKind::Quadratic
    }

    fn n(&self) -> usize {
        self.y.rows()
    }

    fn q(&self) -> usize {
        self.y.cols()
    }

    fn gamma(&self) -> Option<f64> {
        Some(1.0)
    }

    fn loss(&self, z: &Mat) -> f64 {
        let mut s = 0.0;
        for (zi, yi) in z.as_slice().iter().zip(self.y.as_slice()) {
            let r = yi - zi;
            s += r * r;
        }
        0.5 * s
    }

    fn neg_grad(&self, z: &Mat, out: &mut Mat) {
        // rho = Y - Z: the quadratic link refresh, through the dispatched
        // SIMD `sub` kernel (bitwise identical under every backend).
        crate::linalg::sub(self.y.as_slice(), z.as_slice(), out.as_mut_slice());
    }

    fn dual(&self, theta: &Mat, lam: f64) -> f64 {
        // D(theta) = ||Y||_F^2/2 - ||Y - lam Theta||_F^2 / 2.
        let mut s = 0.0;
        for (ti, yi) in theta.as_slice().iter().zip(self.y.as_slice()) {
            let r = yi - lam * ti;
            s += r * r;
        }
        self.y_sq_half - 0.5 * s
    }

    fn lipschitz_scale(&self) -> f64 {
        1.0
    }

    fn targets(&self) -> &Mat {
        &self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn loss_and_residual() {
        let fit = Quadratic::from_vec(&[1.0, 2.0]);
        let z = Mat::col_vec(&[0.0, 0.0]);
        assert_eq!(fit.loss(&z), 2.5);
        let mut rho = Mat::zeros(2, 1);
        fit.neg_grad(&z, &mut rho);
        assert_eq!(rho.as_slice(), &[1.0, 2.0]);
    }

    #[test]
    fn dual_at_scaled_residual_matches_formula() {
        let mut rng = Prng::new(1);
        let y: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let fit = Quadratic::from_vec(&y);
        let lam = 0.7;
        // theta = y / lam  -> D = ||y||^2/2 (the unconstrained max).
        let theta = Mat::col_vec(&y.iter().map(|v| v / lam).collect::<Vec<_>>());
        let want = 0.5 * y.iter().map(|v| v * v).sum::<f64>();
        assert!((fit.dual(&theta, lam) - want).abs() < 1e-12);
    }

    #[test]
    fn weak_duality_random() {
        let mut rng = Prng::new(2);
        let y: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let fit = Quadratic::from_vec(&y);
        for _ in 0..20 {
            let z = Mat::col_vec(&(0..6).map(|_| rng.gaussian()).collect::<Vec<_>>());
            let th = Mat::col_vec(&(0..6).map(|_| rng.gaussian()).collect::<Vec<_>>());
            // P >= D always (lam-free check with Omega = 0: loss vs dual)
            // here we just check D(theta) <= loss(z) + <stuff>; the real
            // weak-duality test lives in problem.rs where Omega enters.
            assert!(fit.dual(&th, 1.0) <= 0.5 * y.iter().map(|v| v * v).sum::<f64>() + 1e-12);
            let _ = fit.loss(&z);
        }
    }

    #[test]
    fn multitask_shapes() {
        let mut y = Mat::zeros(3, 2);
        y[(0, 0)] = 1.0;
        y[(2, 1)] = -2.0;
        let fit = Quadratic::new(y);
        assert_eq!((fit.n(), fit.q()), (3, 2));
        let z = Mat::zeros(3, 2);
        assert_eq!(fit.loss(&z), 2.5);
    }
}
