//! Binary logistic fit (Sec. 4.4, Table 1 col. 3):
//!   f_i(z) = -y_i z + log(1 + e^z),   y_i in {0, 1},
//!   f_i^*(u) = Nh(u + y_i),           gamma = 4.

use super::{neg_entropy, sigmoid, softplus, DataFit, FitKind};
use crate::linalg::Mat;

/// l1-regularised logistic regression data fit.
#[derive(Debug, Clone)]
pub struct Logistic {
    y: Mat,
}

impl Logistic {
    /// Labels must be exactly 0.0 or 1.0 (Remark 13: map {-1,+1} via (l+1)/2).
    pub fn new(y: &[f64]) -> Self {
        assert!(
            y.iter().all(|&v| v == 0.0 || v == 1.0),
            "logistic labels must be in {{0, 1}}"
        );
        Logistic { y: Mat::col_vec(y) }
    }
}

impl DataFit for Logistic {
    fn kind(&self) -> FitKind {
        FitKind::Logistic
    }

    fn n(&self) -> usize {
        self.y.rows()
    }

    fn q(&self) -> usize {
        1
    }

    fn gamma(&self) -> Option<f64> {
        Some(4.0)
    }

    fn loss(&self, z: &Mat) -> f64 {
        let mut s = 0.0;
        for (zi, yi) in z.as_slice().iter().zip(self.y.as_slice()) {
            s += softplus(*zi) - yi * zi;
        }
        s
    }

    fn neg_grad(&self, z: &Mat, out: &mut Mat) {
        for ((o, zi), yi) in out
            .as_mut_slice()
            .iter_mut()
            .zip(z.as_slice())
            .zip(self.y.as_slice())
        {
            *o = yi - sigmoid(*zi);
        }
    }

    fn dual(&self, theta: &Mat, lam: f64) -> f64 {
        // D(theta) = -sum Nh(y_i - lam theta_i); dom requires the argument
        // in [0, 1] — guaranteed by the rescaling (Remark 14); clamp the
        // inevitable 1e-17-scale rounding excursions.
        let mut s = 0.0;
        for (ti, yi) in theta.as_slice().iter().zip(self.y.as_slice()) {
            let u = (yi - lam * ti).clamp(0.0, 1.0);
            s += neg_entropy(u);
        }
        -s
    }

    fn lipschitz_scale(&self) -> f64 {
        0.25 // |sigma'| <= 1/4
    }

    fn targets(&self) -> &Mat {
        &self.y
    }

    fn refresh_link_rows(&self, z: &Mat, rows: &[usize], link: &mut Mat) {
        // Row-local: link_i = y_i - (y_i - sigma(z_i)), computed with the
        // same two rounding steps as the full neg_grad + subtract pass so
        // the restricted refresh is bitwise identical to it.
        let zs = z.as_slice();
        let ys = self.y.as_slice();
        let ls = link.as_mut_slice();
        for &i in rows {
            let g = ys[i] - sigmoid(zs[i]);
            ls[i] = ys[i] - g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn loss_at_zero_is_n_log2() {
        let fit = Logistic::new(&[0.0, 1.0, 1.0, 0.0]);
        let z = Mat::zeros(4, 1);
        assert!((fit.loss(&z) - 4.0 * std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn residual_at_zero() {
        let fit = Logistic::new(&[0.0, 1.0]);
        let z = Mat::zeros(2, 1);
        let mut rho = Mat::zeros(2, 1);
        fit.neg_grad(&z, &mut rho);
        assert_eq!(rho.as_slice(), &[-0.5, 0.5]);
    }

    #[test]
    fn dual_bounded_by_zero() {
        // D(theta) = -sum Nh(.) and Nh >= -log 2, so D <= n log 2; also D <= P always.
        let mut rng = Prng::new(3);
        let y: Vec<f64> = (0..6).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let fit = Logistic::new(&y);
        for _ in 0..50 {
            let th =
                Mat::col_vec(&(0..6).map(|_| 0.2 * rng.gaussian()).collect::<Vec<_>>());
            let d = fit.dual(&th, 0.5);
            assert!(d <= 6.0 * std::f64::consts::LN_2 + 1e-12);
        }
    }

    #[test]
    fn fenchel_young_equality_at_optimum() {
        // At theta* = -G(z)/lam: f(z) + f*(-lam theta*) = z * grad f(z).
        let _fit = Logistic::new(&[1.0]);
        let z = 0.8_f64;
        let lam = 0.3;
        let theta = (1.0 - sigmoid(z)) / lam; // = -grad f / lam
        let f = softplus(z) - z;
        let fstar = neg_entropy(1.0 - lam * theta); // Nh(-lam theta + y)
        let grad = sigmoid(z) - 1.0;
        assert!((f + fstar - z * grad).abs() < 1e-10);
    }

    #[test]
    #[should_panic(expected = "labels")]
    fn rejects_pm1_labels() {
        let _ = Logistic::new(&[-1.0, 1.0]);
    }

    #[test]
    fn refresh_link_rows_bitwise_matches_full_pass() {
        let mut rng = Prng::new(7);
        let y: Vec<f64> = (0..9).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let fit = Logistic::new(&y);
        let mut z = Mat::zeros(9, 1);
        for v in z.as_mut_slice() {
            *v = 2.0 * rng.gaussian();
        }
        // full pass: link = Y - neg_grad(Z)
        let mut full = Mat::zeros(9, 1);
        fit.neg_grad(&z, &mut full);
        for (l, yi) in full.as_mut_slice().iter_mut().zip(fit.targets().as_slice()) {
            *l = yi - *l;
        }
        // restricted pass over a scrambled subset, rest seeded from full
        let mut part = full.clone();
        let rows = [5usize, 0, 7, 3];
        for &i in &rows {
            part[(i, 0)] = f64::NAN; // must be overwritten
        }
        fit.refresh_link_rows(&z, &rows, &mut part);
        for i in 0..9 {
            assert_eq!(
                full[(i, 0)].to_bits(),
                part[(i, 0)].to_bits(),
                "row {i} diverged"
            );
        }
    }
}
