//! Poisson / KL data fit (count regression with the canonical log link):
//!   f_i(z) = e^z - y_i z,   y_i in {0, 1, 2, ...},
//!   f_i^*(u) = v ln v - v with v = u + y_i (0 ln 0 = 0; +inf for v < 0).
//!
//! The gradient e^z is *not* globally Lipschitz, so the paper's Table-1
//! gamma does not exist and the classic Gap Safe radius is unavailable.
//! Following Dantas, Soubies & Fevotte (2021, "Expanding Boundaries of
//! Gap Safe Screening") the conjugate curvature 1/v is instead bounded
//! *locally*, on the very ball the radius defines: with
//! v_i = y_i - lambda theta_i at the center, every point of
//! B(theta_c, r) has v_i <= v_max + lambda r, so the dual is
//! (lambda^2 / (v_max + lambda r))-strongly concave there and the safe
//! radius is the fixed point of r = sqrt(2 gap (v_max + lambda r)) /
//! lambda — a quadratic with the closed-form root implemented by
//! [`Poisson::gap_safe_radius`]. See the "Locally bounded duals" section
//! of the `screening` module docs.

use super::{DataFit, FitKind};
use crate::linalg::Mat;

/// l1-regularised Poisson regression data fit.
#[derive(Debug, Clone)]
pub struct Poisson {
    y: Mat,
}

impl Poisson {
    /// Counts must be finite and non-negative (they need not be integers:
    /// exposure-weighted rates are fine).
    pub fn new(y: &[f64]) -> Self {
        assert!(
            y.iter().all(|&v| v.is_finite() && v >= 0.0),
            "poisson counts must be finite and >= 0"
        );
        Poisson { y: Mat::col_vec(y) }
    }
}

/// One conjugate term v ln v - v with the 0 ln 0 = 0 convention; the
/// argument is clamped at 0 so rounding excursions of a feasible theta
/// (and the probe points of the `refine` dual strategy) keep the dual
/// finite instead of poisoning the gap trace with NaN.
fn conj_term(v: f64) -> f64 {
    let v = v.max(0.0);
    if v > 0.0 {
        v * v.ln() - v
    } else {
        0.0
    }
}

impl DataFit for Poisson {
    fn kind(&self) -> FitKind {
        FitKind::Poisson
    }

    fn n(&self) -> usize {
        self.y.rows()
    }

    fn q(&self) -> usize {
        1
    }

    /// No global curvature bound exists (e^z is not globally Lipschitz):
    /// every radius must go through [`Poisson::gap_safe_radius`]. `None`
    /// makes a forgotten call site fall back to an *infinite* default
    /// radius (screens nothing — safe), never to the gamma = infinity
    /// radius-0 formula that would screen unsafely.
    fn gamma(&self) -> Option<f64> {
        None
    }

    fn loss(&self, z: &Mat) -> f64 {
        let mut s = 0.0;
        for (zi, yi) in z.as_slice().iter().zip(self.y.as_slice()) {
            s += zi.exp() - yi * zi;
        }
        s
    }

    fn neg_grad(&self, z: &Mat, out: &mut Mat) {
        for ((o, zi), yi) in out
            .as_mut_slice()
            .iter_mut()
            .zip(z.as_slice())
            .zip(self.y.as_slice())
        {
            *o = yi - zi.exp();
        }
    }

    fn dual(&self, theta: &Mat, lam: f64) -> f64 {
        // D(theta) = -sum (v ln v - v), v_i = y_i - lam theta_i; dom
        // requires v >= 0 — guaranteed by the rescaling (alpha >= lam
        // keeps v_i a convex combination of y_i and e^{z_i}).
        let mut s = 0.0;
        for (ti, yi) in theta.as_slice().iter().zip(self.y.as_slice()) {
            s += conj_term(yi - lam * ti);
        }
        -s
    }

    /// Locally-bounded Gap Safe radius (Dantas et al. 2021). At the
    /// center, v_max = max_i (y_i - lambda theta_i)_+; on B(theta_c, r)
    /// every v_i is at most v_max + lambda r, so the radius solves
    /// lambda^2 r^2 = 2 gap (v_max + lambda r), whose positive root is
    ///   r = (gap + sqrt(gap^2 + 2 gap v_max)) / lambda.
    /// It degrades gracefully: r -> 0 as gap -> 0, and r = 2 gap / lambda
    /// when every count is already matched (v_max = 0).
    fn gap_safe_radius(&self, gap: f64, lam: f64, theta: &Mat) -> f64 {
        let mut v_max = 0.0_f64;
        for (ti, yi) in theta.as_slice().iter().zip(self.y.as_slice()) {
            v_max = v_max.max(yi - lam * ti);
        }
        (gap + (gap * gap + 2.0 * gap * v_max).sqrt()) / lam
    }

    /// Curvature of f at z = 0 (the cold-start predictor). The CD/FISTA
    /// steps treat this as a *trial* majorizer and backtrack per group
    /// whenever the true local curvature e^z exceeds it.
    fn lipschitz_scale(&self) -> f64 {
        1.0
    }

    fn targets(&self) -> &Mat {
        &self.y
    }

    fn refresh_link_rows(&self, z: &Mat, rows: &[usize], link: &mut Mat) {
        // Row-local: link_i = y_i - (y_i - e^{z_i}), computed with the
        // same two rounding steps as the full neg_grad + subtract pass so
        // the restricted refresh is bitwise identical to it.
        let zs = z.as_slice();
        let ys = self.y.as_slice();
        let ls = link.as_mut_slice();
        for &i in rows {
            let g = ys[i] - zs[i].exp();
            ls[i] = ys[i] - g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn counts(rng: &mut Prng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.below(7) as f64).collect()
    }

    #[test]
    fn loss_and_residual_at_zero() {
        let fit = Poisson::new(&[0.0, 1.0, 3.0]);
        let z = Mat::zeros(3, 1);
        // f(0) = e^0 - y * 0 = 1 per sample.
        assert_eq!(fit.loss(&z), 3.0);
        let mut rho = Mat::zeros(3, 1);
        fit.neg_grad(&z, &mut rho);
        assert_eq!(rho.as_slice(), &[-1.0, 0.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "counts")]
    fn rejects_negative_counts() {
        let _ = Poisson::new(&[1.0, -2.0]);
    }

    #[test]
    #[should_panic(expected = "counts")]
    fn rejects_non_finite_counts() {
        let _ = Poisson::new(&[1.0, f64::NAN]);
    }

    #[test]
    fn fenchel_young_equality_at_conjugate_pair() {
        // At u = f'(z) = e^z - y: f(z) + f*(u) = u z.
        let y = 3.0;
        let fit = Poisson::new(&[y]);
        for z in [-1.3, 0.0, 0.8, 2.1] {
            let lam = 0.7;
            let theta = (y - z.exp()) / lam; // theta* = rho / lam
            let f = fit.loss(&Mat::col_vec(&[z]));
            let d = fit.dual(&Mat::col_vec(&[theta]), lam);
            // D(theta*) = -f*(-lam theta*) and f + f* = u z with
            // u = -lam theta* => f - D = u z.
            let u = z.exp() - y;
            assert!((f - d - u * z).abs() < 1e-10, "z={z}: {} vs {}", f - d, u * z);
        }
    }

    #[test]
    fn dual_is_total_and_finite_even_infeasible() {
        // v < 0 arguments are clamped: the dual must never be NaN/-inf,
        // so the best-kept tracker and refine probes stay well-defined.
        let mut rng = Prng::new(11);
        let fit = Poisson::new(&counts(&mut rng, 8));
        for _ in 0..50 {
            let th = Mat::col_vec(&(0..8).map(|_| 5.0 * rng.gaussian()).collect::<Vec<_>>());
            let d = fit.dual(&th, 1.3);
            assert!(d.is_finite(), "dual not finite: {d}");
        }
    }

    #[test]
    fn rescaled_theta_is_dual_feasible() {
        // theta = rho / max(lam, alpha) with alpha >= lam makes
        // v_i = y_i (1 - lam/alpha) + (lam/alpha) e^{z_i} >= 0.
        let mut rng = Prng::new(12);
        let y = counts(&mut rng, 10);
        let fit = Poisson::new(&y);
        for _ in 0..50 {
            let z: Vec<f64> = (0..10).map(|_| 1.5 * rng.gaussian()).collect();
            let lam = 0.1 + rng.uniform();
            let alpha = lam * (1.0 + rng.uniform()); // any alpha >= lam
            for (i, zi) in z.iter().enumerate() {
                let rho = y[i] - zi.exp();
                let v = y[i] - lam * (rho / alpha);
                assert!(v >= -1e-12, "infeasible v = {v}");
            }
        }
    }

    #[test]
    fn radius_solves_its_fixed_point_equation() {
        // lambda^2 r^2 = 2 gap (v_max + lambda r) at the closed-form root.
        let mut rng = Prng::new(13);
        let y = counts(&mut rng, 6);
        let fit = Poisson::new(&y);
        for _ in 0..100 {
            let lam = 0.2 + rng.uniform();
            let theta =
                Mat::col_vec(&(0..6).map(|_| 0.5 * rng.gaussian()).collect::<Vec<_>>());
            let gap = rng.uniform() * 3.0;
            let r = fit.gap_safe_radius(gap, lam, &theta);
            let v_max = theta
                .as_slice()
                .iter()
                .zip(&y)
                .map(|(t, yi)| (yi - lam * t).max(0.0))
                .fold(0.0_f64, f64::max);
            let lhs = lam * lam * r * r;
            let rhs = 2.0 * gap * (v_max + lam * r);
            assert!(
                (lhs - rhs).abs() <= 1e-9 * (1.0 + lhs.abs() + rhs.abs()),
                "fixed point violated: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn radius_vanishes_with_the_gap() {
        let fit = Poisson::new(&[2.0, 0.0, 5.0]);
        let theta = Mat::col_vec(&[0.1, -0.2, 0.3]);
        let lam = 0.8;
        assert_eq!(fit.gap_safe_radius(0.0, lam, &theta), 0.0);
        let mut prev = f64::INFINITY;
        for k in 0..12 {
            let r = fit.gap_safe_radius(10.0_f64.powi(-k), lam, &theta);
            assert!(r < prev, "radius not decreasing in gap");
            prev = r;
        }
        assert!(prev < 1e-11);
    }

    #[test]
    fn local_bound_dominates_true_curvature_on_the_ball() {
        // The strong-concavity modulus used by the radius is
        // lambda^2 / (v_max + lambda r); the true curvature of -D at any
        // feasible point of the ball is lambda^2 / v_i. Dominance needs
        // v_i <= v_max + lambda r for every theta' in B(theta_c, r) —
        // check it on random points of the ball.
        let mut rng = Prng::new(14);
        let y = counts(&mut rng, 6);
        let fit = Poisson::new(&y);
        for _ in 0..100 {
            let lam = 0.2 + rng.uniform();
            let theta_c =
                Mat::col_vec(&(0..6).map(|_| 0.4 * rng.gaussian()).collect::<Vec<_>>());
            let gap = rng.uniform() * 2.0;
            let r = fit.gap_safe_radius(gap, lam, &theta_c);
            let v_ball = {
                let v_max = theta_c
                    .as_slice()
                    .iter()
                    .zip(&y)
                    .map(|(t, yi)| (yi - lam * t).max(0.0))
                    .fold(0.0_f64, f64::max);
                v_max + lam * r
            };
            // Random perturbation of norm <= r.
            for _ in 0..10 {
                let mut d: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
                let nd = d.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
                let scale = r * rng.uniform() / nd;
                d.iter_mut().for_each(|v| *v *= scale);
                for (i, di) in d.iter().enumerate() {
                    let v_i = y[i] - lam * (theta_c.as_slice()[i] + di);
                    assert!(
                        v_i <= v_ball + 1e-9,
                        "curvature bound violated on the ball: v_i={v_i} > {v_ball}"
                    );
                }
            }
        }
    }

    #[test]
    fn refresh_link_rows_bitwise_matches_full_pass() {
        let mut rng = Prng::new(15);
        let y = counts(&mut rng, 9);
        let fit = Poisson::new(&y);
        let mut z = Mat::zeros(9, 1);
        for v in z.as_mut_slice() {
            *v = 1.5 * rng.gaussian();
        }
        let mut full = Mat::zeros(9, 1);
        fit.neg_grad(&z, &mut full);
        for (l, yi) in full.as_mut_slice().iter_mut().zip(fit.targets().as_slice()) {
            *l = yi - *l;
        }
        let mut part = full.clone();
        let rows = [4usize, 0, 8, 2];
        for &i in &rows {
            part[(i, 0)] = f64::NAN; // must be overwritten
        }
        fit.refresh_link_rows(&z, &rows, &mut part);
        for i in 0..9 {
            assert_eq!(
                full[(i, 0)].to_bits(),
                part[(i, 0)].to_bits(),
                "row {i} diverged"
            );
        }
    }
}
