//! Model registry + warm-start cache: canonical model keys mapped to
//! fitted path artifacts, with LRU bounding and nearest-lambda warm
//! starts.
//!
//! # Why a resident registry
//!
//! Gap Safe screening composes with warm starts (Sec. 3.3-3.4): a solve
//! seeded near the optimum certifies a small duality gap at its very
//! first gap pass, so the safe sphere is tiny and almost everything
//! screens immediately. A long-lived registry that keeps `(beta, active)`
//! per (dataset, penalty, grid) key therefore answers
//!
//! * **repeat fits** (same [`ModelKey`]) from the artifact itself — no
//!   solver work at all, and
//! * **nearby fits** (same model family, perturbed lambda grid) by
//!   seeding every grid point from the closest cached solution via the
//!   active-warm-start entry point
//!   [`solve_fixed_lambda_with`](crate::solver::solve_fixed_lambda_with)
//!   — typically orders of magnitude fewer epochs than a cold path.
//!
//! # Concurrency contract
//!
//! Fits are **single-flight**: the first caller of a key computes it, any
//! concurrent caller of the same key blocks on a condvar and receives the
//! same `Arc<FittedModel>`. Combined with the deterministic solver
//! (`threads = 1` inside a fit) this makes N clients hammering one key
//! bitwise-identical to a serial run — `rust/tests/serve.rs` pins it.
//!
//! The cache is LRU-bounded by approximate resident bytes (design matrix
//! + path betas); eviction never removes in-flight fits or the entry just
//! inserted.

use super::{lock_ok, wait_ok, Metrics};
use crate::data::load_spec;
use crate::linalg::Mat;
use crate::penalty::ActiveSet;
use crate::problem::Problem;
use crate::screening::{DualStrategy, PrevSolution, Rule};
use crate::solver::path::{
    lambda_grid, point_from_result, prev_from_result, scaled_eps, solve_path, PathConfig,
    PathResult, WarmStart,
};
use crate::solver::{solve_fixed_lambda_with, SolveOptions};
use crate::util::json::Json;
use crate::util::Stopwatch;
use crate::{build_problem, Task};

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};

/// Canonical identity of a fitted model: dataset spec, penalty/task, and
/// the lambda-grid / tolerance parameters. Two requests with equal keys
/// are the same model and share one artifact. `delta` and `eps` are
/// stored as bit patterns so the key is `Eq + Hash` without fuzz.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    /// Dataset spec understood by [`crate::data::load_spec`].
    pub data: String,
    /// Task label understood by [`Task::parse`] (e.g. `lasso`, `sgl:0.4`).
    pub task: String,
    pub seed: u64,
    pub small: bool,
    pub n_lambdas: usize,
    delta_bits: u64,
    eps_bits: u64,
    pub max_epochs: usize,
}

impl ModelKey {
    pub fn new(
        data: &str,
        task: &str,
        seed: u64,
        small: bool,
        n_lambdas: usize,
        delta: f64,
        eps: f64,
        max_epochs: usize,
    ) -> ModelKey {
        ModelKey {
            data: data.to_string(),
            task: task.to_string(),
            seed,
            small,
            n_lambdas: n_lambdas.max(1),
            delta_bits: delta.to_bits(),
            eps_bits: eps.to_bits(),
            max_epochs: max_epochs.max(1),
        }
    }

    pub fn delta(&self) -> f64 {
        f64::from_bits(self.delta_bits)
    }

    pub fn eps(&self) -> f64 {
        f64::from_bits(self.eps_bits)
    }

    /// Canonical string form — the registry index and the `key` field of
    /// every serving response (f64 components print with shortest
    /// round-trip formatting, so equal keys stringify equally).
    pub fn canonical(&self) -> String {
        format!(
            "{}|{}|seed={}|small={}|T={}|delta={}|eps={}|K={}",
            self.data,
            self.task,
            self.seed,
            self.small,
            self.n_lambdas,
            self.delta(),
            self.eps(),
            self.max_epochs
        )
    }

    /// Same underlying data + penalty (only the grid/tolerance differ):
    /// warm starts transfer within a family.
    pub fn same_family(&self, other: &ModelKey) -> bool {
        self.data == other.data
            && self.task == other.task
            && self.seed == other.seed
            && self.small == other.small
    }

    /// The solver configuration this key pins down. Fits run serially
    /// (`threads = 1`) inside one worker so results are bitwise
    /// independent of pool sizes, exactly like
    /// [`crate::coordinator::BatchRunner`].
    pub fn path_config(&self) -> PathConfig {
        PathConfig {
            n_lambdas: self.n_lambdas,
            delta: self.delta(),
            rule: Rule::GapSafeFull,
            warm: WarmStart::Standard,
            eps: self.eps(),
            eps_is_absolute: false,
            max_epochs: self.max_epochs,
            screen_every: 10,
            threads: 1,
            compact: true,
            dual: DualStrategy::default(),
        }
    }

    /// Parse a key from a JSON request body (`/v1/fit`, `/v1/predict`).
    /// Absent fields take defaults; *present but malformed* fields are
    /// errors (they must not be silently coerced into a different key).
    pub fn from_json(v: &Json) -> Result<ModelKey, String> {
        let data = field(v, "data", Json::as_str, "a string", "synth:leukemia")?;
        let task = field(v, "task", Json::as_str, "a string", "lasso")?;
        // validate early so submit-time errors reach the client as 400s
        Task::parse(task)?;
        let seed = field(v, "seed", Json::as_usize, "a non-negative integer", 42)? as u64;
        let small = field(v, "small", Json::as_bool, "a boolean", false)?;
        let n_lambdas = field(v, "grid", Json::as_usize, "a non-negative integer", 20)?;
        let delta = field(v, "delta", Json::as_f64, "a number", 2.0)?;
        let eps = field(v, "eps", Json::as_f64, "a number", 1e-6)?;
        let max_epochs =
            field(v, "max_epochs", Json::as_usize, "a non-negative integer", 10_000)?;
        if !(delta.is_finite() && delta > 0.0) {
            return Err("delta must be finite and > 0".into());
        }
        if !(eps.is_finite() && eps > 0.0) {
            return Err("eps must be finite and > 0".into());
        }
        if n_lambdas == 0 || n_lambdas > 10_000 {
            return Err("grid must be in 1..=10000".into());
        }
        validate_data_spec(data)?;
        Ok(ModelKey::new(data, task, seed, small, n_lambdas, delta, eps, max_epochs))
    }
}

/// Largest synthetic design (n * p cells) a fit request may ask the
/// server to materialize (~200 MiB of f64). The CLI has no such cap — an
/// operator sizing a benchmark is not an unauthenticated HTTP client
/// whose single request could abort the resident process on allocation
/// failure (or overflow `n * p` in release).
const MAX_SYNTH_CELLS: usize = 25_000_000;

/// Serving-side guard on request dataset specs (the shared
/// [`load_spec`] grammar itself is validated at fit time):
///
/// * `csv:` is refused outright — an HTTP client must not be able to
///   make the resident server read (and expose model output derived
///   from) arbitrary local files; csv stays a CLI-only spec;
/// * `synth:reg` dimensions are capped so a request cannot ask the
///   process to materialize an allocation-abort-sized design.
fn validate_data_spec(data: &str) -> Result<(), String> {
    if data.starts_with("csv:") {
        return Err("csv: specs are not served over HTTP (use the CLI)".into());
    }
    if data.starts_with("synth:reg:") {
        let (n, p) = crate::data::parse_reg_dims(data).ok_or("use synth:reg:<n>x<p>")?;
        if n == 0 || p == 0 {
            return Err("synth:reg dimensions must be positive".into());
        }
        if n.checked_mul(p).map(|cells| cells > MAX_SYNTH_CELLS).unwrap_or(true) {
            return Err(format!(
                "synth:reg:{n}x{p} exceeds the serving cap of {MAX_SYNTH_CELLS} cells"
            ));
        }
    }
    if data.starts_with("synth:counts:") {
        let (n, p) = crate::data::parse_counts_dims(data).ok_or("use synth:counts:<n>x<p>")?;
        if n == 0 || p == 0 {
            return Err("synth:counts dimensions must be positive".into());
        }
        if n.checked_mul(p).map(|cells| cells > MAX_SYNTH_CELLS).unwrap_or(true) {
            return Err(format!(
                "synth:counts:{n}x{p} exceeds the serving cap of {MAX_SYNTH_CELLS} cells"
            ));
        }
    }
    Ok(())
}

/// Extract an optional request field: absent → `default`, present but of
/// the wrong shape → an error naming the expectation.
fn field<'a, T>(
    v: &'a Json,
    key: &str,
    extract: fn(&'a Json) -> Option<T>,
    expect: &str,
    default: T,
) -> Result<T, String> {
    match v.get(key) {
        None => Ok(default),
        Some(j) => extract(j).ok_or_else(|| format!("'{key}' must be {expect}")),
    }
}

/// A fitted artifact held by the registry.
pub struct FittedModel {
    pub key: ModelKey,
    /// The assembled problem (kept for `/v1/predict` and warm starts).
    pub prob: Arc<Problem>,
    pub path: PathResult,
    /// Sum of per-lambda epochs actually run for this artifact.
    pub total_epochs: usize,
    /// Whether this fit was seeded from a cached family member.
    pub warm_started: bool,
    pub fit_seconds: f64,
}

/// How a fit request was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitKind {
    /// Exact key already fitted — artifact returned as-is.
    Hit,
    /// New key, seeded from a cached family member.
    Warm,
    /// New key, no usable seed.
    Cold,
}

impl FitKind {
    pub fn label(&self) -> &'static str {
        match self {
            FitKind::Hit => "hit",
            FitKind::Warm => "warm",
            FitKind::Cold => "cold",
        }
    }
}

enum Entry {
    /// A fit is in flight; waiters sleep on the registry condvar.
    Pending,
    Done(Slot),
}

struct Slot {
    model: Arc<FittedModel>,
    bytes: usize,
    last_used: u64,
}

struct RegState {
    entries: HashMap<String, Entry>,
    /// Monotone access clock for LRU.
    tick: u64,
    /// Resident bytes of Done entries.
    bytes: usize,
    evictions: u64,
}

/// Registry snapshot for `/metrics`.
#[derive(Debug, Clone)]
pub struct RegistryStats {
    pub models: usize,
    pub pending: usize,
    pub bytes: usize,
    pub cap_bytes: usize,
    pub evictions: u64,
}

/// The model registry (see module docs).
pub struct Registry {
    state: Mutex<RegState>,
    cv: Condvar,
    metrics: Arc<Metrics>,
    cap_bytes: usize,
    /// Active-set compaction for fits solved here (`serve --no-compact`).
    compact: bool,
    /// Dual-point strategy for fits solved here (`serve --dual`): cached
    /// artifacts carry the best-kept theta per lambda, so warm starts
    /// seeded from them center their first sequential sphere at the best
    /// dual point the original fit ever saw.
    dual: DualStrategy,
}

impl Registry {
    /// A registry bounded to roughly `cache_mb` MiB of fitted artifacts
    /// (0 means "one model at most" — the floor is always the entry just
    /// inserted).
    pub fn new(cache_mb: usize, metrics: Arc<Metrics>) -> Registry {
        Registry {
            state: Mutex::new(RegState {
                entries: HashMap::new(),
                tick: 0,
                bytes: 0,
                evictions: 0,
            }),
            cv: Condvar::new(),
            metrics,
            cap_bytes: cache_mb.saturating_mul(1024 * 1024),
            compact: true,
            dual: DualStrategy::default(),
        }
    }

    /// Toggle active-set compaction for every fit this registry solves
    /// (bitwise-transparent either way; `gapsafe serve --no-compact`).
    pub fn with_compact(mut self, compact: bool) -> Registry {
        self.compact = compact;
        self
    }

    /// Select the dual-point strategy for every fit this registry solves
    /// (`gapsafe serve --dual`; see [`crate::screening::dual`]).
    pub fn with_dual(mut self, dual: DualStrategy) -> Registry {
        self.dual = dual;
        self
    }

    /// Fit (or fetch) the model for `key`. Exact hits return the cached
    /// artifact; misses solve — warm-started from the best cached family
    /// member when one exists — and publish the artifact for every
    /// concurrent waiter of the same key.
    pub fn fit(&self, key: &ModelKey) -> Result<(Arc<FittedModel>, FitKind), String> {
        let canon = key.canonical();
        let sw = crate::obs::enabled().then(Stopwatch::start);
        let seed: Option<Arc<FittedModel>>;
        {
            let mut st = lock_ok(&self.state);
            loop {
                st.tick += 1;
                let tick = st.tick;
                match st.entries.get_mut(&canon) {
                    Some(Entry::Done(slot)) => {
                        slot.last_used = tick;
                        self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                        let model = slot.model.clone();
                        if let Some(sw) = &sw {
                            crate::obs::emit(&crate::obs::Event::Fit {
                                key: canon.clone(),
                                kind: FitKind::Hit.label(),
                                secs: sw.secs(),
                                epochs: model.total_epochs,
                            });
                        }
                        return Ok((model, FitKind::Hit));
                    }
                    Some(Entry::Pending) => {
                        st = wait_ok(&self.cv, st);
                    }
                    None => {
                        seed = best_seed(&st, key);
                        st.entries.insert(canon.clone(), Entry::Pending);
                        break;
                    }
                }
            }
            self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        // Solve outside the lock; waiters sleep on the condvar meanwhile.
        // The guard clears the Pending claim if build_model panics —
        // otherwise every later fit of this key would block forever.
        let mut guard = PendingGuard { reg: self, canon: &canon, armed: true };
        let built = self.build_model(key, seed.as_deref());
        guard.armed = false; // normal paths below publish or clear the claim
        let mut st = lock_ok(&self.state);
        match built {
            Ok(model) => {
                let model = Arc::new(model);
                let bytes = estimate_bytes(&model);
                st.tick += 1;
                let tick = st.tick;
                st.bytes += bytes;
                st.entries.insert(
                    canon.clone(),
                    Entry::Done(Slot { model: model.clone(), bytes, last_used: tick }),
                );
                self.evict_locked(&mut st, &canon);
                self.cv.notify_all();
                let kind = if model.warm_started { FitKind::Warm } else { FitKind::Cold };
                match kind {
                    FitKind::Warm => self.metrics.warm_hits.fetch_add(1, Ordering::Relaxed),
                    _ => self.metrics.cold_fits.fetch_add(1, Ordering::Relaxed),
                };
                self.metrics.fit_duration.record(model.fit_seconds);
                if crate::obs::enabled() {
                    crate::obs::emit(&crate::obs::Event::Fit {
                        key: canon.clone(),
                        kind: kind.label(),
                        secs: model.fit_seconds,
                        epochs: model.total_epochs,
                    });
                }
                Ok((model, kind))
            }
            Err(e) => {
                // Clear the claim so a later request can retry.
                st.entries.remove(&canon);
                self.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Fetch a fitted artifact by canonical key (no solving).
    pub fn get(&self, canon: &str) -> Option<Arc<FittedModel>> {
        let mut st = lock_ok(&self.state);
        st.tick += 1;
        let tick = st.tick;
        match st.entries.get_mut(canon) {
            Some(Entry::Done(slot)) => {
                slot.last_used = tick;
                Some(slot.model.clone())
            }
            _ => None,
        }
    }

    pub fn stats(&self) -> RegistryStats {
        let st = lock_ok(&self.state);
        let models = st.entries.values().filter(|e| matches!(e, Entry::Done(_))).count();
        let pending = st.entries.len() - models;
        RegistryStats {
            models,
            pending,
            bytes: st.bytes,
            cap_bytes: self.cap_bytes,
            evictions: st.evictions,
        }
    }

    fn build_model(
        &self,
        key: &ModelKey,
        seed: Option<&FittedModel>,
    ) -> Result<FittedModel, String> {
        let sw = Stopwatch::start();
        // A seed is always from the same family (same data/task/seed/
        // small), so its Problem is this model's Problem: share the Arc
        // instead of materializing another copy of the design matrix.
        let prob = match seed {
            Some(s) => s.prob.clone(),
            None => {
                let task = Task::parse(&key.task)?;
                let ds = load_spec(&key.data, key.seed, key.small)?;
                Arc::new(build_problem(ds, task)?)
            }
        };
        let mut cfg = key.path_config();
        cfg.compact = self.compact;
        cfg.dual = self.dual;
        // Degenerate grid anchors (e.g. Poisson lambda_max = 0 on all-zero
        // counts) become a client-visible error, not a NaN-filled path.
        crate::solver::path::lambda_grid_checked(prob.lambda_max(), cfg.n_lambdas, cfg.delta)?;
        let (path, warm_started) = match seed {
            Some(s) => (solve_path_seeded(&prob, &cfg, s), true),
            None => (solve_path(&prob, &cfg), false),
        };
        let total_epochs: usize = path.points.iter().map(|p| p.epochs).sum();
        self.metrics.epochs_total.fetch_add(total_epochs as u64, Ordering::Relaxed);
        if let Some(s) = seed {
            // Epochs-saved estimate: the seed's own cost scaled to this
            // grid length, minus what the warm path actually spent.
            let scaled = s.total_epochs * path.points.len() / s.path.points.len().max(1);
            let saved = scaled.saturating_sub(total_epochs);
            self.metrics.epochs_saved.fetch_add(saved as u64, Ordering::Relaxed);
        }
        Ok(FittedModel {
            key: key.clone(),
            prob,
            path,
            total_epochs,
            warm_started,
            fit_seconds: sw.secs(),
        })
    }

    /// Evict least-recently-used Done entries (never `keep`, never
    /// Pending) until under the byte cap.
    fn evict_locked(&self, st: &mut RegState, keep: &str) {
        while st.bytes > self.cap_bytes {
            let victim = st
                .entries
                .iter()
                .filter_map(|(k, e)| match e {
                    Entry::Done(s) if k != keep => Some((k.clone(), s.last_used, s.bytes)),
                    _ => None,
                })
                .min_by_key(|&(_, last_used, _)| last_used);
            match victim {
                Some((k, _, bytes)) => {
                    st.entries.remove(&k);
                    st.bytes -= bytes;
                    st.evictions += 1;
                    self.metrics.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }
}

/// Unwind guard for the single-flight claim: while `armed`, dropping it
/// (i.e. a panic in the in-flight solve) removes the Pending entry and
/// wakes waiters so the key is retryable instead of wedged forever.
struct PendingGuard<'a> {
    reg: &'a Registry,
    canon: &'a str,
    armed: bool,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Never panic inside Drop (double panic aborts): take the state
        // even if another thread poisoned the mutex.
        let mut st = match self.reg.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.entries.remove(self.canon);
        self.reg.cv.notify_all();
    }
}

/// Most-recently-used cached family member, if any.
fn best_seed(st: &RegState, key: &ModelKey) -> Option<Arc<FittedModel>> {
    let mut best: Option<&Slot> = None;
    for entry in st.entries.values() {
        if let Entry::Done(slot) = entry {
            if slot.model.key.same_family(key)
                && best.map(|b| slot.last_used > b.last_used).unwrap_or(true)
            {
                best = Some(slot);
            }
        }
    }
    best.map(|s| s.model.clone())
}

/// Approximate resident bytes of one artifact: design + targets +
/// per-lambda coefficient matrices. Family members share one
/// `Arc<Problem>`, so charging the design to every entry *overcounts* —
/// deliberately: an entry holding the last Arc to an evicted seed's
/// design still pins that memory, and a budget that errs toward early
/// eviction can never exceed `--cache-mb` in real bytes.
fn estimate_bytes(m: &FittedModel) -> usize {
    let (n, p, q) = (m.prob.n(), m.prob.p(), m.prob.q());
    let design = n * p * 8;
    let targets = n * q * 8;
    let betas = m.path.betas.len() * p * q * 8;
    design + targets + betas + 4096
}

/// Solve a lambda path seeded from a cached family artifact: every grid
/// point warm-starts from the *nearest* cached solution (log-lambda
/// distance) — or from the sequential predecessor when that is closer —
/// via the active-warm-start scheme of Eq. (22): a first restricted solve
/// on the seed's support, then the full problem. Screening stays safe for
/// any seed (Thm. 2 holds for every primal/dual pair), so a stale or
/// far-away cache entry costs epochs, never correctness.
pub fn solve_path_seeded(prob: &Problem, cfg: &PathConfig, seed: &FittedModel) -> PathResult {
    let sw_total = Stopwatch::start();
    let lam_max = prob.lambda_max();
    let lambdas = lambda_grid(lam_max, cfg.n_lambdas, cfg.delta);
    let eps = if cfg.eps_is_absolute { cfg.eps } else { scaled_eps(prob, cfg.eps) };
    let opts = SolveOptions {
        max_epochs: cfg.max_epochs,
        screen_every: cfg.screen_every,
        eps,
        max_kkt_rounds: 20,
        compact: cfg.compact,
        dual: cfg.dual,
    };
    let mut rule = cfg.rule.build();
    let mut prev: Option<PrevSolution> = None;
    let mut points = Vec::with_capacity(lambdas.len());
    let mut betas = Vec::with_capacity(lambdas.len());
    for &lam in &lambdas {
        let sw = Stopwatch::start();
        let (ci, clam) = nearest_lambda(&seed.path.lambdas, lam);
        let cache_closer = match prev.as_ref() {
            None => true,
            Some(p) => log_dist(clam, lam) < log_dist(p.lam, lam),
        };
        // `cache_closer` is true whenever `prev` is None, so the fallback
        // arm is unreachable; it re-seeds from the cache rather than
        // panicking on a serving thread (serve-no-panic).
        let seeded_prev = match (cache_closer, prev.clone()) {
            (false, Some(p)) => p,
            _ => make_prev(prob, &seed.path.betas[ci], clam),
        };
        // Phase 1 (Eq. 22): restricted to the seed's support.
        let support = support_active(prob, &seeded_prev.beta);
        let mut phase1_epochs = 0usize;
        let phase1_beta = if support.n_active_feats() > 0 {
            let r1 = solve_fixed_lambda_with(
                prob,
                lam,
                lam_max,
                Some(&seeded_prev.beta),
                Some(&support),
                rule.as_mut(),
                Some(&seeded_prev),
                &opts,
            );
            phase1_epochs = r1.epochs;
            Some(r1.beta)
        } else {
            None
        };
        // Phase 2: the full problem, initialized from phase 1.
        let init = phase1_beta.as_ref().or(Some(&seeded_prev.beta));
        let res = solve_fixed_lambda_with(
            prob,
            lam,
            lam_max,
            init,
            None,
            rule.as_mut(),
            Some(&seeded_prev),
            &opts,
        );
        points.push(point_from_result(lam, &res, res.epochs + phase1_epochs, sw.secs()));
        let (pv, beta) = prev_from_result(prob, lam, res);
        prev = Some(pv);
        betas.push(beta);
    }
    PathResult { lambdas, points, betas, total_seconds: sw_total.secs(), lam_max }
}

/// Reconstruct a [`PrevSolution`] from a cached coefficient matrix: one
/// gap pass at the cached lambda yields a dual-feasible theta, and the
/// full active set keeps every downstream screen safe.
fn make_prev(prob: &Problem, beta: &Mat, lam: f64) -> PrevSolution {
    let z = prob.predict(beta);
    let full = ActiveSet::full(prob.pen.groups());
    let gp = prob.gap_pass(beta, &z, lam, &full);
    let loss = prob.fit.loss(&z);
    PrevSolution {
        lam,
        loss,
        pen_value: prob.pen.value(beta),
        z,
        theta: gp.theta,
        active: full,
        beta: beta.clone(),
    }
}

/// Active set spanning exactly the support of `beta` (the phase-1
/// restriction of the active warm start).
fn support_active(prob: &Problem, beta: &Mat) -> ActiveSet {
    let groups = prob.pen.groups();
    let q = beta.cols();
    let mut a = ActiveSet::full(groups);
    for g in 0..groups.len() {
        let any = groups
            .feats(g)
            .iter()
            .any(|&j| (0..q).any(|k| beta[(j, k)] != 0.0));
        if !any {
            a.kill_group(groups, g);
        }
    }
    a
}

fn log_dist(a: f64, b: f64) -> f64 {
    (a.max(1e-300).ln() - b.max(1e-300).ln()).abs()
}

/// Index and value of the grid lambda closest to `lam` in log scale.
fn nearest_lambda(lams: &[f64], lam: f64) -> (usize, f64) {
    let mut bi = 0usize;
    let mut bd = f64::INFINITY;
    for (i, &l) in lams.iter().enumerate() {
        let d = log_dist(l, lam);
        if d < bd {
            bd = d;
            bi = i;
        }
    }
    (bi, lams[bi])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    fn key(grid: usize, delta: f64) -> ModelKey {
        ModelKey::new("synth:reg:24x60", "lasso", 5, false, grid, delta, 1e-6, 10_000)
    }

    #[test]
    fn canonical_round_trips_equality() {
        let a = key(10, 2.0);
        let b = key(10, 2.0);
        assert_eq!(a, b);
        assert_eq!(a.canonical(), b.canonical());
        assert_ne!(a.canonical(), key(10, 2.5).canonical());
        assert!(a.same_family(&key(30, 1.5)));
        assert!(!a.same_family(&ModelKey::new(
            "synth:reg:24x60",
            "lasso",
            6,
            false,
            10,
            2.0,
            1e-6,
            10_000
        )));
    }

    #[test]
    fn from_json_validates() {
        let ok = Json::parse(r#"{"data":"synth:reg:10x20","task":"lasso","grid":5}"#).unwrap();
        let k = ModelKey::from_json(&ok).unwrap();
        assert_eq!(k.n_lambdas, 5);
        assert_eq!(k.delta(), 2.0);
        let bad = Json::parse(r#"{"task":"nope"}"#).unwrap();
        assert!(ModelKey::from_json(&bad).is_err());
        let bad_eps = Json::parse(r#"{"eps":0}"#).unwrap();
        assert!(ModelKey::from_json(&bad_eps).is_err());
        // present-but-malformed fields are rejected, not coerced
        for doc in [r#"{"grid":7.9}"#, r#"{"seed":-1}"#, r#"{"small":"yes"}"#, r#"{"grid":"8"}"#]
        {
            let v = Json::parse(doc).unwrap();
            assert!(ModelKey::from_json(&v).is_err(), "{doc} should be rejected");
        }
        // synthetic datasets a request may materialize are capped, and
        // csv (local file access) is CLI-only
        for doc in [
            r#"{"data":"synth:reg:1000000x1000000"}"#,
            r#"{"data":"synth:reg:0x10"}"#,
            r#"{"data":"synth:reg:10"}"#,
            r#"{"data":"synth:counts:1000000x1000000"}"#,
            r#"{"data":"synth:counts:0x10"}"#,
            r#"{"data":"synth:counts:10"}"#,
            r#"{"data":"csv:/etc/passwd"}"#,
        ] {
            let v = Json::parse(doc).unwrap();
            assert!(ModelKey::from_json(&v).is_err(), "{doc} should be rejected");
        }
        assert!(validate_data_spec("synth:reg:100x2000").is_ok());
        assert!(validate_data_spec("synth:counts:100x2000").is_ok());
        assert!(validate_data_spec("synth:leukemia").is_ok());
    }

    #[test]
    fn exact_hit_returns_same_artifact() {
        let reg = Registry::new(256, metrics());
        let k = key(6, 1.5);
        let (a, kind_a) = reg.fit(&k).unwrap();
        assert_eq!(kind_a, FitKind::Cold);
        let (b, kind_b) = reg.fit(&k).unwrap();
        assert_eq!(kind_b, FitKind::Hit);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(reg.get(&k.canonical()).is_some());
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn warm_fit_converges_and_saves_epochs() {
        let m = metrics();
        let reg = Registry::new(256, m.clone());
        let (cold, _) = reg.fit(&key(10, 2.0)).unwrap();
        assert!(cold.path.points.iter().all(|p| p.converged));
        let (warm, kind) = reg.fit(&key(10, 2.02)).unwrap();
        assert_eq!(kind, FitKind::Warm);
        assert!(warm.warm_started);
        assert!(warm.path.points.iter().all(|p| p.converged));
        assert!(
            warm.total_epochs < cold.total_epochs,
            "warm start did not save epochs: warm {} vs cold {}",
            warm.total_epochs,
            cold.total_epochs
        );
        assert!(m.warm_hits.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn lru_eviction_respects_cap() {
        let m = metrics();
        let reg = Registry::new(0, m); // floor: only the newest artifact survives
        let first = key(5, 1.5);
        reg.fit(&first).unwrap();
        reg.fit(&key(5, 1.6)).unwrap();
        let stats = reg.stats();
        assert_eq!(stats.models, 1, "cap 0 must keep only the latest model");
        assert!(stats.evictions >= 1);
        assert!(reg.get(&first.canonical()).is_none());
    }

    #[test]
    fn failed_fit_clears_the_claim() {
        let reg = Registry::new(64, metrics());
        let bad = ModelKey::new("no:such", "lasso", 1, false, 3, 1.0, 1e-6, 100);
        assert!(reg.fit(&bad).is_err());
        // the claim is gone: a retry errors again instead of deadlocking
        assert!(reg.fit(&bad).is_err());
        assert_eq!(reg.stats().pending, 0);
    }
}
