//! Background fit-job queue: submit → poll → fetch.
//!
//! `POST /v1/fit` must not hold an HTTP worker hostage for the length of a
//! path solve, so fit requests are enqueued here and executed by a
//! dedicated pool of fit workers (detached threads — the queue outlives
//! any single connection). Workers drain the queue through the
//! [`Registry`](super::registry::Registry), so single-flight dedup, warm
//! starts and LRU bounding all apply; the queue itself only tracks job
//! lifecycle (`queued → running → done|failed`) and exposes depth for
//! `/metrics`.
//!
//! Jobs are executed in submission order by `workers` threads — the same
//! requests-over-a-pool discipline as
//! [`BatchRunner`](crate::coordinator::BatchRunner), but resident: the
//! queue accepts work forever instead of fanning out one finite batch.

use super::registry::{FitKind, ModelKey, Registry};
use super::{lock_ok, wait_ok, wait_timeout_ok, Metrics};
use crate::obs;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Retention bound for finished (done/failed) job records: the newest
/// `MAX_FINISHED` stay pollable, older ones are pruned so a resident
/// server does not grow its job table forever.
const MAX_FINISHED: usize = 1024;

/// Lifecycle of one fit job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed(String),
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Snapshot of a job for polling / the jobs endpoint.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub key: ModelKey,
    pub state: JobState,
    /// Set once the job is done.
    pub outcome: Option<JobOutcome>,
    /// When the job entered the queue.
    pub submitted: Instant,
    /// When a worker picked it up (None while queued).
    pub started: Option<Instant>,
    /// When it reached a terminal state (None until done/failed).
    pub finished: Option<Instant>,
}

impl JobRecord {
    /// Submit → start delay (the queueing cost a client paid), once known.
    pub fn queue_seconds(&self) -> Option<f64> {
        self.started.map(|s| s.saturating_duration_since(self.submitted).as_secs_f64())
    }

    /// Start → finish wall time, once the job is terminal.
    pub fn run_seconds(&self) -> Option<f64> {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => Some(f.saturating_duration_since(s).as_secs_f64()),
            _ => None,
        }
    }
}

/// What a completed fit reports back.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// `hit` | `warm` | `cold` (see [`FitKind`]).
    pub kind: FitKind,
    pub seconds: f64,
    pub total_epochs: usize,
    pub n_lambdas: usize,
    pub converged: bool,
}

struct QueueState {
    queue: VecDeque<u64>,
    jobs: HashMap<u64, JobRecord>,
    /// Terminal job ids in completion order (drives [`MAX_FINISHED`]).
    finished: VecDeque<u64>,
    next_id: u64,
    shutdown: bool,
}

impl QueueState {
    /// Record `id` as terminal and prune the oldest finished records
    /// beyond the retention bound.
    fn mark_finished(&mut self, id: u64) {
        self.finished.push_back(id);
        while self.finished.len() > MAX_FINISHED {
            if let Some(old) = self.finished.pop_front() {
                self.jobs.remove(&old);
            }
        }
    }
}

struct Inner {
    state: Mutex<QueueState>,
    /// Signals workers (new job / shutdown) and pollers (job finished).
    cv: Condvar,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
}

/// The background fit queue (see module docs).
pub struct JobQueue {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl JobQueue {
    /// Start `workers` fit workers draining into `registry`.
    pub fn start(registry: Arc<Registry>, metrics: Arc<Metrics>, workers: usize) -> JobQueue {
        let inner = Arc::new(Inner {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                finished: VecDeque::new(),
                next_id: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            registry,
            metrics,
        });
        let workers = (0..workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// Enqueue a fit; returns the job id immediately.
    pub fn submit(&self, key: ModelKey) -> u64 {
        let mut st = lock_ok(&self.inner.state);
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            JobRecord {
                id,
                key,
                state: JobState::Queued,
                outcome: None,
                submitted: Instant::now(),
                started: None,
                finished: None,
            },
        );
        st.queue.push_back(id);
        self.inner.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.cv.notify_all();
        id
    }

    /// Snapshot a job.
    pub fn status(&self, id: u64) -> Option<JobRecord> {
        lock_ok(&self.inner.state).jobs.get(&id).cloned()
    }

    /// Block until the job reaches a terminal state (or `timeout`
    /// elapses); returns the final snapshot.
    pub fn wait(&self, id: u64, timeout: Duration) -> Option<JobRecord> {
        let deadline = Instant::now() + timeout;
        let mut st = lock_ok(&self.inner.state);
        loop {
            match st.jobs.get(&id) {
                None => return None,
                Some(rec) if matches!(rec.state, JobState::Done | JobState::Failed(_)) => {
                    return Some(rec.clone());
                }
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return st.jobs.get(&id).cloned();
            }
            let (guard, _res) = wait_timeout_ok(&self.inner.cv, st, deadline - now);
            st = guard;
        }
    }

    /// Jobs waiting to start (the `/metrics` queue-depth gauge).
    pub fn depth(&self) -> usize {
        lock_ok(&self.inner.state).queue.len()
    }

    /// Jobs currently executing on a worker (the `jobs_running` gauge).
    /// A scan over the (retention-bounded) job table — cheap enough for a
    /// metrics poll.
    pub fn running(&self) -> usize {
        lock_ok(&self.inner.state)
            .jobs
            .values()
            .filter(|r| r.state == JobState::Running)
            .count()
    }

    /// Stop accepting work and join the workers (in-flight jobs finish).
    pub fn shutdown(&mut self) {
        {
            let mut st = lock_ok(&self.inner.state);
            st.shutdown = true;
        }
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        // Pull the next job (or exit on shutdown with an empty queue).
        let (id, key) = {
            let mut st = lock_ok(&inner.state);
            loop {
                if let Some(id) = st.queue.pop_front() {
                    // Queued jobs are never pruned (only finished ones),
                    // so the record is present; skip defensively if not.
                    if let Some(rec) = st.jobs.get_mut(&id) {
                        rec.state = JobState::Running;
                        rec.started = Some(Instant::now());
                        break (id, rec.key.clone());
                    }
                    continue;
                }
                if st.shutdown {
                    return;
                }
                st = wait_ok(&inner.cv, st);
            }
        };
        // Solve without holding the queue lock.
        let result = inner.registry.fit(&key);
        let mut st = lock_ok(&inner.state);
        if let Some(rec) = st.jobs.get_mut(&id) {
            rec.finished = Some(Instant::now());
            let ok = result.is_ok();
            match result {
                Ok((model, kind)) => {
                    rec.state = JobState::Done;
                    rec.outcome = Some(JobOutcome {
                        kind,
                        seconds: model.fit_seconds,
                        total_epochs: model.total_epochs,
                        n_lambdas: model.path.points.len(),
                        converged: model.path.points.iter().all(|p| p.converged),
                    });
                    inner.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    rec.state = JobState::Failed(e);
                    inner.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                }
            }
            let queue_secs = rec.queue_seconds().unwrap_or(0.0);
            let run_secs = rec.run_seconds().unwrap_or(0.0);
            inner.metrics.job_queue_wait.record(queue_secs);
            inner.metrics.job_run.record(run_secs);
            if obs::enabled() {
                obs::emit(&obs::Event::Job { id, queue_secs, run_secs, ok });
            }
            st.mark_finished(id);
        }
        drop(st);
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue(workers: usize) -> JobQueue {
        let metrics = Arc::new(Metrics::default());
        let registry = Arc::new(Registry::new(64, metrics.clone()));
        JobQueue::start(registry, metrics, workers)
    }

    fn small_key(delta: f64) -> ModelKey {
        ModelKey::new("synth:reg:16x24", "lasso", 7, false, 4, delta, 1e-4, 2000)
    }

    #[test]
    fn submit_poll_fetch_lifecycle() {
        let q = queue(2);
        let id = q.submit(small_key(1.5));
        let rec = q.wait(id, Duration::from_secs(60)).expect("job exists");
        assert_eq!(rec.state, JobState::Done, "job did not finish: {rec:?}");
        // queue-wait and run durations are stamped on the way through
        assert!(rec.queue_seconds().is_some(), "started timestamp missing");
        assert!(rec.run_seconds().is_some(), "finished timestamp missing");
        let out = rec.outcome.expect("outcome recorded");
        assert_eq!(out.n_lambdas, 4);
        assert!(out.converged);
        // second submit of the same key is a cache hit
        let id2 = q.submit(small_key(1.5));
        let rec2 = q.wait(id2, Duration::from_secs(60)).unwrap();
        assert_eq!(rec2.outcome.unwrap().kind, FitKind::Hit);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn failed_jobs_report_failure() {
        let q = queue(1);
        let id = q.submit(ModelKey::new("no:such", "lasso", 0, false, 3, 1.0, 1e-4, 100));
        let rec = q.wait(id, Duration::from_secs(30)).unwrap();
        assert!(matches!(rec.state, JobState::Failed(_)), "{rec:?}");
    }

    #[test]
    fn finished_retention_prunes_old_records() {
        let mut st = QueueState {
            queue: VecDeque::new(),
            jobs: HashMap::new(),
            finished: VecDeque::new(),
            next_id: 0,
            shutdown: false,
        };
        for id in 0..(MAX_FINISHED as u64 + 10) {
            st.jobs.insert(
                id,
                JobRecord {
                    id,
                    key: small_key(1.0),
                    state: JobState::Done,
                    outcome: None,
                    submitted: Instant::now(),
                    started: None,
                    finished: None,
                },
            );
            st.mark_finished(id);
        }
        assert_eq!(st.finished.len(), MAX_FINISHED);
        assert_eq!(st.jobs.len(), MAX_FINISHED);
        assert!(!st.jobs.contains_key(&0), "oldest record must be pruned");
        assert!(st.jobs.contains_key(&(MAX_FINISHED as u64 + 9)));
    }

    #[test]
    fn unknown_job_is_none() {
        let q = queue(1);
        assert!(q.status(999).is_none());
        assert!(q.wait(999, Duration::from_millis(10)).is_none());
    }
}
