//! Model-serving subsystem: a resident HTTP server over the solver stack.
//!
//! `gapsafe serve` turns the one-shot CLI into a long-lived service so
//! fitted paths persist between requests — the prerequisite for the
//! warm-start reuse that Gap Safe screening makes so effective (see
//! [`registry`]). Everything is std-only, like the rest of the crate.
//!
//! ```text
//!            ┌────────────────────────────────────────────────┐
//! clients →  │ http  bounded accept/worker pool (HTTP/1.1)    │
//!            ├────────────────────────────────────────────────┤
//!            │ router  /healthz /metrics /v1/fit /v1/jobs/{id}│
//!            │         /v1/predict                            │
//!            ├───────────────┬────────────────────────────────┤
//!            │ jobs          │ registry                       │
//!            │ background    │ ModelKey → fitted PathResult,  │
//!            │ fit queue     │ single-flight, LRU-bounded     │
//!            │ (submit/poll/ │ warm-start cache seeding       │
//!            │  fetch)       │ solve_fixed_lambda_with        │
//!            └───────────────┴────────────────────────────────┘
//! ```
//!
//! # Endpoints (JSON in, JSON out)
//!
//! | endpoint | method | purpose |
//! |---|---|---|
//! | `/healthz` | GET | liveness + uptime |
//! | `/metrics` | GET | request counts, cache hit rate, queue depth, epochs saved |
//! | `/v1/fit` | POST | submit a fit job (`{"wait":true}` blocks until done) |
//! | `/v1/jobs/{id}` | GET | poll a job |
//! | `/v1/predict` | POST | fitted values `X beta_t` for a registered model |
//!
//! `docs/SERVING.md` has the full request/response reference and a curl
//! walkthrough; `rust/tests/serve.rs` drives all of it over a real TCP
//! socket.

pub mod http;
pub mod jobs;
pub mod registry;

use crate::obs;
use crate::obs::metrics::LogHistogram;
use crate::screening::DualStrategy;
use crate::solver::parallel::effective_threads;
use crate::util::json::Json;
use http::{Request, Response};
use jobs::{JobQueue, JobRecord, JobState};
use registry::{FitKind, ModelKey, Registry};

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// Poison-recovering lock/wait helpers now live in `util::sync` so the
// parallel solver pool and trace sinks share them; re-exported here to
// keep the historical `serve::lock_ok` paths working. A resident server
// must not let one panicked worker turn every later request into a
// `lock().unwrap()` panic (the serve-no-panic audit lint forbids that).
pub(crate) use crate::util::sync::{lock_ok, wait_ok, wait_timeout_ok};

/// How long `/v1/fit` with `"wait": true` may park an HTTP worker before
/// handing the client back a still-running (202) job snapshot to poll.
/// Kept short on purpose: each waiting request occupies one accept-pool
/// worker, and the background queue exists precisely so fits don't hold
/// HTTP threads hostage.
const WAIT_FIT_TIMEOUT: Duration = Duration::from_secs(60);

/// Serving counters (all monotone; `/metrics` adds the gauges) plus
/// lock-free latency histograms (see [`LogHistogram`]): recording is a
/// handful of relaxed atomic adds, so it stays on even without a trace
/// sink — quantiles must be there *before* anyone turns tracing on.
///
/// Ordering: every counter here is read and written with `Relaxed`.
/// The counters are independent monotone statistics — nothing ever
/// branches on cross-counter consistency, and `/metrics` readers are
/// content with any valid interleaving of concurrent increments, so no
/// happens-before edge (Acquire/Release) is required or implied.
#[derive(Debug, Default)]
pub struct Metrics {
    pub http_requests: AtomicU64,
    pub http_errors: AtomicU64,
    pub fit_requests: AtomicU64,
    pub predict_requests: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub warm_hits: AtomicU64,
    pub cold_fits: AtomicU64,
    pub evictions: AtomicU64,
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub epochs_total: AtomicU64,
    pub epochs_saved: AtomicU64,
    /// End-to-end router latency, all endpoints together.
    pub lat_all: LogHistogram,
    /// Router latency per endpoint family (the `/metrics` exposition
    /// labels them `endpoint="fit"` etc.).
    pub lat_fit: LogHistogram,
    pub lat_predict: LogHistogram,
    pub lat_jobs: LogHistogram,
    pub lat_health: LogHistogram,
    pub lat_metrics: LogHistogram,
    pub lat_other: LogHistogram,
    /// Wall time of registry fits actually solved (hits excluded).
    pub fit_duration: LogHistogram,
    /// Wall time of successful predict bodies.
    pub predict_duration: LogHistogram,
    /// Background jobs: submit → start delay, and start → finish run.
    pub job_queue_wait: LogHistogram,
    pub job_run: LogHistogram,
}

impl Metrics {
    /// The per-endpoint latency histogram for a label from
    /// [`endpoint_label`].
    pub fn latency_for(&self, endpoint: &str) -> &LogHistogram {
        match endpoint {
            "fit" => &self.lat_fit,
            "predict" => &self.lat_predict,
            "jobs" => &self.lat_jobs,
            "healthz" => &self.lat_health,
            "metrics" => &self.lat_metrics,
            _ => &self.lat_other,
        }
    }
}

/// Endpoint family of a request — the `endpoint` label on latency series
/// and request trace events (unknown paths collapse into "other" so a URL
/// scanner cannot mint unbounded label values).
fn endpoint_label(req: &Request) -> &'static str {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => "healthz",
        ("GET", "/metrics") => "metrics",
        ("POST", "/v1/fit") => "fit",
        ("POST", "/v1/predict") => "predict",
        ("GET", p) if p.starts_with("/v1/jobs/") => "jobs",
        _ => "other",
    }
}

/// Server configuration (`gapsafe serve --port/--threads/--cache-mb`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port — tests).
    pub addr: String,
    /// HTTP accept/worker pool size (0 = all cores).
    pub http_threads: usize,
    /// Background fit workers (0 = all cores).
    pub fit_workers: usize,
    /// Registry byte budget in MiB.
    pub cache_mb: usize,
    /// Active-set compaction for registry fits (`--no-compact` turns it
    /// off; bitwise-transparent either way — see `linalg::compact`).
    pub compact: bool,
    /// Dual-point strategy for registry fits (`--dual`, default `best`;
    /// see [`crate::screening::dual`]).
    pub dual: DualStrategy,
    /// Max accepted request-body size in MiB (`--max-body-mb`): a
    /// client-declared `Content-Length` above this is answered with
    /// `413 Payload Too Large` before any body byte is buffered, so one
    /// request cannot size an allocation on the resident server.
    pub max_body_mb: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            http_threads: 0,
            fit_workers: 0,
            cache_mb: 256,
            compact: true,
            dual: DualStrategy::default(),
            max_body_mb: 16,
        }
    }
}

/// Shared state behind the router.
pub struct ServerState {
    pub registry: Arc<Registry>,
    pub jobs: JobQueue,
    pub metrics: Arc<Metrics>,
    started: Instant,
}

/// A bound, ready-to-run server.
pub struct Server {
    listener: TcpListener,
    state: ServerState,
    stop: Arc<AtomicBool>,
    http_threads: usize,
    max_body: usize,
}

impl Server {
    /// Bind the listener and start the fit workers (no requests are
    /// served until [`Server::run`]).
    pub fn bind(cfg: &ServeConfig) -> Result<Server, String> {
        if cfg.max_body_mb == 0 {
            // Reject loudly instead of silently reinterpreting — the same
            // contract the CLI enforces for --max-body-mb and --threads 0.
            return Err("max_body_mb must be >= 1 (a 0-byte body cap rejects every POST)".into());
        }
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let metrics = Arc::new(Metrics::default());
        let registry = Arc::new(
            Registry::new(cfg.cache_mb, metrics.clone())
                .with_compact(cfg.compact)
                .with_dual(cfg.dual),
        );
        let jobs = JobQueue::start(
            registry.clone(),
            metrics.clone(),
            effective_threads(cfg.fit_workers),
        );
        Ok(Server {
            listener,
            state: ServerState { registry, jobs, metrics, started: Instant::now() },
            stop: Arc::new(AtomicBool::new(false)),
            http_threads: effective_threads(cfg.http_threads),
            max_body: cfg.max_body_mb.saturating_mul(1024 * 1024),
        })
    }

    /// The bound port (useful with an ephemeral bind).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// Flag that makes [`Server::run`] return (set from another thread).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until the stop flag is set. Blocks the calling thread; the
    /// accept/worker pool runs on scoped threads underneath.
    pub fn run(&self) -> Result<(), String> {
        http::serve(&self.listener, self.http_threads, &self.stop, self.max_body, |req| {
            route(&self.state, req)
        })
        .map_err(|e| format!("serve: {e}"))
    }
}

/// Dispatch one request (public so tests can drive the router without a
/// socket).
pub fn route(state: &ServerState, req: &Request) -> Response {
    state.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(state),
        ("GET", "/metrics") => handle_metrics(state, req),
        ("POST", "/v1/fit") => handle_fit(state, req),
        ("POST", "/v1/predict") => handle_predict(state, req),
        ("GET", p) if p.starts_with("/v1/jobs/") => handle_job(state, p),
        ("GET", _) | ("POST", _) => Response::error(404, "no such endpoint"),
        _ => Response::error(405, "method not allowed"),
    };
    if resp.status >= 400 {
        state.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
    }
    let secs = t0.elapsed().as_secs_f64();
    let endpoint = endpoint_label(req);
    state.metrics.lat_all.record(secs);
    state.metrics.latency_for(endpoint).record(secs);
    if obs::enabled() {
        obs::emit(&obs::Event::Request { endpoint, status: resp.status, secs });
    }
    resp
}

/// Parse a JSON body; an empty body reads as `{}` so GET-style POSTs work.
fn parse_body(req: &Request) -> Result<Json, Response> {
    let s = req.body_str().map_err(|e| Response::error(400, &e))?;
    if s.trim().is_empty() {
        return Ok(Json::Obj(Default::default()));
    }
    Json::parse(s).map_err(|e| Response::error(400, &format!("bad JSON body: {e}")))
}

fn handle_healthz(state: &ServerState) -> Response {
    Response::json(
        200,
        &Json::obj([
            ("ok", Json::Bool(true)),
            ("uptime_seconds", Json::Num(state.started.elapsed().as_secs_f64())),
            // Which SIMD kernel backend this process solves with (bitwise
            // identical across backends — purely an ops/perf signal).
            (
                "kernel_backend",
                Json::Str(crate::linalg::kernels::active_kind().label().to_string()),
            ),
        ]),
    )
}

fn handle_fit(state: &ServerState, req: &Request) -> Response {
    state.metrics.fit_requests.fetch_add(1, Ordering::Relaxed);
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let key = match ModelKey::from_json(&body) {
        Ok(k) => k,
        Err(e) => return Response::error(400, &e),
    };
    let wait = body.get("wait").and_then(Json::as_bool).unwrap_or(false);
    let id = state.jobs.submit(key.clone());
    if wait {
        match state.jobs.wait(id, WAIT_FIT_TIMEOUT) {
            Some(rec) => job_response(&rec),
            None => Response::error(500, "job record vanished"),
        }
    } else {
        Response::json(
            202,
            &Json::obj([
                ("job_id", Json::Num(id as f64)),
                ("key", Json::Str(key.canonical())),
                ("state", Json::Str("queued".to_string())),
            ]),
        )
    }
}

fn handle_job(state: &ServerState, path: &str) -> Response {
    let id_str = &path["/v1/jobs/".len()..];
    let Ok(id) = id_str.parse::<u64>() else {
        return Response::error(400, "job id must be an integer");
    };
    match state.jobs.status(id) {
        Some(rec) => job_response(&rec),
        None => Response::error(404, "no such job"),
    }
}

/// Render a job snapshot: 200 once done, 500 on failure, 202 while the
/// job is still queued/running (e.g. a `wait:true` fit that outlived
/// [`WAIT_FIT_TIMEOUT`] — the client keeps polling `/v1/jobs/{id}`).
fn job_response(rec: &JobRecord) -> Response {
    let mut pairs: Vec<(String, Json)> = vec![
        ("id".to_string(), Json::Num(rec.id as f64)),
        ("key".to_string(), Json::Str(rec.key.canonical())),
        ("state".to_string(), Json::Str(rec.state.label().to_string())),
    ];
    if let JobState::Failed(e) = &rec.state {
        pairs.push(("error".to_string(), Json::Str(e.clone())));
    }
    if let Some(q) = rec.queue_seconds() {
        pairs.push(("queue_seconds".to_string(), Json::Num(q)));
    }
    if let Some(r) = rec.run_seconds() {
        pairs.push(("run_seconds".to_string(), Json::Num(r)));
    }
    if let Some(out) = &rec.outcome {
        pairs.push(("fit".to_string(), Json::Str(out.kind.label().to_string())));
        pairs.push(("warm".to_string(), Json::Bool(out.kind == FitKind::Warm)));
        pairs.push(("seconds".to_string(), Json::Num(out.seconds)));
        pairs.push(("epochs".to_string(), Json::Num(out.total_epochs as f64)));
        pairs.push(("n_lambdas".to_string(), Json::Num(out.n_lambdas as f64)));
        pairs.push(("converged".to_string(), Json::Bool(out.converged)));
    }
    let status = match rec.state {
        JobState::Failed(_) => 500,
        JobState::Done => 200,
        JobState::Queued | JobState::Running => 202,
    };
    Response::json(status, &Json::obj(pairs))
}

fn handle_predict(state: &ServerState, req: &Request) -> Response {
    state.metrics.predict_requests.fetch_add(1, Ordering::Relaxed);
    let body = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    // Resolve the artifact: canonical "key", "job_id", or the same
    // parameters a fit request carries.
    let model = if let Some(k) = body.get("key").and_then(Json::as_str) {
        state.registry.get(k)
    } else if let Some(id) = body.get("job_id").and_then(Json::as_usize) {
        state
            .jobs
            .status(id as u64)
            .and_then(|rec| state.registry.get(&rec.key.canonical()))
    } else {
        match ModelKey::from_json(&body) {
            Ok(k) => state.registry.get(&k.canonical()),
            Err(e) => return Response::error(400, &e),
        }
    };
    let Some(model) = model else {
        return Response::error(404, "model not fitted (POST /v1/fit first)");
    };
    let t0 = Instant::now();
    let n_betas = model.path.betas.len();
    let t = match body.get("t") {
        None => n_betas.saturating_sub(1),
        Some(j) => match j.as_usize() {
            Some(t) => t,
            None => return Response::error(400, "t must be a non-negative integer"),
        },
    };
    if t >= n_betas {
        return Response::error(400, &format!("t out of range (path has {n_betas} lambdas)"));
    }
    let beta = &model.path.betas[t];
    let z = model.prob.predict(beta);
    let (n, q, p) = (z.rows(), z.cols(), beta.rows());
    // Flat row-major arrays; Json::Num round-trips f64 bitwise.
    let mut z_flat = Vec::with_capacity(n * q);
    for i in 0..n {
        for k in 0..q {
            z_flat.push(z[(i, k)]);
        }
    }
    let mut pairs: Vec<(String, Json)> = vec![
        ("key".to_string(), Json::Str(model.key.canonical())),
        ("t".to_string(), Json::Num(t as f64)),
        ("lam".to_string(), Json::Num(model.path.lambdas[t])),
        ("n".to_string(), Json::Num(n as f64)),
        ("q".to_string(), Json::Num(q as f64)),
        ("p".to_string(), Json::Num(p as f64)),
        ("z".to_string(), Json::arr_f64(&z_flat)),
    ];
    if body.get("beta").and_then(Json::as_bool).unwrap_or(false) {
        let mut b_flat = Vec::with_capacity(p * q);
        for j in 0..p {
            for k in 0..q {
                b_flat.push(beta[(j, k)]);
            }
        }
        pairs.push(("beta".to_string(), Json::arr_f64(&b_flat)));
    }
    let secs = t0.elapsed().as_secs_f64();
    state.metrics.predict_duration.record(secs);
    if obs::enabled() {
        obs::emit(&obs::Event::Predict { key: model.key.canonical(), t, secs });
    }
    Response::json(200, &Json::obj(pairs))
}

/// `GET /metrics` content negotiation: JSON by default, Prometheus text
/// exposition when the client asks via `?format=prometheus` or an
/// `Accept` header naming `text/plain` / `openmetrics`.
fn wants_prometheus(req: &Request) -> bool {
    if req.query_param("format") == Some("prometheus") {
        return true;
    }
    req.header("accept")
        .map(|a| a.contains("text/plain") || a.contains("openmetrics"))
        .unwrap_or(false)
}

fn handle_metrics(state: &ServerState, req: &Request) -> Response {
    if wants_prometheus(req) {
        return Response {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: render_prometheus(state),
        };
    }
    let m = &state.metrics;
    let reg = state.registry.stats();
    let load = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
    let hits = m.cache_hits.load(Ordering::Relaxed) as f64;
    let misses = m.cache_misses.load(Ordering::Relaxed) as f64;
    let hit_rate = if hits + misses > 0.0 { hits / (hits + misses) } else { 0.0 };
    let mut pairs: Vec<(String, Json)> = vec![
        ("uptime_seconds".into(), Json::Num(state.started.elapsed().as_secs_f64())),
        (
            "kernel_backend".into(),
            Json::Str(crate::linalg::kernels::active_kind().label().to_string()),
        ),
        ("http_requests".into(), load(&m.http_requests)),
        ("http_errors".into(), load(&m.http_errors)),
        ("fit_requests".into(), load(&m.fit_requests)),
        ("predict_requests".into(), load(&m.predict_requests)),
        ("cache_hits".into(), load(&m.cache_hits)),
        ("cache_misses".into(), load(&m.cache_misses)),
        ("cache_hit_rate".into(), Json::Num(hit_rate)),
        ("warm_hits".into(), load(&m.warm_hits)),
        ("cold_fits".into(), load(&m.cold_fits)),
        ("evictions".into(), load(&m.evictions)),
        ("jobs_submitted".into(), load(&m.jobs_submitted)),
        ("jobs_completed".into(), load(&m.jobs_completed)),
        ("jobs_failed".into(), load(&m.jobs_failed)),
        ("queue_depth".into(), Json::Num(state.jobs.depth() as f64)),
        ("jobs_running".into(), Json::Num(state.jobs.running() as f64)),
        ("epochs_total".into(), load(&m.epochs_total)),
        ("epochs_saved".into(), load(&m.epochs_saved)),
        ("registry_models".into(), Json::Num(reg.models as f64)),
        ("registry_pending".into(), Json::Num(reg.pending as f64)),
        ("registry_bytes".into(), Json::Num(reg.bytes as f64)),
        ("registry_cap_bytes".into(), Json::Num(reg.cap_bytes as f64)),
        // Screening provenance ledger (obs::ledger): process-wide columns
        // screened per rule and the overall screened fraction — how much
        // work Gap Safe spheres saved across every fit this server ran.
        ("screened_fraction".into(), Json::Num(crate::obs::ledger::screened_fraction())),
        (
            "screened_columns".into(),
            Json::obj(
                crate::obs::ledger::screened_by_rule()
                    .into_iter()
                    .map(|(rule, v)| (rule.to_string(), Json::Num(v as f64)))
                    .collect::<Vec<_>>(),
            ),
        ),
    ];
    // Latency quantiles: derived from the same histograms the Prometheus
    // view exposes raw, so `p50 <= p99 <= p999` holds structurally.
    for (prefix, h) in [
        ("request_seconds", &m.lat_all),
        ("fit_seconds", &m.fit_duration),
        ("predict_seconds", &m.predict_duration),
        ("job_queue_seconds", &m.job_queue_wait),
        ("job_run_seconds", &m.job_run),
    ] {
        pairs.push((format!("{prefix}_count"), Json::Num(h.count() as f64)));
        pairs.push((format!("{prefix}_p50"), Json::Num(h.quantile(0.50))));
        pairs.push((format!("{prefix}_p99"), Json::Num(h.quantile(0.99))));
        pairs.push((format!("{prefix}_p999"), Json::Num(h.quantile(0.999))));
    }
    Response::json(200, &Json::obj(pairs))
}

/// Render every counter, gauge and histogram in Prometheus text
/// exposition format (version 0.0.4): `# TYPE` per metric name, label
/// values only from fixed internal sets (endpoint families, backend
/// labels), histograms as cumulative `le` ladders.
fn render_prometheus(state: &ServerState) -> String {
    use std::fmt::Write;
    let m = &state.metrics;
    let reg = state.registry.stats();
    let mut out = String::with_capacity(8 * 1024);
    let counter = |out: &mut String, name: &str, v: u64| {
        let _ = writeln!(out, "# TYPE {name} counter\n{name} {v}");
    };
    let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
    counter(&mut out, "gapsafe_http_requests_total", c(&m.http_requests));
    counter(&mut out, "gapsafe_http_errors_total", c(&m.http_errors));
    counter(&mut out, "gapsafe_fit_requests_total", c(&m.fit_requests));
    counter(&mut out, "gapsafe_predict_requests_total", c(&m.predict_requests));
    counter(&mut out, "gapsafe_cache_hits_total", c(&m.cache_hits));
    counter(&mut out, "gapsafe_cache_misses_total", c(&m.cache_misses));
    counter(&mut out, "gapsafe_warm_hits_total", c(&m.warm_hits));
    counter(&mut out, "gapsafe_cold_fits_total", c(&m.cold_fits));
    counter(&mut out, "gapsafe_evictions_total", c(&m.evictions));
    counter(&mut out, "gapsafe_jobs_submitted_total", c(&m.jobs_submitted));
    counter(&mut out, "gapsafe_jobs_completed_total", c(&m.jobs_completed));
    counter(&mut out, "gapsafe_jobs_failed_total", c(&m.jobs_failed));
    counter(&mut out, "gapsafe_solver_epochs_total", c(&m.epochs_total));
    counter(&mut out, "gapsafe_solver_epochs_saved_total", c(&m.epochs_saved));
    // Screening ledger: one counter family, fixed rule label set.
    let _ = writeln!(out, "# TYPE gapsafe_screened_columns_total counter");
    for (rule, v) in crate::obs::ledger::screened_by_rule() {
        let _ = writeln!(out, "gapsafe_screened_columns_total{{rule=\"{rule}\"}} {v}");
    }
    let gauge = |out: &mut String, name: &str, v: f64| {
        let _ = writeln!(out, "# TYPE {name} gauge\n{name} {v}");
    };
    gauge(&mut out, "gapsafe_uptime_seconds", state.started.elapsed().as_secs_f64());
    gauge(&mut out, "gapsafe_jobs_queued", state.jobs.depth() as f64);
    gauge(&mut out, "gapsafe_jobs_running", state.jobs.running() as f64);
    gauge(&mut out, "gapsafe_registry_models", reg.models as f64);
    gauge(&mut out, "gapsafe_registry_pending", reg.pending as f64);
    gauge(&mut out, "gapsafe_registry_bytes", reg.bytes as f64);
    gauge(&mut out, "gapsafe_registry_cap_bytes", reg.cap_bytes as f64);
    gauge(&mut out, "gapsafe_screened_fraction", crate::obs::ledger::screened_fraction());
    let _ = writeln!(
        out,
        "# TYPE gapsafe_kernel_backend gauge\ngapsafe_kernel_backend{{backend=\"{}\"}} 1",
        crate::linalg::kernels::active_kind().label()
    );
    // Per-endpoint request latency: one metric name, endpoint label.
    for (i, (label, h)) in [
        ("fit", &m.lat_fit),
        ("predict", &m.lat_predict),
        ("jobs", &m.lat_jobs),
        ("healthz", &m.lat_health),
        ("metrics", &m.lat_metrics),
        ("other", &m.lat_other),
    ]
    .iter()
    .enumerate()
    {
        h.render_prometheus(
            &mut out,
            "gapsafe_request_duration_seconds",
            &format!("endpoint=\"{label}\""),
            i == 0,
        );
    }
    m.fit_duration.render_prometheus(&mut out, "gapsafe_fit_duration_seconds", "", true);
    m.predict_duration.render_prometheus(&mut out, "gapsafe_predict_duration_seconds", "", true);
    m.job_queue_wait.render_prometheus(&mut out, "gapsafe_job_queue_seconds", "", true);
    m.job_run.render_prometheus(&mut out, "gapsafe_job_run_seconds", "", true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ServerState {
        let metrics = Arc::new(Metrics::default());
        let registry = Arc::new(Registry::new(64, metrics.clone()));
        let jobs = JobQueue::start(registry.clone(), metrics.clone(), 2);
        ServerState { registry, jobs, metrics, started: Instant::now() }
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: String::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query: String::new(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    #[test]
    fn router_health_metrics_and_404() {
        let st = state();
        // Both ops endpoints surface the active kernel backend by name.
        let want_backend = crate::linalg::kernels::active_kind().label();
        for path in ["/healthz", "/metrics"] {
            let resp = route(&st, &get(path));
            assert_eq!(resp.status, 200);
            let v = Json::parse(&resp.body).unwrap();
            assert_eq!(
                v.get("kernel_backend").and_then(Json::as_str),
                Some(want_backend),
                "{path} missing kernel_backend"
            );
        }
        assert_eq!(route(&st, &get("/nope")).status, 404);
        let del = Request {
            method: "DELETE".to_string(),
            path: "/healthz".to_string(),
            query: String::new(),
            headers: Vec::new(),
            body: Vec::new(),
        };
        assert_eq!(route(&st, &del).status, 405);
        assert!(st.metrics.http_errors.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn metrics_negotiates_prometheus_exposition() {
        let st = state();
        // warm the histograms with a couple of routed requests
        assert_eq!(route(&st, &get("/healthz")).status, 200);
        assert_eq!(route(&st, &get("/metrics")).status, 200);
        // query-string negotiation
        let mut prom = get("/metrics");
        prom.query = "format=prometheus".to_string();
        let resp = route(&st, &prom);
        assert_eq!(resp.status, 200);
        assert!(resp.content_type.starts_with("text/plain"), "{}", resp.content_type);
        assert!(resp.body.contains("# TYPE gapsafe_http_requests_total counter"));
        assert!(resp.body.contains("# TYPE gapsafe_request_duration_seconds histogram"));
        assert!(resp
            .body
            .contains("gapsafe_request_duration_seconds_bucket{endpoint=\"healthz\",le=\"+Inf\"}"));
        assert!(resp.body.contains("gapsafe_jobs_running "));
        // the TYPE header for the labeled histogram appears exactly once
        let types = resp
            .body
            .matches("# TYPE gapsafe_request_duration_seconds histogram")
            .count();
        assert_eq!(types, 1);
        // Accept-header negotiation
        let mut acc = get("/metrics");
        acc.headers.push(("accept".to_string(), "text/plain".to_string()));
        assert!(route(&st, &acc).body.starts_with("# TYPE "));
        // default stays JSON, now with structurally ordered quantiles
        let v = Json::parse(&route(&st, &get("/metrics")).body).unwrap();
        let q = |k: &str| v.get(k).and_then(Json::as_f64).unwrap();
        assert!(q("request_seconds_p50") <= q("request_seconds_p99"));
        assert!(q("request_seconds_p99") <= q("request_seconds_p999"));
        assert!(v.get("jobs_running").is_some());
    }

    #[test]
    fn fit_wait_then_predict_through_router() {
        let st = state();
        let fit = post(
            "/v1/fit",
            r#"{"data":"synth:reg:16x24","task":"lasso","grid":4,"delta":1.5,
               "eps":1e-4,"seed":7,"wait":true}"#,
        );
        let resp = route(&st, &fit);
        assert_eq!(resp.status, 200, "{}", resp.body);
        let v = Json::parse(&resp.body).unwrap();
        assert_eq!(v.get("state").and_then(Json::as_str), Some("done"));
        let pred = post(
            "/v1/predict",
            r#"{"data":"synth:reg:16x24","task":"lasso","grid":4,"delta":1.5,
               "eps":1e-4,"seed":7,"t":3,"beta":true}"#,
        );
        let presp = route(&st, &pred);
        assert_eq!(presp.status, 200, "{}", presp.body);
        let pv = Json::parse(&presp.body).unwrap();
        assert_eq!(pv.get("n").and_then(Json::as_usize), Some(16));
        assert_eq!(pv.get("z").unwrap().as_arr().unwrap().len(), 16);
        assert_eq!(pv.get("beta").unwrap().as_arr().unwrap().len(), 24);
    }

    #[test]
    fn predict_before_fit_is_404_and_bad_fit_is_400() {
        let st = state();
        assert_eq!(route(&st, &post("/v1/predict", r#"{"data":"synth:reg:8x8"}"#)).status, 404);
        assert_eq!(route(&st, &post("/v1/fit", "{not json")).status, 400);
        assert_eq!(route(&st, &post("/v1/fit", r#"{"task":"nope"}"#)).status, 400);
        assert_eq!(route(&st, &get("/v1/jobs/abc")).status, 400);
        assert_eq!(route(&st, &get("/v1/jobs/99")).status, 404);
    }
}
