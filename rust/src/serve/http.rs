//! Minimal HTTP/1.1 front end on `std::net` — no hyper, no tokio.
//!
//! The server is a bounded accept/worker pool: `threads` scoped workers
//! ([`crate::solver::parallel::run_workers`]) share one non-blocking
//! [`TcpListener`]; each worker accepts a connection, parses one request,
//! hands it to the router and writes the response (`Connection: close`
//! framing — one request per connection keeps the parser and the clients
//! trivial; curl and the test harness both reconnect per call).
//!
//! Resource bounds, so a misbehaving client cannot wedge a worker:
//! header block ≤ 64 KiB, body ≤ a configurable cap (default 16 MiB,
//! `serve --max-body-mb`; an oversized `Content-Length` is answered with
//! `413 Payload Too Large` before a single body byte is buffered), 10 s
//! per-read timeouts, and a 20 s whole-request deadline (slow-loris
//! trickle included).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// Max bytes of request head (request line + headers).
const MAX_HEAD: usize = 64 * 1024;
/// Default max request body bytes (`ServeConfig::max_body_mb` overrides).
pub const DEFAULT_MAX_BODY: usize = 16 * 1024 * 1024;
/// Per-read socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);
/// Whole-request deadline: a client trickling one byte per read (slow
/// loris) hits this wall instead of holding a worker for MAX_HEAD reads.
const REQUEST_DEADLINE: Duration = Duration::from_secs(20);
/// Accept-poll sleep bounds while idle (the listener is non-blocking so
/// workers can observe the stop flag): the sleep starts at the minimum
/// after any accepted connection and doubles up to the maximum, so a
/// busy server stays responsive while an idle one barely wakes.
const ACCEPT_POLL_MIN: Duration = Duration::from_millis(2);
const ACCEPT_POLL_MAX: Duration = Duration::from_millis(50);

/// A parsed request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path with the query string stripped.
    pub path: String,
    /// Raw query string (after `?`, empty when absent).
    pub query: String,
    /// Lower-cased header names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Value of a `k=v` query parameter (`k` alone yields an empty value).
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
            (k == name).then_some(v)
        })
    }

    /// Body as UTF-8 (endpoints are JSON).
    pub fn body_str(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|_| "body is not UTF-8".to_string())
    }
}

/// A response about to be written.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response { status, content_type: "application/json", body: format!("{body}\n") }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body: body.to_string() }
    }

    /// A JSON error envelope `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        use crate::util::json::Json;
        Response::json(status, &Json::obj([("error", Json::Str(msg.to_string()))]))
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            413 => "Payload Too Large",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Why a request could not be read: the HTTP status the worker should
/// answer with, plus the human-readable detail for the error envelope.
#[derive(Debug, Clone)]
pub struct ReadError {
    pub status: u16,
    pub msg: String,
}

impl ReadError {
    fn bad(msg: impl Into<String>) -> ReadError {
        ReadError { status: 400, msg: msg.into() }
    }
}

/// Read and parse one request from the stream with the default body cap.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, ReadError> {
    read_request_limited(stream, DEFAULT_MAX_BODY)
}

/// Read and parse one request, rejecting any declared `Content-Length`
/// above `max_body` with a 413 before a single body byte is buffered —
/// the declared length is client-supplied, so it must never size an
/// allocation or a read loop on its own.
pub fn read_request_limited(
    stream: &mut TcpStream,
    max_body: usize,
) -> Result<Request, ReadError> {
    let deadline = std::time::Instant::now() + REQUEST_DEADLINE;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            // 431, not 413: the *header block* is over budget — a client
            // reacting to 413 by shrinking its JSON body would retry
            // forever (RFC 6585 assigns oversized headers their own code).
            return Err(ReadError { status: 431, msg: "request head too large".into() });
        }
        if std::time::Instant::now() > deadline {
            return Err(ReadError::bad("request deadline exceeded"));
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Err(ReadError::bad("connection closed mid-request")),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ReadError::bad(format!("read: {e}"))),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| ReadError::bad("request head is not UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| ReadError::bad("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| ReadError::bad("missing method"))?.to_string();
    let target = parts.next().ok_or_else(|| ReadError::bad("missing path"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) =
            line.split_once(':').ok_or_else(|| ReadError::bad(format!("bad header '{line}'")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().map_err(|_| ReadError::bad("bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if content_length > max_body {
        return Err(ReadError {
            status: 413,
            msg: format!("body of {content_length} bytes exceeds the {max_body}-byte cap"),
        });
    }
    let mut body: Vec<u8> = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        if std::time::Instant::now() > deadline {
            return Err(ReadError::bad("request deadline exceeded"));
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Err(ReadError::bad("connection closed mid-body")),
            Ok(n) => {
                // Never grow past the validated length: a client that
                // streams more than it declared cannot outgrow the cap
                // (the surplus dies with the connection).
                let room = content_length - body.len();
                body.extend_from_slice(&tmp[..n.min(room)]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ReadError::bad(format!("read body: {e}"))),
        }
    }
    body.truncate(content_length);
    Ok(Request { method, path, query, headers, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write a response (Connection: close framing).
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Serve connections until `stop` is set: `threads` workers accept on the
/// shared listener and run `handler` per request, refusing bodies larger
/// than `max_body` bytes with a 413. Returns once every worker has
/// observed the stop flag and exited.
pub fn serve<H>(
    listener: &TcpListener,
    threads: usize,
    stop: &AtomicBool,
    max_body: usize,
    handler: H,
) -> std::io::Result<()>
where
    H: Fn(&Request) -> Response + Sync,
{
    listener.set_nonblocking(true)?;
    crate::solver::parallel::run_workers(threads, |_| {
        let mut idle_sleep = ACCEPT_POLL_MIN;
        loop {
            // Ordering: Relaxed is enough for a one-way latch. The flag
            // carries no payload to synchronize — workers only need to
            // *eventually* observe `true`, and the bounded accept-poll
            // sleep guarantees the load is retried within ACCEPT_POLL_MAX.
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _addr)) => {
                    idle_sleep = ACCEPT_POLL_MIN;
                    handle_connection(stream, max_body, &handler);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(idle_sleep);
                    idle_sleep = (idle_sleep * 2).min(ACCEPT_POLL_MAX);
                }
                Err(_) => std::thread::sleep(idle_sleep),
            }
        }
    });
    Ok(())
}

fn handle_connection<H>(mut stream: TcpStream, max_body: usize, handler: &H)
where
    H: Fn(&Request) -> Response,
{
    // On BSD-derived platforms accepted sockets inherit the listener's
    // O_NONBLOCK flag (Linux accept does not); force blocking mode so the
    // read loop below never sees spurious WouldBlock, then put a ceiling
    // on how long a slow client can hold the worker.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let resp = match read_request_limited(&mut stream, max_body) {
        Ok(req) => handler(&req),
        Err(e) => Response::error(e.status, &e.msg),
    };
    let _ = write_response(&mut stream, &resp);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn roundtrip_limited(raw: &str, max_body: usize) -> Result<Request, ReadError> {
        // Push raw bytes through a real socket pair so read_request sees
        // the same framing a client produces.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let writer = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(raw.as_bytes()).unwrap();
            let _ = c.shutdown(std::net::Shutdown::Write);
        });
        let (mut s, _) = listener.accept().unwrap();
        let req = read_request_limited(&mut s, max_body);
        writer.join().unwrap();
        req
    }

    fn roundtrip(raw: &str) -> Result<Request, ReadError> {
        roundtrip_limited(raw, DEFAULT_MAX_BODY)
    }

    #[test]
    fn parses_request_with_body() {
        let req = roundtrip(
            "POST /v1/fit?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/fit");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.query_param("x"), Some("1"));
        assert_eq!(req.query_param("y"), None);
        assert_eq!(req.header("host"), Some("h"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parses_request_without_body() {
        let req = roundtrip("GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.query.is_empty());
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        let err = roundtrip("not-http\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn oversized_content_length_is_413_without_buffering() {
        // The declared length alone must trigger the refusal: no body
        // bytes are sent at all, yet the parse fails immediately with the
        // payload-too-large status (a streaming client would otherwise
        // hold a worker while it uploads gigabytes to a doomed request).
        let err = roundtrip_limited(
            "POST /v1/fit HTTP/1.1\r\nContent-Length: 1048576\r\n\r\n",
            64 * 1024,
        )
        .unwrap_err();
        assert_eq!(err.status, 413, "{}", err.msg);
        assert!(err.msg.contains("1048576"), "unhelpful message: {}", err.msg);
        // At the cap exactly: accepted (the body below is tiny, the
        // declared length is what is judged).
        let ok = roundtrip_limited("POST /x HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd", 4);
        assert_eq!(ok.unwrap().body, b"abcd");
        let err = roundtrip_limited("POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nabcde", 4)
            .unwrap_err();
        assert_eq!(err.status, 413);
    }

    #[test]
    fn serve_answers_413_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            serve(&listener, 1, &stop2, 1024, |_| Response::text(200, "ok")).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"POST /v1/fit HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap();
        let _ = c.shutdown(std::net::Shutdown::Write);
        let mut out = String::new();
        c.read_to_string(&mut out).unwrap();
        assert!(
            out.starts_with("HTTP/1.1 413 Payload Too Large\r\n"),
            "expected 413 status line, got: {out}"
        );
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn serve_round_trips_over_tcp_and_stops() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            serve(&listener, 2, &stop2, DEFAULT_MAX_BODY, |req| {
                Response::text(200, &format!("echo {}", req.path))
            })
            .unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"GET /ping HTTP/1.1\r\n\r\n").unwrap();
        let mut out = String::new();
        c.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");
        assert!(out.ends_with("echo /ping"), "{out}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }
}
