//! Trace sinks: JSONL file output (the CLI's `--trace-out`) and an
//! in-memory collector for tests.

use super::{Event, Sink};
use crate::util::sync::lock_ok;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::Mutex;

/// Writes one JSON object per line (JSONL) through a buffered writer.
/// Every line is flushed on write: traces exist to survive the run that
/// produced them (a crashed solve with an empty trace file is useless),
/// and the flush only costs anything when tracing is on.
pub struct FileSink {
    out: Mutex<BufWriter<File>>,
}

impl FileSink {
    /// Create (truncate) the trace file.
    pub fn create(path: &str) -> Result<FileSink, String> {
        let f = File::create(path).map_err(|e| format!("cannot create trace file {path}: {e}"))?;
        Ok(FileSink { out: Mutex::new(BufWriter::new(f)) })
    }
}

impl Sink for FileSink {
    fn record(&self, ev: &Event) {
        let line = format!("{}\n", ev.to_json());
        // Poison recovery: a panicked emitter must not silence every
        // later event — the file is line-buffered, so the guarded writer
        // is consistent at any unwind point.
        let mut out = lock_ok(&self.out);
        let _ = out.write_all(line.as_bytes());
        let _ = out.flush();
    }
}

/// Collects events in memory; tests keep a clone of the inner `Arc` so
/// the data stays reachable after the global sink is uninstalled (the
/// global deliberately leaks — see [`super::install`]).
#[derive(Clone, Default)]
pub struct CollectSink {
    pub events: std::sync::Arc<Mutex<Vec<Event>>>,
}

impl CollectSink {
    pub fn new() -> CollectSink {
        CollectSink::default()
    }

    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut lock_ok(&self.events))
    }
}

impl Sink for CollectSink {
    fn record(&self, ev: &Event) {
        lock_ok(&self.events).push(ev.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn file_sink_writes_parseable_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("gapsafe_trace_unit_{}.jsonl", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let sink = FileSink::create(&path_s).unwrap();
        sink.record(&Event::Kkt { lam: 0.5, reactivated: 2, round: 1 });
        sink.record(&Event::PathEnd { n_lambdas: 3, total_epochs: 30, secs: 0.1 });
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("type").and_then(|t| t.as_str()).unwrap(), "kkt");
        assert_eq!(first.get("reactivated").and_then(|v| v.as_usize()).unwrap(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn collect_sink_accumulates() {
        let sink = CollectSink::new();
        sink.record(&Event::Kkt { lam: 1.0, reactivated: 0, round: 0 });
        sink.record(&Event::Kkt { lam: 0.5, reactivated: 1, round: 1 });
        let evs = sink.take();
        assert_eq!(evs.len(), 2);
        assert!(sink.take().is_empty());
    }
}
