//! Lock-free log-bucketed latency histograms (HDR-style).
//!
//! A [`LogHistogram`] holds atomic counters over a fixed 1-2-5 log-spaced
//! grid of upper bounds from 1 microsecond to 5000 seconds, so `record`
//! is a binary search plus three relaxed atomic adds — safe to hammer
//! from every HTTP worker with no lock. Quantiles are read as the upper
//! bound of the first bucket whose cumulative count reaches the rank, so
//! `p50 <= p99 <= p999` holds *structurally* (cumulative counts are
//! monotone by construction), and the same cumulative counts render
//! directly as Prometheus `_bucket{le="..."}` lines.

use std::sync::atomic::{AtomicU64, Ordering};

/// The shared 1-2-5 bucket grid, in seconds.
fn default_bounds() -> Vec<f64> {
    let mut b = Vec::with_capacity(30);
    let mut decade = 1e-6;
    while decade < 1.5e3 {
        for m in [1.0, 2.0, 5.0] {
            b.push(m * decade);
        }
        decade *= 10.0;
    }
    b
}

/// A fixed-bucket histogram of durations in seconds.
#[derive(Debug)]
pub struct LogHistogram {
    /// Ascending bucket upper bounds (seconds).
    bounds: Vec<f64>,
    /// One counter per bound, plus one overflow bucket at the end.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Total observed time in integer nanoseconds (f64 atomics don't
    /// exist; nanos keep the sum exact for any realistic uptime).
    sum_nanos: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        let bounds = default_bounds();
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        LogHistogram { bounds, buckets, count: AtomicU64::new(0), sum_nanos: AtomicU64::new(0) }
    }

    /// Record one observation (negative / NaN clamp to zero).
    pub fn record(&self, secs: f64) {
        let s = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        let idx = self.bounds.partition_point(|&b| b < s);
        // Ordering: Relaxed on all three adds. Each counter is an
        // independent statistic — readers tolerate torn *sets* of
        // counters (a snapshot may see the bucket add but not yet the
        // count add); no reader derives a safety decision from their
        // mutual consistency, and every counter is individually atomic.
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add((s * 1e9) as u64, Ordering::Relaxed);
    }

    // Ordering: all loads below are Relaxed — readers are monitoring /
    // rendering paths that only need *eventually current* counts, never
    // happens-before edges with the recording threads. A concurrently
    // recorded observation may or may not appear in a given read; both
    // outcomes are valid snapshots of a live histogram.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total observed seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Non-cumulative per-bucket counts (last entry = overflow bucket).
    pub fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// The bucket upper bounds (seconds); `snapshot()[bounds.len()]` is
    /// the overflow bucket above the last bound.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Quantile estimate in `[0, 1]`: the upper bound of the first bucket
    /// whose cumulative count reaches rank `ceil(p * count)`. Returns 0
    /// with no observations; overflow observations clamp to the largest
    /// bound (5000 s). Because the estimate only moves to later buckets as
    /// p grows, `quantile(a) <= quantile(b)` whenever `a <= b`.
    pub fn quantile(&self, p: f64) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 1.0) * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return self.bounds[i.min(self.bounds.len() - 1)];
            }
        }
        // The bounds ladder is a non-empty constant; 0.0 (not a panic)
        // backstops the impossible empty case at a serve-reachable site.
        self.bounds.last().copied().unwrap_or(0.0)
    }

    /// Append this histogram in Prometheus text exposition format:
    /// `# TYPE` header, cumulative `_bucket{le="..."}` series ending in
    /// `le="+Inf"`, then `_sum` and `_count`. `labels` is either empty or
    /// a comma-joined `key="value"` list (no braces).
    pub fn render_prometheus(&self, out: &mut String, name: &str, labels: &str, with_type: bool) {
        use std::fmt::Write;
        if with_type {
            let _ = writeln!(out, "# TYPE {name} histogram");
        }
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (i, bound) in self.bounds.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{bound}\"}} {cum}");
        }
        cum += self.buckets[self.bounds.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {cum}");
        let braces = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{braces} {}", self.sum_secs());
        let _ = writeln!(out, "{name}_count{braces} {}", self.count());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bounds_are_ascending_and_span_micro_to_kilo_seconds() {
        let h = LogHistogram::new();
        let b = h.bounds();
        assert!(b.windows(2).all(|w| w[0] < w[1]), "bounds not ascending");
        assert_eq!(b[0], 1e-6);
        assert!(*b.last().unwrap() >= 1e3);
    }

    #[test]
    fn record_places_observations_and_quantiles_are_monotone() {
        let h = LogHistogram::new();
        for _ in 0..90 {
            h.record(1e-4); // 100us
        }
        for _ in 0..10 {
            h.record(0.5); // 500ms
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        assert!(p50 <= p99 && p99 <= p999, "p50={p50} p99={p99} p999={p999}");
        // p50 lands in the 100us bucket, p99 in the 500ms one
        assert!(p50 <= 2e-4, "p50={p50}");
        assert!((0.1..=1.0).contains(&p99), "p99={p99}");
        assert!(h.sum_secs() > 5.0 && h.sum_secs() < 5.1, "sum={}", h.sum_secs());
    }

    #[test]
    fn degenerate_inputs_clamp() {
        let h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(-1.0);
        h.record(f64::INFINITY);
        h.record(1e9); // overflow bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.quantile(0.999), *h.bounds().last().unwrap());
        let snap = h.snapshot();
        assert_eq!(*snap.last().unwrap(), 2, "inf + 1e9 land in overflow");
        assert_eq!(snap[0], 2, "NaN and negative clamp to the first bucket");
    }

    #[test]
    fn prometheus_rendering_is_cumulative_and_well_formed() {
        let h = LogHistogram::new();
        h.record(1e-4);
        h.record(1e-2);
        h.record(2.0);
        let mut out = String::new();
        h.render_prometheus(&mut out, "t_seconds", "endpoint=\"fit\"", true);
        assert!(out.starts_with("# TYPE t_seconds histogram\n"));
        assert!(out.contains("t_seconds_bucket{endpoint=\"fit\",le=\"+Inf\"} 3"));
        assert!(out.contains("t_seconds_count{endpoint=\"fit\"} 3"));
        // cumulative counts never decrease down the le ladder
        let mut last = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts not cumulative: {line}");
            last = v;
        }
    }

    /// Satellite: hammer one histogram from N threads; total count and
    /// cumulative-bucket monotonicity must survive.
    #[test]
    fn concurrent_recording_is_lossless() {
        let h = Arc::new(LogHistogram::new());
        let threads = 8;
        let per = 5_000;
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..per {
                        // deterministic spread across several decades
                        let secs = 1e-6 * ((t * per + i) % 1_000_000 + 1) as f64;
                        h.record(secs);
                    }
                });
            }
        });
        assert_eq!(h.count(), (threads * per) as u64);
        let snap = h.snapshot();
        assert_eq!(snap.iter().sum::<u64>(), (threads * per) as u64);
        let mut cum = 0u64;
        for c in snap {
            let next = cum.checked_add(c).expect("no overflow");
            assert!(next >= cum);
            cum = next;
        }
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.quantile(0.999));
    }

    /// Interleaving stress: readers call `quantile` / `snapshot` /
    /// `render_prometheus` *while* writers are still recording. Every
    /// intermediate read must yield a bound inside the grid and a
    /// well-formed exposition (the ladder `p50 <= p99` is only a
    /// fixed-snapshot guarantee, so it is asserted after the join, not
    /// between racing calls). This is also the workload the nightly
    /// TSan leg leans on.
    #[test]
    fn quantiles_stay_sane_under_concurrent_recording() {
        let h = Arc::new(LogHistogram::new());
        let writers = 4;
        let per = 10_000;
        let lo = *h.bounds().first().unwrap();
        let hi = *h.bounds().last().unwrap();
        std::thread::scope(|s| {
            for t in 0..writers {
                let h = Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..per {
                        h.record(1e-5 * ((t * per + i) % 100_000 + 1) as f64);
                    }
                });
            }
            for _ in 0..2 {
                let h = Arc::clone(&h);
                s.spawn(move || {
                    for _ in 0..2_000 {
                        for p in [0.5, 0.99, 0.999] {
                            let q = h.quantile(p);
                            // 0.0 only before the first recorded obs.
                            assert!(
                                q == 0.0 || (lo..=hi).contains(&q),
                                "quantile({p})={q} outside the bucket grid"
                            );
                        }
                        let snap = h.snapshot();
                        assert!(snap.iter().sum::<u64>() <= (writers * per) as u64);
                        let mut out = String::new();
                        h.render_prometheus(&mut out, "x_seconds", "", false);
                        assert!(out.contains("le=\"+Inf\""));
                    }
                });
            }
        });
        assert_eq!(h.count(), (writers * per) as u64);
        // Quiesced: the structural ladder holds on a fixed histogram.
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.quantile(0.999));
    }
}
