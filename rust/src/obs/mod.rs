//! Observability: structured tracing + metrics, std-only.
//!
//! The paper's whole argument is a *certificate* — the duality gap — and
//! this module makes it (and everything the solver does to shrink it)
//! observable in production, not just in tests:
//!
//! * **Tracing** — a process-wide [`Sink`] receives typed [`Event`]s from
//!   the solver (per-lambda spans, gap passes, KKT repairs, working-set
//!   rounds, path chunks) and the server (request / fit / predict / job
//!   spans). Installed via [`install`] (the CLI's `--trace-out <file>`
//!   writes JSONL through [`trace::FileSink`]); absent by default.
//! * **Metrics** — [`metrics::LogHistogram`], a lock-free log-bucketed
//!   latency histogram feeding `GET /metrics` (JSON quantiles and
//!   Prometheus text exposition — see `serve`).
//! * **Analysis** — [`analyze`] renders per-lambda tables and phase
//!   breakdowns from a JSONL trace (`gapsafe trace summarize|...`).
//! * **Provenance ledger** — [`ledger`] stamps every solve and sphere
//!   application with process-unique ids; the screening sites append
//!   [`Event::SphereCenter`] / [`Event::ScreenCol`] /
//!   [`Event::Reactivate`] records and every solve ends with an
//!   [`Event::Certificate`], making each discarded column's safety
//!   argument re-checkable offline (`gapsafe trace verify`, see
//!   [`analyze::verify`]).
//!
//! # Overhead and transparency contract
//!
//! With no sink installed, the entire layer costs **one relaxed atomic
//! load** per instrumented region ([`enabled`]); no event is constructed,
//! no clock is read. With a sink installed, clocks are read and events
//! are built — but timing values never feed solver arithmetic, so tracing
//! on/off is **bitwise-transparent**: it can never change a solver
//! trajectory, a screening decision, or a served byte
//! (`rust/tests/obs_trace.rs` pins whole `solve_path` runs bit for bit
//! with and without a sink).

pub mod analyze;
pub mod ledger;
pub mod metrics;
pub mod trace;

use crate::util::json::Json;
use std::sync::atomic::{AtomicPtr, Ordering};

/// A tracing backend. Implementations must be cheap and non-blocking
/// enough for the hot path they observe (the bundled [`trace::FileSink`]
/// buffers writes behind a mutex; contention only exists when tracing is
/// on, which is already the "observed" regime).
pub trait Sink: Send + Sync {
    fn record(&self, ev: &Event);
}

/// The global sink. `dyn Sink` is a fat pointer, so the atomic holds a
/// thin pointer to a heap-allocated `Box<dyn Sink>` instead.
static SINK: AtomicPtr<Box<dyn Sink>> = AtomicPtr::new(std::ptr::null_mut());

/// Install a process-wide sink. A replaced sink is deliberately leaked:
/// another thread may still be inside its `record`, and a sink lives for
/// the process in every real use (CLI flag, serve flag, test harness) —
/// leaking trades a few bytes for not needing hazard pointers.
pub fn install(sink: Box<dyn Sink>) {
    let ptr = Box::into_raw(Box::new(sink));
    // Ordering: the Release half publishes the fully-constructed sink —
    // every write that built it happens-before any emitter's Acquire
    // load in `emit` (a Relaxed publish could let a concurrent emitter
    // call `record` on a half-initialized sink). The Acquire half orders
    // this thread after the previous sink's publication, keeping
    // install/uninstall sequences coherent.
    SINK.swap(ptr, Ordering::AcqRel);
}

/// Remove the sink (tracing returns to the no-op fast path). The old sink
/// is leaked, not dropped — see [`install`]. Intended for tests; callers
/// that need the sink's data should keep their own `Arc` into it.
pub fn uninstall() {
    // Ordering: AcqRel for symmetry with `install` — publishing null
    // needs no Release, but the Acquire half synchronizes with the
    // prior install so the swap cannot be reordered ahead of it.
    SINK.swap(std::ptr::null_mut(), Ordering::AcqRel);
}

/// Is a sink installed? One relaxed load — callers capture this once per
/// solve / request and skip all clock reads and event construction when
/// false.
#[inline]
pub fn enabled() -> bool {
    // Ordering: Relaxed is enough for a null-check — the pointer is
    // never dereferenced here, so no pointee writes need to be visible.
    // `emit` re-loads with Acquire before any dereference.
    !SINK.load(Ordering::Relaxed).is_null()
}

/// Deliver an event to the installed sink, if any.
#[inline]
pub fn emit(ev: &Event) {
    // Ordering: Acquire pairs with the Release half of `install`'s swap,
    // so the sink's construction happens-before this dereference.
    let p = SINK.load(Ordering::Acquire);
    if !p.is_null() {
        // SAFETY: `p` came from `Box::into_raw` in `install` and is never
        // freed (replaced sinks leak by design), so a non-null pointer is
        // valid for the life of the process; the Acquire load above makes
        // the pointee's initialization visible.
        unsafe { (*p).record(ev) }
    }
}

/// A structured trace event. Everything is plain data (no matrices): an
/// event is a *span summary*, sized for a JSONL line, not a data dump.
#[derive(Debug, Clone)]
pub enum Event {
    /// One gap/screening pass inside a fixed-lambda solve (Alg. 2 line 5):
    /// the duality-gap certificate, the Gap Safe radius it induces, what
    /// screening did with it, and what the pass cost.
    GapPass {
        lam: f64,
        /// CD epochs completed when the pass ran.
        epoch: usize,
        gap: f64,
        /// Gap Safe sphere radius from this pass's dual point.
        radius: f64,
        active_groups: usize,
        active_feats: usize,
        /// Features killed by this pass (active before - after).
        screened: usize,
        /// Columns the compact working view carries (p when not packed).
        view_cols: usize,
        /// Dual-point engine decision: "fresh" | "kept" | "refined".
        dual_choice: &'static str,
        secs: f64,
    },
    /// A whole fixed-lambda solve, with the phase time split.
    SolveSpan {
        lam: f64,
        epochs: usize,
        gap_passes: usize,
        gap: f64,
        converged: bool,
        kkt_violations: usize,
        active_feats: usize,
        /// Time inside CD epochs (includes `link_secs`).
        cd_secs: f64,
        /// Time inside gap passes (dual point + stats + screening).
        gap_secs: f64,
        /// Time inside logistic/multinomial/Poisson link refreshes.
        link_secs: f64,
        total_secs: f64,
        /// Active SIMD kernel backend label.
        kernel: &'static str,
    },
    /// Strong-rule KKT repair reactivated groups (Sec. 3.6).
    Kkt { lam: f64, reactivated: usize, round: usize },
    /// A Blitz working-set round (Sec. 5.1).
    WsRound { lam: f64, round: usize, ws_feats: usize, gap: f64 },
    /// A lambda path run started.
    PathStart { n_lambdas: usize, lam_max: f64, threads: usize, kernel: &'static str },
    /// One path point finished (rollup over its warm-start phases).
    PathPoint {
        lam: f64,
        epochs: usize,
        gap: f64,
        active_feats: usize,
        nnz_coefs: usize,
        converged: bool,
        secs: f64,
    },
    /// A lambda path run finished.
    PathEnd { n_lambdas: usize, total_epochs: usize, secs: f64 },
    /// A parallel-path work span: the coarse warm-start pre-pass or one
    /// weighted lambda chunk.
    Chunk { kind: &'static str, lo: usize, hi: usize, secs: f64 },
    /// One served HTTP request.
    Request { endpoint: &'static str, status: u16, secs: f64 },
    /// One registry fit ("hit" | "warm" | "cold").
    Fit { key: String, kind: &'static str, secs: f64, epochs: usize },
    /// One served prediction.
    Predict { key: String, t: usize, secs: f64 },
    /// One background fit job, with the queueing delay made visible.
    Job { id: u64, queue_secs: f64, run_secs: f64, ok: bool },
    /// Provenance ledger: the sphere center (dual point) a batch of
    /// [`Event::ScreenCol`] kills was tested against; `cid` links them.
    /// Written only when a sphere application actually discarded columns.
    SphereCenter {
        /// Ledger id of the enclosing fixed-lambda solve.
        sid: u64,
        /// Ledger id of this sphere application.
        cid: u64,
        lam: f64,
        /// CD epochs completed when the sphere was applied.
        epoch: usize,
        /// Screening-rule label (`Rule::label`).
        rule: &'static str,
        /// Emission site: "seq" (pre-solve sphere), "dyn" (gap-pass
        /// sphere), "strong" (heuristic pre-solve intersect — no sphere).
        site: &'static str,
        /// Safe sphere radius (NaN -> null for the strong heuristic).
        radius: f64,
        n: usize,
        q: usize,
        /// Column-major n*q dual point, bitwise through the JSON layer.
        theta: Vec<f64>,
    },
    /// Provenance ledger: one discarded column, with the exact inequality
    /// that killed it: `stat + radius*norm < thresh`.
    ScreenCol {
        sid: u64,
        /// Links to the [`Event::SphereCenter`] this kill was tested at.
        cid: u64,
        lam: f64,
        epoch: usize,
        rule: &'static str,
        /// Which test fired: "l1" | "group" | "sgl-group" | "sgl-feat" |
        /// "strong".
        test: &'static str,
        /// Full design column index.
        j: usize,
        /// Group index the column belongs to.
        group: usize,
        /// The correlation statistic, e.g. |x_j^T theta| for l1.
        stat: f64,
        /// The matching column/group operator norm.
        norm: f64,
        radius: f64,
        /// Kill threshold (1 - SCREEN_MARGIN for l1, per-test otherwise).
        thresh: f64,
        /// Slack: thresh - stat - radius*norm (>= 0 for a sound kill).
        margin: f64,
    },
    /// Provenance ledger: one group brought back by a KKT repair round.
    Reactivate {
        sid: u64,
        lam: f64,
        round: usize,
        group: usize,
        /// Features the group contributes back to the active set.
        feats: usize,
        /// The violating dual statistic that triggered the repair.
        stat: f64,
    },
    /// Provenance ledger: per-solve safety certificate — the final dual
    /// point (bitwise), its gap/radius, and the support the solver ended
    /// with.
    Certificate {
        sid: u64,
        lam: f64,
        gap: f64,
        /// Gap Safe radius at the final dual point.
        radius: f64,
        n: usize,
        q: usize,
        /// Total design columns (so `initial: None` can mean "all p").
        p: usize,
        /// Column-major n*q final dual point, bitwise.
        theta: Vec<f64>,
        /// Final active (unscreened) feature indices.
        support: Vec<usize>,
        /// Feature indices active when the solve started; None = all p.
        initial: Option<Vec<usize>>,
        rule: &'static str,
        /// Datafit label: "quadratic" | "logistic" | "multinomial" |
        /// "poisson".
        fit: &'static str,
    },
}

impl Event {
    /// The event's `type` tag as serialized.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::GapPass { .. } => "gap_pass",
            Event::SolveSpan { .. } => "solve",
            Event::Kkt { .. } => "kkt",
            Event::WsRound { .. } => "ws_round",
            Event::PathStart { .. } => "path_start",
            Event::PathPoint { .. } => "path_point",
            Event::PathEnd { .. } => "path_end",
            Event::Chunk { .. } => "chunk",
            Event::Request { .. } => "request",
            Event::Fit { .. } => "fit",
            Event::Predict { .. } => "predict",
            Event::Job { .. } => "job",
            Event::SphereCenter { .. } => "sphere_center",
            Event::ScreenCol { .. } => "screen_col",
            Event::Reactivate { .. } => "reactivate",
            Event::Certificate { .. } => "certificate",
        }
    }

    /// Serialize through the crate's JSON layer (f64s round-trip bitwise;
    /// non-finite values become null). One object per event; the schema is
    /// documented in docs/OBSERVABILITY.md.
    pub fn to_json(&self) -> Json {
        let mut obj = match self {
            Event::GapPass {
                lam,
                epoch,
                gap,
                radius,
                active_groups,
                active_feats,
                screened,
                view_cols,
                dual_choice,
                secs,
            } => Json::obj(vec![
                ("lam", Json::Num(*lam)),
                ("epoch", Json::Num(*epoch as f64)),
                ("gap", Json::Num(*gap)),
                ("radius", Json::Num(*radius)),
                ("active_groups", Json::Num(*active_groups as f64)),
                ("active_feats", Json::Num(*active_feats as f64)),
                ("screened", Json::Num(*screened as f64)),
                ("view_cols", Json::Num(*view_cols as f64)),
                ("dual_choice", Json::Str((*dual_choice).to_string())),
                ("secs", Json::Num(*secs)),
            ]),
            Event::SolveSpan {
                lam,
                epochs,
                gap_passes,
                gap,
                converged,
                kkt_violations,
                active_feats,
                cd_secs,
                gap_secs,
                link_secs,
                total_secs,
                kernel,
            } => Json::obj(vec![
                ("lam", Json::Num(*lam)),
                ("epochs", Json::Num(*epochs as f64)),
                ("gap_passes", Json::Num(*gap_passes as f64)),
                ("gap", Json::Num(*gap)),
                ("converged", Json::Bool(*converged)),
                ("kkt_violations", Json::Num(*kkt_violations as f64)),
                ("active_feats", Json::Num(*active_feats as f64)),
                ("cd_secs", Json::Num(*cd_secs)),
                ("gap_secs", Json::Num(*gap_secs)),
                ("link_secs", Json::Num(*link_secs)),
                ("total_secs", Json::Num(*total_secs)),
                ("kernel", Json::Str((*kernel).to_string())),
            ]),
            Event::Kkt { lam, reactivated, round } => Json::obj(vec![
                ("lam", Json::Num(*lam)),
                ("reactivated", Json::Num(*reactivated as f64)),
                ("round", Json::Num(*round as f64)),
            ]),
            Event::WsRound { lam, round, ws_feats, gap } => Json::obj(vec![
                ("lam", Json::Num(*lam)),
                ("round", Json::Num(*round as f64)),
                ("ws_feats", Json::Num(*ws_feats as f64)),
                ("gap", Json::Num(*gap)),
            ]),
            Event::PathStart { n_lambdas, lam_max, threads, kernel } => Json::obj(vec![
                ("n_lambdas", Json::Num(*n_lambdas as f64)),
                ("lam_max", Json::Num(*lam_max)),
                ("threads", Json::Num(*threads as f64)),
                ("kernel", Json::Str((*kernel).to_string())),
            ]),
            Event::PathPoint { lam, epochs, gap, active_feats, nnz_coefs, converged, secs } => {
                Json::obj(vec![
                    ("lam", Json::Num(*lam)),
                    ("epochs", Json::Num(*epochs as f64)),
                    ("gap", Json::Num(*gap)),
                    ("active_feats", Json::Num(*active_feats as f64)),
                    ("nnz_coefs", Json::Num(*nnz_coefs as f64)),
                    ("converged", Json::Bool(*converged)),
                    ("secs", Json::Num(*secs)),
                ])
            }
            Event::PathEnd { n_lambdas, total_epochs, secs } => Json::obj(vec![
                ("n_lambdas", Json::Num(*n_lambdas as f64)),
                ("total_epochs", Json::Num(*total_epochs as f64)),
                ("secs", Json::Num(*secs)),
            ]),
            Event::Chunk { kind, lo, hi, secs } => Json::obj(vec![
                ("kind", Json::Str((*kind).to_string())),
                ("lo", Json::Num(*lo as f64)),
                ("hi", Json::Num(*hi as f64)),
                ("secs", Json::Num(*secs)),
            ]),
            Event::Request { endpoint, status, secs } => Json::obj(vec![
                ("endpoint", Json::Str((*endpoint).to_string())),
                ("status", Json::Num(*status as f64)),
                ("secs", Json::Num(*secs)),
            ]),
            Event::Fit { key, kind, secs, epochs } => Json::obj(vec![
                ("key", Json::Str(key.clone())),
                ("kind", Json::Str((*kind).to_string())),
                ("secs", Json::Num(*secs)),
                ("epochs", Json::Num(*epochs as f64)),
            ]),
            Event::Predict { key, t, secs } => Json::obj(vec![
                ("key", Json::Str(key.clone())),
                ("t", Json::Num(*t as f64)),
                ("secs", Json::Num(*secs)),
            ]),
            Event::Job { id, queue_secs, run_secs, ok } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("queue_secs", Json::Num(*queue_secs)),
                ("run_secs", Json::Num(*run_secs)),
                ("ok", Json::Bool(*ok)),
            ]),
            Event::SphereCenter { sid, cid, lam, epoch, rule, site, radius, n, q, theta } => {
                Json::obj(vec![
                    ("sid", Json::Num(*sid as f64)),
                    ("cid", Json::Num(*cid as f64)),
                    ("lam", Json::Num(*lam)),
                    ("epoch", Json::Num(*epoch as f64)),
                    ("rule", Json::Str((*rule).to_string())),
                    ("site", Json::Str((*site).to_string())),
                    ("radius", Json::Num(*radius)),
                    ("n", Json::Num(*n as f64)),
                    ("q", Json::Num(*q as f64)),
                    ("theta", Json::arr_f64(theta)),
                ])
            }
            Event::ScreenCol {
                sid,
                cid,
                lam,
                epoch,
                rule,
                test,
                j,
                group,
                stat,
                norm,
                radius,
                thresh,
                margin,
            } => Json::obj(vec![
                ("sid", Json::Num(*sid as f64)),
                ("cid", Json::Num(*cid as f64)),
                ("lam", Json::Num(*lam)),
                ("epoch", Json::Num(*epoch as f64)),
                ("rule", Json::Str((*rule).to_string())),
                ("test", Json::Str((*test).to_string())),
                ("j", Json::Num(*j as f64)),
                ("group", Json::Num(*group as f64)),
                ("stat", Json::Num(*stat)),
                ("norm", Json::Num(*norm)),
                ("radius", Json::Num(*radius)),
                ("thresh", Json::Num(*thresh)),
                ("margin", Json::Num(*margin)),
            ]),
            Event::Reactivate { sid, lam, round, group, feats, stat } => Json::obj(vec![
                ("sid", Json::Num(*sid as f64)),
                ("lam", Json::Num(*lam)),
                ("round", Json::Num(*round as f64)),
                ("group", Json::Num(*group as f64)),
                ("feats", Json::Num(*feats as f64)),
                ("stat", Json::Num(*stat)),
            ]),
            Event::Certificate { sid, lam, gap, radius, n, q, p, theta, support, initial, rule, fit } => {
                Json::obj(vec![
                    ("sid", Json::Num(*sid as f64)),
                    ("lam", Json::Num(*lam)),
                    ("gap", Json::Num(*gap)),
                    ("radius", Json::Num(*radius)),
                    ("n", Json::Num(*n as f64)),
                    ("q", Json::Num(*q as f64)),
                    ("p", Json::Num(*p as f64)),
                    ("theta", Json::arr_f64(theta)),
                    (
                        "support",
                        Json::Arr(support.iter().map(|&j| Json::Num(j as f64)).collect()),
                    ),
                    (
                        "initial",
                        match initial {
                            None => Json::Null,
                            Some(idx) => {
                                Json::Arr(idx.iter().map(|&j| Json::Num(j as f64)).collect())
                            }
                        },
                    ),
                    ("rule", Json::Str((*rule).to_string())),
                    ("fit", Json::Str((*fit).to_string())),
                ])
            }
        };
        if let Json::Obj(map) = &mut obj {
            map.insert("type".to_string(), Json::Str(self.kind().to_string()));
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_event_serializes_with_type_tag() {
        let events = vec![
            Event::GapPass {
                lam: 0.5,
                epoch: 10,
                gap: 1e-3,
                radius: 0.1,
                active_groups: 4,
                active_feats: 4,
                screened: 2,
                view_cols: 6,
                dual_choice: "kept",
                secs: 1e-4,
            },
            Event::SolveSpan {
                lam: 0.5,
                epochs: 100,
                gap_passes: 11,
                gap: 1e-9,
                converged: true,
                kkt_violations: 0,
                active_feats: 4,
                cd_secs: 0.1,
                gap_secs: 0.02,
                link_secs: 0.0,
                total_secs: 0.13,
                kernel: "scalar",
            },
            Event::Kkt { lam: 0.5, reactivated: 1, round: 1 },
            Event::WsRound { lam: 0.5, round: 0, ws_feats: 20, gap: 0.3 },
            Event::PathStart { n_lambdas: 10, lam_max: 2.0, threads: 1, kernel: "scalar" },
            Event::PathPoint {
                lam: 0.5,
                epochs: 40,
                gap: 1e-9,
                active_feats: 4,
                nnz_coefs: 4,
                converged: true,
                secs: 0.01,
            },
            Event::PathEnd { n_lambdas: 10, total_epochs: 400, secs: 0.1 },
            Event::Chunk { kind: "chunk", lo: 0, hi: 5, secs: 0.05 },
            Event::Request { endpoint: "fit", status: 202, secs: 1e-3 },
            Event::Fit { key: "k".into(), kind: "cold", secs: 1.0, epochs: 100 },
            Event::Predict { key: "k".into(), t: 9, secs: 1e-4 },
            Event::Job { id: 3, queue_secs: 0.01, run_secs: 1.0, ok: true },
            Event::SphereCenter {
                sid: 7,
                cid: 8,
                lam: 0.5,
                epoch: 3,
                rule: "gap-full",
                site: "dyn",
                radius: 0.2,
                n: 2,
                q: 1,
                theta: vec![0.1, -0.2],
            },
            Event::ScreenCol {
                sid: 7,
                cid: 8,
                lam: 0.5,
                epoch: 3,
                rule: "gap-full",
                test: "l1",
                j: 11,
                group: 11,
                stat: 0.4,
                norm: 1.0,
                radius: 0.2,
                thresh: 1.0 - 1e-11,
                margin: 0.4,
            },
            Event::Reactivate { sid: 7, lam: 0.5, round: 1, group: 4, feats: 3, stat: 1.01 },
            Event::Certificate {
                sid: 7,
                lam: 0.5,
                gap: 1e-9,
                radius: 1e-4,
                n: 2,
                q: 1,
                p: 20,
                theta: vec![0.1, -0.2],
                support: vec![0, 11],
                initial: None,
                rule: "gap-full",
                fit: "quadratic",
            },
        ];
        for ev in &events {
            let j = ev.to_json();
            let tag = j.get("type").and_then(|t| t.as_str()).expect("type tag");
            assert_eq!(tag, ev.kind());
            // round-trips through the crate's own parser
            let text = format!("{j}");
            let back = Json::parse(&text).expect("event JSON parses");
            assert_eq!(back.get("type").and_then(|t| t.as_str()).unwrap(), ev.kind());
        }
    }

    #[test]
    fn emit_without_sink_is_a_noop() {
        // No unit test installs a global sink (the install/uninstall tests
        // live in the dedicated integration binary rust/tests/obs_trace.rs,
        // which owns the process-global), so emit here hits the null path.
        emit(&Event::Kkt { lam: 1.0, reactivated: 0, round: 0 });
    }
}
