//! Trace analysis: turn a `--trace-out` JSONL file back into answers
//! ("where did the time go", "what did screening buy, per lambda").
//! Backs the `gapsafe trace summarize|lambda-table|flame` subcommand.

use crate::util::json::Json;

/// Load a JSONL trace. Every line must parse through the crate's own
/// JSON layer — a malformed line is a hard error (this is also the CI
/// well-formedness gate for trace files), with its line number.
pub fn load(path: &str) -> Result<Vec<Json>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace file {path}: {e}"))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| format!("{path}:{}: malformed trace line: {e}", i + 1))?;
        if ev.get("type").and_then(|t| t.as_str()).is_none() {
            return Err(format!("{path}:{}: trace line has no \"type\" tag", i + 1));
        }
        events.push(ev);
    }
    Ok(events)
}

fn typed<'a>(events: &'a [Json], kind: &str) -> impl Iterator<Item = &'a Json> {
    let kind = kind.to_string();
    events.iter().filter(move |e| e.get("type").and_then(|t| t.as_str()) == Some(kind.as_str()))
}

fn num(ev: &Json, key: &str) -> f64 {
    ev.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

fn unum(ev: &Json, key: &str) -> usize {
    ev.get(key).and_then(|v| v.as_usize()).unwrap_or(0)
}

/// One per-lambda rollup row (solve spans + gap passes, first-seen order).
#[derive(Debug, Clone, Default)]
struct LamRow {
    lam: f64,
    epochs: usize,
    passes: usize,
    active: usize,
    initial: usize,
    converged: bool,
    cd_secs: f64,
    gap_secs: f64,
    link_secs: f64,
    total_secs: f64,
    kkt: usize,
}

/// Aggregate solve spans and gap passes by lambda (keyed on the exact
/// f64 bits, so distinct lambdas never merge).
fn lambda_rows(events: &[Json]) -> Vec<LamRow> {
    let mut rows: Vec<(u64, LamRow)> = Vec::new();
    let mut row = |lam: f64, rows: &mut Vec<(u64, LamRow)>| -> usize {
        let bits = lam.to_bits();
        if let Some(i) = rows.iter().position(|(b, _)| *b == bits) {
            i
        } else {
            rows.push((bits, LamRow { lam, ..LamRow::default() }));
            rows.len() - 1
        }
    };
    for ev in typed(events, "solve") {
        let i = row(num(ev, "lam"), &mut rows);
        let r = &mut rows[i].1;
        r.epochs += unum(ev, "epochs");
        r.passes += unum(ev, "gap_passes");
        r.active = unum(ev, "active_feats");
        r.converged = ev.get("converged").and_then(|v| v.as_bool()).unwrap_or(false);
        r.cd_secs += num(ev, "cd_secs");
        r.gap_secs += num(ev, "gap_secs");
        r.link_secs += num(ev, "link_secs");
        r.total_secs += num(ev, "total_secs");
        r.kkt += unum(ev, "kkt_violations");
    }
    for ev in typed(events, "gap_pass") {
        let i = row(num(ev, "lam"), &mut rows);
        let before = unum(ev, "active_feats") + unum(ev, "screened");
        let r = &mut rows[i].1;
        r.initial = r.initial.max(before);
    }
    rows.into_iter().map(|(_, r)| r).collect()
}

/// The per-lambda table: epochs, passes, final active count, screened
/// fraction, and the cd/gap/link time split.
pub fn lambda_table(events: &[Json]) -> String {
    let rows = lambda_rows(events);
    let mut out = String::new();
    if rows.is_empty() {
        out.push_str("no solver spans in trace (serve-only trace? try `summarize`)\n");
        return out;
    }
    out.push_str(&format!(
        "{:>12} {:>7} {:>6} {:>7} {:>6} {:>9} {:>9} {:>9} {:>9} {:>5} {:>4}\n",
        "lambda", "epochs", "passes", "active", "scr%", "cd_s", "gap_s", "link_s", "total_s",
        "kkt", "conv"
    ));
    for r in &rows {
        let scr = if r.initial > 0 {
            100.0 * (1.0 - r.active as f64 / r.initial as f64)
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:>12.6e} {:>7} {:>6} {:>7} {:>5.1}% {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>5} {:>4}\n",
            r.lam,
            r.epochs,
            r.passes,
            r.active,
            scr,
            r.cd_secs,
            r.gap_secs,
            r.link_secs,
            r.total_secs,
            r.kkt,
            if r.converged { "yes" } else { "NO" }
        ));
    }
    out
}

/// Aggregate phase breakdown as text bars: CD epochs (excluding link
/// refreshes), link refreshes, gap passes, and the unattributed rest.
pub fn flame(events: &[Json]) -> String {
    let mut cd = 0.0;
    let mut gap = 0.0;
    let mut link = 0.0;
    let mut total = 0.0;
    for ev in typed(events, "solve") {
        cd += num(ev, "cd_secs");
        gap += num(ev, "gap_secs");
        link += num(ev, "link_secs");
        total += num(ev, "total_secs");
    }
    let mut out = String::new();
    if total <= 0.0 {
        out.push_str("no solver time recorded in trace\n");
        return out;
    }
    let cd_only = (cd - link).max(0.0);
    let other = (total - cd - gap).max(0.0);
    let phases =
        [("cd epochs", cd_only), ("link refresh", link), ("gap passes", gap), ("other", other)];
    for (name, secs) in phases {
        let frac = secs / total;
        let bar = "#".repeat((frac * 50.0).round() as usize);
        out.push_str(&format!("{name:>13} {secs:>9.4}s {:>5.1}% |{bar}\n", 100.0 * frac));
    }
    out.push_str(&format!("{:>13} {total:>9.4}s\n", "total"));
    out
}

/// Headline summary: event counts, solver rollup (lambdas, epochs, time
/// split) and — when present — the serve-side request/fit aggregates.
pub fn summarize(events: &[Json]) -> String {
    let mut out = String::new();
    out.push_str(&format!("events: {}\n", events.len()));
    // count per type, first-seen order
    let mut kinds: Vec<(String, usize)> = Vec::new();
    for ev in events {
        let k = ev.get("type").and_then(|t| t.as_str()).unwrap_or("?").to_string();
        match kinds.iter_mut().find(|(n, _)| *n == k) {
            Some((_, c)) => *c += 1,
            None => kinds.push((k, 1)),
        }
    }
    for (k, c) in &kinds {
        out.push_str(&format!("  {k:>10} x{c}\n"));
    }
    if let Some(start) = typed(events, "path_start").next() {
        out.push_str(&format!(
            "path: {} lambdas, lam_max {:.6e}, threads {}, kernel {}\n",
            unum(start, "n_lambdas"),
            num(start, "lam_max"),
            unum(start, "threads"),
            start.get("kernel").and_then(|v| v.as_str()).unwrap_or("?"),
        ));
    }
    let rows = lambda_rows(events);
    if !rows.is_empty() {
        out.push_str(&format!(
            "solver: {} lambdas, {} epochs, {} gap passes, {:.4}s\n",
            rows.len(),
            rows.iter().map(|r| r.epochs).sum::<usize>(),
            rows.iter().map(|r| r.passes).sum::<usize>(),
            rows.iter().map(|r| r.total_secs).sum::<f64>(),
        ));
        out.push('\n');
        out.push_str(&lambda_table(events));
        out.push('\n');
        out.push_str(&flame(events));
    }
    // serve-side aggregates, when the trace came from `serve --trace-out`
    let mut endpoints: Vec<(String, usize, f64)> = Vec::new();
    for ev in typed(events, "request") {
        let e = ev.get("endpoint").and_then(|v| v.as_str()).unwrap_or("?").to_string();
        let secs = num(ev, "secs");
        match endpoints.iter_mut().find(|(n, _, _)| *n == e) {
            Some((_, c, s)) => {
                *c += 1;
                *s += secs;
            }
            None => endpoints.push((e, 1, secs)),
        }
    }
    if !endpoints.is_empty() {
        out.push_str("\nrequests:\n");
        for (e, c, s) in &endpoints {
            out.push_str(&format!(
                "  {e:>8} x{c:<6} total {s:.4}s  mean {:.6}s\n",
                s / *c as f64
            ));
        }
    }
    let fits: Vec<&Json> = typed(events, "fit").collect();
    if !fits.is_empty() {
        for kind in ["cold", "warm", "hit"] {
            let of_kind: Vec<&&Json> = fits
                .iter()
                .filter(|f| f.get("kind").and_then(|v| v.as_str()) == Some(kind))
                .collect();
            if !of_kind.is_empty() {
                let secs: f64 = of_kind.iter().map(|f| num(f, "secs")).sum();
                out.push_str(&format!("fits ({kind}): x{} total {secs:.4}s\n", of_kind.len()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Event;

    fn demo_events() -> Vec<Json> {
        vec![
            Event::PathStart { n_lambdas: 2, lam_max: 2.0, threads: 1, kernel: "scalar" }
                .to_json(),
            Event::GapPass {
                lam: 1.0,
                epoch: 0,
                gap: 0.5,
                radius: 0.3,
                active_groups: 40,
                active_feats: 40,
                screened: 60,
                view_cols: 100,
                dual_choice: "fresh",
                secs: 1e-4,
            }
            .to_json(),
            Event::SolveSpan {
                lam: 1.0,
                epochs: 30,
                gap_passes: 4,
                gap: 1e-9,
                converged: true,
                kkt_violations: 0,
                active_feats: 10,
                cd_secs: 0.03,
                gap_secs: 0.01,
                link_secs: 0.005,
                total_secs: 0.05,
                kernel: "scalar",
            }
            .to_json(),
            Event::SolveSpan {
                lam: 0.5,
                epochs: 50,
                gap_passes: 6,
                gap: 1e-9,
                converged: true,
                kkt_violations: 1,
                active_feats: 20,
                cd_secs: 0.08,
                gap_secs: 0.02,
                link_secs: 0.0,
                total_secs: 0.11,
                kernel: "scalar",
            }
            .to_json(),
        ]
    }

    #[test]
    fn lambda_table_rolls_up_by_lambda() {
        let t = lambda_table(&demo_events());
        assert!(t.contains("lambda"), "missing header: {t}");
        // two distinct lambdas -> header + 2 rows
        assert_eq!(t.lines().count(), 3, "{t}");
        // screened fraction of lam=1.0: initial 100 (40 active + 60
        // screened), final 10 -> 90%
        assert!(t.contains("90.0%"), "{t}");
    }

    #[test]
    fn flame_attributes_all_time() {
        let f = flame(&demo_events());
        assert!(f.contains("cd epochs"));
        assert!(f.contains("link refresh"));
        assert!(f.contains("gap passes"));
        assert!(f.contains("total"));
    }

    #[test]
    fn summarize_counts_and_embeds_table() {
        let s = summarize(&demo_events());
        assert!(s.contains("events: 4"));
        assert!(s.contains("solve x2"));
        assert!(s.contains("lambda")); // the embedded per-lambda table
        assert!(s.contains("kernel scalar"));
    }

    #[test]
    fn load_rejects_malformed_lines_with_line_number() {
        let path =
            std::env::temp_dir().join(format!("gapsafe_trace_bad_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"type\":\"kkt\"}\nnot json\n").unwrap();
        let err = load(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains(":2:"), "error should carry line number: {err}");
        std::fs::write(&path, "{\"type\":\"kkt\"}\n{\"no_tag\":1}\n").unwrap();
        let err = load(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("type"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
