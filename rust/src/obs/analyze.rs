//! Trace analysis: turn a `--trace-out` JSONL file back into answers
//! ("where did the time go", "what did screening buy, per lambda") and —
//! via [`verify`] — re-check every screening decision the ledger recorded
//! against the raw design matrix. Backs the
//! `gapsafe trace summarize|lambda-table|flame|verify` subcommands.

use crate::linalg::sparse::Design;
use crate::penalty::{PenaltyKind, SCREEN_MARGIN};
use crate::problem::Problem;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Load a JSONL trace ([`load_opts`] with `strict = false`): a single
/// truncated *trailing* line (the common artifact of a killed writer) is
/// dropped with a loud warning; any earlier malformed line is still a
/// hard error.
pub fn load(path: &str) -> Result<Vec<Json>, String> {
    load_opts(path, false)
}

/// Load a JSONL trace. Every line must parse through the crate's own
/// JSON layer and carry a `"type"` tag — a malformed line is a hard
/// error (this is also the CI well-formedness gate for trace files),
/// with its line number. The one exception: when `strict` is false, a
/// malformed *final* line is tolerated (a process killed mid-write
/// leaves exactly one partial trailing line) — it is dropped with a
/// warning on stderr; `strict = true` (CLI `--strict`) restores the
/// hard error.
pub fn load_opts(path: &str, strict: bool) -> Result<Vec<Json>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace file {path}: {e}"))?;
    let lines: Vec<(usize, &str)> =
        text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty()).collect();
    let mut events = Vec::new();
    for (k, &(i, line)) in lines.iter().enumerate() {
        let parsed = Json::parse(line)
            .map_err(|e| format!("{path}:{}: malformed trace line: {e}", i + 1))
            .and_then(|ev| {
                if ev.get("type").and_then(|t| t.as_str()).is_none() {
                    Err(format!("{path}:{}: trace line has no \"type\" tag", i + 1))
                } else {
                    Ok(ev)
                }
            });
        match parsed {
            Ok(ev) => events.push(ev),
            Err(e) if !strict && k + 1 == lines.len() => {
                eprintln!(
                    "warning: dropped 1 truncated trailing trace line ({e}); \
                     pass --strict to make this fatal"
                );
            }
            Err(e) => return Err(e),
        }
    }
    Ok(events)
}

fn typed<'a>(events: &'a [Json], kind: &str) -> impl Iterator<Item = &'a Json> {
    let kind = kind.to_string();
    events.iter().filter(move |e| e.get("type").and_then(|t| t.as_str()) == Some(kind.as_str()))
}

fn num(ev: &Json, key: &str) -> f64 {
    ev.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

fn unum(ev: &Json, key: &str) -> usize {
    ev.get(key).and_then(|v| v.as_usize()).unwrap_or(0)
}

/// One per-lambda rollup row (solve spans + gap passes, first-seen order).
#[derive(Debug, Clone, Default)]
struct LamRow {
    lam: f64,
    epochs: usize,
    passes: usize,
    active: usize,
    initial: usize,
    converged: bool,
    cd_secs: f64,
    gap_secs: f64,
    link_secs: f64,
    total_secs: f64,
    kkt: usize,
    /// Provenance-ledger events recorded at this lambda (sphere centers,
    /// screened columns, reactivations, certificates).
    ledger: usize,
}

/// Aggregate solve spans and gap passes by lambda (keyed on the exact
/// f64 bits, so distinct lambdas never merge).
fn lambda_rows(events: &[Json]) -> Vec<LamRow> {
    let mut rows: Vec<(u64, LamRow)> = Vec::new();
    let mut row = |lam: f64, rows: &mut Vec<(u64, LamRow)>| -> usize {
        let bits = lam.to_bits();
        if let Some(i) = rows.iter().position(|(b, _)| *b == bits) {
            i
        } else {
            rows.push((bits, LamRow { lam, ..LamRow::default() }));
            rows.len() - 1
        }
    };
    for ev in typed(events, "solve") {
        let i = row(num(ev, "lam"), &mut rows);
        let r = &mut rows[i].1;
        r.epochs += unum(ev, "epochs");
        r.passes += unum(ev, "gap_passes");
        r.active = unum(ev, "active_feats");
        r.converged = ev.get("converged").and_then(|v| v.as_bool()).unwrap_or(false);
        r.cd_secs += num(ev, "cd_secs");
        r.gap_secs += num(ev, "gap_secs");
        r.link_secs += num(ev, "link_secs");
        r.total_secs += num(ev, "total_secs");
        r.kkt += unum(ev, "kkt_violations");
    }
    for ev in typed(events, "gap_pass") {
        let i = row(num(ev, "lam"), &mut rows);
        let before = unum(ev, "active_feats") + unum(ev, "screened");
        let r = &mut rows[i].1;
        r.initial = r.initial.max(before);
    }
    for ev in events {
        if matches!(
            ev.get("type").and_then(|t| t.as_str()),
            Some("sphere_center") | Some("screen_col") | Some("reactivate")
                | Some("certificate")
        ) {
            let i = row(num(ev, "lam"), &mut rows);
            rows[i].1.ledger += 1;
        }
    }
    rows.into_iter().map(|(_, r)| r).collect()
}

/// The per-lambda table: epochs, passes, final active count, screened
/// fraction, and the cd/gap/link time split.
pub fn lambda_table(events: &[Json]) -> String {
    let rows = lambda_rows(events);
    let mut out = String::new();
    if rows.is_empty() {
        out.push_str("no solver spans in trace (serve-only trace? try `summarize`)\n");
        return out;
    }
    out.push_str(&format!(
        "{:>12} {:>7} {:>6} {:>7} {:>6} {:>9} {:>9} {:>9} {:>9} {:>5} {:>7} {:>4}\n",
        "lambda", "epochs", "passes", "active", "scr%", "cd_s", "gap_s", "link_s", "total_s",
        "kkt", "ledger", "conv"
    ));
    for r in &rows {
        let scr = if r.initial > 0 {
            100.0 * (1.0 - r.active as f64 / r.initial as f64)
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:>12.6e} {:>7} {:>6} {:>7} {:>5.1}% {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>5} \
             {:>7} {:>4}\n",
            r.lam,
            r.epochs,
            r.passes,
            r.active,
            scr,
            r.cd_secs,
            r.gap_secs,
            r.link_secs,
            r.total_secs,
            r.kkt,
            r.ledger,
            if r.converged { "yes" } else { "NO" }
        ));
    }
    out
}

/// Aggregate phase breakdown as text bars: CD epochs (excluding link
/// refreshes), link refreshes, gap passes, and the unattributed rest.
pub fn flame(events: &[Json]) -> String {
    let mut cd = 0.0;
    let mut gap = 0.0;
    let mut link = 0.0;
    let mut total = 0.0;
    for ev in typed(events, "solve") {
        cd += num(ev, "cd_secs");
        gap += num(ev, "gap_secs");
        link += num(ev, "link_secs");
        total += num(ev, "total_secs");
    }
    let mut out = String::new();
    if total <= 0.0 {
        out.push_str("no solver time recorded in trace\n");
        return out;
    }
    let cd_only = (cd - link).max(0.0);
    let other = (total - cd - gap).max(0.0);
    let phases =
        [("cd epochs", cd_only), ("link refresh", link), ("gap passes", gap), ("other", other)];
    for (name, secs) in phases {
        let frac = secs / total;
        let bar = "#".repeat((frac * 50.0).round() as usize);
        out.push_str(&format!("{name:>13} {secs:>9.4}s {:>5.1}% |{bar}\n", 100.0 * frac));
    }
    out.push_str(&format!("{:>13} {total:>9.4}s\n", "total"));
    out
}

/// Headline summary: event counts, solver rollup (lambdas, epochs, time
/// split) and — when present — the serve-side request/fit aggregates.
pub fn summarize(events: &[Json]) -> String {
    let mut out = String::new();
    out.push_str(&format!("events: {}\n", events.len()));
    // count per type, first-seen order
    let mut kinds: Vec<(String, usize)> = Vec::new();
    for ev in events {
        let k = ev.get("type").and_then(|t| t.as_str()).unwrap_or("?").to_string();
        match kinds.iter_mut().find(|(n, _)| *n == k) {
            Some((_, c)) => *c += 1,
            None => kinds.push((k, 1)),
        }
    }
    for (k, c) in &kinds {
        out.push_str(&format!("  {k:>10} x{c}\n"));
    }
    if let Some(start) = typed(events, "path_start").next() {
        out.push_str(&format!(
            "path: {} lambdas, lam_max {:.6e}, threads {}, kernel {}\n",
            unum(start, "n_lambdas"),
            num(start, "lam_max"),
            unum(start, "threads"),
            start.get("kernel").and_then(|v| v.as_str()).unwrap_or("?"),
        ));
    }
    let rows = lambda_rows(events);
    if !rows.is_empty() {
        out.push_str(&format!(
            "solver: {} lambdas, {} epochs, {} gap passes, {:.4}s\n",
            rows.len(),
            rows.iter().map(|r| r.epochs).sum::<usize>(),
            rows.iter().map(|r| r.passes).sum::<usize>(),
            rows.iter().map(|r| r.total_secs).sum::<f64>(),
        ));
        out.push('\n');
        out.push_str(&lambda_table(events));
        out.push('\n');
        out.push_str(&flame(events));
    }
    // provenance-ledger rollup, when the trace carries one
    let n_cols = typed(events, "screen_col").count();
    let n_centers = typed(events, "sphere_center").count();
    let n_react = typed(events, "reactivate").count();
    let n_certs = typed(events, "certificate").count();
    if n_cols + n_centers + n_react + n_certs > 0 {
        out.push_str(&format!(
            "\nledger: {n_cols} screen_col, {n_centers} sphere_center, {n_react} reactivate, \
             {n_certs} certificate(s)\n"
        ));
        let mut per: Vec<(String, usize)> = Vec::new();
        for ev in typed(events, "screen_col") {
            let r = ev.get("rule").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            match per.iter_mut().find(|(name, _)| *name == r) {
                Some((_, c)) => *c += 1,
                None => per.push((r, 1)),
            }
        }
        if !per.is_empty() {
            out.push_str("screened columns by rule:\n");
            for (r, c) in &per {
                out.push_str(&format!("  {r:>16} x{c}\n"));
            }
        }
        out.push_str("(re-check every kill with `gapsafe trace verify --in <trace> ...`)\n");
    }
    // serve-side aggregates, when the trace came from `serve --trace-out`
    let mut endpoints: Vec<(String, usize, f64)> = Vec::new();
    for ev in typed(events, "request") {
        let e = ev.get("endpoint").and_then(|v| v.as_str()).unwrap_or("?").to_string();
        let secs = num(ev, "secs");
        match endpoints.iter_mut().find(|(n, _, _)| *n == e) {
            Some((_, c, s)) => {
                *c += 1;
                *s += secs;
            }
            None => endpoints.push((e, 1, secs)),
        }
    }
    if !endpoints.is_empty() {
        out.push_str("\nrequests:\n");
        for (e, c, s) in &endpoints {
            out.push_str(&format!(
                "  {e:>8} x{c:<6} total {s:.4}s  mean {:.6}s\n",
                s / *c as f64
            ));
        }
    }
    let fits: Vec<&Json> = typed(events, "fit").collect();
    if !fits.is_empty() {
        for kind in ["cold", "warm", "hit"] {
            let of_kind: Vec<&&Json> = fits
                .iter()
                .filter(|f| f.get("kind").and_then(|v| v.as_str()) == Some(kind))
                .collect();
            if !of_kind.is_empty() {
                let secs: f64 = of_kind.iter().map(|f| num(f, "secs")).sum();
                out.push_str(&format!("fits ({kind}): x{} total {secs:.4}s\n", of_kind.len()));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Offline safety-certificate verifier (`gapsafe trace verify`).
//
// Re-checks every provenance-ledger record against the raw design matrix
// with a deliberately *decoupled* implementation: plain serial dot
// products over `Design` columns, local soft-thresholding, local radius
// formulas — none of the kernel engine, solver, or production screening
// code paths. If the solver's screening ever discarded a column it should
// not have, the recomputation here disagrees and the CLI exits nonzero.
// ---------------------------------------------------------------------------

/// Comparison tolerance between a recomputed statistic and its recorded
/// value: absorbs kernel-vs-naive summation-order noise (~1e-13 relative)
/// while still catching any real corruption.
const VERIFY_TOL: f64 = 1e-6;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= VERIFY_TOL * (1.0 + a.abs().max(b.abs()))
}

/// Is the sphere inequality `stat + r*norm < thresh` satisfied up to
/// tolerance? Non-finite left-hand sides (NaN radius on a non-strong
/// record, corrupted fields) fail — a kill must have a finite argument.
fn sound(stat: f64, r: f64, norm: f64, thresh: f64) -> bool {
    let lhs = stat + r * norm;
    lhs.is_finite() && lhs < thresh + VERIFY_TOL * (1.0 + lhs.abs())
}

/// f64 field access where absent/null (the JSON image of NaN) maps to NaN
/// instead of 0.0 — the ledger serializes the strong rule's radius-free
/// records that way.
fn fnum(ev: &Json, key: &str) -> f64 {
    ev.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
}

fn f64_arr(ev: &Json, key: &str) -> Option<Vec<f64>> {
    match ev.get(key)? {
        Json::Arr(xs) => Some(xs.iter().map(|x| x.as_f64().unwrap_or(f64::NAN)).collect()),
        _ => None,
    }
}

fn usize_arr(ev: &Json, key: &str) -> Option<Vec<usize>> {
    match ev.get(key)? {
        Json::Arr(xs) => xs.iter().map(|x| x.as_usize()).collect(),
        _ => None,
    }
}

/// Serial dot of design column j with an n-vector — deliberately NOT
/// `Design::col_dot`, which routes through the SIMD kernel engine the
/// verifier must stay independent of.
fn naive_col_dot(x: &Design, j: usize, v: &[f64]) -> f64 {
    match x {
        Design::Dense(m) => m.col(j).iter().zip(v).map(|(a, b)| a * b).sum(),
        Design::Sparse(s) => {
            let (rows, vals) = s.col(j);
            rows.iter().zip(vals).map(|(&r, &a)| a * v[r]).sum()
        }
    }
}

fn naive_col_norm(x: &Design, j: usize) -> f64 {
    match x {
        Design::Dense(m) => m.col(j).iter().map(|a| a * a).sum::<f64>().sqrt(),
        Design::Sparse(s) => s.col(j).1.iter().map(|a| a * a).sum::<f64>().sqrt(),
    }
}

/// ||X_g^T Theta||_F by naive per-column dots (`theta` column-major n*q).
fn naive_group_frob(x: &Design, feats: &[usize], theta: &[f64], n: usize, q: usize) -> f64 {
    let mut s = 0.0;
    for &j in feats {
        for c in 0..q {
            let d = naive_col_dot(x, j, &theta[c * n..(c + 1) * n]);
            s += d * d;
        }
    }
    s.sqrt()
}

/// Local soft-threshold (no dependence on the linalg helpers).
fn soft(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

/// Everything `verify` counted and found. `violations` empty = the trace
/// is certified against the data.
#[derive(Debug, Default)]
pub struct VerifyReport {
    pub certificates: usize,
    pub sphere_centers: usize,
    pub screen_cols: usize,
    pub reactivations: usize,
    pub violations: Vec<String>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "checked {} certificate(s), {} screened column(s) at {} sphere center(s), \
             {} reactivation(s)\n",
            self.certificates, self.screen_cols, self.sphere_centers, self.reactivations
        );
        if self.ok() {
            out.push_str(
                "VERIFIED: every recorded screening decision re-checks against the data\n",
            );
        } else {
            out.push_str(&format!("{} VIOLATION(S):\n", self.violations.len()));
            for v in &self.violations {
                out.push_str("  ");
                out.push_str(v);
                out.push('\n');
            }
        }
        out
    }
}

/// Dual-ball feasibility Omega^D(X^T theta) <= 1 of a recorded dual
/// point, rebuilt from first principles per penalty family (for SGL via
/// the Prop. 7 ball characterization ||S_tau(X_g^T theta)||_2 <=
/// (1-tau) w_g, which avoids the production epsilon-norm code entirely).
fn ball_violation(prob: &Problem, theta: &[f64], tag: &str) -> Option<String> {
    let (n, q) = (prob.n(), prob.q());
    let groups = prob.pen.groups();
    match prob.pen.kind() {
        PenaltyKind::L1 => {
            for j in 0..prob.p() {
                let s = naive_group_frob(&prob.x, &[j], theta, n, q);
                if s > 1.0 + VERIFY_TOL {
                    return Some(format!(
                        "{tag}: dual point infeasible: |x_{j}^T theta| = {s:e} > 1"
                    ));
                }
            }
        }
        PenaltyKind::GroupL2 => {
            for g in 0..groups.len() {
                let s = naive_group_frob(&prob.x, groups.feats(g), theta, n, q)
                    / prob.pen.group_weight(g);
                if s > 1.0 + VERIFY_TOL {
                    return Some(format!(
                        "{tag}: dual point infeasible: ||X_g^T theta|| / w_g = {s:e} > 1 \
                         (group {g})"
                    ));
                }
            }
        }
        PenaltyKind::SparseGroup => {
            let tau = prob.pen.tau().unwrap_or(1.0);
            for g in 0..groups.len() {
                let w = prob.pen.group_weight(g);
                let mut stsq = 0.0;
                for &j in groups.feats(g) {
                    let t = soft(naive_col_dot(&prob.x, j, &theta[..n]), tau);
                    stsq += t * t;
                }
                let lhs = stsq.sqrt();
                let rhs = (1.0 - tau) * w;
                if lhs > rhs + VERIFY_TOL * (1.0 + rhs) {
                    return Some(format!(
                        "{tag}: dual point infeasible: ||S_tau(X_g^T theta)|| = {lhs:e} > \
                         (1-tau) w_g = {rhs:e} (group {g})"
                    ));
                }
            }
        }
    }
    None
}

/// Datafit-side feasibility of a recorded dual point. Only Poisson
/// constrains it: v_i = y_i - lam*theta_i must be nonnegative for the KL
/// conjugate (logistic/multinomial duals clamp into their domain, so any
/// ball-feasible theta already yields a valid bound there).
fn domain_violation(prob: &Problem, lam: f64, theta: &[f64], tag: &str) -> Option<String> {
    if prob.fit.kind().label() != "poisson" {
        return None;
    }
    for (i, (&yi, &ti)) in prob.fit.targets().as_slice().iter().zip(theta).enumerate() {
        let v = yi - lam * ti;
        if v < -VERIFY_TOL * (1.0 + yi.abs()) {
            return Some(format!(
                "{tag}: dual point outside KL domain: y_{i} - lam*theta_{i} = {v:e} < 0"
            ));
        }
    }
    None
}

/// The Gap Safe radius the recorded (gap, lam, theta) induce, rebuilt
/// locally: sqrt(2 gap / gamma) / lam with gamma = 1 (quadratic,
/// multinomial) or 4 (logistic); Poisson uses the locally bounded form
/// (gap + sqrt(gap^2 + 2 gap v_max)) / lam with v_max = max_i (y_i -
/// lam theta_i)_+.
fn expected_radius(fit: &str, gap: f64, lam: f64, theta: &[f64], y: &[f64]) -> Option<f64> {
    let gap = gap.max(0.0);
    match fit {
        "quadratic" | "multinomial" => Some((2.0 * gap).sqrt() / lam),
        "logistic" => Some((2.0 * gap / 4.0).sqrt() / lam),
        "poisson" => {
            let mut v_max = 0.0_f64;
            for (&yi, &ti) in y.iter().zip(theta) {
                v_max = v_max.max(yi - lam * ti);
            }
            Some((gap + (gap * gap + 2.0 * gap * v_max).sqrt()) / lam)
        }
        _ => None,
    }
}

/// Re-check a provenance ledger against the raw design: every
/// [`crate::obs::Event::ScreenCol`] must satisfy its sphere inequality at
/// its recorded center with a recomputed statistic, every
/// [`crate::obs::Event::Certificate`]'s dual point must be feasible with
/// a radius that matches its gap, and replaying each solve's kill /
/// reactivation stream from its initial set must land exactly on the
/// certified final support.
pub fn verify(events: &[Json], prob: &Problem) -> VerifyReport {
    let mut rep = VerifyReport::default();
    let (n, q, p) = (prob.n(), prob.q(), prob.p());
    let groups = prob.pen.groups();
    let ng = groups.len();
    let kind = prob.pen.kind();
    let tau_opt = prob.pen.tau();
    let x = &prob.x;

    // --- sphere centers, indexed by cid -----------------------------------
    let mut centers: BTreeMap<u64, (&Json, Vec<f64>)> = BTreeMap::new();
    for ev in typed(events, "sphere_center") {
        rep.sphere_centers += 1;
        let cid = unum(ev, "cid") as u64;
        if unum(ev, "n") != n || unum(ev, "q") != q {
            rep.violations.push(format!(
                "sphere_center cid={cid}: dual shape {}x{} does not match data {n}x{q}",
                unum(ev, "n"),
                unum(ev, "q")
            ));
            continue;
        }
        let theta = match f64_arr(ev, "theta") {
            Some(t) if t.len() == n * q => t,
            _ => {
                rep.violations
                    .push(format!("sphere_center cid={cid}: theta missing or wrong length"));
                continue;
            }
        };
        let site = ev.get("site").and_then(|s| s.as_str()).unwrap_or("?");
        let rule = ev.get("rule").and_then(|s| s.as_str()).unwrap_or("?");
        let radius = fnum(ev, "radius");
        let tag = format!("sphere_center cid={cid} rule={rule}");
        if site == "strong" {
            if !radius.is_nan() {
                rep.violations.push(format!("{tag}: strong site with a sphere radius"));
            }
        } else {
            if !(radius.is_finite() && radius >= 0.0) {
                rep.violations.push(format!("{tag}: non-finite sphere radius {radius}"));
            }
            // Gap Safe spheres are only safe at a *feasible* center (the
            // gap-radius bound needs D(theta) on the dual domain); the
            // DST3/El Ghaoui geometric spheres carry their own arguments
            // and may legitimately use out-of-ball centers.
            if rule.contains("gap") {
                if let Some(v) = ball_violation(prob, &theta, &tag) {
                    rep.violations.push(v);
                }
                if let Some(v) = domain_violation(prob, fnum(ev, "lam"), &theta, &tag) {
                    rep.violations.push(v);
                }
            }
        }
        if centers.insert(cid, (ev, theta)).is_some() {
            rep.violations.push(format!("sphere_center cid={cid}: duplicate cid"));
        }
    }

    // --- every screened column, re-tested at its recorded center ---------
    for ev in typed(events, "screen_col") {
        rep.screen_cols += 1;
        let sid = unum(ev, "sid") as u64;
        let cid = unum(ev, "cid") as u64;
        let j = unum(ev, "j");
        let g = unum(ev, "group");
        let test = ev.get("test").and_then(|t| t.as_str()).unwrap_or("?");
        let tag = format!("screen_col sid={sid} cid={cid} j={j} test={test}");
        if j >= p || g >= ng || groups.group_of(j) != g {
            rep.violations
                .push(format!("{tag}: column/group indices out of range or mismatched"));
            continue;
        }
        let stat = fnum(ev, "stat");
        let norm = fnum(ev, "norm");
        let radius = fnum(ev, "radius");
        let thresh = fnum(ev, "thresh");
        let margin = fnum(ev, "margin");
        let Some((cev, theta)) = centers.get(&cid) else {
            rep.violations.push(format!("{tag}: no sphere_center with this cid"));
            continue;
        };
        let cev: &Json = cev;
        if unum(cev, "sid") as u64 != sid
            || fnum(cev, "lam").to_bits() != fnum(ev, "lam").to_bits()
            || unum(cev, "epoch") != unum(ev, "epoch")
        {
            rep.violations
                .push(format!("{tag}: sid/lam/epoch disagree with its sphere_center"));
        }
        let c_rad = fnum(cev, "radius");
        if radius.to_bits() != c_rad.to_bits() && !(radius.is_nan() && c_rad.is_nan()) {
            rep.violations
                .push(format!("{tag}: radius {radius:e} != sphere radius {c_rad:e}"));
        }
        // bookkeeping: recorded margin must be thresh - stat - r*norm
        // (radius-free for the strong heuristic).
        let margin_want =
            if radius.is_nan() { thresh - stat } else { thresh - stat - radius * norm };
        if !close(margin, margin_want) {
            rep.violations.push(format!(
                "{tag}: margin {margin:e} inconsistent with thresh - stat - r*norm = \
                 {margin_want:e}"
            ));
        }
        let feats = groups.feats(g);
        match test {
            "l1" => {
                let stat_re = naive_group_frob(x, &[j], theta, n, q);
                let norm_re = naive_col_norm(x, j);
                if !close(stat_re, stat) {
                    rep.violations.push(format!(
                        "{tag}: recorded stat {stat:e}, recomputed |x_j^T theta| = {stat_re:e}"
                    ));
                }
                if !close(norm_re, norm) {
                    rep.violations.push(format!(
                        "{tag}: recorded norm {norm:e}, recomputed ||x_j|| = {norm_re:e}"
                    ));
                }
                if !close(thresh, 1.0 - SCREEN_MARGIN) {
                    rep.violations
                        .push(format!("{tag}: l1 threshold {thresh:e} is not 1 - margin"));
                }
                if !sound(stat_re, radius, norm_re, thresh) {
                    rep.violations.push(format!(
                        "{tag}: UNSAFE kill: |x_j^T theta| + r*||x_j|| = {:e} >= {thresh:e}",
                        stat_re + radius * norm_re
                    ));
                }
            }
            "group" => {
                let w = prob.pen.group_weight(g);
                let stat_re = naive_group_frob(x, feats, theta, n, q) / w;
                if !close(stat_re, stat) {
                    rep.violations.push(format!(
                        "{tag}: recorded stat {stat:e}, recomputed ||X_g^T theta||/w_g = \
                         {stat_re:e}"
                    ));
                }
                if !close(thresh, 1.0 - SCREEN_MARGIN) {
                    rep.violations
                        .push(format!("{tag}: group threshold {thresh:e} is not 1 - margin"));
                }
                // The recorded slope is a spectral-norm *estimate*; it is
                // safe iff it upper-bounds sigma_max, which pins it into
                // [max_j ||x_j||, Frobenius].
                let col2: Vec<f64> = feats.iter().map(|&f| naive_col_norm(x, f)).collect();
                let maxc = col2.iter().cloned().fold(0.0, f64::max);
                let frob = col2.iter().map(|c| c * c).sum::<f64>().sqrt();
                let spec = norm * w;
                if spec < maxc * (1.0 - VERIFY_TOL) - VERIFY_TOL
                    || spec > frob * (1.0 + VERIFY_TOL) + VERIFY_TOL
                {
                    rep.violations.push(format!(
                        "{tag}: recorded operator norm {spec:e} outside safe window \
                         [{maxc:e}, {frob:e}]"
                    ));
                }
                if !sound(stat_re, radius, norm, thresh) {
                    rep.violations.push(format!(
                        "{tag}: UNSAFE group kill: stat + r*norm = {:e} >= {thresh:e}",
                        stat_re + radius * norm
                    ));
                }
            }
            "sgl-group" => {
                let (Some(tau), true) = (tau_opt, q == 1) else {
                    rep.violations
                        .push(format!("{tag}: SGL record but the penalty is not SGL"));
                    continue;
                };
                let w = prob.pen.group_weight(g);
                let mut stsq = 0.0;
                let mut ma = 0.0_f64;
                for &f in feats {
                    let d = naive_col_dot(x, f, &theta[..n]);
                    ma = ma.max(d.abs());
                    let t = soft(d, tau);
                    stsq += t * t;
                }
                let st_norm = stsq.sqrt();
                let stat_re = if ma > tau { st_norm } else { ma - tau };
                if !close(stat_re, stat) {
                    rep.violations.push(format!(
                        "{tag}: recorded stat {stat:e}, recomputed SGL group stat = {stat_re:e}"
                    ));
                }
                if !close(thresh, (1.0 - tau) * w - SCREEN_MARGIN) {
                    rep.violations.push(format!(
                        "{tag}: SGL group threshold {thresh:e} is not (1-tau) w_g - margin"
                    ));
                }
                let col2: Vec<f64> = feats.iter().map(|&f| naive_col_norm(x, f)).collect();
                let maxc = col2.iter().cloned().fold(0.0, f64::max);
                let frob = col2.iter().map(|c| c * c).sum::<f64>().sqrt();
                if norm < maxc * (1.0 - VERIFY_TOL) - VERIFY_TOL
                    || norm > frob * (1.0 + VERIFY_TOL) + VERIFY_TOL
                {
                    rep.violations.push(format!(
                        "{tag}: recorded operator norm {norm:e} outside safe window \
                         [{maxc:e}, {frob:e}]"
                    ));
                }
                // the exact two-branch test of Prop. 8 at the recorded radius
                let rx = radius * norm;
                let t_g = if ma > tau { st_norm + rx } else { (ma + rx - tau).max(0.0) };
                if !(t_g.is_finite() && t_g < thresh + VERIFY_TOL * (1.0 + t_g.abs())) {
                    rep.violations.push(format!(
                        "{tag}: UNSAFE group kill: T_g = {t_g:e} >= {thresh:e}"
                    ));
                }
            }
            "sgl-feat" => {
                let (Some(tau), true) = (tau_opt, q == 1) else {
                    rep.violations
                        .push(format!("{tag}: SGL record but the penalty is not SGL"));
                    continue;
                };
                let stat_re = naive_col_dot(x, j, &theta[..n]).abs();
                let norm_re = naive_col_norm(x, j);
                if !close(stat_re, stat) {
                    rep.violations.push(format!(
                        "{tag}: recorded stat {stat:e}, recomputed |x_j^T theta| = {stat_re:e}"
                    ));
                }
                if !close(norm_re, norm) {
                    rep.violations.push(format!(
                        "{tag}: recorded norm {norm:e}, recomputed ||x_j|| = {norm_re:e}"
                    ));
                }
                if !close(thresh, tau - SCREEN_MARGIN) {
                    rep.violations.push(format!(
                        "{tag}: SGL feature threshold {thresh:e} is not tau - margin"
                    ));
                }
                if !sound(stat_re, radius, norm_re, thresh) {
                    rep.violations.push(format!(
                        "{tag}: UNSAFE feature kill: |x_j^T theta| + r*||x_j|| = {:e} >= \
                         {thresh:e}",
                        stat_re + radius * norm_re
                    ));
                }
            }
            "strong" => {
                // Heuristic site: no sphere, no safety claim — verify the
                // recorded statistic is faithful and its inequality held.
                if !radius.is_nan() {
                    rep.violations.push(format!("{tag}: strong record with a radius"));
                }
                let stat_re = match kind {
                    PenaltyKind::L1 => naive_group_frob(x, &[j], theta, n, q),
                    PenaltyKind::GroupL2 => {
                        naive_group_frob(x, feats, theta, n, q) / prob.pen.group_weight(g)
                    }
                    PenaltyKind::SparseGroup => {
                        let tau = tau_opt.unwrap_or(1.0);
                        let w = prob.pen.group_weight(g);
                        let mut stsq = 0.0;
                        let mut ma = 0.0_f64;
                        for &f in feats {
                            let d = naive_col_dot(x, f, &theta[..n]);
                            ma = ma.max(d.abs());
                            let t = soft(d, tau);
                            stsq += t * t;
                        }
                        if tau < 1.0 && w > 0.0 {
                            stsq.sqrt() / ((1.0 - tau) * w)
                        } else {
                            ma
                        }
                    }
                };
                if !close(stat_re, stat) {
                    rep.violations.push(format!(
                        "{tag}: recorded strong stat {stat:e}, recomputed {stat_re:e}"
                    ));
                }
                if !(stat < thresh) {
                    rep.violations
                        .push(format!("{tag}: strong kill with stat {stat:e} >= {thresh:e}"));
                }
            }
            other => {
                rep.violations.push(format!("{tag}: unknown test kind {other:?}"));
            }
        }
    }

    // --- certificates + per-solve support replay --------------------------
    let mut certs: BTreeMap<u64, &Json> = BTreeMap::new();
    for ev in typed(events, "certificate") {
        rep.certificates += 1;
        let sid = unum(ev, "sid") as u64;
        if certs.insert(sid, ev).is_some() {
            rep.violations.push(format!("certificate sid={sid}: duplicate certificate"));
        }
    }
    // ordered kill/reactivation stream per solve (file order is emission
    // order: the ledger is append-only and a solve is single-threaded)
    let mut streams: BTreeMap<u64, Vec<&Json>> = BTreeMap::new();
    for ev in events {
        match ev.get("type").and_then(|t| t.as_str()) {
            Some("screen_col") => {
                streams.entry(unum(ev, "sid") as u64).or_default().push(ev);
            }
            Some("reactivate") => {
                rep.reactivations += 1;
                streams.entry(unum(ev, "sid") as u64).or_default().push(ev);
            }
            _ => {}
        }
    }
    for &sid in streams.keys() {
        if sid == 0 {
            rep.violations
                .push("ledger events with sid=0 (emitted outside any solve)".to_string());
        } else if !certs.contains_key(&sid) {
            rep.violations
                .push(format!("solve sid={sid} screened columns but left no certificate"));
        }
    }
    for (&sid, &cert) in &certs {
        let tag = format!("certificate sid={sid}");
        if unum(cert, "n") != n || unum(cert, "q") != q || unum(cert, "p") != p {
            rep.violations.push(format!(
                "{tag}: shape (n={}, q={}, p={}) does not match data (n={n}, q={q}, p={p})",
                unum(cert, "n"),
                unum(cert, "q"),
                unum(cert, "p")
            ));
            continue;
        }
        let fit = cert.get("fit").and_then(|f| f.as_str()).unwrap_or("?");
        if fit != prob.fit.kind().label() {
            rep.violations.push(format!(
                "{tag}: datafit {fit:?} does not match data ({:?})",
                prob.fit.kind().label()
            ));
            continue;
        }
        let lam = fnum(cert, "lam");
        let gap = fnum(cert, "gap");
        let radius = fnum(cert, "radius");
        if !(lam > 0.0 && lam.is_finite()) {
            rep.violations.push(format!("{tag}: bad lambda {lam}"));
            continue;
        }
        if !(gap >= -1e-9) {
            rep.violations.push(format!("{tag}: negative duality gap {gap:e}"));
        }
        let theta = match f64_arr(cert, "theta") {
            Some(t) if t.len() == n * q && t.iter().all(|v| v.is_finite()) => t,
            _ => {
                rep.violations
                    .push(format!("{tag}: theta missing, wrong length, or non-finite"));
                continue;
            }
        };
        if let Some(v) = ball_violation(prob, &theta, &tag) {
            rep.violations.push(v);
        }
        if let Some(v) = domain_violation(prob, lam, &theta, &tag) {
            rep.violations.push(v);
        }
        match expected_radius(fit, gap, lam, &theta, prob.fit.targets().as_slice()) {
            Some(want) => {
                if !close(radius, want) {
                    rep.violations.push(format!(
                        "{tag}: recorded radius {radius:e}, but gap {gap:e} induces {want:e}"
                    ));
                }
            }
            None => rep.violations.push(format!("{tag}: unknown datafit label {fit:?}")),
        }
        // replay the kill/reactivation stream from the initial set and
        // compare with the certified final support
        let Some(support) = usize_arr(cert, "support") else {
            rep.violations.push(format!("{tag}: support missing or malformed"));
            continue;
        };
        let initial = match cert.get("initial") {
            None | Some(Json::Null) => None,
            Some(_) => match usize_arr(cert, "initial") {
                Some(idx) => Some(idx),
                None => {
                    rep.violations.push(format!("{tag}: initial set malformed"));
                    continue;
                }
            },
        };
        let mut act = vec![initial.is_none(); p];
        if let Some(idx) = &initial {
            for &f in idx {
                if f < p {
                    act[f] = true;
                } else {
                    rep.violations
                        .push(format!("{tag}: initial feature {f} out of range"));
                }
            }
        }
        for &sev in streams.get(&sid).map(|v| v.as_slice()).unwrap_or(&[]) {
            match sev.get("type").and_then(|t| t.as_str()) {
                Some("screen_col") => {
                    let f = unum(sev, "j");
                    if f < p {
                        if !act[f] {
                            rep.violations.push(format!(
                                "{tag}: replay screened column {f} while it was already \
                                 inactive"
                            ));
                        }
                        act[f] = false;
                    }
                }
                Some("reactivate") => {
                    let g = unum(sev, "group");
                    if g < ng {
                        for &f in groups.feats(g) {
                            act[f] = true;
                        }
                    } else {
                        rep.violations
                            .push(format!("{tag}: reactivated group {g} out of range"));
                    }
                }
                _ => {}
            }
        }
        let replayed: Vec<usize> = (0..p).filter(|&f| act[f]).collect();
        let mut want = support.clone();
        want.sort_unstable();
        if replayed != want {
            rep.violations.push(format!(
                "{tag}: support replay mismatch: certificate lists {} feature(s), replaying \
                 the ledger gives {}",
                support.len(),
                replayed.len()
            ));
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Event;

    fn demo_events() -> Vec<Json> {
        vec![
            Event::PathStart { n_lambdas: 2, lam_max: 2.0, threads: 1, kernel: "scalar" }
                .to_json(),
            Event::GapPass {
                lam: 1.0,
                epoch: 0,
                gap: 0.5,
                radius: 0.3,
                active_groups: 40,
                active_feats: 40,
                screened: 60,
                view_cols: 100,
                dual_choice: "fresh",
                secs: 1e-4,
            }
            .to_json(),
            Event::SolveSpan {
                lam: 1.0,
                epochs: 30,
                gap_passes: 4,
                gap: 1e-9,
                converged: true,
                kkt_violations: 0,
                active_feats: 10,
                cd_secs: 0.03,
                gap_secs: 0.01,
                link_secs: 0.005,
                total_secs: 0.05,
                kernel: "scalar",
            }
            .to_json(),
            Event::SolveSpan {
                lam: 0.5,
                epochs: 50,
                gap_passes: 6,
                gap: 1e-9,
                converged: true,
                kkt_violations: 1,
                active_feats: 20,
                cd_secs: 0.08,
                gap_secs: 0.02,
                link_secs: 0.0,
                total_secs: 0.11,
                kernel: "scalar",
            }
            .to_json(),
        ]
    }

    #[test]
    fn lambda_table_rolls_up_by_lambda() {
        let t = lambda_table(&demo_events());
        assert!(t.contains("lambda"), "missing header: {t}");
        // two distinct lambdas -> header + 2 rows
        assert_eq!(t.lines().count(), 3, "{t}");
        // screened fraction of lam=1.0: initial 100 (40 active + 60
        // screened), final 10 -> 90%
        assert!(t.contains("90.0%"), "{t}");
    }

    #[test]
    fn flame_attributes_all_time() {
        let f = flame(&demo_events());
        assert!(f.contains("cd epochs"));
        assert!(f.contains("link refresh"));
        assert!(f.contains("gap passes"));
        assert!(f.contains("total"));
    }

    #[test]
    fn summarize_counts_and_embeds_table() {
        let s = summarize(&demo_events());
        assert!(s.contains("events: 4"));
        assert!(s.contains("solve x2"));
        assert!(s.contains("lambda")); // the embedded per-lambda table
        assert!(s.contains("kernel scalar"));
    }

    #[test]
    fn loader_is_lenient_only_for_the_trailing_line() {
        let path =
            std::env::temp_dir().join(format!("gapsafe_trace_bad_{}.jsonl", std::process::id()));
        // malformed NON-trailing line: always a hard error, with line number
        std::fs::write(&path, "not json\n{\"type\":\"kkt\"}\n").unwrap();
        let err = load(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains(":1:"), "error should carry line number: {err}");
        // truncated trailing line (killed writer): dropped by default...
        std::fs::write(&path, "{\"type\":\"kkt\"}\n{\"type\":\"so").unwrap();
        let evs = load(path.to_str().unwrap()).unwrap();
        assert_eq!(evs.len(), 1, "one good event should survive");
        // ...but fatal under --strict, with its line number
        let err = load_opts(path.to_str().unwrap(), true).unwrap_err();
        assert!(err.contains(":2:"), "{err}");
        // a non-trailing line without a type tag is also always fatal
        std::fs::write(&path, "{\"no_tag\":1}\n{\"type\":\"kkt\"}\n").unwrap();
        let err = load(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("type"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    // ---- offline verifier -------------------------------------------------

    use crate::data::synth;
    use crate::problem::Problem;
    use crate::{build_problem, Task};

    /// A hand-built, internally consistent one-solve Lasso ledger: theta
    /// is the (feasible) lambda_max dual point, the gap is chosen so the
    /// induced radius screens some but not all columns, and every field
    /// is derived with the same naive arithmetic the verifier re-checks
    /// with — so the trace verifies cleanly until a test corrupts it.
    fn lasso_fixture() -> (Problem, f64, f64, f64, Vec<f64>) {
        let ds = synth::leukemia_like_scaled(20, 30, 3, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lmax = prob.lambda_max();
        let lam = 0.9 * lmax;
        let theta: Vec<f64> =
            prob.fit.targets().as_slice().iter().map(|v| v / lmax).collect();
        let radius = 0.05;
        let gap = 0.5 * (radius * lam) * (radius * lam);
        (prob, lam, gap, radius, theta)
    }

    fn fixture_events(
        prob: &Problem,
        lam: f64,
        gap: f64,
        radius: f64,
        theta: &[f64],
    ) -> (Vec<Json>, usize) {
        let (n, p) = (prob.n(), prob.p());
        let thresh = 1.0 - SCREEN_MARGIN;
        let mut evs = vec![Event::SphereCenter {
            sid: 1,
            cid: 2,
            lam,
            epoch: 0,
            rule: "gap-dyn",
            site: "dyn",
            radius,
            n,
            q: 1,
            theta: theta.to_vec(),
        }
        .to_json()];
        let mut support = Vec::new();
        let mut kills = 0;
        for j in 0..p {
            let stat = naive_col_dot(&prob.x, j, theta).abs();
            let norm = naive_col_norm(&prob.x, j);
            if stat + radius * norm < thresh {
                kills += 1;
                evs.push(
                    Event::ScreenCol {
                        sid: 1,
                        cid: 2,
                        lam,
                        epoch: 0,
                        rule: "gap-dyn",
                        test: "l1",
                        j,
                        group: j,
                        stat,
                        norm,
                        radius,
                        thresh,
                        margin: thresh - stat - radius * norm,
                    }
                    .to_json(),
                );
            } else {
                support.push(j);
            }
        }
        evs.push(
            Event::Certificate {
                sid: 1,
                lam,
                gap,
                radius,
                n,
                q: 1,
                p,
                theta: theta.to_vec(),
                support,
                initial: None,
                rule: "gap-dyn",
                fit: "quadratic",
            }
            .to_json(),
        );
        (evs, kills)
    }

    #[test]
    fn verify_accepts_a_consistent_synthetic_ledger() {
        let (prob, lam, gap, radius, theta) = lasso_fixture();
        let (evs, kills) = fixture_events(&prob, lam, gap, radius, &theta);
        assert!(
            kills >= 1 && kills < prob.p(),
            "fixture should screen some but not all columns, got {kills}"
        );
        let rep = verify(&evs, &prob);
        assert!(rep.ok(), "unexpected violations: {:#?}", rep.violations);
        assert_eq!(rep.certificates, 1);
        assert_eq!(rep.screen_cols, kills);
        assert!(rep.render().contains("VERIFIED"));
    }

    fn tamper(evs: &mut [Json], idx: usize, key: &str, v: f64) {
        if let Json::Obj(m) = &mut evs[idx] {
            m.insert(key.to_string(), Json::Num(v));
        }
    }

    #[test]
    fn verify_flags_hand_corrupted_traces() {
        let (prob, lam, gap, radius, theta) = lasso_fixture();
        let (evs, kills) = fixture_events(&prob, lam, gap, radius, &theta);
        assert!(kills >= 1);
        let last = evs.len() - 1; // the certificate
        let thresh = 1.0 - SCREEN_MARGIN;

        // (a) a lied-about correlation statistic on the first kill
        let mut bad = evs.clone();
        tamper(&mut bad, 1, "stat", 0.0);
        let rep = verify(&bad, &prob);
        assert!(rep.violations.iter().any(|v| v.contains("stat")), "{:#?}", rep.violations);

        // (b) an *unsafe* kill — the lambda_max column, whose true
        // statistic fails the sphere test, recorded faithfully: only the
        // independent re-test can reject it
        let mut bad = evs.clone();
        let j_max = (0..prob.p())
            .max_by(|&a, &b| {
                let sa = naive_col_dot(&prob.x, a, &theta).abs();
                let sb = naive_col_dot(&prob.x, b, &theta).abs();
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap();
        let stat = naive_col_dot(&prob.x, j_max, &theta).abs();
        let norm = naive_col_norm(&prob.x, j_max);
        bad.push(
            Event::ScreenCol {
                sid: 1,
                cid: 2,
                lam,
                epoch: 0,
                rule: "gap-dyn",
                test: "l1",
                j: j_max,
                group: j_max,
                stat,
                norm,
                radius,
                thresh,
                margin: thresh - stat - radius * norm,
            }
            .to_json(),
        );
        let rep = verify(&bad, &prob);
        assert!(rep.violations.iter().any(|v| v.contains("UNSAFE")), "{:#?}", rep.violations);

        // (c) a support lie: the certificate claims a screened column is
        // still active
        let mut bad = evs.clone();
        let killed_j = bad[1].get("j").and_then(|v| v.as_usize()).unwrap();
        if let Json::Obj(m) = &mut bad[last] {
            if let Some(Json::Arr(sup)) = m.get_mut("support") {
                sup.push(Json::Num(killed_j as f64));
            }
        }
        let rep = verify(&bad, &prob);
        assert!(rep.violations.iter().any(|v| v.contains("replay")), "{:#?}", rep.violations);

        // (d) an infeasible certificate dual point
        let mut bad = evs.clone();
        let blown: Vec<f64> = theta.iter().map(|t| 3.0 * t).collect();
        if let Json::Obj(m) = &mut bad[last] {
            m.insert("theta".to_string(), Json::arr_f64(&blown));
        }
        let rep = verify(&bad, &prob);
        assert!(
            rep.violations.iter().any(|v| v.contains("infeasible")),
            "{:#?}",
            rep.violations
        );

        // (e) a radius that does not match the recorded gap
        let mut bad = evs.clone();
        tamper(&mut bad, last, "radius", 2.0 * radius);
        let rep = verify(&bad, &prob);
        assert!(rep.violations.iter().any(|v| v.contains("radius")), "{:#?}", rep.violations);
    }

    #[test]
    fn verify_checks_poisson_local_radius_and_domain() {
        let ds = synth::poisson_like(16, 12, 5);
        let prob = build_problem(ds, Task::Poisson).unwrap();
        let lam = 0.7 * prob.lambda_max();
        // theta = 0 is always dual-feasible for KL (v_i = y_i >= 0)
        let theta = vec![0.0; prob.n()];
        let gap = 0.01;
        let v_max =
            prob.fit.targets().as_slice().iter().cloned().fold(0.0_f64, f64::max);
        let radius = (gap + (gap * gap + 2.0 * gap * v_max).sqrt()) / lam;
        let cert = |r: f64, th: &[f64]| {
            Event::Certificate {
                sid: 1,
                lam,
                gap,
                radius: r,
                n: prob.n(),
                q: 1,
                p: prob.p(),
                theta: th.to_vec(),
                support: (0..prob.p()).collect(),
                initial: None,
                rule: "gap-dyn",
                fit: "poisson",
            }
            .to_json()
        };
        let rep = verify(&[cert(radius, &theta)], &prob);
        assert!(rep.ok(), "{:#?}", rep.violations);
        // a quadratic-style radius is wrong for KL and must be flagged
        let wrong = (2.0 * gap).sqrt() / lam;
        let rep = verify(&[cert(wrong, &theta)], &prob);
        assert!(rep.violations.iter().any(|v| v.contains("radius")), "{:#?}", rep.violations);
        // a dual point with y - lam*theta < 0 is outside the KL domain
        let infeasible = vec![1e3; prob.n()];
        let rep = verify(&[cert(radius, &infeasible)], &prob);
        assert!(rep.violations.iter().any(|v| v.contains("domain")), "{:#?}", rep.violations);
    }
}
