//! Screening provenance ledger: identity + counters for the certificate
//! trail.
//!
//! The ledger gives every fixed-lambda solve a process-unique id (`sid`)
//! and every sphere application that discards columns a center id
//! (`cid`), so the JSONL events written by the tracing layer —
//! [`super::Event::SphereCenter`], [`super::Event::ScreenCol`],
//! [`super::Event::Reactivate`], [`super::Event::Certificate`] — can be
//! re-assembled into per-solve kill/repair histories by the offline
//! verifier (`gapsafe trace verify`).
//!
//! Identity flows through a **thread-local** context, not through solver
//! signatures: a fixed-lambda solve runs its screening decisions on the
//! calling thread (the screening fan-out parallelizes the correlation
//! sweep, never the kill loop), so [`begin_solve`] + [`set_epoch`] from
//! the solver are enough for every sphere site to stamp its events via
//! [`current`]. The scope guard restores the previous context on drop,
//! which keeps nested solves (working-set outer/inner, KKT repair
//! re-entry) correctly attributed.
//!
//! Everything here is ids and monotonic counters — no clocks, and nothing
//! read back into solver arithmetic, preserving the bitwise-transparency
//! contract of the tracing layer.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Process-wide id source for solves (`sid`) and sphere centers (`cid`).
/// Starts at 1 so 0 can mean "no context" in the events themselves.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh ledger id (relaxed: ids only need uniqueness).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Master switch for ledger *event emission* (ids and counters always
/// run). Lets the ledger bench separate PR 7 span-tracing cost from the
/// per-column provenance cost with the same sink installed.
static EMIT: AtomicBool = AtomicBool::new(true);

/// Enable/disable ledger event emission (spans still trace).
pub fn set_emit(on: bool) {
    EMIT.store(on, Ordering::Relaxed);
}

/// Should ledger events be emitted? Callers combine this with
/// [`super::enabled`]; both are relaxed loads.
#[inline]
pub fn emit_enabled() -> bool {
    EMIT.load(Ordering::Relaxed)
}

#[derive(Clone, Copy)]
struct Ctx {
    sid: u64,
    lam: f64,
    epoch: usize,
}

thread_local! {
    static CTX: Cell<Option<Ctx>> = const { Cell::new(None) };
}

/// Scope guard for one fixed-lambda solve; restores the outer context on
/// drop so nested solves stay correctly attributed.
pub struct SolveScope {
    prev: Option<Ctx>,
}

impl Drop for SolveScope {
    fn drop(&mut self) {
        CTX.with(|c| c.set(self.prev));
    }
}

/// Enter a solve: allocates its `sid` and makes (sid, lam, epoch=0) the
/// thread's current ledger context until the returned scope drops.
pub fn begin_solve(lam: f64) -> (u64, SolveScope) {
    let sid = next_id();
    let prev = CTX.with(|c| c.replace(Some(Ctx { sid, lam, epoch: 0 })));
    (sid, SolveScope { prev })
}

/// Update the epoch stamp for subsequent screening events in this solve.
pub fn set_epoch(epoch: usize) {
    CTX.with(|c| {
        if let Some(mut ctx) = c.get() {
            ctx.epoch = epoch;
            c.set(Some(ctx));
        }
    });
}

/// The current (sid, lam, epoch), or (0, NaN, 0) outside any solve (a
/// direct `sphere_screen` call from a test, say).
pub fn current() -> (u64, f64, usize) {
    match CTX.with(|c| c.get()) {
        Some(ctx) => (ctx.sid, ctx.lam, ctx.epoch),
        None => (0, f64::NAN, 0),
    }
}

/// The fixed per-rule label set for the screened-columns counters (the
/// `Rule` zoo labels; "other" catches anything new until it is added).
pub const RULE_LABELS: [&str; 10] = [
    "no-screening",
    "static-gap",
    "static-elghaoui",
    "dst3",
    "bonnefoy",
    "gap-seq",
    "gap-dyn",
    "gap-full",
    "strong",
    "other",
];

const ZERO: AtomicU64 = AtomicU64::new(0);
/// Monotonic per-rule screened-column totals (Prometheus counter
/// semantics; never reset, survive across solves and serve requests).
static SCREENED: [AtomicU64; RULE_LABELS.len()] = [ZERO; RULE_LABELS.len()];
/// Total columns entering solves (denominator for `screened_fraction`).
static COLS_SEEN: AtomicU64 = AtomicU64::new(0);

fn rule_slot(rule: &str) -> usize {
    RULE_LABELS.iter().position(|r| *r == rule).unwrap_or(RULE_LABELS.len() - 1)
}

/// Record `n` columns screened out by `rule`.
pub fn count_screened(rule: &str, n: usize) {
    if n > 0 {
        SCREENED[rule_slot(rule)].fetch_add(n as u64, Ordering::Relaxed);
    }
}

/// Record `p` columns entering a fixed-lambda solve.
pub fn count_cols(p: usize) {
    COLS_SEEN.fetch_add(p as u64, Ordering::Relaxed);
}

/// Per-rule screened totals, in [`RULE_LABELS`] order (zeros included so
/// the Prometheus family keeps a stable label set).
pub fn screened_by_rule() -> Vec<(&'static str, u64)> {
    RULE_LABELS
        .iter()
        .enumerate()
        .map(|(i, r)| (*r, SCREENED[i].load(Ordering::Relaxed)))
        .collect()
}

/// Total screened columns / total columns entering solves (0 before any
/// solve ran).
pub fn screened_fraction() -> f64 {
    let cols = COLS_SEEN.load(Ordering::Relaxed);
    if cols == 0 {
        return 0.0;
    }
    let screened: u64 = SCREENED.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    screened as f64 / cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_scopes_nest_and_restore() {
        assert_eq!(current().0, 0);
        let (sid_outer, _outer) = begin_solve(0.5);
        assert_eq!(current().0, sid_outer);
        set_epoch(7);
        assert_eq!(current().2, 7);
        {
            let (sid_inner, _inner) = begin_solve(0.25);
            assert_ne!(sid_inner, sid_outer);
            assert_eq!(current(), (sid_inner, 0.25, 0));
        }
        // inner scope dropped: outer context (including its epoch) is back
        let (sid, lam, epoch) = current();
        assert_eq!((sid, epoch), (sid_outer, 7));
        assert_eq!(lam, 0.5);
        drop(_outer);
        assert_eq!(current().0, 0);
    }

    #[test]
    fn ids_are_unique_and_nonzero() {
        let a = next_id();
        let b = next_id();
        assert!(a > 0 && b > a);
    }

    #[test]
    fn counters_accumulate_and_fraction_is_bounded() {
        // Other tests share the process-globals; only check monotonicity.
        let before = screened_by_rule();
        count_cols(100);
        count_screened("gap-seq", 40);
        count_screened("not-a-rule", 2); // lands in "other"
        let after = screened_by_rule();
        let get = |v: &[(&str, u64)], r: &str| v.iter().find(|(n, _)| *n == r).unwrap().1;
        assert_eq!(get(&after, "gap-seq") - get(&before, "gap-seq"), 40);
        assert_eq!(get(&after, "other") - get(&before, "other"), 2);
        let f = screened_fraction();
        assert!(f.is_finite() && f >= 0.0, "fraction out of range: {f}");
    }
}
