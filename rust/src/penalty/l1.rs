//! The ell_1 penalty (Lasso, Sec. 4.1; also the l1-logistic case of Sec. 4.4).
//!
//! Groups are singletons; with q > 1 this module is NOT used — row groups
//! with q > 1 belong to `GroupL2` (multi-task, Sec. 4.5).

use super::{
    ActiveSet, GroupNorms, Groups, KillRecord, Penalty, PenaltyKind, ScreenStats,
};
use crate::linalg::sparse::Design;
use crate::linalg::{norm1, st, Mat};

/// Omega(beta) = ||beta||_1,  Omega^D = ||.||_inf  (Table 1).
#[derive(Debug, Clone)]
pub struct L1 {
    groups: Groups,
}

impl L1 {
    pub fn new(p: usize) -> Self {
        L1 { groups: Groups::singletons(p) }
    }
}

impl Penalty for L1 {
    fn kind(&self) -> PenaltyKind {
        PenaltyKind::L1
    }

    fn groups(&self) -> &Groups {
        &self.groups
    }

    fn value(&self, beta: &Mat) -> f64 {
        norm1(beta.as_slice())
    }

    fn group_dual_norm(&self, _g: usize, block: &[f64]) -> f64 {
        debug_assert_eq!(block.len(), 1);
        block[0].abs()
    }

    fn prox_group(&self, _g: usize, block: &mut [f64], t: f64) {
        block[0] = st(block[0], t);
    }

    fn op_norms(&self, x: &Design) -> GroupNorms {
        let col2: Vec<f64> = x.col_norms_sq().iter().map(|s| s.sqrt()).collect();
        GroupNorms { op: col2.clone(), spectral: col2.clone(), col2 }
    }

    fn stats(&self, corr: &Mat, active: &ActiveSet) -> ScreenStats {
        debug_assert_eq!(corr.cols(), 1);
        let p = self.groups.p();
        let mut group_dual = vec![0.0; p];
        let c = corr.as_slice();
        for j in 0..p {
            if active.group[j] {
                group_dual[j] = c[j].abs();
            }
        }
        ScreenStats { group_dual, sgl: None }
    }

    fn sphere_screen(
        &self,
        stats: &ScreenStats,
        r: f64,
        norms: &GroupNorms,
        active: &mut ActiveSet,
        mut ledger: Option<&mut Vec<KillRecord>>,
    ) -> (usize, usize) {
        let mut killed = 0;
        let thresh = 1.0 - super::SCREEN_MARGIN;
        for j in 0..self.groups.p() {
            if active.group[j] && stats.group_dual[j] + r * norms.op[j] < thresh {
                active.group[j] = false;
                active.feat[j] = false;
                killed += 1;
                if let Some(recs) = ledger.as_deref_mut() {
                    recs.push(KillRecord {
                        j,
                        group: j,
                        test: "l1",
                        stat: stats.group_dual[j],
                        norm: norms.op[j],
                        thresh,
                    });
                }
            }
        }
        (killed, killed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn value_and_dual() {
        let pen = L1::new(3);
        let b = Mat::col_vec(&[1.0, -2.0, 0.5]);
        assert_eq!(pen.value(&b), 3.5);
        assert_eq!(pen.group_dual_norm(0, &[-4.0]), 4.0);
    }

    #[test]
    fn prox_is_soft_threshold() {
        let pen = L1::new(1);
        let mut blk = [3.0];
        pen.prox_group(0, &mut blk, 1.0);
        assert_eq!(blk[0], 2.0);
        let mut blk = [-0.4];
        pen.prox_group(0, &mut blk, 1.0);
        assert_eq!(blk[0], 0.0);
    }

    #[test]
    fn screen_kills_small_scores() {
        let pen = L1::new(3);
        let x = Design::Dense(Mat::from_row_major(
            2,
            3,
            &[1.0, 0.0, 0.5, 0.0, 1.0, 0.5],
        ));
        let norms = pen.op_norms(&x);
        let mut active = ActiveSet::full(pen.groups());
        // scores: j0 -> 0.95 + 0.1*1 = 1.05 (keep), j1 -> 0.2 + 0.1 (kill),
        // j2 -> 0.99 + 0.1*sqrt(0.5) ~ 1.06 (keep)
        let corr = Mat::col_vec(&[0.95, 0.2, 0.99]);
        let stats = pen.stats(&corr, &active);
        let mut recs = Vec::new();
        let (kg, kf) = pen.sphere_screen(&stats, 0.1, &norms, &mut active, Some(&mut recs));
        assert_eq!((kg, kf), (1, 1));
        assert!(active.group[0] && !active.group[1] && active.group[2]);
        // the ledger carries the exact test that fired
        assert_eq!(recs.len(), 1);
        assert_eq!((recs[0].j, recs[0].test), (1, "l1"));
        assert!(recs[0].stat + 0.1 * recs[0].norm < recs[0].thresh);
    }
}
