//! Weighted ell_1/ell_2 penalty (Group Lasso, Sec. 4.2; multi-task rows,
//! Sec. 4.5; multinomial rows, Sec. 4.6).
//!
//! Omega_w(beta) = sum_g w_g ||beta_g||_2,  Omega_w^D(xi) = max_g ||xi_g||_2 / w_g.
//! For multi-task problems, instantiate with singleton feature groups and
//! q > 1: the block of feature j is the row B_{j,:}.

use super::{
    ActiveSet, GroupNorms, Groups, KillRecord, Penalty, PenaltyKind, ScreenStats,
};
use crate::linalg::sparse::Design;
use crate::linalg::{block_soft_threshold, norm2, Mat};

/// The weighted ell_1/ell_2 norm.
#[derive(Debug, Clone)]
pub struct GroupL2 {
    groups: Groups,
    weights: Vec<f64>,
}

impl GroupL2 {
    /// Uniform unit weights.
    pub fn new(groups: Groups) -> Self {
        let weights = vec![1.0; groups.len()];
        GroupL2 { groups, weights }
    }

    /// Explicit weights (w_g > 0, Sec. 4.2).
    pub fn with_weights(groups: Groups, weights: Vec<f64>) -> Self {
        assert_eq!(weights.len(), groups.len());
        assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");
        GroupL2 { groups, weights }
    }

    /// The classical sqrt(group size) weighting of Yuan & Lin (2006).
    pub fn sqrt_size_weights(groups: Groups) -> Self {
        let weights = (0..groups.len())
            .map(|g| (groups.feats(g).len() as f64).sqrt())
            .collect();
        GroupL2 { groups, weights }
    }

    pub fn weight(&self, g: usize) -> f64 {
        self.weights[g]
    }
}

impl Penalty for GroupL2 {
    fn kind(&self) -> PenaltyKind {
        PenaltyKind::GroupL2
    }

    fn groups(&self) -> &Groups {
        &self.groups
    }

    fn group_weight(&self, g: usize) -> f64 {
        self.weights[g]
    }

    fn value(&self, beta: &Mat) -> f64 {
        let q = beta.cols();
        let mut s = 0.0;
        for g in 0..self.groups.len() {
            let mut nsq = 0.0;
            for &j in self.groups.feats(g) {
                for k in 0..q {
                    let v = beta[(j, k)];
                    nsq += v * v;
                }
            }
            s += self.weights[g] * nsq.sqrt();
        }
        s
    }

    fn group_dual_norm(&self, g: usize, block: &[f64]) -> f64 {
        norm2(block) / self.weights[g]
    }

    fn prox_group(&self, g: usize, block: &mut [f64], t: f64) {
        block_soft_threshold(block, t * self.weights[g]);
    }

    fn op_norms(&self, x: &Design) -> GroupNorms {
        let col2: Vec<f64> = x.col_norms_sq().iter().map(|s| s.sqrt()).collect();
        let mut spectral = Vec::with_capacity(self.groups.len());
        let mut op = Vec::with_capacity(self.groups.len());
        for g in 0..self.groups.len() {
            let feats = self.groups.feats(g);
            let s = if feats.len() == 1 {
                // Singleton group (multi-task rows): exact, no iteration.
                col2[feats[0]]
            } else {
                // Power iteration under-estimates sigma_max; inflate by the
                // convergence slack and cap with the always-valid Frobenius
                // bound so the sphere test stays *safe*.
                let est = x.block_spectral_norm(feats, 60) * (1.0 + 1e-9);
                let frob: f64 =
                    feats.iter().map(|&j| col2[j] * col2[j]).sum::<f64>().sqrt();
                est.min(frob).max(feats.iter().map(|&j| col2[j]).fold(0.0, f64::max))
            };
            spectral.push(s);
            op.push(s / self.weights[g]);
        }
        GroupNorms { op, col2, spectral }
    }

    fn stats(&self, corr: &Mat, active: &ActiveSet) -> ScreenStats {
        let q = corr.cols();
        let mut group_dual = vec![0.0; self.groups.len()];
        for g in 0..self.groups.len() {
            if !active.group[g] {
                continue;
            }
            let mut nsq = 0.0;
            for &j in self.groups.feats(g) {
                for k in 0..q {
                    let v = corr[(j, k)];
                    nsq += v * v;
                }
            }
            group_dual[g] = nsq.sqrt() / self.weights[g];
        }
        ScreenStats { group_dual, sgl: None }
    }

    fn sphere_screen(
        &self,
        stats: &ScreenStats,
        r: f64,
        norms: &GroupNorms,
        active: &mut ActiveSet,
        mut ledger: Option<&mut Vec<KillRecord>>,
    ) -> (usize, usize) {
        let mut kg = 0;
        let mut kf = 0;
        let thresh = 1.0 - super::SCREEN_MARGIN;
        for g in 0..self.groups.len() {
            if active.group[g] && stats.group_dual[g] + r * norms.op[g] < thresh {
                kf += self.groups.feats(g).len();
                active.kill_group(&self.groups, g);
                kg += 1;
                if let Some(recs) = ledger.as_deref_mut() {
                    // One record per feature the group kill removed; the
                    // group-level test values are shared by all of them.
                    for &j in self.groups.feats(g) {
                        recs.push(KillRecord {
                            j,
                            group: g,
                            test: "group",
                            stat: stats.group_dual[g],
                            norm: norms.op[g],
                            thresh,
                        });
                    }
                }
            }
        }
        (kg, kf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_group_lasso() {
        let pen = GroupL2::new(Groups::contiguous(4, 2));
        let b = Mat::col_vec(&[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(pen.value(&b), 5.0);
    }

    #[test]
    fn value_multitask_rows() {
        // p=2 features, q=2 tasks, singleton row groups.
        let pen = GroupL2::new(Groups::singletons(2));
        let mut b = Mat::zeros(2, 2);
        b[(0, 0)] = 3.0;
        b[(0, 1)] = 4.0;
        assert_eq!(pen.value(&b), 5.0);
    }

    #[test]
    fn weighted_dual_norm() {
        let pen = GroupL2::with_weights(Groups::contiguous(4, 2), vec![2.0, 1.0]);
        assert_eq!(pen.group_dual_norm(0, &[3.0, 4.0]), 2.5);
        assert_eq!(pen.group_dual_norm(1, &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn prox_block_shrinks() {
        let pen = GroupL2::new(Groups::contiguous(2, 2));
        let mut blk = [3.0, 4.0];
        pen.prox_group(0, &mut blk, 2.5);
        assert!((norm2(&blk) - 2.5).abs() < 1e-12);
        let mut blk = [3.0, 4.0];
        pen.prox_group(0, &mut blk, 6.0);
        assert_eq!(blk, [0.0, 0.0]);
    }

    #[test]
    fn op_norms_safe_upper_bound() {
        use crate::util::prng::Prng;
        let mut rng = Prng::new(8);
        let mut x = Mat::zeros(12, 6);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let d = Design::Dense(x.clone());
        let pen = GroupL2::new(Groups::contiguous(6, 3));
        let norms = pen.op_norms(&d);
        // op norm must dominate ||X_g^T u||/||u|| for random u.
        for _ in 0..50 {
            let u: Vec<f64> = (0..12).map(|_| rng.gaussian()).collect();
            let un = norm2(&u);
            for g in 0..2 {
                let mut nsq = 0.0;
                for &j in pen.groups().feats(g) {
                    let d = crate::linalg::dot(x.col(j), &u);
                    nsq += d * d;
                }
                assert!(
                    nsq.sqrt() / un <= norms.spectral[g] + 1e-7,
                    "operator norm bound violated"
                );
            }
        }
    }
}
