//! Sparsity-enforcing, group-decomposable penalties (paper Sec. 4).
//!
//! A penalty owns the group structure over *features*; the coefficient
//! object is a `Mat` of shape (p, q) — q = 1 for Lasso / Group Lasso / SGL,
//! q > 1 for the multi-task and multinomial row-group cases (Sec. 4.5–4.6,
//! where each feature j forms the block B_{j,:}).
//!
//! Every penalty provides the four ingredients the Gap Safe machinery needs
//! (Table 1 bottom): its value Omega, the group dual norms Omega_g^D used
//! both for the dual rescaling (Eq. 9) and the sphere tests (Eq. 8), the
//! group prox for the CD solver, and the operator norms Omega_g^D(X_g)
//! appearing in the sphere-test bound.

pub mod epsilon_norm;
mod group_l2;
mod l1;
mod sparse_group;

pub use group_l2::GroupL2;
pub use l1::L1;
pub use sparse_group::SparseGroup;

use crate::linalg::sparse::Design;
use crate::linalg::Mat;

/// Partition of the feature set `[p]` into groups.
#[derive(Debug, Clone)]
pub struct Groups {
    /// Feature indices per group (a partition of 0..p).
    index: Vec<Vec<usize>>,
    p: usize,
    /// group id of each feature.
    of_feature: Vec<usize>,
}

impl Groups {
    /// Singleton groups {0}, {1}, ..., {p-1} (Lasso / multi-task rows).
    pub fn singletons(p: usize) -> Self {
        Groups {
            index: (0..p).map(|j| vec![j]).collect(),
            p,
            of_feature: (0..p).collect(),
        }
    }

    /// Contiguous groups of uniform size (p must be divisible).
    pub fn contiguous(p: usize, group_size: usize) -> Self {
        assert!(group_size > 0 && p % group_size == 0, "p not divisible by group size");
        let mut index = Vec::with_capacity(p / group_size);
        let mut of_feature = vec![0usize; p];
        for (g, start) in (0..p).step_by(group_size).enumerate() {
            let idx: Vec<usize> = (start..start + group_size).collect();
            for &j in &idx {
                of_feature[j] = g;
            }
            index.push(idx);
        }
        Groups { index, p, of_feature }
    }

    /// Arbitrary partition (validated).
    pub fn from_parts(p: usize, parts: Vec<Vec<usize>>) -> Self {
        let mut seen = vec![false; p];
        for part in &parts {
            assert!(!part.is_empty(), "empty group");
            for &j in part {
                assert!(j < p && !seen[j], "groups must partition [p]");
                seen[j] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "groups must cover [p]");
        let mut of_feature = vec![0usize; p];
        for (g, part) in parts.iter().enumerate() {
            for &j in part {
                of_feature[j] = g;
            }
        }
        Groups { index: parts, p, of_feature }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn feats(&self, g: usize) -> &[usize] {
        &self.index[g]
    }

    #[inline]
    pub fn group_of(&self, j: usize) -> usize {
        self.of_feature[j]
    }
}

/// Which estimator family a penalty instance belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PenaltyKind {
    L1,
    GroupL2,
    SparseGroup,
}

/// Precomputed operator norms used by the sphere tests (Eq. 8 / Prop. 8).
#[derive(Debug, Clone)]
pub struct GroupNorms {
    /// Omega_g^D(X_g) per group (the sphere-test slope).
    pub op: Vec<f64>,
    /// ||X_j||_2 per feature (SGL feature-level tests).
    pub col2: Vec<f64>,
    /// Spectral norm ||X_g||_2 per group (SGL group-level T_g bound).
    pub spectral: Vec<f64>,
}

/// Screening statistics of a dual center theta_c: everything the sphere
/// tests need, computed from the correlations `corr = X^T theta_c`.
/// Entries for inactive groups are stale and must not be read.
#[derive(Debug, Clone)]
pub struct ScreenStats {
    /// Omega_g^D([X^T theta]_g) per group.
    pub group_dual: Vec<f64>,
    /// SGL extras: (||S_tau(c_g)||_2, ||c_g||_inf) per group and |c_j| per feature.
    pub sgl: Option<SglStats>,
}

/// Sparse-Group Lasso two-level statistics (Prop. 8).
#[derive(Debug, Clone)]
pub struct SglStats {
    pub st_norm: Vec<f64>,
    pub max_abs: Vec<f64>,
    pub feat_abs: Vec<f64>,
}

/// Active sets at both levels. For non-SGL penalties the feature level
/// mirrors the group level.
#[derive(Debug, Clone)]
pub struct ActiveSet {
    pub group: Vec<bool>,
    pub feat: Vec<bool>,
}

impl ActiveSet {
    pub fn full(groups: &Groups) -> Self {
        ActiveSet { group: vec![true; groups.len()], feat: vec![true; groups.p()] }
    }

    pub fn n_active_groups(&self) -> usize {
        self.group.iter().filter(|&&a| a).count()
    }

    pub fn n_active_feats(&self) -> usize {
        self.feat.iter().filter(|&&a| a).count()
    }

    /// Deactivate a whole group (and its features).
    pub fn kill_group(&mut self, groups: &Groups, g: usize) {
        self.group[g] = false;
        for &j in groups.feats(g) {
            self.feat[j] = false;
        }
    }

    /// Restrict to the intersection with `other`.
    pub fn intersect(&mut self, other: &ActiveSet) {
        for (a, b) in self.group.iter_mut().zip(&other.group) {
            *a = *a && *b;
        }
        for (a, b) in self.feat.iter_mut().zip(&other.feat) {
            *a = *a && *b;
        }
    }
}

/// Gather the coefficient block of group `g` (feature-major, task-minor).
pub fn gather_block(beta: &Mat, feats: &[usize], out: &mut Vec<f64>) {
    out.clear();
    for &j in feats {
        for k in 0..beta.cols() {
            out.push(beta[(j, k)]);
        }
    }
}

/// Scatter a block back into the coefficient matrix.
pub fn scatter_block(beta: &mut Mat, feats: &[usize], block: &[f64]) {
    let q = beta.cols();
    for (i, &j) in feats.iter().enumerate() {
        for k in 0..q {
            beta[(j, k)] = block[i * q + k];
        }
    }
}

/// Provenance of one screened-out feature: the exact inequality
/// `stat + r*norm < thresh` (per `test` kind) that discarded column `j`.
/// Collected by [`Penalty::sphere_screen`] when the caller passes a
/// ledger, and turned into `obs::Event::ScreenCol` records by the
/// screening layer.
#[derive(Debug, Clone)]
pub struct KillRecord {
    /// Full design column index.
    pub j: usize,
    /// Group the column belongs to.
    pub group: usize,
    /// Which test fired: "l1" | "group" | "sgl-group" | "sgl-feat".
    pub test: &'static str,
    /// Correlation statistic at the sphere center.
    pub stat: f64,
    /// Matching operator/column norm (the sphere-test slope).
    pub norm: f64,
    /// Kill threshold the strict inequality was checked against.
    pub thresh: f64,
}

/// Group-decomposable sparsity-enforcing norm (Sec. 2.1).
pub trait Penalty: Send + Sync {
    fn kind(&self) -> PenaltyKind;

    fn groups(&self) -> &Groups;

    /// Omega(beta).
    fn value(&self, beta: &Mat) -> f64;

    /// Omega_g^D of the correlation block of group g (block = rows `feats(g)`
    /// of `corr`, feature-major/task-minor as produced by `gather_block`).
    fn group_dual_norm(&self, g: usize, block: &[f64]) -> f64;

    /// In-place prox of `t * Omega_g` on a coefficient block.
    fn prox_group(&self, g: usize, block: &mut [f64], t: f64);

    /// Operator norms for the sphere tests.
    fn op_norms(&self, x: &Design) -> GroupNorms;

    /// Screening statistics of a center from its correlations (only active
    /// groups are filled; `corr` rows of inactive features may be stale).
    fn stats(&self, corr: &Mat, active: &ActiveSet) -> ScreenStats;

    /// Apply the sphere test with center stats `stats` and radius `r`,
    /// deactivating groups/features in `active`. Returns (groups killed,
    /// features killed). When `ledger` is given, one [`KillRecord`] per
    /// discarded feature is appended with the exact test that killed it
    /// (provenance for `gapsafe trace verify`); passing `None` keeps the
    /// hot path allocation-free.
    fn sphere_screen(
        &self,
        stats: &ScreenStats,
        r: f64,
        norms: &GroupNorms,
        active: &mut ActiveSet,
        ledger: Option<&mut Vec<KillRecord>>,
    ) -> (usize, usize);

    /// The l1 trade-off for SGL; None otherwise.
    fn tau(&self) -> Option<f64> {
        None
    }

    /// The weight w_g of group g (1.0 for unweighted penalties). Exposed
    /// as plain data so the offline certificate verifier
    /// (`obs::analyze::verify`) can rebuild every sphere-test threshold
    /// without touching the production screening code.
    fn group_weight(&self, _g: usize) -> f64 {
        1.0
    }
}

/// Numerical safety margin for the strict sphere tests: with an exactly-zero
/// radius (gap = 0 to f64 precision) the test `score < 1` becomes razor
/// sharp and rounding of an equicorrelated score (= 1 in exact arithmetic,
/// 1 - few ulp in floats) could wrongly screen a support feature of a
/// non-unique solution. Screening `score < 1 - MARGIN` is strictly more
/// conservative, hence still safe.
pub const SCREEN_MARGIN: f64 = 1e-11;

/// Shared helper: Omega^D(X^T theta) as max over *active* groups (the
/// active-set trick of Sec. 2.2.2 — the argmax provably lies in any safe
/// active set, so inactive groups can be skipped).
pub fn dual_norm_active(
    pen: &dyn Penalty,
    corr: &Mat,
    active: &ActiveSet,
    block_buf: &mut Vec<f64>,
) -> f64 {
    let groups = pen.groups();
    let mut m: f64 = 0.0;
    for g in 0..groups.len() {
        if !active.group[g] {
            continue;
        }
        gather_block(corr, groups.feats(g), block_buf);
        m = m.max(pen.group_dual_norm(g, block_buf));
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_constructors() {
        let s = Groups::singletons(4);
        assert_eq!(s.len(), 4);
        assert_eq!(s.feats(2), &[2]);
        let c = Groups::contiguous(6, 3);
        assert_eq!(c.len(), 2);
        assert_eq!(c.feats(1), &[3, 4, 5]);
        assert_eq!(c.group_of(4), 1);
        let f = Groups::from_parts(3, vec![vec![2], vec![0, 1]]);
        assert_eq!(f.group_of(1), 1);
    }

    #[test]
    #[should_panic(expected = "partition")]
    fn groups_must_partition() {
        let _ = Groups::from_parts(3, vec![vec![0, 1], vec![1, 2]]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut b = Mat::zeros(4, 2);
        for j in 0..4 {
            for k in 0..2 {
                b[(j, k)] = (j * 2 + k) as f64;
            }
        }
        let mut blk = Vec::new();
        gather_block(&b, &[1, 3], &mut blk);
        assert_eq!(blk, vec![2.0, 3.0, 6.0, 7.0]);
        blk.iter_mut().for_each(|v| *v += 10.0);
        scatter_block(&mut b, &[1, 3], &blk);
        assert_eq!(b[(1, 0)], 12.0);
        assert_eq!(b[(3, 1)], 17.0);
        assert_eq!(b[(0, 0)], 0.0);
    }

    #[test]
    fn active_set_ops() {
        let g = Groups::contiguous(6, 2);
        let mut a = ActiveSet::full(&g);
        assert_eq!(a.n_active_groups(), 3);
        a.kill_group(&g, 1);
        assert_eq!(a.n_active_groups(), 2);
        assert_eq!(a.n_active_feats(), 4);
        assert!(!a.feat[2] && !a.feat[3]);
        let mut b = ActiveSet::full(&g);
        b.kill_group(&g, 0);
        a.intersect(&b);
        assert_eq!(a.n_active_groups(), 1);
    }
}
