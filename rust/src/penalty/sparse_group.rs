//! Sparse-Group Lasso penalty (Sec. 4.3):
//!
//!   Omega_{tau,w}(beta) = tau ||beta||_1 + (1 - tau) sum_g w_g ||beta_g||_2
//!
//! with dual norm Omega^D(xi) = max_g ||xi_g||_{eps_g} / (tau + (1-tau) w_g),
//! eps_g = (1-tau) w_g / (tau + (1-tau) w_g)  (Prop. 7, via the epsilon-norm).
//!
//! Two-level screening (Prop. 8): groups are eliminated through the T_g
//! bound on ||S_tau(X_g^T theta)||_2, individual features through
//! |X_j^T theta| + r ||X_j||_2 < tau.

use super::epsilon_norm::epsilon_norm;
use super::{
    ActiveSet, GroupNorms, Groups, KillRecord, Penalty, PenaltyKind, ScreenStats, SglStats,
};
use crate::linalg::sparse::Design;
use crate::linalg::{block_soft_threshold, st, Mat};

/// The Sparse-Group Lasso norm with trade-off tau and group weights w.
#[derive(Debug, Clone)]
pub struct SparseGroup {
    groups: Groups,
    tau: f64,
    weights: Vec<f64>,
    /// eps_g per group (Prop. 7).
    eps: Vec<f64>,
    /// tau + (1 - tau) w_g per group.
    scale: Vec<f64>,
}

impl SparseGroup {
    pub fn new(groups: Groups, tau: f64, weights: Vec<f64>) -> Self {
        assert!((0.0..=1.0).contains(&tau), "tau in [0,1]");
        assert_eq!(weights.len(), groups.len());
        assert!(
            weights.iter().all(|&w| w >= 0.0),
            "weights must be non-negative"
        );
        assert!(
            tau > 0.0 || weights.iter().all(|&w| w > 0.0),
            "tau = 0 with a zero weight is not a norm (Sec. 4.3)"
        );
        let scale: Vec<f64> = weights.iter().map(|&w| tau + (1.0 - tau) * w).collect();
        let eps: Vec<f64> = weights
            .iter()
            .zip(&scale)
            .map(|(&w, &s)| if s > 0.0 { (1.0 - tau) * w / s } else { 0.0 })
            .collect();
        SparseGroup { groups, tau, weights, eps, scale }
    }

    /// Unit group weights.
    pub fn with_unit_weights(groups: Groups, tau: f64) -> Self {
        let w = vec![1.0; groups.len()];
        SparseGroup::new(groups, tau, w)
    }

    pub fn eps_g(&self, g: usize) -> f64 {
        self.eps[g]
    }

    pub fn weight(&self, g: usize) -> f64 {
        self.weights[g]
    }
}

impl Penalty for SparseGroup {
    fn kind(&self) -> PenaltyKind {
        PenaltyKind::SparseGroup
    }

    fn groups(&self) -> &Groups {
        &self.groups
    }

    fn tau(&self) -> Option<f64> {
        Some(self.tau)
    }

    fn group_weight(&self, g: usize) -> f64 {
        self.weights[g]
    }

    fn value(&self, beta: &Mat) -> f64 {
        debug_assert_eq!(beta.cols(), 1);
        let b = beta.as_slice();
        let mut s = 0.0;
        for g in 0..self.groups.len() {
            let mut l1 = 0.0;
            let mut l2sq = 0.0;
            for &j in self.groups.feats(g) {
                l1 += b[j].abs();
                l2sq += b[j] * b[j];
            }
            s += self.tau * l1 + (1.0 - self.tau) * self.weights[g] * l2sq.sqrt();
        }
        s
    }

    fn group_dual_norm(&self, g: usize, block: &[f64]) -> f64 {
        epsilon_norm(block, self.eps[g]) / self.scale[g]
    }

    fn prox_group(&self, g: usize, block: &mut [f64], t: f64) {
        // prox of t(tau ||.||_1 + (1-tau) w_g ||.||_2): soft-threshold then
        // block soft-threshold (composition is exact for this pair).
        for v in block.iter_mut() {
            *v = st(*v, t * self.tau);
        }
        block_soft_threshold(block, t * (1.0 - self.tau) * self.weights[g]);
    }

    fn op_norms(&self, x: &Design) -> GroupNorms {
        let col2: Vec<f64> = x.col_norms_sq().iter().map(|s| s.sqrt()).collect();
        let mut spectral = Vec::with_capacity(self.groups.len());
        for g in 0..self.groups.len() {
            let feats = self.groups.feats(g);
            let s = if feats.len() == 1 {
                col2[feats[0]]
            } else {
                let est = x.block_spectral_norm(feats, 60) * (1.0 + 1e-9);
                let frob: f64 =
                    feats.iter().map(|&j| col2[j] * col2[j]).sum::<f64>().sqrt();
                est.min(frob).max(feats.iter().map(|&j| col2[j]).fold(0.0, f64::max))
            };
            spectral.push(s);
        }
        GroupNorms { op: spectral.clone(), col2, spectral }
    }

    fn stats(&self, corr: &Mat, active: &ActiveSet) -> ScreenStats {
        debug_assert_eq!(corr.cols(), 1);
        let c = corr.as_slice();
        let ng = self.groups.len();
        let mut group_dual = vec![0.0; ng];
        let mut st_norm = vec![0.0; ng];
        let mut max_abs = vec![0.0; ng];
        let mut feat_abs = vec![0.0; self.groups.p()];
        for g in 0..ng {
            if !active.group[g] {
                continue;
            }
            let mut stsq = 0.0;
            let mut ma: f64 = 0.0;
            for &j in self.groups.feats(g) {
                let a = c[j].abs();
                feat_abs[j] = a;
                ma = ma.max(a);
                let t = st(c[j], self.tau);
                stsq += t * t;
            }
            st_norm[g] = stsq.sqrt();
            max_abs[g] = ma;
            // Perf (§Perf log): the two-level sphere tests (Prop. 8) only
            // need st_norm / max_abs / feat_abs; the exact epsilon-norm is
            // already evaluated separately for the dual rescaling
            // (dual_norm_active). Evaluating it here again doubled the
            // epsilon-norm cost of every SGL gap pass, so group_dual
            // carries a cheap *monotone proxy* used only for working-set
            // ordering: ||S_tau(c_g)||_2 / ((1-tau) w_g) — it crosses 1
            // exactly when the exact dual norm does (Prop. 7 ball).
            group_dual[g] = if self.tau < 1.0 && self.weights[g] > 0.0 {
                st_norm[g] / ((1.0 - self.tau) * self.weights[g])
            } else {
                ma
            };
        }
        ScreenStats {
            group_dual,
            sgl: Some(SglStats { st_norm, max_abs, feat_abs }),
        }
    }

    fn sphere_screen(
        &self,
        stats: &ScreenStats,
        r: f64,
        norms: &GroupNorms,
        active: &mut ActiveSet,
        mut ledger: Option<&mut Vec<KillRecord>>,
    ) -> (usize, usize) {
        // Stats produced by any other penalty lack the SGL block; screen
        // nothing (always safe) instead of unwrapping — the pairing is a
        // caller invariant, not something a sphere test should die on.
        let Some(sgl) = stats.sgl.as_ref() else { return (0, 0) };
        let (mut kg, mut kf) = (0, 0);
        for g in 0..self.groups.len() {
            if !active.group[g] {
                continue;
            }
            // Group-level test (Prop. 8): T_g < (1 - tau) w_g.
            let rx = r * norms.spectral[g];
            let t_g = if sgl.max_abs[g] > self.tau {
                sgl.st_norm[g] + rx
            } else {
                (sgl.max_abs[g] + rx - self.tau).max(0.0)
            };
            let thresh_g = (1.0 - self.tau) * self.weights[g] - super::SCREEN_MARGIN;
            if t_g < thresh_g {
                if let Some(recs) = ledger.as_deref_mut() {
                    // A kill needs thresh_g > 0, so the max(., 0) clamp of
                    // the second branch never changes the inequality:
                    // recording the unclamped statistic keeps the record
                    // in `stat + r*norm < thresh` form for both branches.
                    let stat = if sgl.max_abs[g] > self.tau {
                        sgl.st_norm[g]
                    } else {
                        sgl.max_abs[g] - self.tau
                    };
                    for &j in self.groups.feats(g) {
                        if active.feat[j] {
                            recs.push(KillRecord {
                                j,
                                group: g,
                                test: "sgl-group",
                                stat,
                                norm: norms.spectral[g],
                                thresh: thresh_g,
                            });
                        }
                    }
                }
                kf += active_feats_in(active, self.groups.feats(g));
                active.kill_group(&self.groups, g);
                kg += 1;
                continue;
            }
            // Feature-level test: |X_j^T theta| + r ||X_j||_2 < tau.
            for &j in self.groups.feats(g) {
                if active.feat[j]
                    && sgl.feat_abs[j] + r * norms.col2[j] < self.tau - super::SCREEN_MARGIN
                {
                    active.feat[j] = false;
                    kf += 1;
                    if let Some(recs) = ledger.as_deref_mut() {
                        recs.push(KillRecord {
                            j,
                            group: g,
                            test: "sgl-feat",
                            stat: sgl.feat_abs[j],
                            norm: norms.col2[j],
                            thresh: self.tau - super::SCREEN_MARGIN,
                        });
                    }
                }
            }
        }
        (kg, kf)
    }
}

fn active_feats_in(active: &ActiveSet, feats: &[usize]) -> usize {
    feats.iter().filter(|&&j| active.feat[j]).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;
    use crate::linalg::norm2;

    fn pen(tau: f64) -> SparseGroup {
        SparseGroup::with_unit_weights(Groups::contiguous(6, 3), tau)
    }

    #[test]
    fn value_interpolates() {
        let b = Mat::col_vec(&[1.0, -2.0, 0.0, 0.5, 0.0, 0.0]);
        let l1 = 3.5;
        let gl = (1.0f64 + 4.0).sqrt() + 0.5;
        assert!((pen(1.0).value(&b) - l1).abs() < 1e-12);
        assert!((pen(0.0).value(&b) - gl).abs() < 1e-12);
        let v = pen(0.4).value(&b);
        assert!((v - (0.4 * l1 + 0.6 * gl)).abs() < 1e-12);
    }

    #[test]
    fn dual_norm_limits() {
        let blk = [3.0, -1.0, 2.0];
        // tau = 1 -> eps = 0 -> sup-norm, scale = 1.
        assert!((pen(1.0).group_dual_norm(0, &blk) - 3.0).abs() < 1e-12);
        // tau = 0 -> eps = 1 -> l2 norm / w.
        let l2 = (9.0f64 + 1.0 + 4.0).sqrt();
        assert!((pen(0.0).group_dual_norm(0, &blk) - l2).abs() < 1e-10);
    }

    #[test]
    fn prox_zero_at_large_t() {
        let p = pen(0.4);
        let mut blk = [0.5, -0.2, 0.1];
        p.prox_group(0, &mut blk, 10.0);
        assert_eq!(blk, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn prox_matches_subgradient_optimality() {
        // prox_t(v) = argmin_z 0.5||z-v||^2 + t Omega_g(z): check the
        // optimality condition v - z in t * dOmega_g(z) on random cases.
        check_property("sgl_prox_kkt", 100, |rng| {
            let tau = rng.uniform_in(0.05, 0.95);
            let p = SparseGroup::with_unit_weights(Groups::contiguous(3, 3), tau);
            let t = rng.uniform_in(0.05, 2.0);
            let v: Vec<f64> = (0..3).map(|_| 2.0 * rng.gaussian()).collect();
            let mut z = v.clone();
            p.prox_group(0, &mut z, t);
            let zn = norm2(&z);
            for i in 0..3 {
                let r = v[i] - z[i];
                if zn > 0.0 {
                    // subgradient: t*tau*sign(z_i) + t*(1-tau)*z_i/||z|| when z_i != 0
                    if z[i] != 0.0 {
                        let want = t * tau * z[i].signum() + t * (1.0 - tau) * z[i] / zn;
                        if (r - want).abs() > 1e-8 {
                            return Err(format!("kkt fail i={i} r={r} want={want}"));
                        }
                    } else if r.abs() > t * tau + 1e-8 {
                        return Err(format!("|r| > t*tau at zero coord: {r}"));
                    }
                } else {
                    // z = 0: need ||S_{t tau}(v)||_2 <= t (1-tau)
                    let s: f64 = v.iter().map(|&vi| st(vi, t * tau).powi(2)).sum();
                    if s.sqrt() > t * (1.0 - tau) + 1e-8 {
                        return Err(format!("zero prox but dual cert fails: {}", s.sqrt()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dual_norm_matches_feasibility_characterisation() {
        // Prop. 7: Omega^D(xi) <= 1 iff for all g ||S_tau(xi_g)||_2 <= (1-tau) w_g.
        check_property("sgl_dualnorm_ball", 200, |rng| {
            let tau = rng.uniform_in(0.05, 0.95);
            let p = SparseGroup::with_unit_weights(Groups::contiguous(4, 4), tau);
            let xi: Vec<f64> = (0..4).map(|_| 1.5 * rng.gaussian()).collect();
            let dn = p.group_dual_norm(0, &xi);
            let stn: f64 = xi.iter().map(|&v| st(v, tau).powi(2)).sum::<f64>().sqrt();
            let inside_ball = stn <= (1.0 - tau) + 1e-12;
            let dn_le_1 = dn <= 1.0 + 1e-9;
            if inside_ball != dn_le_1 {
                return Err(format!(
                    "ball mismatch: dn={dn} st_norm={stn} tau={tau} xi={xi:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn two_level_screen() {
        let groups = Groups::contiguous(4, 2);
        let p = SparseGroup::with_unit_weights(groups, 0.5);
        let x = Design::Dense(Mat::from_row_major(
            2,
            4,
            &[1.0, 0.0, 0.3, 0.0, 0.0, 1.0, 0.0, 0.3],
        ));
        let norms = p.op_norms(&x);
        let mut active = ActiveSet::full(p.groups());
        // group 0 has strong correlations, group 1 weak -> group-killed;
        // inside group 0, feature 1 weak -> feature-killed.
        let corr = Mat::col_vec(&[1.2, 0.1, 0.01, 0.02]);
        let stats = p.stats(&corr, &active);
        let mut recs = Vec::new();
        let (kg, kf) = p.sphere_screen(&stats, 0.05, &norms, &mut active, Some(&mut recs));
        assert_eq!(kg, 1);
        assert!(kf >= 2, "kf={kf}");
        assert!(active.group[0] && !active.group[1]);
        assert!(active.feat[0] && !active.feat[1]);
        // ledger reconciliation: one record per killed feature, and every
        // record's inequality really holds with the recorded numbers
        assert_eq!(recs.len(), kf);
        for rec in &recs {
            assert!(
                rec.stat + 0.05 * rec.norm < rec.thresh,
                "unsound record {rec:?}"
            );
        }
        assert!(recs.iter().any(|r| r.test == "sgl-group"));
        assert!(recs.iter().any(|r| r.test == "sgl-feat"));
    }
}
