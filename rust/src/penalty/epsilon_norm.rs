//! The epsilon-norm of Burdakov (Eq. 25) and its exact evaluation.
//!
//! `||x||_eps` is the unique nu >= 0 with  sum_i (|x_i| - (1-eps) nu)_+^2
//! = (eps nu)^2 ; conventions `||x||_0 = ||x||_inf`, `||x||_1 = ||x||_2`.
//! It is the building block of the Sparse-Group Lasso dual norm (Prop. 7).
//!
//! Two evaluators are provided:
//! * [`epsilon_norm`] — the exact O(d log d) sorting algorithm of
//!   (Ndiaye et al. 2016b, Prop. 5): on the bracket where exactly k
//!   coordinates survive the soft-threshold, the defining equation is the
//!   quadratic ((1-eps)^2 k - eps^2) nu^2 - 2 (1-eps) S_k nu + Q_k = 0 with
//!   S_k, Q_k the prefix sum / sum of squares of the sorted |x|; the valid
//!   root is the one falling in the bracket.
//! * [`epsilon_norm_bisect`] — a 100-iteration bisection oracle on the
//!   strictly decreasing phi(nu) = ||S_{(1-eps)nu}(x)||_2 - eps nu, used by
//!   tests (and mirroring the jnp implementation in
//!   `python/compile/kernels/ref.py`).

/// Exact epsilon-norm via the sorting algorithm (Remark 12).
pub fn epsilon_norm(x: &[f64], eps: f64) -> f64 {
    assert!((0.0..=1.0).contains(&eps), "eps must be in [0,1]");
    if x.is_empty() {
        return 0.0;
    }
    let mut a: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    let linf = a.iter().fold(0.0_f64, |m, &v| m.max(v));
    if eps <= 0.0 || linf == 0.0 {
        return linf;
    }
    if eps >= 1.0 {
        return a.iter().map(|v| v * v).sum::<f64>().sqrt();
    }
    // Descending. The Equal fallback fires only for NaN entries — the
    // stable sort then leaves them in place and the quadratic below
    // yields NaN anyway — and keeps the comparator total (panic-free)
    // without perturbing the order of non-NaN magnitudes.
    a.sort_by(|p, q| q.partial_cmp(p).unwrap_or(std::cmp::Ordering::Equal));
    let ome = 1.0 - eps;
    let (mut s, mut q) = (0.0_f64, 0.0_f64);
    for k in 1..=a.len() {
        s += a[k - 1];
        q += a[k - 1] * a[k - 1];
        // bracket for nu when exactly k coordinates are active:
        //   a_{k+1} <= (1-eps) nu < a_k    (a_{d+1} := 0)
        let lo = if k < a.len() { a[k] / ome } else { 0.0 };
        let hi = a[k - 1] / ome;
        let ca = ome * ome * (k as f64) - eps * eps;
        let cb = -2.0 * ome * s;
        let cc = q;
        // Solve ca nu^2 + cb nu + cc = 0 for nu in [lo, hi].
        let mut cands = [f64::NAN, f64::NAN];
        if ca.abs() < 1e-300 {
            if cb != 0.0 {
                cands[0] = -cc / cb;
            }
        } else {
            let disc = cb * cb - 4.0 * ca * cc;
            if disc >= 0.0 {
                let sq = disc.sqrt();
                // Numerically stable pair.
                let qq = -0.5 * (cb + cb.signum() * sq);
                cands[0] = qq / ca;
                if qq != 0.0 {
                    cands[1] = cc / qq;
                }
            }
        }
        let tol = 1e-9 * (hi.abs() + 1.0);
        for &nu in cands.iter() {
            if nu.is_finite() && nu >= lo - tol && nu <= hi + tol && nu > 0.0 {
                // verify it is the decreasing-phi root: phi'(nu) < 0 always
                // holds for the true root; the spurious root of the squared
                // equation has ||S(x)||_2 = -eps nu < 0, impossible, so any
                // in-bracket root is the answer.
                return nu.max(lo).min(hi);
            }
        }
    }
    // Fallback (should be unreachable): bisection oracle.
    epsilon_norm_bisect(x, eps)
}

/// Bisection oracle for the epsilon-norm (test reference; always correct).
pub fn epsilon_norm_bisect(x: &[f64], eps: f64) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let linf = x.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
    if eps <= 1e-12 {
        return linf;
    }
    let l2 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    let phi = |nu: f64| -> f64 {
        let t = (1.0 - eps) * nu;
        let s: f64 = x
            .iter()
            .map(|v| {
                let a = v.abs() - t;
                if a > 0.0 {
                    a * a
                } else {
                    0.0
                }
            })
            .sum();
        s.sqrt() - eps * nu
    };
    let (mut lo, mut hi) = (0.0_f64, l2 / eps + 1e-30);
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if phi(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check_property, prng::Prng};

    fn rand_vec(rng: &mut Prng, d: usize) -> Vec<f64> {
        (0..d).map(|_| rng.gaussian()).collect()
    }

    #[test]
    fn limits() {
        let x = [3.0, -4.0, 1.0];
        assert!((epsilon_norm(&x, 0.0) - 4.0).abs() < 1e-12);
        let l2 = (9.0 + 16.0 + 1.0_f64).sqrt();
        assert!((epsilon_norm(&x, 1.0) - l2).abs() < 1e-12);
    }

    #[test]
    fn singleton() {
        // d=1: equation (|x| - (1-eps)nu)_+^2 = (eps nu)^2 -> nu = |x|.
        for eps in [0.1, 0.5, 0.9] {
            assert!((epsilon_norm(&[-2.5], eps) - 2.5).abs() < 1e-10);
        }
    }

    #[test]
    fn zero_vector() {
        assert_eq!(epsilon_norm(&[0.0, 0.0], 0.3), 0.0);
        assert_eq!(epsilon_norm(&[], 0.5), 0.0);
    }

    #[test]
    fn matches_bisection_property() {
        check_property("epsnorm_sort_vs_bisect", 300, |rng| {
            let d = 1 + rng.below(12);
            let eps = rng.uniform_in(1e-4, 1.0);
            let x = rand_vec(rng, d);
            let a = epsilon_norm(&x, eps);
            let b = epsilon_norm_bisect(&x, eps);
            if (a - b).abs() > 1e-7 * (1.0 + b.abs()) {
                return Err(format!("sort={a} bisect={b} eps={eps} x={x:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn defining_equation_property() {
        check_property("epsnorm_defining_eq", 200, |rng| {
            let d = 1 + rng.below(10);
            let eps = rng.uniform_in(0.01, 0.99);
            let x = rand_vec(rng, d);
            let nu = epsilon_norm(&x, eps);
            let t = (1.0 - eps) * nu;
            let lhs: f64 = x
                .iter()
                .map(|v| {
                    let a = v.abs() - t;
                    if a > 0.0 {
                        a * a
                    } else {
                        0.0
                    }
                })
                .sum();
            let rhs = (eps * nu) * (eps * nu);
            if (lhs - rhs).abs() > 1e-8 * (1.0 + rhs) {
                return Err(format!("lhs={lhs} rhs={rhs} nu={nu}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sandwiched_between_linf_and_l2() {
        check_property("epsnorm_bounds", 200, |rng| {
            let d = 1 + rng.below(10);
            let eps = rng.uniform_in(0.0, 1.0);
            let x = rand_vec(rng, d);
            let nu = epsilon_norm(&x, eps);
            let linf = x.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
            let l2 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
            if nu < linf - 1e-9 || nu > l2 + 1e-9 {
                return Err(format!("nu={nu} not in [{linf}, {l2}]"));
            }
            Ok(())
        });
    }

    #[test]
    fn homogeneous() {
        check_property("epsnorm_homog", 100, |rng| {
            let d = 1 + rng.below(8);
            let eps = rng.uniform_in(0.05, 0.95);
            let c = rng.uniform_in(0.1, 10.0);
            let x = rand_vec(rng, d);
            let xs: Vec<f64> = x.iter().map(|v| c * v).collect();
            let a = epsilon_norm(&xs, eps);
            let b = c * epsilon_norm(&x, eps);
            if (a - b).abs() > 1e-8 * (1.0 + b.abs()) {
                return Err(format!("scale fail {a} vs {b}"));
            }
            Ok(())
        });
    }
}
