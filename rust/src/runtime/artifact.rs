//! Artifact manifest: the contract between the Python compile path
//! (`python/compile/aot.py`) and the Rust PJRT runtime.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT-compiled gap-pass artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub task: String,
    pub file: PathBuf,
    pub n: usize,
    pub p: usize,
    pub q: usize,
    pub group_size: usize,
    pub dtype: String,
    pub inputs: Vec<String>,
    pub n_outputs: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| format!("bad manifest: {e}"))?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing 'artifacts'")?;
        let mut entries = Vec::new();
        for a in arts {
            let get_s = |k: &str| -> Result<String, String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| format!("artifact missing '{k}'"))
            };
            let get_n = |k: &str| -> Result<usize, String> {
                a.get(k).and_then(|v| v.as_usize()).ok_or_else(|| format!("missing '{k}'"))
            };
            entries.push(ArtifactEntry {
                name: get_s("name")?,
                task: get_s("task")?,
                file: dir.join(get_s("file")?),
                n: get_n("n")?,
                p: get_n("p")?,
                q: get_n("q")?,
                group_size: get_n("group_size")?,
                dtype: get_s("dtype")?,
                inputs: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter().filter_map(|x| x.as_str().map(str::to_string)).collect()
                    })
                    .unwrap_or_default(),
                n_outputs: get_n("n_outputs")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find an artifact by name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find an artifact matching (task, n, p, q, group_size).
    pub fn find(
        &self,
        task: &str,
        n: usize,
        p: usize,
        q: usize,
        group_size: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.task == task && e.n == n && e.p == p && e.q == q && e.group_size == group_size
        })
    }

    /// All artifact files exist on disk.
    pub fn validate(&self) -> Result<(), String> {
        for e in &self.entries {
            if !e.file.exists() {
                return Err(format!("missing artifact file {}", e.file.display()));
            }
        }
        Ok(())
    }
}

/// Default artifacts directory: $GAPSAFE_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("GAPSAFE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[{"name":"lasso_t","task":"lasso",
             "file":"x.hlo.txt","n":4,"p":6,"q":1,"group_size":1,
             "dtype":"f64","inputs":["X","y","beta","lam"],"n_outputs":6}]}"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join("gapsafe_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert!(m.by_name("lasso_t").is_some());
        assert!(m.find("lasso", 4, 6, 1, 1).is_some());
        assert!(m.find("lasso", 4, 7, 1, 1).is_none());
        m.validate().unwrap();
    }

    #[test]
    fn missing_file_fails_validation() {
        let dir = std::env::temp_dir().join("gapsafe_manifest_test2");
        write_manifest(&dir);
        std::fs::remove_file(dir.join("x.hlo.txt")).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.validate().is_err());
    }
}
