//! Runtime for the AOT gap-pass artifacts (the Rust side of the bridge to
//! `python/compile/aot.py`).
//!
//! Two interchangeable backends sit behind one API:
//!
//! * **`xla` feature** — the real PJRT path: loads the HLO-text artifacts,
//!   compiles them once per (task, shape) on the PJRT CPU client, keeps the
//!   design matrix resident as a device buffer, and serves duality-gap /
//!   screening passes to the L3 solver. Python is never on this path.
//!   Requires vendoring the `xla` and `anyhow` crates (the offline registry
//!   ships neither — see README.md § PJRT runtime).
//! * **default** — a pure-Rust fallback with the same types and methods:
//!   the artifact manifest is still loaded and validated (so shape
//!   mismatches fail identically), but `gap_pass` evaluates the identical
//!   mathematical contract through [`Problem::gap_pass`]. Self-tests and
//!   examples run unchanged; they just exercise the native kernels twice.
//!
//! Layout note (xla path): JAX lowers row-major (C-order) arrays; the
//! solver's `Mat` is column-major, so matrices are transposed into
//! row-major scratch buffers at the boundary (X only once, at engine
//! setup).

pub mod artifact;

use crate::linalg::Mat;
use crate::penalty::ActiveSet;
use crate::problem::{GapResult, Problem};

/// Boxed error for the runtime layer. The default build has no `anyhow`;
/// with the `xla` feature the bindings' errors convert into it.
pub type RtError = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Runtime results.
pub type RtResult<T> = Result<T, RtError>;

#[cfg(not(feature = "xla"))]
mod fallback {
    use super::artifact::{ArtifactEntry, Manifest};
    use super::{RtError, RtResult};
    use crate::linalg::Mat;
    use crate::penalty::{ActiveSet, PenaltyKind};
    use crate::problem::{GapResult, Problem};

    fn rt_err(msg: String) -> RtError {
        msg.into()
    }

    /// Native-fallback engine: manifest handling without a PJRT client.
    pub struct PjrtEngine {
        pub manifest: Manifest,
    }

    /// A "compiled" gap pass bound to one artifact entry; evaluates the
    /// same quantities through the native kernels.
    pub struct GapExecutable {
        entry: ArtifactEntry,
    }

    impl PjrtEngine {
        /// Load and validate `<dir>/manifest.json`. No device is touched.
        pub fn new(artifacts_dir: &std::path::Path) -> RtResult<Self> {
            let manifest = Manifest::load(artifacts_dir).map_err(rt_err)?;
            manifest.validate().map_err(rt_err)?;
            Ok(PjrtEngine { manifest })
        }

        pub fn platform(&self) -> String {
            "native-fallback (build with --features xla for PJRT)".to_string()
        }

        /// Match `problem` against the manifest exactly as the PJRT path
        /// does; the returned executable evaluates natively.
        pub fn bind(&self, prob: &Problem, task_name: &str) -> RtResult<GapExecutable> {
            let gs = match prob.pen.kind() {
                PenaltyKind::SparseGroup => prob.pen.groups().feats(0).len(),
                _ => 1,
            };
            let entry = self
                .manifest
                .find(task_name, prob.n(), prob.p(), prob.q(), gs)
                .ok_or_else(|| {
                    rt_err(format!(
                        "no artifact for task={task_name} n={} p={} q={} gs={gs}; \
                         add the shape to python/compile/aot.py REGISTRY and rebuild artifacts",
                        prob.n(),
                        prob.p(),
                        prob.q()
                    ))
                })?
                .clone();
            Ok(GapExecutable { entry })
        }
    }

    impl GapExecutable {
        pub fn name(&self) -> &str {
            &self.entry.name
        }

        /// One gap pass at (beta, lam): same outputs as the artifact
        /// contract (statistics over *all* groups — the caller intersects
        /// with its active set), computed by the native kernels. Shape
        /// mismatches against the bound artifact fail exactly like the
        /// PJRT path's device-buffer uploads would.
        pub fn gap_pass(&self, prob: &Problem, beta: &Mat, lam: f64) -> RtResult<GapResult> {
            self.check_shapes(prob, beta)?;
            let z = prob.predict(beta);
            let active = ActiveSet::full(prob.pen.groups());
            Ok(prob.gap_pass(beta, &z, lam, &active))
        }

        /// Same contract, reusing a caller-held prediction Z = X beta
        /// (used by [`super::GapBackend`], whose callers already maintain
        /// it — skips the O(np) re-predict).
        pub(super) fn gap_pass_with_z(
            &self,
            prob: &Problem,
            beta: &Mat,
            z: &Mat,
            lam: f64,
        ) -> RtResult<GapResult> {
            self.check_shapes(prob, beta)?;
            let active = ActiveSet::full(prob.pen.groups());
            Ok(prob.gap_pass(beta, z, lam, &active))
        }

        fn check_shapes(&self, prob: &Problem, beta: &Mat) -> RtResult<()> {
            let (n, p, q) = (prob.n(), prob.p(), prob.q());
            if (n, p, q) != (self.entry.n, self.entry.p, self.entry.q)
                || beta.rows() != self.entry.p
                || beta.cols() != self.entry.q
            {
                return Err(rt_err(format!(
                    "shape mismatch: artifact {} expects n={} p={} q={}, \
                     got problem n={n} p={p} q={q} with beta {}x{}",
                    self.entry.name,
                    self.entry.n,
                    self.entry.p,
                    self.entry.q,
                    beta.rows(),
                    beta.cols()
                )));
            }
            Ok(())
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use fallback::{GapExecutable, PjrtEngine};

#[cfg(feature = "xla")]
mod pjrt {
    use super::artifact::{ArtifactEntry, Manifest};
    use crate::linalg::Mat;
    use crate::penalty::{ScreenStats, SglStats};
    use crate::problem::{GapResult, Problem};

    use anyhow::{anyhow, Context, Result};

    /// A compiled gap-pass executable bound to one (task, shape) and one
    /// design matrix (held on-device).
    pub struct GapExecutable {
        entry: ArtifactEntry,
        exe: xla::PjRtLoadedExecutable,
        /// X as a device buffer (row-major), transferred once.
        x_buf: xla::PjRtBuffer,
        /// y / Y as a device buffer, transferred once.
        y_buf: xla::PjRtBuffer,
        /// SGL extras, transferred once.
        tau_w: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    }

    /// The PJRT engine: client + manifest.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        pub manifest: Manifest,
    }

    /// Row-major copy of a column-major Mat.
    fn to_row_major(m: &Mat) -> Vec<f64> {
        let (r, c) = (m.rows(), m.cols());
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                out[i * c + j] = m[(i, j)];
            }
        }
        out
    }

    /// Column-major Mat from a row-major buffer.
    fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = data[i * cols + j];
            }
        }
        m
    }

    impl PjrtEngine {
        /// Create a CPU PJRT client and load the artifact manifest.
        pub fn new(artifacts_dir: &std::path::Path) -> Result<Self> {
            let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
            manifest.validate().map_err(|e| anyhow!(e))?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtEngine { client, manifest })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile the artifact for `problem` (matched by task/shape) and pin
        /// the problem's X and Y on-device. SGL problems also pin (tau, w).
        pub fn bind(&self, prob: &Problem, task_name: &str) -> Result<GapExecutable> {
            let gs = match prob.pen.kind() {
                crate::penalty::PenaltyKind::SparseGroup => prob.pen.groups().feats(0).len(),
                _ => 1,
            };
            let entry = self
                .manifest
                .find(task_name, prob.n(), prob.p(), prob.q(), gs)
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact for task={task_name} n={} p={} q={} gs={gs}; \
                         add the shape to python/compile/aot.py REGISTRY and re-run `make artifacts`",
                        prob.n(),
                        prob.p(),
                        prob.q()
                    )
                })?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("PJRT compile")?;
            let xd = prob.x.to_dense();
            let x_rm = to_row_major(&xd);
            let x_buf = self
                .client
                .buffer_from_host_buffer(&x_rm, &[entry.n, entry.p], None)
                .context("uploading X")?;
            let y = prob.fit.targets();
            let y_buf = if entry.q > 1 {
                let y_rm = to_row_major(y);
                self.client.buffer_from_host_buffer(&y_rm, &[entry.n, entry.q], None)
            } else {
                self.client.buffer_from_host_buffer(y.as_slice(), &[entry.n], None)
            }
            .context("uploading Y")?;
            let tau_w = if entry.task == "sgl" {
                let tau = prob.pen.tau().ok_or_else(|| anyhow!("sgl artifact needs tau"))?;
                let ng = prob.n_groups();
                let w: Vec<f64> = (0..ng).map(|_| 1.0).collect();
                let tau_buf = self.client.buffer_from_host_buffer(&[tau], &[], None)?;
                let w_buf = self.client.buffer_from_host_buffer(&w, &[ng], None)?;
                Some((tau_buf, w_buf))
            } else {
                None
            };
            Ok(GapExecutable { entry, exe, x_buf, y_buf, tau_w })
        }
    }

    impl GapExecutable {
        pub fn name(&self) -> &str {
            &self.entry.name
        }

        /// Execute one gap pass at (beta, lam); returns the same quantities as
        /// `Problem::gap_pass` (statistics over *all* groups: the artifact works
        /// on the full matrix; the caller intersects with its active set).
        pub fn gap_pass(&self, prob: &Problem, beta: &Mat, lam: f64) -> Result<GapResult> {
            let client = self.exe.client();
            let beta_buf = if self.entry.q > 1 {
                let b_rm = to_row_major(beta);
                client.buffer_from_host_buffer(&b_rm, &[self.entry.p, self.entry.q], None)?
            } else {
                client.buffer_from_host_buffer(beta.as_slice(), &[self.entry.p], None)?
            };
            let lam_buf = client.buffer_from_host_buffer(&[lam], &[], None)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                vec![&self.x_buf, &self.y_buf, &beta_buf, &lam_buf];
            if let Some((tau_buf, w_buf)) = &self.tau_w {
                args.push(tau_buf);
                args.push(w_buf);
            }
            let out = self.exe.execute_b(&args)?;
            let lit = out[0][0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != self.entry.n_outputs {
                return Err(anyhow!(
                    "artifact returned {} outputs, manifest says {}",
                    parts.len(),
                    self.entry.n_outputs
                ));
            }
            let scal = |l: &xla::Literal| -> Result<f64> { Ok(l.to_vec::<f64>()?[0]) };
            let primal = scal(&parts[0])?;
            let dual = scal(&parts[1])?;
            let gap = scal(&parts[2])?;
            let radius = scal(&parts[3])?;
            let theta_raw = parts[4].to_vec::<f64>()?;
            let theta = if self.entry.q > 1 {
                from_row_major(self.entry.n, self.entry.q, &theta_raw)
            } else {
                Mat::col_vec(&theta_raw)
            };
            let stats = if self.entry.task == "sgl" {
                let feat_abs = parts[5].to_vec::<f64>()?;
                let st_norm = parts[6].to_vec::<f64>()?;
                let max_abs = parts[7].to_vec::<f64>()?;
                // group_dual is not emitted by the artifact (the two-level SGL
                // tests don't need it); recompute lazily only if requested.
                let ng = st_norm.len();
                ScreenStats {
                    group_dual: vec![f64::NAN; ng],
                    sgl: Some(SglStats { st_norm, max_abs, feat_abs }),
                }
            } else {
                let cg = parts[5].to_vec::<f64>()?;
                ScreenStats { group_dual: cg, sgl: None }
            };
            let _ = prob;
            Ok(GapResult { primal, dual, gap, radius, theta, stats })
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{GapExecutable, PjrtEngine};

/// Gap-pass backend selection for the solver / examples.
pub enum GapBackend {
    /// Pure-Rust implementation (`Problem::gap_pass`).
    Native,
    /// AOT artifact (PJRT with the `xla` feature, native fallback without).
    Pjrt(GapExecutable),
}

impl GapBackend {
    pub fn label(&self) -> &'static str {
        match self {
            GapBackend::Native => "native",
            GapBackend::Pjrt(_) => "pjrt",
        }
    }

    /// Run a gap pass through the backend.
    pub fn gap_pass(
        &self,
        prob: &Problem,
        beta: &Mat,
        z: &Mat,
        lam: f64,
        active: &ActiveSet,
    ) -> RtResult<GapResult> {
        match self {
            GapBackend::Native => Ok(prob.gap_pass(beta, z, lam, active)),
            #[cfg(feature = "xla")]
            GapBackend::Pjrt(exe) => exe.gap_pass(prob, beta, lam).map_err(Into::into),
            // The fallback reuses the caller-held Z instead of re-deriving
            // it from beta like the device path must.
            #[cfg(not(feature = "xla"))]
            GapBackend::Pjrt(exe) => exe.gap_pass_with_z(prob, beta, z, lam),
        }
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::{build_problem, Task};
    use std::path::Path;

    fn write_manifest(dir: &Path, n: usize, p: usize) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("lasso.hlo.txt"), "HloModule lasso").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!(
                r#"{{"version":1,"artifacts":[{{"name":"lasso_small","task":"lasso",
                 "file":"lasso.hlo.txt","n":{n},"p":{p},"q":1,"group_size":1,
                 "dtype":"f64","inputs":["X","y","beta","lam"],"n_outputs":6}}]}}"#
            ),
        )
        .unwrap();
    }

    #[test]
    fn fallback_engine_binds_and_matches_native() {
        let dir = std::env::temp_dir().join("gapsafe_rt_fallback_test");
        write_manifest(&dir, 16, 40);
        let engine = PjrtEngine::new(&dir).unwrap();
        assert!(engine.platform().contains("native-fallback"));
        let ds = synth::leukemia_like_scaled(16, 40, 7, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let exe = engine.bind(&prob, "lasso").unwrap();
        assert_eq!(exe.name(), "lasso_small");
        let lam = 0.5 * prob.lambda_max();
        let beta = Mat::zeros(40, 1);
        let via_exe = exe.gap_pass(&prob, &beta, lam).unwrap();
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let native = prob.gap_pass(&beta, &z, lam, &active);
        assert_eq!(via_exe.gap.to_bits(), native.gap.to_bits());
        // shape mismatch is still rejected, like the real PJRT path
        let ds2 = synth::leukemia_like_scaled(16, 41, 7, false);
        let prob2 = build_problem(ds2, Task::Lasso).unwrap();
        assert!(engine.bind(&prob2, "lasso").is_err());
    }

    #[test]
    fn backend_native_and_pjrt_fallback_agree() {
        let dir = std::env::temp_dir().join("gapsafe_rt_backend_test");
        write_manifest(&dir, 12, 20);
        let engine = PjrtEngine::new(&dir).unwrap();
        let ds = synth::leukemia_like_scaled(12, 20, 3, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let exe = engine.bind(&prob, "lasso").unwrap();
        let lam = 0.4 * prob.lambda_max();
        let beta = Mat::zeros(20, 1);
        let z = prob.predict(&beta);
        let active = ActiveSet::full(prob.pen.groups());
        let native = GapBackend::Native.gap_pass(&prob, &beta, &z, lam, &active).unwrap();
        let pj = GapBackend::Pjrt(exe).gap_pass(&prob, &beta, &z, lam, &active).unwrap();
        assert_eq!(native.primal.to_bits(), pj.primal.to_bits());
        assert_eq!(native.dual.to_bits(), pj.dual.to_bits());
    }
}
