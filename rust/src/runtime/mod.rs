//! PJRT runtime (the Rust side of the AOT bridge).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them once per (task, shape) on the PJRT CPU client, keeps the
//! design matrix resident as a device buffer, and serves duality-gap /
//! screening passes to the L3 solver. Python is never on this path.
//!
//! Layout note: JAX lowers row-major (C-order) arrays; the solver's `Mat`
//! is column-major, so matrices are transposed into row-major scratch
//! buffers at the boundary (X only once, at engine setup).

pub mod artifact;

use crate::linalg::Mat;
use crate::penalty::{ActiveSet, ScreenStats, SglStats};
use crate::problem::{GapResult, Problem};
use artifact::{ArtifactEntry, Manifest};

use anyhow::{anyhow, Context, Result};

/// A compiled gap-pass executable bound to one (task, shape) and one design
/// matrix (held on-device).
pub struct GapExecutable {
    entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    /// X as a device buffer (row-major), transferred once.
    x_buf: xla::PjRtBuffer,
    /// y / Y as a device buffer, transferred once.
    y_buf: xla::PjRtBuffer,
    /// SGL extras, transferred once.
    tau_w: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
}

/// The PJRT engine: client + manifest.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

/// Row-major copy of a column-major Mat.
fn to_row_major(m: &Mat) -> Vec<f64> {
    let (r, c) = (m.rows(), m.cols());
    let mut out = vec![0.0; r * c];
    for i in 0..r {
        for j in 0..c {
            out[i * c + j] = m[(i, j)];
        }
    }
    out
}

/// Column-major Mat from a row-major buffer.
fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            m[(i, j)] = data[i * cols + j];
        }
    }
    m
}

impl PjrtEngine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        manifest.validate().map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile the artifact for `problem` (matched by task/shape) and pin
    /// the problem's X and Y on-device. SGL problems also pin (tau, w).
    pub fn bind(&self, prob: &Problem, task_name: &str) -> Result<GapExecutable> {
        let gs = match prob.pen.kind() {
            crate::penalty::PenaltyKind::SparseGroup => {
                prob.pen.groups().feats(0).len()
            }
            _ => 1,
        };
        let entry = self
            .manifest
            .find(task_name, prob.n(), prob.p(), prob.q(), gs)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for task={task_name} n={} p={} q={} gs={gs}; \
                     add the shape to python/compile/aot.py REGISTRY and re-run `make artifacts`",
                    prob.n(),
                    prob.p(),
                    prob.q()
                )
            })?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        let xd = prob.x.to_dense();
        let x_rm = to_row_major(&xd);
        let x_buf = self
            .client
            .buffer_from_host_buffer(&x_rm, &[entry.n, entry.p], None)
            .context("uploading X")?;
        let y = prob.fit.targets();
        let y_buf = if entry.q > 1 {
            let y_rm = to_row_major(y);
            self.client.buffer_from_host_buffer(&y_rm, &[entry.n, entry.q], None)
        } else {
            self.client.buffer_from_host_buffer(y.as_slice(), &[entry.n], None)
        }
        .context("uploading Y")?;
        let tau_w = if entry.task == "sgl" {
            let tau = prob.pen.tau().ok_or_else(|| anyhow!("sgl artifact needs tau"))?;
            let ng = prob.n_groups();
            let w: Vec<f64> = (0..ng).map(|_| 1.0).collect();
            let tau_buf = self.client.buffer_from_host_buffer(&[tau], &[], None)?;
            let w_buf = self.client.buffer_from_host_buffer(&w, &[ng], None)?;
            Some((tau_buf, w_buf))
        } else {
            None
        };
        Ok(GapExecutable { entry, exe, x_buf, y_buf, tau_w })
    }
}

impl GapExecutable {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    /// Execute one gap pass at (beta, lam); returns the same quantities as
    /// `Problem::gap_pass` (statistics over *all* groups: the artifact works
    /// on the full matrix; the caller intersects with its active set).
    pub fn gap_pass(&self, prob: &Problem, beta: &Mat, lam: f64) -> Result<GapResult> {
        let client = self.exe.client();
        let beta_buf = if self.entry.q > 1 {
            let b_rm = to_row_major(beta);
            client.buffer_from_host_buffer(&b_rm, &[self.entry.p, self.entry.q], None)?
        } else {
            client.buffer_from_host_buffer(beta.as_slice(), &[self.entry.p], None)?
        };
        let lam_buf = client.buffer_from_host_buffer(&[lam], &[], None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&self.x_buf, &self.y_buf, &beta_buf, &lam_buf];
        if let Some((tau_buf, w_buf)) = &self.tau_w {
            args.push(tau_buf);
            args.push(w_buf);
        }
        let out = self.exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.entry.n_outputs {
            return Err(anyhow!(
                "artifact returned {} outputs, manifest says {}",
                parts.len(),
                self.entry.n_outputs
            ));
        }
        let scal = |l: &xla::Literal| -> Result<f64> {
            Ok(l.to_vec::<f64>()?[0])
        };
        let primal = scal(&parts[0])?;
        let dual = scal(&parts[1])?;
        let gap = scal(&parts[2])?;
        let radius = scal(&parts[3])?;
        let theta_raw = parts[4].to_vec::<f64>()?;
        let theta = if self.entry.q > 1 {
            from_row_major(self.entry.n, self.entry.q, &theta_raw)
        } else {
            Mat::col_vec(&theta_raw)
        };
        let stats = if self.entry.task == "sgl" {
            let feat_abs = parts[5].to_vec::<f64>()?;
            let st_norm = parts[6].to_vec::<f64>()?;
            let max_abs = parts[7].to_vec::<f64>()?;
            // group_dual is not emitted by the artifact (the two-level SGL
            // tests don't need it); recompute lazily only if requested.
            let ng = st_norm.len();
            ScreenStats {
                group_dual: vec![f64::NAN; ng],
                sgl: Some(SglStats { st_norm, max_abs, feat_abs }),
            }
        } else {
            let cg = parts[5].to_vec::<f64>()?;
            ScreenStats { group_dual: cg, sgl: None }
        };
        let _ = prob;
        Ok(GapResult { primal, dual, gap, radius, theta, stats })
    }
}

/// Gap-pass backend selection for the solver / examples.
pub enum GapBackend {
    /// Pure-Rust implementation (`Problem::gap_pass`).
    Native,
    /// AOT artifact via PJRT.
    Pjrt(GapExecutable),
}

impl GapBackend {
    pub fn label(&self) -> &'static str {
        match self {
            GapBackend::Native => "native",
            GapBackend::Pjrt(_) => "pjrt",
        }
    }

    /// Run a gap pass through the backend.
    pub fn gap_pass(
        &self,
        prob: &Problem,
        beta: &Mat,
        z: &Mat,
        lam: f64,
        active: &ActiveSet,
    ) -> Result<GapResult> {
        match self {
            GapBackend::Native => Ok(prob.gap_pass(beta, z, lam, active)),
            GapBackend::Pjrt(exe) => exe.gap_pass(prob, beta, lam),
        }
    }
}
