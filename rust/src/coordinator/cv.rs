//! Model selection: the train/test protocol of Sec. 5.4 (50% split, pick
//! the tau with best held-out prediction error) plus generic K-fold CV over
//! the lambda path.
//!
//! Folds and tau candidates are embarrassingly parallel, so both protocols
//! fan out over the [`crate::solver::parallel`] pool: every work item is a
//! pure function of its inputs and results are re-assembled in input
//! order, making the parallel runs bitwise identical to the serial ones.

use crate::data::Dataset;
use crate::linalg::sparse::Design;
use crate::linalg::Mat;
use crate::problem::Problem;
use crate::solver::parallel::parallel_map;
use crate::solver::path::{lambda_grid, solve_path, solve_path_on_grid, PathConfig};
use crate::util::prng::Prng;
use crate::{build_problem, Task};

/// Split a dataset into (train, test) by a random permutation.
pub fn split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    let n = ds.n();
    let mut rng = Prng::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (subset(ds, train_idx), subset(ds, test_idx))
}

/// Row subset of a dataset (densifies sparse designs).
pub fn subset(ds: &Dataset, rows: &[usize]) -> Dataset {
    subset_from_dense(&ds.x.to_dense(), ds, rows)
}

/// Row subset given an already-densified design — callers slicing the same
/// dataset many times (K-fold CV) densify once and share it instead of
/// paying the O(np) copy per slice.
fn subset_from_dense(x: &Mat, ds: &Dataset, rows: &[usize]) -> Dataset {
    let mut xs = Mat::zeros(rows.len(), ds.p());
    let mut ys = Mat::zeros(rows.len(), ds.q());
    for (ri, &i) in rows.iter().enumerate() {
        for j in 0..ds.p() {
            xs[(ri, j)] = x[(i, j)];
        }
        for k in 0..ds.q() {
            ys[(ri, k)] = ds.y[(i, k)];
        }
    }
    Dataset {
        x: Design::Dense(xs),
        y: ys,
        group_size: ds.group_size,
        name: format!("{}[{} rows]", ds.name, rows.len()),
    }
}

/// Mean squared prediction error of coefficients on a dataset.
pub fn mse(ds: &Dataset, beta: &Mat) -> f64 {
    let n = ds.n();
    let mut err = 0.0;
    for k in 0..ds.q() {
        let bk: Vec<f64> = (0..ds.p()).map(|j| beta[(j, k)]).collect();
        let mut z = vec![0.0; n];
        ds.x.gemv(&bk, &mut z);
        for i in 0..n {
            let d = ds.y[(i, k)] - z[i];
            err += d * d;
        }
    }
    err / (n as f64 * ds.q() as f64)
}

/// Outcome of the tau selection protocol.
#[derive(Debug, Clone)]
pub struct TauSelection {
    pub taus: Vec<f64>,
    pub test_mse: Vec<f64>,
    pub best_tau: f64,
}

/// Sec. 5.4: pick tau in {0, 0.1, ..., 1} by a 50% train/test split, fitting
/// the whole lambda path on train and scoring the best point on test.
pub fn select_tau_sgl(ds: &Dataset, cfg: &PathConfig, seed: u64) -> TauSelection {
    select_tau_sgl_threaded(ds, cfg, seed, 1)
}

/// [`select_tau_sgl`] with the eleven tau candidates fanned out over
/// `threads` workers (0 = all cores). Bitwise identical to the serial run:
/// the split is computed once and every candidate path is independent.
pub fn select_tau_sgl_threaded(
    ds: &Dataset,
    cfg: &PathConfig,
    seed: u64,
    threads: usize,
) -> TauSelection {
    let (train, test) = split(ds, 0.5, seed);
    let taus: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
    let threads = crate::solver::parallel::effective_threads(threads);
    let test_mse = parallel_map(threads, taus.clone(), |_, tau| {
        // tau = 0 with unit weights is plain group lasso; allowed.
        let prob = build_problem(train.clone(), Task::SparseGroupLasso { tau }).unwrap();
        let cfg = PathConfig { threads: 1, ..cfg.clone() };
        let res = solve_path(&prob, &cfg);
        res.betas.iter().map(|b| mse(&test, b)).fold(f64::INFINITY, f64::min)
    });
    let best_i = test_mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    TauSelection { best_tau: taus[best_i], taus, test_mse }
}

/// K-fold cross-validation configuration.
#[derive(Debug, Clone)]
pub struct CvConfig {
    /// Number of folds K (>= 2).
    pub folds: usize,
    /// Shuffle seed for the fold assignment.
    pub seed: u64,
    /// Fold-level workers (0 = all cores, 1 = serial). Paths inside a fold
    /// always run serially: fold-level fan-out already saturates the pool
    /// and keeps results bitwise independent of the thread count.
    pub threads: usize,
}

impl Default for CvConfig {
    fn default() -> Self {
        CvConfig { folds: 5, seed: 42, threads: 1 }
    }
}

/// K-fold cross-validation outcome over a shared lambda grid.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// The shared grid (computed from the full dataset's lambda_max).
    pub lambdas: Vec<f64>,
    /// Held-out MSE per fold per lambda: `fold_mse[f][t]`.
    pub fold_mse: Vec<Vec<f64>>,
    /// Mean held-out MSE per lambda.
    pub mean_mse: Vec<f64>,
    /// Index of the lambda minimizing the mean MSE.
    pub best_index: usize,
    /// The winning lambda.
    pub best_lambda: f64,
}

/// Shuffled round-robin fold assignment: `n` rows into `k` disjoint folds.
pub fn kfold_indices(n: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    Prng::new(seed).shuffle(&mut idx);
    let mut folds = vec![Vec::new(); k];
    for (i, &row) in idx.iter().enumerate() {
        folds[i % k].push(row);
    }
    folds
}

/// K-fold CV over the lambda path: every fold fits the whole path on its
/// training rows (over one shared grid anchored at the full dataset's
/// lambda_max, as glmnet does) and scores each path point on its held-out
/// rows. Folds fan out over `cv.threads` workers.
pub fn kfold_cv(
    ds: &Dataset,
    task: Task,
    cfg: &PathConfig,
    cv: &CvConfig,
) -> Result<CvResult, String> {
    if cv.folds < 2 {
        return Err("kfold_cv needs at least 2 folds".into());
    }
    if ds.n() < cv.folds {
        return Err(format!("{} rows cannot fill {} folds", ds.n(), cv.folds));
    }
    let full: Problem = build_problem(ds.clone(), task)?;
    let lambdas = lambda_grid(full.lambda_max(), cfg.n_lambdas, cfg.delta);
    drop(full);
    // Densify once; every fold slices this shared copy instead of paying
    // its own O(np) to_dense inside the fan-out.
    let xd = ds.x.to_dense();
    let folds = kfold_indices(ds.n(), cv.folds, cv.seed);
    let threads = crate::solver::parallel::effective_threads(cv.threads);
    let jobs: Vec<usize> = (0..cv.folds).collect();
    let per_fold = parallel_map(threads, jobs, |_, f| -> Result<Vec<f64>, String> {
        let mut in_test = vec![false; ds.n()];
        for &i in &folds[f] {
            in_test[i] = true;
        }
        let train_idx: Vec<usize> = (0..ds.n()).filter(|&i| !in_test[i]).collect();
        let train = subset_from_dense(&xd, ds, &train_idx);
        let test = subset_from_dense(&xd, ds, &folds[f]);
        let prob = build_problem(train, task)?;
        let cfg = PathConfig { threads: 1, ..cfg.clone() };
        let res = solve_path_on_grid(&prob, &cfg, &lambdas);
        Ok(res.betas.iter().map(|b| mse(&test, b)).collect())
    });
    let mut fold_mse = Vec::with_capacity(cv.folds);
    for r in per_fold {
        fold_mse.push(r?);
    }
    let t = lambdas.len();
    let mean_mse: Vec<f64> = (0..t)
        .map(|j| fold_mse.iter().map(|f| f[j]).sum::<f64>() / cv.folds as f64)
        .collect();
    let best_index = mean_mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .ok_or("empty lambda grid")?;
    Ok(CvResult { best_lambda: lambdas[best_index], lambdas, fold_mse, mean_mse, best_index })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::screening::Rule;
    use crate::solver::path::WarmStart;

    #[test]
    fn split_partitions_rows() {
        let ds = synth::leukemia_like_scaled(20, 8, 1, false);
        let (tr, te) = split(&ds, 0.25, 3);
        assert_eq!(tr.n() + te.n(), 20);
        assert_eq!(te.n(), 5);
        assert_eq!(tr.p(), 8);
    }

    #[test]
    fn mse_zero_for_perfect_fit() {
        let ds = synth::leukemia_like_scaled(10, 4, 2, false);
        // beta = 0 -> mse = mean(y^2)
        let b = Mat::zeros(4, 1);
        let want: f64 =
            ds.y.as_slice().iter().map(|v| v * v).sum::<f64>() / 10.0;
        assert!((mse(&ds, &b) - want).abs() < 1e-12);
    }

    #[test]
    fn tau_selection_runs() {
        let ds = synth::climate_like(36, 6, 4);
        let cfg = PathConfig {
            n_lambdas: 5,
            delta: 1.5,
            rule: Rule::GapSafeFull,
            warm: WarmStart::Standard,
            eps: 1e-4,
            eps_is_absolute: false,
            max_epochs: 500,
            screen_every: 10,
            threads: 1,
            compact: true,
            ..Default::default()
        };
        let sel = select_tau_sgl(&ds, &cfg, 7);
        assert_eq!(sel.taus.len(), 11);
        assert!(sel.taus.contains(&sel.best_tau));
        assert!(sel.test_mse.iter().all(|&m| m.is_finite()));
    }

    #[test]
    fn kfold_indices_partition_rows() {
        let folds = kfold_indices(23, 5, 9);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![false; 23];
        for f in &folds {
            for &i in f {
                assert!(!seen[i], "row {i} in two folds");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // balanced to within one row
        let sizes: Vec<usize> = folds.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn kfold_cv_runs_and_selects() {
        let ds = synth::leukemia_like_scaled(30, 40, 11, false);
        let cfg = PathConfig {
            n_lambdas: 8,
            delta: 2.0,
            eps: 1e-6,
            max_epochs: 3000,
            ..Default::default()
        };
        let cv = CvConfig { folds: 3, seed: 5, threads: 1 };
        let res = kfold_cv(&ds, Task::Lasso, &cfg, &cv).unwrap();
        assert_eq!(res.lambdas.len(), 8);
        assert_eq!(res.fold_mse.len(), 3);
        assert_eq!(res.mean_mse.len(), 8);
        assert!(res.mean_mse.iter().all(|m| m.is_finite()));
        assert_eq!(res.best_lambda, res.lambdas[res.best_index]);
        // lambda_max fits nothing: some smaller lambda must beat it
        assert!(res.best_index > 0);
    }

    #[test]
    fn kfold_cv_rejects_degenerate_configs() {
        let ds = synth::leukemia_like_scaled(10, 8, 1, false);
        let cfg = PathConfig::default();
        assert!(kfold_cv(&ds, Task::Lasso, &cfg, &CvConfig { folds: 1, ..Default::default() })
            .is_err());
        assert!(kfold_cv(&ds, Task::Lasso, &cfg, &CvConfig { folds: 11, ..Default::default() })
            .is_err());
    }
}
