//! Model selection: the train/test protocol of Sec. 5.4 (50% split, pick
//! the tau with best held-out prediction error) plus generic K-fold CV over
//! the lambda path.

use crate::data::Dataset;
use crate::linalg::sparse::Design;
use crate::linalg::Mat;
use crate::solver::path::{solve_path, PathConfig};
use crate::util::prng::Prng;
use crate::{build_problem, Task};

/// Split a dataset into (train, test) by a random permutation.
pub fn split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    let n = ds.n();
    let mut rng = Prng::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (subset(ds, train_idx), subset(ds, test_idx))
}

/// Row subset of a dataset (densifies sparse designs).
pub fn subset(ds: &Dataset, rows: &[usize]) -> Dataset {
    let x = ds.x.to_dense();
    let mut xs = Mat::zeros(rows.len(), ds.p());
    let mut ys = Mat::zeros(rows.len(), ds.q());
    for (ri, &i) in rows.iter().enumerate() {
        for j in 0..ds.p() {
            xs[(ri, j)] = x[(i, j)];
        }
        for k in 0..ds.q() {
            ys[(ri, k)] = ds.y[(i, k)];
        }
    }
    Dataset {
        x: Design::Dense(xs),
        y: ys,
        group_size: ds.group_size,
        name: format!("{}[{} rows]", ds.name, rows.len()),
    }
}

/// Mean squared prediction error of coefficients on a dataset.
pub fn mse(ds: &Dataset, beta: &Mat) -> f64 {
    let n = ds.n();
    let mut err = 0.0;
    for k in 0..ds.q() {
        let bk: Vec<f64> = (0..ds.p()).map(|j| beta[(j, k)]).collect();
        let mut z = vec![0.0; n];
        ds.x.gemv(&bk, &mut z);
        for i in 0..n {
            let d = ds.y[(i, k)] - z[i];
            err += d * d;
        }
    }
    err / (n as f64 * ds.q() as f64)
}

/// Outcome of the tau selection protocol.
#[derive(Debug, Clone)]
pub struct TauSelection {
    pub taus: Vec<f64>,
    pub test_mse: Vec<f64>,
    pub best_tau: f64,
}

/// Sec. 5.4: pick tau in {0, 0.1, ..., 1} by a 50% train/test split, fitting
/// the whole lambda path on train and scoring the best point on test.
pub fn select_tau_sgl(ds: &Dataset, cfg: &PathConfig, seed: u64) -> TauSelection {
    let (train, test) = split(ds, 0.5, seed);
    let taus: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
    let mut test_mse = Vec::with_capacity(taus.len());
    for &tau in &taus {
        // tau = 0 with unit weights is plain group lasso; allowed.
        let prob = build_problem(train.clone(), Task::SparseGroupLasso { tau }).unwrap();
        let res = solve_path(&prob, cfg);
        let best = res
            .betas
            .iter()
            .map(|b| mse(&test, b))
            .fold(f64::INFINITY, f64::min);
        test_mse.push(best);
    }
    let best_i = test_mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    TauSelection { best_tau: taus[best_i], taus, test_mse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::screening::Rule;
    use crate::solver::path::WarmStart;

    #[test]
    fn split_partitions_rows() {
        let ds = synth::leukemia_like_scaled(20, 8, 1, false);
        let (tr, te) = split(&ds, 0.25, 3);
        assert_eq!(tr.n() + te.n(), 20);
        assert_eq!(te.n(), 5);
        assert_eq!(tr.p(), 8);
    }

    #[test]
    fn mse_zero_for_perfect_fit() {
        let ds = synth::leukemia_like_scaled(10, 4, 2, false);
        // beta = 0 -> mse = mean(y^2)
        let b = Mat::zeros(4, 1);
        let want: f64 =
            ds.y.as_slice().iter().map(|v| v * v).sum::<f64>() / 10.0;
        assert!((mse(&ds, &b) - want).abs() < 1e-12);
    }

    #[test]
    fn tau_selection_runs() {
        let ds = synth::climate_like(36, 6, 4);
        let cfg = PathConfig {
            n_lambdas: 5,
            delta: 1.5,
            rule: Rule::GapSafeFull,
            warm: WarmStart::Standard,
            eps: 1e-4,
            eps_is_absolute: false,
            max_epochs: 500,
            screen_every: 10,
        };
        let sel = select_tau_sgl(&ds, &cfg, 7);
        assert_eq!(sel.taus.len(), 11);
        assert!(sel.taus.contains(&sel.best_tau));
        assert!(sel.test_mse.iter().all(|&m| m.is_finite()));
    }
}
