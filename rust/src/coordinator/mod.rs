//! Experiment coordinator: drives the pathwise solver through the paper's
//! evaluation protocols (Sec. 5), collects the series each figure plots,
//! and — via [`BatchRunner`] — schedules many independent path requests
//! across the worker pool (the serving entry point for concurrent traffic).

pub mod cv;
pub mod report;

use crate::problem::Problem;
use crate::screening::Rule;
use crate::solver::parallel::{effective_threads, parallel_map};
use crate::solver::path::{lambda_grid, scaled_eps, solve_path, PathConfig, PathResult, WarmStart};
use crate::solver::{solve_fixed_lambda_with, SolveOptions};
use crate::util::Stopwatch;

/// Schedules many `(Problem, PathConfig)` path requests across a worker
/// pool — the batch/serving front end: one long-lived runner absorbs a
/// stream of independent solve requests (distinct datasets, tasks or
/// grids) and keeps every core busy without oversubscription.
///
/// Each request runs serially on one worker (`threads` inside a request is
/// forced to 1), so results are bitwise independent of the pool size and
/// come back in request order.
#[derive(Debug, Clone)]
pub struct BatchRunner {
    threads: usize,
}

impl BatchRunner {
    /// A runner over `threads` workers (0 = all available cores).
    pub fn new(threads: usize) -> Self {
        BatchRunner { threads: effective_threads(threads) }
    }

    /// The resolved pool size.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Solve every request; results return in request order.
    pub fn run(&self, requests: Vec<(Problem, PathConfig)>) -> Vec<PathResult> {
        parallel_map(self.threads, requests, |_, (prob, cfg)| {
            let cfg = PathConfig { threads: 1, ..cfg };
            solve_path(&prob, &cfg)
        })
    }

    /// Many configurations against one shared problem (e.g. a rule /
    /// warm-start sweep over the same dataset).
    pub fn run_shared(&self, prob: &Problem, cfgs: &[PathConfig]) -> Vec<PathResult> {
        parallel_map(self.threads, cfgs.to_vec(), |_, cfg| {
            let cfg = PathConfig { threads: 1, ..cfg };
            solve_path(prob, &cfg)
        })
    }
}

/// One row of a fraction-of-active-variables experiment (Figs. 3-6 left
/// panels): for a fixed iteration budget K, the fraction of variables still
/// active at each lambda of the grid.
#[derive(Debug, Clone)]
pub struct ActiveFractionRow {
    pub k_epochs: usize,
    /// fraction in [0,1] per lambda index (feature level).
    pub frac_feats: Vec<f64>,
    /// group-level fraction (equal to frac_feats for singleton groups).
    pub frac_groups: Vec<f64>,
}

/// Run the "fraction of active variables" protocol: solvers run for each
/// lambda during exactly K epochs (K in `budgets`), with warm starts along
/// the path, recording the final active-set sizes.
pub fn active_fraction_experiment(
    prob: &Problem,
    rule: Rule,
    budgets: &[usize],
    n_lambdas: usize,
    delta: f64,
    screen_every: usize,
) -> Vec<ActiveFractionRow> {
    let lam_max = prob.lambda_max();
    let lambdas = lambda_grid(lam_max, n_lambdas, delta);
    let p = prob.p() as f64;
    let ng = prob.n_groups() as f64;
    let mut rows = Vec::new();
    for &k in budgets {
        let mut r = rule.build();
        let mut prev = None;
        let mut frac_feats = Vec::with_capacity(lambdas.len());
        let mut frac_groups = Vec::with_capacity(lambdas.len());
        let opts = SolveOptions {
            max_epochs: k,
            screen_every,
            eps: 0.0, // run the full budget
            max_kkt_rounds: 3,
            compact: true,
            ..Default::default()
        };
        for &lam in &lambdas {
            let beta0 = prev
                .as_ref()
                .map(|p: &crate::screening::PrevSolution| p.beta.clone());
            let res = solve_fixed_lambda_with(
                prob,
                lam,
                lam_max,
                beta0.as_ref(),
                None,
                r.as_mut(),
                prev.as_ref(),
                &opts,
            );
            frac_feats.push(res.active.n_active_feats() as f64 / p);
            frac_groups.push(res.active.n_active_groups() as f64 / ng);
            prev = Some(crate::screening::PrevSolution {
                lam,
                loss: prob.fit.loss(&res.z),
                pen_value: prob.pen.value(&res.beta),
                z: res.z,
                theta: res.theta,
                active: res.active,
                beta: res.beta,
            });
        }
        rows.push(ActiveFractionRow { k_epochs: k, frac_feats, frac_groups });
    }
    rows
}

/// One cell of a time-to-convergence table (Figs. 3-6 right panels).
#[derive(Debug, Clone)]
pub struct TimingCell {
    pub rule: Rule,
    pub warm: WarmStart,
    pub eps: f64,
    pub seconds: f64,
    pub all_converged: bool,
    pub total_epochs: usize,
}

/// Time the full path at each requested duality-gap tolerance for each
/// (rule, warm-start) strategy.
pub fn time_to_convergence(
    prob: &Problem,
    strategies: &[(Rule, WarmStart)],
    eps_list: &[f64],
    n_lambdas: usize,
    delta: f64,
    max_epochs: usize,
) -> Vec<TimingCell> {
    let mut cells = Vec::new();
    for &(rule, warm) in strategies {
        for &eps in eps_list {
            let cfg = PathConfig {
                n_lambdas,
                delta,
                rule,
                warm,
                eps,
                eps_is_absolute: false,
                max_epochs,
                screen_every: 10,
                threads: 1,
                compact: true,
                ..Default::default()
            };
            let sw = Stopwatch::start();
            let res = solve_path(prob, &cfg);
            cells.push(TimingCell {
                rule,
                warm,
                eps,
                seconds: sw.secs(),
                all_converged: res.points.iter().all(|p| p.converged),
                total_epochs: res.points.iter().map(|p| p.epochs).sum(),
            });
        }
    }
    cells
}

/// Equicorrelation-set identification diagnostic (Prop. 6): epochs until
/// the safe active set stabilises to its final value.
pub fn identification_epoch(prob: &Problem, rule: Rule, lam: f64, eps: f64) -> Option<usize> {
    let lam_max = prob.lambda_max();
    let mut r = rule.build();
    let opts = SolveOptions {
        max_epochs: 100_000,
        screen_every: 10,
        eps: scaled_eps(prob, eps),
        max_kkt_rounds: 5,
        compact: true,
        ..Default::default()
    };
    let res = solve_fixed_lambda_with(prob, lam, lam_max, None, None, r.as_mut(), None, &opts);
    identification_epoch_from(&res, opts.eps)
}

/// Trace-scan half of [`identification_epoch`], over a finished solve.
/// `res.converged` is only set inside the epoch loop; a solve whose gap
/// already certifies the tolerance at the fallback pass (epoch budget
/// exhausted before the first screening event) counts too.
pub(crate) fn identification_epoch_from(
    res: &crate::solver::SolveResult,
    eps: f64,
) -> Option<usize> {
    if !(res.converged || res.gap <= eps) {
        return None;
    }
    // The exit active set is the certified final support superset — the
    // same set the solve's ledger certificate records. Reading it from
    // `screen_trace.last()` was wrong twice over: the trace is absent
    // entirely on the zero-gap-pass path, and its last entry understates
    // the final set when the last KKT round reactivated groups after the
    // pass was recorded.
    let final_active = res.active.n_active_feats();
    if res.screen_trace.is_empty() {
        // No screening event ever ran: the initial set was already final.
        return Some(0);
    }
    // first epoch index whose trace entry already equals the final count
    res.screen_trace
        .iter()
        .find(|ev| ev.active_after == final_active)
        .map(|ev| ev.epoch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::{build_problem, Task};

    #[test]
    fn active_fraction_monotone_in_budget() {
        let ds = synth::leukemia_like_scaled(24, 60, 3, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let rows =
            active_fraction_experiment(&prob, Rule::GapSafeDyn, &[2, 64], 8, 2.0, 2);
        assert_eq!(rows.len(), 2);
        // more iterations -> tighter gap -> (weakly) more screening on average
        let avg = |r: &ActiveFractionRow| {
            r.frac_feats.iter().sum::<f64>() / r.frac_feats.len() as f64
        };
        assert!(
            avg(&rows[1]) <= avg(&rows[0]) + 1e-9,
            "K=64 screened less than K=2: {} vs {}",
            avg(&rows[1]),
            avg(&rows[0])
        );
    }

    #[test]
    fn timing_table_shapes() {
        let ds = synth::leukemia_like_scaled(20, 40, 4, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let cells = time_to_convergence(
            &prob,
            &[(Rule::None, WarmStart::Standard), (Rule::GapSafeFull, WarmStart::Standard)],
            &[1e-4, 1e-6],
            6,
            2.0,
            5000,
        );
        assert_eq!(cells.len(), 4);
        assert!(cells.iter().all(|c| c.all_converged));
    }

    #[test]
    fn batch_runner_bitwise_matches_serial_in_order() {
        let mk = |seed| {
            let ds = synth::leukemia_like_scaled(20, 30, seed, false);
            build_problem(ds, Task::Lasso).unwrap()
        };
        let cfg = PathConfig {
            n_lambdas: 6,
            delta: 1.5,
            eps: 1e-6,
            max_epochs: 2000,
            ..Default::default()
        };
        let serial: Vec<_> = (0..4).map(|s| solve_path(&mk(s), &cfg)).collect();
        let runner = BatchRunner::new(4);
        assert!(runner.threads() >= 1);
        let jobs: Vec<_> = (0..4).map(|s| (mk(s), cfg.clone())).collect();
        let batch = runner.run(jobs);
        assert_eq!(batch.len(), serial.len());
        for (job, (a, b)) in serial.iter().zip(&batch).enumerate() {
            assert_eq!(a.betas.len(), b.betas.len());
            for (ba, bb) in a.betas.iter().zip(&b.betas) {
                assert_eq!(ba, bb, "batch result diverged on job {job}");
            }
        }
    }

    #[test]
    fn identification_happens() {
        let ds = synth::leukemia_like_scaled(24, 50, 5, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = 0.3 * prob.lambda_max();
        let e = identification_epoch(&prob, Rule::GapSafeDyn, lam, 1e-10);
        assert!(e.is_some());
    }

    #[test]
    fn identification_survives_zero_gap_pass_solves() {
        // Regression: a solve whose epoch budget runs out before the first
        // screening event has an *empty* screen_trace but a perfectly
        // certified exit active set; `screen_trace.last()?` used to turn
        // that into a silent None.
        let ds = synth::leukemia_like_scaled(20, 30, 3, false);
        let prob = build_problem(ds, Task::Lasso).unwrap();
        let lam = 0.9 * prob.lambda_max();
        let opts = SolveOptions { max_epochs: 0, eps: 1e30, ..Default::default() };
        let mut rule = Rule::GapSafeDyn.build();
        let res = crate::solver::solve_fixed_lambda(&prob, lam, rule.as_mut(), &opts);
        assert!(res.screen_trace.is_empty(), "budget-0 solve recorded a pass");
        assert_eq!(identification_epoch_from(&res, opts.eps), Some(0));
        // and an unconverged, uncertified solve still reports None
        assert_eq!(identification_epoch_from(&res, -1.0), None);
    }
}
