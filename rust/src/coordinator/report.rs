//! Report emitters: paper-style console tables and results/*.csv series.

use super::{ActiveFractionRow, TimingCell};
use crate::util::{fmt_secs, write_csv};
use std::path::Path;

/// Print a Fig. 3/4/5-left style table: rows = iteration budgets, columns =
/// a subsample of the lambda grid, cells = active fraction.
pub fn print_active_fraction(title: &str, lambdas: &[f64], rows: &[ActiveFractionRow]) {
    println!("\n== {title}: fraction of active variables ==");
    let cols: Vec<usize> = sample_indices(lambdas.len(), 8);
    print!("{:>8}", "K\\l/lmax");
    for &c in &cols {
        print!("{:>9.3}", lambdas[c] / lambdas[0]);
    }
    println!();
    for row in rows {
        print!("{:>8}", row.k_epochs);
        for &c in &cols {
            print!("{:>9.3}", row.frac_feats[c]);
        }
        println!();
    }
}

/// Write the full active-fraction series to CSV (one row per (K, lambda)).
pub fn write_active_fraction_csv(
    path: &Path,
    lambdas: &[f64],
    rows: &[ActiveFractionRow],
) -> std::io::Result<()> {
    let mut out = Vec::new();
    for row in rows {
        for (t, &lam) in lambdas.iter().enumerate() {
            out.push(vec![
                row.k_epochs.to_string(),
                t.to_string(),
                format!("{lam}"),
                format!("{}", lam / lambdas[0]),
                format!("{}", row.frac_feats[t]),
                format!("{}", row.frac_groups[t]),
            ]);
        }
    }
    write_csv(
        path,
        &["k_epochs", "lambda_idx", "lambda", "lambda_ratio", "frac_feats", "frac_groups"],
        &out,
    )
}

/// Print a Fig. 3/4/5/6-right style table: time to solve the whole path per
/// strategy and tolerance, with speed-ups vs the no-screening baseline.
pub fn print_timing(title: &str, cells: &[TimingCell]) {
    println!("\n== {title}: path time to convergence ==");
    let mut eps_list: Vec<f64> = cells.iter().map(|c| c.eps).collect();
    eps_list.sort_by(|a, b| b.partial_cmp(a).unwrap());
    eps_list.dedup();
    print!("{:<28}", "strategy\\eps");
    for e in &eps_list {
        print!("{:>12.0e}", e);
    }
    println!("{:>10}", "speedup");
    let mut seen = Vec::new();
    for c in cells {
        let key = (c.rule, c.warm);
        if seen.contains(&key) {
            continue;
        }
        seen.push(key);
        let label = format!("{}+{}", c.rule.label(), c.warm.label());
        print!("{label:<28}");
        let mut last_secs = None;
        for e in &eps_list {
            if let Some(cell) = cells
                .iter()
                .find(|x| x.rule == c.rule && x.warm == c.warm && x.eps == *e)
            {
                let mark = if cell.all_converged { "" } else { "*" };
                print!("{:>12}", format!("{}{}", fmt_secs(cell.seconds), mark));
                last_secs = Some(cell.seconds);
            } else {
                print!("{:>12}", "-");
            }
        }
        // speedup vs no-screening at the tightest tolerance
        let base = cells
            .iter()
            .filter(|x| {
                x.rule == crate::screening::Rule::None && x.eps == *eps_list.last().unwrap()
            })
            .map(|x| x.seconds)
            .next();
        match (base, last_secs) {
            (Some(b), Some(s)) if s > 0.0 => println!("{:>9.1}x", b / s),
            _ => println!("{:>10}", "-"),
        }
    }
    println!("(* = at least one path point hit the epoch cap before the gap target)");
}

/// CSV dump of a timing table.
pub fn write_timing_csv(path: &Path, cells: &[TimingCell]) -> std::io::Result<()> {
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.rule.label().to_string(),
                c.warm.label().to_string(),
                format!("{:e}", c.eps),
                format!("{}", c.seconds),
                c.all_converged.to_string(),
                c.total_epochs.to_string(),
            ]
        })
        .collect();
    write_csv(
        path,
        &["rule", "warm_start", "eps", "seconds", "converged", "total_epochs"],
        &rows,
    )
}

fn sample_indices(len: usize, k: usize) -> Vec<usize> {
    if len <= k {
        return (0..len).collect();
    }
    (0..k).map(|i| i * (len - 1) / (k - 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::Rule;
    use crate::solver::path::WarmStart;

    #[test]
    fn sample_indices_cover_ends() {
        let s = sample_indices(100, 8);
        assert_eq!(s[0], 0);
        assert_eq!(*s.last().unwrap(), 99);
        assert_eq!(s.len(), 8);
        assert_eq!(sample_indices(3, 8), vec![0, 1, 2]);
    }

    #[test]
    fn csv_writers_smoke() {
        let dir = std::env::temp_dir().join("gapsafe_report_test");
        let rows = vec![ActiveFractionRow {
            k_epochs: 4,
            frac_feats: vec![1.0, 0.5],
            frac_groups: vec![1.0, 0.5],
        }];
        write_active_fraction_csv(&dir.join("af.csv"), &[1.0, 0.5], &rows).unwrap();
        let cells = vec![TimingCell {
            rule: Rule::GapSafeFull,
            warm: WarmStart::Standard,
            eps: 1e-6,
            seconds: 0.5,
            all_converged: true,
            total_epochs: 100,
        }];
        write_timing_csv(&dir.join("tt.csv"), &cells).unwrap();
        assert!(dir.join("af.csv").exists());
        assert!(dir.join("tt.csv").exists());
    }
}
