//! Dense column-major linear algebra substrate.
//!
//! The coordinate-descent hot loop needs fast access to individual columns
//! of the design matrix, so `Mat` is column-major (like Fortran / the
//! paper's Cython implementation). All the O(np) kernels used by solvers
//! and screening live here: `gemv`, `xtv` (feature–residual correlations),
//! column norms, block spectral norms (power iteration), axpy updates.
//!
//! The hot kernels (`dot`, `axpy`, `sub`, `soft_threshold`, `xtv`,
//! `gemv`, `xtm` and the CSC gather/scatter loops in [`sparse`]) are thin
//! forwarders into the runtime-dispatched SIMD engine in [`kernels`]: a
//! backend (scalar or AVX2) is detected once at startup and every backend
//! is **bitwise identical** by contract, so the choice is purely a
//! performance knob (`GAPSAFE_KERNEL=scalar|avx2|auto`, CLI `--kernel`).

pub mod compact;
pub mod kernels;
pub mod sparse;

/// Dense column-major matrix of `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { data, rows, cols }
    }

    /// Build from a row-major buffer (e.g. literals in tests).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = data[r * cols + c];
            }
        }
        m
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat { data: v.to_vec(), rows: v.len(), cols: 1 }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow column `j` as a slice (the point of column-major layout).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column view.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Whole buffer, column-major.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` copied out (rows are strided in column-major layout).
    pub fn row_copy(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self[(i, j)]).collect()
    }

    /// Squared Frobenius norm.
    pub fn frob_sq(&self) -> f64 {
        dot(&self.data, &self.data)
    }

    /// Euclidean norm of row `i` (for multi-task row groups).
    #[inline]
    pub fn row_norm(&self, i: usize) -> f64 {
        let mut s = 0.0;
        for j in 0..self.cols {
            let v = self[(i, j)];
            s += v * v;
        }
        s.sqrt()
    }

    /// Fill with zeros.
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// `self <- other` (shapes must match).
    pub fn copy_from(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.copy_from_slice(&other.data);
    }

    /// Matrix–matrix product `self * b` (naive, test/setup-path only).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut out = Mat::zeros(self.rows, b.cols);
        for j in 0..b.cols {
            for k in 0..self.cols {
                let bkj = b[(k, j)];
                if bkj != 0.0 {
                    axpy(bkj, self.col(k), out.col_mut(j));
                }
            }
        }
        out
    }

    /// Number of structurally nonzero entries (for sparsity reports).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[c * self.rows + r]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[c * self.rows + r]
    }
}

// ---------------------------------------------------------------------------
// Vector kernels
// ---------------------------------------------------------------------------

/// Dot product — 4-lane strided reduction tree, dispatched to the active
/// SIMD backend (see [`kernels`]; every backend is bitwise identical).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    (kernels::active().dot)(a, b)
}

/// `y += alpha * x` (backend-dispatched, bitwise identical everywhere).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    (kernels::active().axpy)(alpha, x, y)
}

/// `out = a - b` elementwise — the residual / link-refresh kernel
/// (backend-dispatched, bitwise identical everywhere).
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    (kernels::active().sub)(a, b, out)
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm_sq(x).sqrt()
}

/// Sup norm, NaN-propagating.
///
/// `f64::max` silently *ignores* NaN (`NaN.max(x) == x`), so the old
/// fold-based implementation mapped a poisoned residual to a perfectly
/// ordinary-looking norm — and a gap check downstream could pass on
/// garbage. A NaN anywhere in `x` now yields NaN, which every ordered
/// comparison downstream rejects.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    let mut m = 0.0_f64;
    for &v in x {
        let a = v.abs();
        if a.is_nan() {
            return f64::NAN;
        }
        if a > m {
            m = a;
        }
    }
    m
}

/// ell_1 norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Soft-thresholding S_tau (Sec. 2.1), in place (backend-dispatched,
/// bitwise identical everywhere).
#[inline]
pub fn soft_threshold(x: &mut [f64], tau: f64) {
    (kernels::active().soft_threshold)(x, tau)
}

/// Scalar soft-threshold.
#[inline]
pub fn st(x: f64, tau: f64) -> f64 {
    let a = x.abs() - tau;
    if a > 0.0 {
        x.signum() * a
    } else {
        0.0
    }
}

/// Block soft-threshold: `v <- v * (1 - tau/||v||)_+`, returning the new norm.
#[inline]
pub fn block_soft_threshold(v: &mut [f64], tau: f64) -> f64 {
    let n = norm2(v);
    if n <= tau {
        v.iter_mut().for_each(|x| *x = 0.0);
        0.0
    } else {
        let scale = 1.0 - tau / n;
        v.iter_mut().for_each(|x| *x *= scale);
        n - tau
    }
}

// ---------------------------------------------------------------------------
// Matrix kernels
// ---------------------------------------------------------------------------

/// `out = X * b` (n-vector), walking columns so memory access is
/// unit-stride (backend-dispatched; the AVX2 backend applies four columns
/// per pass over `out`, bitwise identically).
pub fn gemv(x: &Mat, b: &[f64], out: &mut [f64]) {
    assert_eq!(x.cols(), b.len());
    assert_eq!(x.rows(), out.len());
    (kernels::active().gemv)(x, b, out)
}

/// `out[j] = X_j^T v` for all columns — the screening hot spot (L3 native
/// counterpart of the L1 Pallas `xtv` kernel; backend-dispatched — the
/// AVX2 backend register-tiles four columns per pass, bitwise
/// identically).
pub fn xtv(x: &Mat, v: &[f64], out: &mut [f64]) {
    assert_eq!(x.rows(), v.len());
    assert_eq!(x.cols(), out.len());
    (kernels::active().xtv)(x, v, out)
}

/// `out = X^T V` (p×q), for the multi-task case (backend-dispatched).
pub fn xtm(x: &Mat, v: &Mat, out: &mut Mat) {
    assert_eq!(x.rows(), v.rows());
    assert_eq!(out.rows(), x.cols());
    assert_eq!(out.cols(), v.cols());
    (kernels::active().xtm)(x, v, out)
}

/// Per-column squared Euclidean norms of X.
pub fn col_norms_sq(x: &Mat) -> Vec<f64> {
    (0..x.cols()).map(|j| norm_sq(x.col(j))).collect()
}

/// Spectral norm of the column block `cols` of X via power iteration.
///
/// Used for the group operator norms Omega_g^D(X_g) in the sphere tests
/// (Eq. 8). The start vector is deterministic, so the estimate is
/// reproducible run to run. Contract: power iteration converges to the
/// true spectral norm **from below**, so the returned value may
/// *under*-estimate it; callers that need a safe (never-too-small) bound
/// must not lean on this estimate alone and instead fall back to the
/// Frobenius norm of the block, which always upper-bounds the spectral
/// norm — see `penalty::GroupNorms` for where each is used.
pub fn block_spectral_norm(x: &Mat, cols: &[usize], iters: usize) -> f64 {
    let n = x.rows();
    if cols.is_empty() || n == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..cols.len())
        .map(|i| 1.0 + (i as f64 * 0.618_033_988_749).fract())
        .collect();
    let mut u = vec![0.0; n];
    let mut sigma = 0.0;
    for _ in 0..iters {
        // u = X_g v
        u.iter_mut().for_each(|x| *x = 0.0);
        for (i, &j) in cols.iter().enumerate() {
            axpy(v[i], x.col(j), &mut u);
        }
        let un = norm2(&u);
        if un == 0.0 {
            return 0.0;
        }
        u.iter_mut().for_each(|x| *x /= un);
        // v = X_g^T u
        for (i, &j) in cols.iter().enumerate() {
            v[i] = dot(x.col(j), &u);
        }
        sigma = norm2(&v);
        if sigma == 0.0 {
            return 0.0;
        }
        v.iter_mut().for_each(|x| *x /= sigma);
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(rng: &mut Prng, n: usize, p: usize) -> Mat {
        let mut m = Mat::zeros(n, p);
        for v in m.as_mut_slice() {
            *v = rng.gaussian();
        }
        m
    }

    #[test]
    fn index_and_col_layout() {
        let m = Mat::from_row_major(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.);
        assert_eq!(m[(1, 2)], 6.);
        assert_eq!(m.col(1), &[2., 5.]);
        assert_eq!(m.row_copy(0), vec![1., 2., 3.]);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Prng::new(1);
        for len in [0, 1, 3, 4, 5, 17, 128] {
            let a: Vec<f64> = (0..len).map(|_| rng.gaussian()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.gaussian()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn gemv_xtv_consistency() {
        let mut rng = Prng::new(2);
        let x = rand_mat(&mut rng, 7, 11);
        let b: Vec<f64> = (0..11).map(|_| rng.gaussian()).collect();
        let mut z = vec![0.0; 7];
        gemv(&x, &b, &mut z);
        // check one entry by hand
        let z0: f64 = (0..11).map(|j| x[(0, j)] * b[j]).sum();
        assert!((z[0] - z0).abs() < 1e-12);
        // X^T (X b) vs column dots
        let mut c = vec![0.0; 11];
        xtv(&x, &z, &mut c);
        for j in 0..11 {
            assert!((c[j] - dot(x.col(j), &z)).abs() < 1e-14);
        }
    }

    #[test]
    fn xtm_matches_xtv_per_column() {
        let mut rng = Prng::new(3);
        let x = rand_mat(&mut rng, 6, 9);
        let v = rand_mat(&mut rng, 6, 4);
        let mut out = Mat::zeros(9, 4);
        xtm(&x, &v, &mut out);
        for k in 0..4 {
            let mut col = vec![0.0; 9];
            xtv(&x, v.col(k), &mut col);
            for j in 0..9 {
                assert!((out[(j, k)] - col[j]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(st(3.0, 1.0), 2.0);
        assert_eq!(st(-3.0, 1.0), -2.0);
        assert_eq!(st(0.5, 1.0), 0.0);
        let mut v = vec![2.0, -0.5, -4.0];
        soft_threshold(&mut v, 1.0);
        assert_eq!(v, vec![1.0, 0.0, -3.0]);
    }

    #[test]
    fn block_soft_threshold_cases() {
        let mut v = vec![3.0, 4.0]; // norm 5
        let nn = block_soft_threshold(&mut v, 5.0);
        assert_eq!(nn, 0.0);
        assert_eq!(v, vec![0.0, 0.0]);
        let mut v = vec![3.0, 4.0];
        let nn = block_soft_threshold(&mut v, 2.5);
        assert!((nn - 2.5).abs() < 1e-12);
        assert!((norm2(&v) - 2.5).abs() < 1e-12);
        // direction preserved
        assert!((v[1] / v[0] - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn spectral_norm_identity_block() {
        // X = I_4: spectral norm of any column block is 1.
        let mut x = Mat::zeros(4, 4);
        for i in 0..4 {
            x[(i, i)] = 1.0;
        }
        let s = block_spectral_norm(&x, &[0, 1, 2], 50);
        assert!((s - 1.0).abs() < 1e-10, "s={s}");
    }

    #[test]
    fn spectral_norm_vs_frobenius_bounds() {
        let mut rng = Prng::new(4);
        let x = rand_mat(&mut rng, 10, 8);
        let cols: Vec<usize> = (0..5).collect();
        let s = block_spectral_norm(&x, &cols, 200);
        let frob: f64 = cols.iter().map(|&j| norm_sq(x.col(j))).sum::<f64>().sqrt();
        let colmax = cols.iter().map(|&j| norm2(x.col(j))).fold(0.0_f64, f64::max);
        assert!(s <= frob + 1e-9, "s={s} frob={frob}");
        assert!(s >= colmax - 1e-9, "s={s} colmax={colmax}");
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_row_major(2, 2, &[1., 2., 3., 4.]);
        let b = Mat::from_row_major(2, 2, &[1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(1, 1)], 7.0);
    }

    #[test]
    fn norms() {
        let v = [3.0, -4.0];
        assert_eq!(norm2(&v), 5.0);
        assert_eq!(norm1(&v), 7.0);
        assert_eq!(norm_inf(&v), 4.0);
    }

    #[test]
    fn norm_inf_propagates_nan() {
        // Regression: `f64::max` ignores NaN, so the old fold returned 2.0
        // for every one of these poisoned inputs and a corrupted residual
        // could sail through a gap check.
        assert!(norm_inf(&[1.0, f64::NAN, 2.0]).is_nan());
        assert!(norm_inf(&[f64::NAN]).is_nan());
        assert!(norm_inf(&[2.0, 1.0, f64::NAN]).is_nan());
        // finite inputs are untouched by the fix
        assert_eq!(norm_inf(&[]), 0.0);
        assert_eq!(norm_inf(&[-7.5, 2.0]), 7.5);
        assert_eq!(norm_inf(&[f64::NEG_INFINITY]), f64::INFINITY);
    }

    #[test]
    fn sub_matches_manual_loop() {
        let mut rng = Prng::new(11);
        for n in [0, 1, 3, 5, 17] {
            let a: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let mut out = vec![0.0; n];
            sub(&a, &b, &mut out);
            for i in 0..n {
                assert_eq!(out[i].to_bits(), (a[i] - b[i]).to_bits());
            }
        }
    }
}
