//! Compressed-sparse-column design matrices.
//!
//! Several of the paper's benchmark families (bag-of-words text, genomics
//! one-hot designs) are sparse; CD + screening only ever touches columns,
//! so CSC gives the same unit-stride access pattern as the dense `Mat`.
//! `Design` abstracts over both so solvers and screening are written once.

use super::{dot, Mat};

/// CSC sparse matrix (f64 values).
#[derive(Debug, Clone)]
pub struct Csc {
    rows: usize,
    cols: usize,
    /// Column start offsets, length cols+1.
    indptr: Vec<usize>,
    /// Row indices per nonzero.
    indices: Vec<usize>,
    /// Values per nonzero.
    values: Vec<f64>,
}

impl Csc {
    /// Build from (col, row, value) triplets.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut trip: Vec<(usize, usize, f64)>,
    ) -> Self {
        trip.sort_by_key(|&(c, r, _)| (c, r));
        let mut indptr = vec![0usize; cols + 1];
        let mut indices = Vec::with_capacity(trip.len());
        let mut values = Vec::with_capacity(trip.len());
        for &(c, r, v) in &trip {
            assert!(c < cols && r < rows, "triplet out of bounds");
            indptr[c + 1] += 1;
            indices.push(r);
            values.push(v);
        }
        for c in 0..cols {
            indptr[c + 1] += indptr[c];
        }
        Csc { rows, cols, indptr, indices, values }
    }

    /// Densify a dense matrix into CSC (test helper / converter).
    pub fn from_dense(m: &Mat) -> Self {
        let mut trip = Vec::new();
        for c in 0..m.cols() {
            for (r, &v) in m.col(c).iter().enumerate() {
                if v != 0.0 {
                    trip.push((c, r, v));
                }
            }
        }
        Csc::from_triplets(m.rows(), m.cols(), trip)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (row indices, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Sparse dot of column j with a dense vector.
    #[inline]
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.col(j);
        let mut s = 0.0;
        for (&i, &x) in idx.iter().zip(val) {
            s += x * v[i];
        }
        s
    }

    /// `out += alpha * X_j`.
    #[inline]
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val) {
            out[i] += alpha * x;
        }
    }

    /// Squared norm of column j.
    pub fn col_norm_sq(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        dot(val, val)
    }

    /// Convert back to dense (tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            for (&i, &x) in idx.iter().zip(val) {
                m[(i, j)] = x;
            }
        }
        m
    }
}

/// A design matrix that is either dense (column-major) or sparse (CSC).
///
/// Solvers only need: column dot with an n-vector, column axpy into an
/// n-vector, column squared norms, and (for PJRT) a dense export.
#[derive(Debug, Clone)]
pub enum Design {
    Dense(Mat),
    Sparse(Csc),
}

impl Design {
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows(),
            Design::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols(),
            Design::Sparse(s) => s.cols(),
        }
    }

    /// `X_j^T v`.
    #[inline]
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => dot(m.col(j), v),
            Design::Sparse(s) => s.col_dot(j, v),
        }
    }

    /// `out += alpha * X_j`.
    #[inline]
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::axpy(alpha, m.col(j), out),
            Design::Sparse(s) => s.col_axpy(j, alpha, out),
        }
    }

    /// Per-column squared norms.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        match self {
            Design::Dense(m) => super::col_norms_sq(m),
            Design::Sparse(s) => (0..s.cols()).map(|j| s.col_norm_sq(j)).collect(),
        }
    }

    /// `out[j] = X_j^T v` over all columns.
    pub fn xtv(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::xtv(m, v, out),
            Design::Sparse(s) => {
                for j in 0..s.cols() {
                    out[j] = s.col_dot(j, v);
                }
            }
        }
    }

    /// `out = X b`.
    pub fn gemv(&self, b: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::gemv(m, b, out),
            Design::Sparse(s) => {
                out.iter_mut().for_each(|x| *x = 0.0);
                for j in 0..s.cols() {
                    if b[j] != 0.0 {
                        s.col_axpy(j, b[j], out);
                    }
                }
            }
        }
    }

    /// Dense view (copies if sparse) — used when exporting to PJRT buffers.
    pub fn to_dense(&self) -> Mat {
        match self {
            Design::Dense(m) => m.clone(),
            Design::Sparse(s) => s.to_dense(),
        }
    }

    /// Spectral norm of a column block (power iteration on the dense path,
    /// exact sparse implementation mirrors it).
    pub fn block_spectral_norm(&self, cols: &[usize], iters: usize) -> f64 {
        match self {
            Design::Dense(m) => super::block_spectral_norm(m, cols, iters),
            Design::Sparse(s) => {
                // Same power iteration over the sparse columns.
                let n = s.rows();
                if cols.is_empty() || n == 0 {
                    return 0.0;
                }
                let mut v: Vec<f64> = (0..cols.len())
                    .map(|i| 1.0 + (i as f64 * 0.618_033_988_749).fract())
                    .collect();
                let mut u = vec![0.0; n];
                let mut sigma = 0.0;
                for _ in 0..iters {
                    u.iter_mut().for_each(|x| *x = 0.0);
                    for (i, &j) in cols.iter().enumerate() {
                        s.col_axpy(j, v[i], &mut u);
                    }
                    let un = super::norm2(&u);
                    if un == 0.0 {
                        return 0.0;
                    }
                    u.iter_mut().for_each(|x| *x /= un);
                    for (i, &j) in cols.iter().enumerate() {
                        v[i] = s.col_dot(j, &u);
                    }
                    sigma = super::norm2(&v);
                    if sigma == 0.0 {
                        return 0.0;
                    }
                    v.iter_mut().for_each(|x| *x /= sigma);
                }
                sigma
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_sparse(rng: &mut Prng, n: usize, p: usize, density: f64) -> Csc {
        let mut trip = Vec::new();
        for c in 0..p {
            for r in 0..n {
                if rng.bernoulli(density) {
                    trip.push((c, r, rng.gaussian()));
                }
            }
        }
        Csc::from_triplets(n, p, trip)
    }

    #[test]
    fn csc_roundtrip_dense() {
        let mut rng = Prng::new(5);
        let s = rand_sparse(&mut rng, 8, 12, 0.3);
        let d = s.to_dense();
        let s2 = Csc::from_dense(&d);
        assert_eq!(s2.to_dense(), d);
        assert_eq!(s.nnz(), s2.nnz());
    }

    #[test]
    fn design_ops_agree_dense_sparse() {
        let mut rng = Prng::new(6);
        let s = rand_sparse(&mut rng, 10, 15, 0.4);
        let dd = Design::Dense(s.to_dense());
        let ds = Design::Sparse(s);
        let v: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let b: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
        for j in 0..15 {
            assert!((dd.col_dot(j, &v) - ds.col_dot(j, &v)).abs() < 1e-12);
        }
        let (mut z1, mut z2) = (vec![0.0; 10], vec![0.0; 10]);
        dd.gemv(&b, &mut z1);
        ds.gemv(&b, &mut z2);
        for i in 0..10 {
            assert!((z1[i] - z2[i]).abs() < 1e-12);
        }
        let (mut c1, mut c2) = (vec![0.0; 15], vec![0.0; 15]);
        dd.xtv(&v, &mut c1);
        ds.xtv(&v, &mut c2);
        for j in 0..15 {
            assert!((c1[j] - c2[j]).abs() < 1e-12);
        }
        let n1 = dd.col_norms_sq();
        let n2 = ds.col_norms_sq();
        for j in 0..15 {
            assert!((n1[j] - n2[j]).abs() < 1e-12);
        }
        let sp1 = dd.block_spectral_norm(&[0, 1, 2, 3], 100);
        let sp2 = ds.block_spectral_norm(&[0, 1, 2, 3], 100);
        assert!((sp1 - sp2).abs() < 1e-9);
    }

    #[test]
    fn empty_column_ok() {
        let s = Csc::from_triplets(4, 3, vec![(0, 1, 2.0)]);
        let (idx, _) = s.col(2);
        assert!(idx.is_empty());
        assert_eq!(s.col_norm_sq(2), 0.0);
    }
}
