//! Compressed-sparse-column design matrices.
//!
//! Several of the paper's benchmark families (bag-of-words text, genomics
//! one-hot designs) are sparse; CD + screening only ever touches columns,
//! so CSC gives the same unit-stride access pattern as the dense `Mat`.
//! `Design` abstracts over both so solvers and screening are written once.

use super::{dot, kernels, Mat};

/// CSC sparse matrix (f64 values).
#[derive(Debug, Clone)]
pub struct Csc {
    rows: usize,
    cols: usize,
    /// Column start offsets, length cols+1.
    indptr: Vec<usize>,
    /// Row indices per nonzero.
    indices: Vec<usize>,
    /// Values per nonzero.
    values: Vec<f64>,
}

impl Csc {
    /// Build from (col, row, value) triplets. Duplicate `(col, row)` entries
    /// are merged by summing their values — the standard COO-to-CSC
    /// semantics — so `col_norm_sq` / `nnz` always agree with the dense
    /// equivalent. (Keeping duplicates as separate nonzeros would silently
    /// corrupt `||x_j||`, the exact ingredient of the Gap Safe sphere test
    /// `|x_j^T theta| + r ||x_j|| < 1`.)
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut trip: Vec<(usize, usize, f64)>,
    ) -> Self {
        trip.sort_by_key(|&(c, r, _)| (c, r));
        let mut indptr = vec![0usize; cols + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(trip.len());
        let mut values: Vec<f64> = Vec::with_capacity(trip.len());
        let mut last: Option<(usize, usize)> = None;
        for &(c, r, v) in &trip {
            assert!(c < cols && r < rows, "triplet out of bounds");
            if last == Some((c, r)) {
                // Same (col, row) as the previously emitted entry: merge.
                // (`last` is only Some right after a push, so the slot
                // exists; if-let instead of unwrap keeps this panic-free.)
                if let Some(tail) = values.last_mut() {
                    *tail += v;
                }
            } else {
                indptr[c + 1] += 1;
                indices.push(r);
                values.push(v);
                last = Some((c, r));
            }
            // An exactly-cancelled merge (or an explicitly zero triplet)
            // must not leave a structural zero behind, or nnz() would
            // disagree with the dense rebuild this doc comment promises.
            if values.last().copied() == Some(0.0) {
                values.pop();
                indices.pop();
                indptr[c + 1] -= 1;
                last = None;
            }
        }
        for c in 0..cols {
            indptr[c + 1] += indptr[c];
        }
        Csc { rows, cols, indptr, indices, values }
    }

    /// Densify a dense matrix into CSC (test helper / converter).
    pub fn from_dense(m: &Mat) -> Self {
        let mut trip = Vec::new();
        for c in 0..m.cols() {
            for (r, &v) in m.col(c).iter().enumerate() {
                if v != 0.0 {
                    trip.push((c, r, v));
                }
            }
        }
        Csc::from_triplets(m.rows(), m.cols(), trip)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (row indices, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Sparse dot of column j with a dense vector — the `sptv` gather
    /// ingredient of the sparse screening sweep, dispatched to the active
    /// SIMD backend. Every backend computes the same 4-lane strided
    /// reduction tree as the dense `dot` (see `linalg::kernels`), so the
    /// result is bitwise identical under any backend. (The tree replaced
    /// the historical single-chain accumulation when the kernel engine
    /// landed — a one-time ~ulp-scale shift on sparse designs.)
    #[inline]
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.col(j);
        (kernels::active().gather_dot)(idx, val, v)
    }

    /// `out += alpha * X_j` — the `spmv` scatter ingredient
    /// (backend-dispatched; scalar in every backend, see
    /// `linalg::kernels`).
    #[inline]
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.col(j);
        (kernels::active().scatter_axpy)(idx, alpha, val, out)
    }

    /// Squared norm of column j.
    pub fn col_norm_sq(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        dot(val, val)
    }

    /// Mutable view of column j's stored values (the structure — row
    /// indices and nnz — is fixed; this supports in-place *scaling*, e.g.
    /// the sparse standardization of `data::preprocess`, which must never
    /// introduce or remove nonzeros).
    #[inline]
    pub fn col_values_mut(&mut self, j: usize) -> &mut [f64] {
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        &mut self.values[a..b]
    }

    /// Physically repack the listed columns into a new, contiguous CSC
    /// matrix (column `c` of the result is column `cols[c]` of `self`,
    /// with identical row indices and values — unit-stride after packing).
    pub fn select_cols(&self, cols: &[usize]) -> Csc {
        let nnz: usize = cols.iter().map(|&j| self.indptr[j + 1] - self.indptr[j]).sum();
        let mut indptr = Vec::with_capacity(cols.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for &j in cols {
            let (a, b) = (self.indptr[j], self.indptr[j + 1]);
            indices.extend_from_slice(&self.indices[a..b]);
            values.extend_from_slice(&self.values[a..b]);
            indptr.push(indices.len());
        }
        Csc { rows: self.rows, cols: cols.len(), indptr, indices, values }
    }

    /// Convert back to dense (tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            for (&i, &x) in idx.iter().zip(val) {
                m[(i, j)] = x;
            }
        }
        m
    }
}

/// A design matrix that is either dense (column-major) or sparse (CSC).
///
/// Solvers only need: column dot with an n-vector, column axpy into an
/// n-vector, column squared norms, and (for PJRT) a dense export.
#[derive(Debug, Clone)]
pub enum Design {
    Dense(Mat),
    Sparse(Csc),
}

impl Design {
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows(),
            Design::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols(),
            Design::Sparse(s) => s.cols(),
        }
    }

    /// `X_j^T v`.
    #[inline]
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => dot(m.col(j), v),
            Design::Sparse(s) => s.col_dot(j, v),
        }
    }

    /// `out += alpha * X_j`.
    #[inline]
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::axpy(alpha, m.col(j), out),
            Design::Sparse(s) => s.col_axpy(j, alpha, out),
        }
    }

    /// `sum_i X_j[i] * (a[i] - b[i])` — the logistic / multinomial CD
    /// gradient inner loop, fused so the difference vector is never
    /// materialized. Kept as one simple accumulation loop (not the
    /// unrolled `dot`) so the packed and full code paths are bitwise
    /// identical.
    #[inline]
    pub fn col_dot_diff(&self, j: usize, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => {
                let col = m.col(j);
                let mut s = 0.0;
                for i in 0..col.len() {
                    s += col[i] * (a[i] - b[i]);
                }
                s
            }
            Design::Sparse(sp) => {
                let (idx, val) = sp.col(j);
                let mut s = 0.0;
                for (&i, &x) in idx.iter().zip(val) {
                    s += x * (a[i] - b[i]);
                }
                s
            }
        }
    }

    /// Row support of column j: `Some(rows)` for a sparse design (the rows
    /// an update to coefficient j touches), `None` when the column is dense
    /// (every row is touched).
    #[inline]
    pub fn col_rows(&self, j: usize) -> Option<&[usize]> {
        match self {
            Design::Dense(_) => None,
            Design::Sparse(s) => Some(s.col(j).0),
        }
    }

    /// Per-column squared norms.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        match self {
            Design::Dense(m) => super::col_norms_sq(m),
            Design::Sparse(s) => (0..s.cols()).map(|j| s.col_norm_sq(j)).collect(),
        }
    }

    /// Physically repack the listed columns into a new design of the same
    /// storage kind (see [`Csc::select_cols`]; the dense path copies the
    /// column slices). Column data is preserved exactly, so every
    /// per-column kernel is bitwise identical on the packed matrix.
    pub fn select_cols(&self, cols: &[usize]) -> Design {
        match self {
            Design::Dense(m) => {
                let mut out = Mat::zeros(m.rows(), cols.len());
                for (c, &j) in cols.iter().enumerate() {
                    out.col_mut(c).copy_from_slice(m.col(j));
                }
                Design::Dense(out)
            }
            Design::Sparse(s) => Design::Sparse(s.select_cols(cols)),
        }
    }

    /// `out[j] = X_j^T v` over all columns.
    pub fn xtv(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::xtv(m, v, out),
            Design::Sparse(s) => {
                for j in 0..s.cols() {
                    out[j] = s.col_dot(j, v);
                }
            }
        }
    }

    /// `out = X b`.
    pub fn gemv(&self, b: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => super::gemv(m, b, out),
            Design::Sparse(s) => {
                out.iter_mut().for_each(|x| *x = 0.0);
                for j in 0..s.cols() {
                    if b[j] != 0.0 {
                        s.col_axpy(j, b[j], out);
                    }
                }
            }
        }
    }

    /// Dense view (copies if sparse) — used when exporting to PJRT buffers.
    pub fn to_dense(&self) -> Mat {
        match self {
            Design::Dense(m) => m.clone(),
            Design::Sparse(s) => s.to_dense(),
        }
    }

    /// Spectral norm of a column block (power iteration on the dense path,
    /// exact sparse implementation mirrors it).
    pub fn block_spectral_norm(&self, cols: &[usize], iters: usize) -> f64 {
        match self {
            Design::Dense(m) => super::block_spectral_norm(m, cols, iters),
            Design::Sparse(s) => {
                // Same power iteration over the sparse columns.
                let n = s.rows();
                if cols.is_empty() || n == 0 {
                    return 0.0;
                }
                let mut v: Vec<f64> = (0..cols.len())
                    .map(|i| 1.0 + (i as f64 * 0.618_033_988_749).fract())
                    .collect();
                let mut u = vec![0.0; n];
                let mut sigma = 0.0;
                for _ in 0..iters {
                    u.iter_mut().for_each(|x| *x = 0.0);
                    for (i, &j) in cols.iter().enumerate() {
                        s.col_axpy(j, v[i], &mut u);
                    }
                    let un = super::norm2(&u);
                    if un == 0.0 {
                        return 0.0;
                    }
                    u.iter_mut().for_each(|x| *x /= un);
                    for (i, &j) in cols.iter().enumerate() {
                        v[i] = s.col_dot(j, &u);
                    }
                    sigma = super::norm2(&v);
                    if sigma == 0.0 {
                        return 0.0;
                    }
                    v.iter_mut().for_each(|x| *x /= sigma);
                }
                sigma
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_sparse(rng: &mut Prng, n: usize, p: usize, density: f64) -> Csc {
        let mut trip = Vec::new();
        for c in 0..p {
            for r in 0..n {
                if rng.bernoulli(density) {
                    trip.push((c, r, rng.gaussian()));
                }
            }
        }
        Csc::from_triplets(n, p, trip)
    }

    #[test]
    fn csc_roundtrip_dense() {
        let mut rng = Prng::new(5);
        let s = rand_sparse(&mut rng, 8, 12, 0.3);
        let d = s.to_dense();
        let s2 = Csc::from_dense(&d);
        assert_eq!(s2.to_dense(), d);
        assert_eq!(s.nnz(), s2.nnz());
    }

    #[test]
    fn design_ops_agree_dense_sparse() {
        let mut rng = Prng::new(6);
        let s = rand_sparse(&mut rng, 10, 15, 0.4);
        let dd = Design::Dense(s.to_dense());
        let ds = Design::Sparse(s);
        let v: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        let b: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
        for j in 0..15 {
            assert!((dd.col_dot(j, &v) - ds.col_dot(j, &v)).abs() < 1e-12);
        }
        let (mut z1, mut z2) = (vec![0.0; 10], vec![0.0; 10]);
        dd.gemv(&b, &mut z1);
        ds.gemv(&b, &mut z2);
        for i in 0..10 {
            assert!((z1[i] - z2[i]).abs() < 1e-12);
        }
        let (mut c1, mut c2) = (vec![0.0; 15], vec![0.0; 15]);
        dd.xtv(&v, &mut c1);
        ds.xtv(&v, &mut c2);
        for j in 0..15 {
            assert!((c1[j] - c2[j]).abs() < 1e-12);
        }
        let n1 = dd.col_norms_sq();
        let n2 = ds.col_norms_sq();
        for j in 0..15 {
            assert!((n1[j] - n2[j]).abs() < 1e-12);
        }
        let sp1 = dd.block_spectral_norm(&[0, 1, 2, 3], 100);
        let sp2 = ds.block_spectral_norm(&[0, 1, 2, 3], 100);
        assert!((sp1 - sp2).abs() < 1e-9);
    }

    #[test]
    fn empty_column_ok() {
        let s = Csc::from_triplets(4, 3, vec![(0, 1, 2.0)]);
        let (idx, _) = s.col(2);
        assert!(idx.is_empty());
        assert_eq!(s.col_norm_sq(2), 0.0);
    }

    #[test]
    fn duplicate_triplets_merge_by_summing() {
        // Regression: duplicates must collapse into one entry with the
        // summed value, so norms / nnz match the dense equivalent. With
        // unmerged duplicates, col 0 would report ||x||^2 = 1 + 4 = 5
        // instead of (1+2)^2 = 9 and screening norms would be corrupt.
        let trip = vec![
            (0, 2, 1.0),
            (0, 2, 2.0), // duplicate of (col 0, row 2)
            (1, 0, -1.5),
            (1, 0, 0.5), // duplicate of (col 1, row 0)
            (1, 3, 4.0),
            (2, 1, 7.0),
        ];
        let s = Csc::from_triplets(4, 4, trip);
        assert_eq!(s.nnz(), 4, "duplicates must merge");
        assert_eq!(s.col(0), (&[2usize][..], &[3.0][..]));
        assert_eq!(s.col(1), (&[0usize, 3][..], &[-1.0, 4.0][..]));
        let d = Design::Sparse(s.clone());
        let from_dense = Csc::from_dense(&s.to_dense());
        let n1 = d.col_norms_sq();
        let n2 = Design::Sparse(from_dense.clone()).col_norms_sq();
        for j in 0..4 {
            assert_eq!(n1[j].to_bits(), n2[j].to_bits(), "col {j} norm corrupt");
        }
        assert_eq!(s.nnz(), from_dense.nnz());
        // exact expected norms
        assert_eq!(n1[0], 9.0);
        assert_eq!(n1[1], 17.0);
        assert_eq!(n1[2], 49.0);
        assert_eq!(n1[3], 0.0);
    }

    #[test]
    fn cancelling_and_zero_triplets_leave_no_structural_zeros() {
        // Exactly-cancelling duplicates and explicitly zero triplets must
        // not survive as structural entries, so nnz() matches the dense
        // rebuild even in the degenerate cases.
        let trip = vec![
            (0, 2, 1.0),
            (0, 2, -1.0), // cancels exactly
            (1, 1, 0.0),  // explicit zero
            (1, 3, 2.0),
            (2, 0, -3.0),
            (2, 0, 3.0),  // cancels exactly ...
            (2, 0, 5.0),  // ... then re-appears
        ];
        let s = Csc::from_triplets(4, 3, trip);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.nnz(), Csc::from_dense(&s.to_dense()).nnz());
        let (idx0, _) = s.col(0);
        assert!(idx0.is_empty(), "cancelled entry survived");
        assert_eq!(s.col(1), (&[3usize][..], &[2.0][..]));
        assert_eq!(s.col(2), (&[0usize][..], &[5.0][..]));
    }

    #[test]
    fn select_cols_packs_exact_column_data() {
        let mut rng = Prng::new(9);
        let s = rand_sparse(&mut rng, 12, 20, 0.3);
        let keep: Vec<usize> = vec![0, 3, 4, 11, 19];
        let packed = s.select_cols(&keep);
        assert_eq!(packed.cols(), keep.len());
        assert_eq!(packed.rows(), 12);
        for (c, &j) in keep.iter().enumerate() {
            assert_eq!(packed.col(c), s.col(j), "column {j} not preserved");
        }
        // dense path too
        let d = Design::Dense(s.to_dense());
        let dp = d.select_cols(&keep);
        let v: Vec<f64> = (0..12).map(|_| rng.gaussian()).collect();
        for (c, &j) in keep.iter().enumerate() {
            assert_eq!(
                d.col_dot(j, &v).to_bits(),
                dp.col_dot(c, &v).to_bits(),
                "packed dense col_dot differs at {j}"
            );
        }
    }

    #[test]
    fn col_dot_diff_and_col_rows_agree_with_naive() {
        let mut rng = Prng::new(10);
        let s = rand_sparse(&mut rng, 9, 7, 0.5);
        let dd = Design::Dense(s.to_dense());
        let ds = Design::Sparse(s);
        let a: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        let b: Vec<f64> = (0..9).map(|_| rng.gaussian()).collect();
        for j in 0..7 {
            let naive = dd.col_dot(j, &a) - dd.col_dot(j, &b);
            assert!((dd.col_dot_diff(j, &a, &b) - naive).abs() < 1e-10);
            assert!((ds.col_dot_diff(j, &a, &b) - naive).abs() < 1e-10);
        }
        assert!(dd.col_rows(0).is_none());
        let rows = ds.col_rows(0).unwrap();
        // sparse row support matches the structural nonzeros
        if let Design::Sparse(s) = &ds {
            assert_eq!(rows, s.col(0).0);
        }
    }
}
