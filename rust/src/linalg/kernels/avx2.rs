//! AVX2 backend: 256-bit `std::arch` implementations of the hot kernels,
//! bit-identical to [`super::scalar`] by construction.
//!
//! # How bitwise parity is achieved
//!
//! The scalar reduction kernels already accumulate in a 4-lane strided
//! tree: lane `k` sums elements `4i + k`. A 256-bit register holds
//! exactly those four lanes, so the vertical `vmulpd` + `vaddpd` sequence
//! performs the *same* IEEE-754 operations on the *same* operands in the
//! *same* order as the scalar code — only four at a time. No FMA is ever
//! emitted (explicit `_mm256_mul_pd` / `_mm256_add_pd`; Rust never
//! auto-contracts), the horizontal sum materializes the lanes and adds
//! them in the fixed `((s0 + s1) + s2) + s3` order, and the `n % 4` tail
//! is folded in element-by-element after the horizontal sum, exactly like
//! the scalar remainder loop. Element-wise kernels map each scalar
//! operation onto one vector lane, which is trivially exact.
//!
//! # Safety
//!
//! Every public function here assumes the CPU supports AVX2; the dispatch
//! layer only hands out this table after `is_x86_feature_detected!`
//! confirms it (see [`super::table`]), and the module is `pub(crate)` so
//! no outside caller can bypass that gate. Debug builds re-assert
//! detection at each entry point.

use crate::linalg::Mat;
use std::arch::x86_64::{
    __m256d, _mm256_add_pd, _mm256_and_pd, _mm256_andnot_pd, _mm256_cmp_pd, _mm256_loadu_pd,
    _mm256_mul_pd, _mm256_or_pd, _mm256_set1_pd, _mm256_set_pd, _mm256_setzero_pd,
    _mm256_storeu_pd, _mm256_sub_pd, _CMP_GT_OQ,
};

#[inline]
fn assert_avx2() {
    debug_assert!(
        std::arch::is_x86_feature_detected!("avx2"),
        "AVX2 kernel invoked on a host without AVX2"
    );
}

/// Horizontal sum in the scalar tree's fixed order: ((s0 + s1) + s2) + s3.
// SAFETY: caller must run on an AVX2 CPU; touches only `acc` and a
// stack array, so there are no pointer obligations.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(acc: __m256d) -> f64 {
    let mut t = [0.0f64; 4];
    _mm256_storeu_pd(t.as_mut_ptr(), acc);
    ((t[0] + t[1]) + t[2]) + t[3]
}

// SAFETY: caller must run on an AVX2 CPU. All raw loads are bounded by
// the min-clamped `n` below, so they stay inside both slices.
#[target_feature(enable = "avx2")]
unsafe fn dot_body(a: &[f64], b: &[f64]) -> f64 {
    // min-clamped so the raw loads can never run past either slice even
    // on a (debug-assert-guarded) length mismatch
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        let va = _mm256_loadu_pd(pa.add(i));
        let vb = _mm256_loadu_pd(pb.add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
    }
    let mut s = hsum(acc);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate (this
    // table is only selected after `is_x86_feature_detected!`) and
    // re-asserted above in debug builds; the body clamps all loads.
    unsafe { dot_body(a, b) }
}

// SAFETY: caller must run on an AVX2 CPU. Loads and stores are bounded
// by the min-clamped `n`, so they stay inside `x` and `y`.
#[target_feature(enable = "avx2")]
unsafe fn axpy_body(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let va = _mm256_set1_pd(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let vy = _mm256_loadu_pd(py.add(i) as *const f64);
        let vx = _mm256_loadu_pd(px.add(i));
        _mm256_storeu_pd(py.add(i), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
    }
    for i in 4 * chunks..n {
        y[i] += alpha * x[i];
    }
}

pub(crate) fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { axpy_body(alpha, x, y) }
}

// SAFETY: caller must run on an AVX2 CPU. Loads and stores are bounded
// by the min-clamped `n`, so they stay inside all three slices.
#[target_feature(enable = "avx2")]
unsafe fn sub_body(a: &[f64], b: &[f64], out: &mut [f64]) {
    let n = out.len().min(a.len()).min(b.len());
    let chunks = n / 4;
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let po = out.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let va = _mm256_loadu_pd(pa.add(i));
        let vb = _mm256_loadu_pd(pb.add(i));
        _mm256_storeu_pd(po.add(i), _mm256_sub_pd(va, vb));
    }
    for i in 4 * chunks..n {
        out[i] = a[i] - b[i];
    }
}

pub(crate) fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { sub_body(a, b, out) }
}

// SAFETY: caller must run on an AVX2 CPU. The vector loop covers
// `4 * (n / 4)` elements of `v` and the remainder loop uses safe slice
// indexing, so every access is in bounds.
#[target_feature(enable = "avx2")]
unsafe fn soft_threshold_body(v: &mut [f64], tau: f64) {
    let n = v.len();
    let chunks = n / 4;
    let vtau = _mm256_set1_pd(tau);
    let zero = _mm256_setzero_pd();
    // Sign-bit mask: -0.0 is all-zero except the top bit.
    let signmask = _mm256_set1_pd(-0.0);
    let p = v.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let x = _mm256_loadu_pd(p.add(i) as *const f64);
        // a = |x| - tau
        let a = _mm256_sub_pd(_mm256_andnot_pd(signmask, x), vtau);
        // keep lanes with a > 0 (ordered compare: NaN lanes are dropped,
        // matching the scalar `if a > 0.0` which is false for NaN)
        let keep = _mm256_cmp_pd::<_CMP_GT_OQ>(a, zero);
        // signum(x) * a == a with x's sign bit OR-ed in, since a > 0
        let signed = _mm256_or_pd(a, _mm256_and_pd(signmask, x));
        // dropped lanes become +0.0, the scalar `else` branch's literal
        _mm256_storeu_pd(p.add(i), _mm256_and_pd(signed, keep));
    }
    for x in &mut v[4 * chunks..] {
        let a = x.abs() - tau;
        *x = if a > 0.0 { x.signum() * a } else { 0.0 };
    }
}

pub(crate) fn soft_threshold(v: &mut [f64], tau: f64) {
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { soft_threshold_body(v, tau) }
}

/// Register-tiled `out[j] = X_j^T v`: four columns per pass share each
/// 256-bit load of `v`, quartering the `v` traffic of the column sweep.
/// Each column still accumulates its own 4-lane tree, so every entry is
/// bit-identical to `dot(X_j, v)`.
// SAFETY: caller must run on an AVX2 CPU. Column pointers come from
// `Mat::col` (each a live slice of `x.rows()` elements) and all raw
// offsets are bounded by the min-clamped `n <= x.rows()`.
#[target_feature(enable = "avx2")]
unsafe fn xtv_body(x: &Mat, v: &[f64], out: &mut [f64]) {
    let n = x.rows().min(v.len());
    let p = x.cols();
    let chunks = n / 4;
    let pv = v.as_ptr();
    let mut j = 0;
    while j + 4 <= p {
        let c0 = x.col(j).as_ptr();
        let c1 = x.col(j + 1).as_ptr();
        let c2 = x.col(j + 2).as_ptr();
        let c3 = x.col(j + 3).as_ptr();
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        for k in 0..chunks {
            let i = 4 * k;
            let vv = _mm256_loadu_pd(pv.add(i));
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(_mm256_loadu_pd(c0.add(i)), vv));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(_mm256_loadu_pd(c1.add(i)), vv));
            a2 = _mm256_add_pd(a2, _mm256_mul_pd(_mm256_loadu_pd(c2.add(i)), vv));
            a3 = _mm256_add_pd(a3, _mm256_mul_pd(_mm256_loadu_pd(c3.add(i)), vv));
        }
        let mut s0 = hsum(a0);
        let mut s1 = hsum(a1);
        let mut s2 = hsum(a2);
        let mut s3 = hsum(a3);
        for i in 4 * chunks..n {
            let vi = *pv.add(i);
            s0 += *c0.add(i) * vi;
            s1 += *c1.add(i) * vi;
            s2 += *c2.add(i) * vi;
            s3 += *c3.add(i) * vi;
        }
        out[j] = s0;
        out[j + 1] = s1;
        out[j + 2] = s2;
        out[j + 3] = s3;
        j += 4;
    }
    while j < p {
        out[j] = dot_body(x.col(j), v);
        j += 1;
    }
}

pub(crate) fn xtv(x: &Mat, v: &[f64], out: &mut [f64]) {
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { xtv_body(x, v, out) }
}

/// Apply four (column, coefficient) updates to `out` in one pass: each
/// 256-bit load/store of `out` serves four columns. Per element the four
/// additions happen in tile order, which the caller keeps equal to the
/// increasing-column order of the scalar axpy sweep — bit-identical.
// SAFETY: caller must run on an AVX2 CPU and pass column pointers and
// `po` that are each valid for `n` reads/writes; `gemv_body` derives
// them from live `Mat` columns and the `out` slice with `n` min-clamped.
#[target_feature(enable = "avx2")]
unsafe fn gemv_tile4(tile: &[(*const f64, f64); 4], n: usize, po: *mut f64) {
    let chunks = n / 4;
    let (c0, b0) = tile[0];
    let (c1, b1) = tile[1];
    let (c2, b2) = tile[2];
    let (c3, b3) = tile[3];
    let vb0 = _mm256_set1_pd(b0);
    let vb1 = _mm256_set1_pd(b1);
    let vb2 = _mm256_set1_pd(b2);
    let vb3 = _mm256_set1_pd(b3);
    for k in 0..chunks {
        let i = 4 * k;
        let mut o = _mm256_loadu_pd(po.add(i) as *const f64);
        o = _mm256_add_pd(o, _mm256_mul_pd(vb0, _mm256_loadu_pd(c0.add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(vb1, _mm256_loadu_pd(c1.add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(vb2, _mm256_loadu_pd(c2.add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(vb3, _mm256_loadu_pd(c3.add(i))));
        _mm256_storeu_pd(po.add(i), o);
    }
    for i in 4 * chunks..n {
        let o = po.add(i);
        *o += b0 * *c0.add(i);
        *o += b1 * *c1.add(i);
        *o += b2 * *c2.add(i);
        *o += b3 * *c3.add(i);
    }
}

/// 4-column-tiled `out = X b`: nonzero-coefficient columns are buffered
/// four at a time in a stack array (no heap allocation on this hot path)
/// and flushed through [`gemv_tile4`]; the `< 4` leftover columns go
/// through the plain AVX2 axpy. Column order — and therefore every
/// per-element addition order — matches the scalar sweep exactly.
// SAFETY: caller must run on an AVX2 CPU. Tile pointers are taken from
// live `Mat` columns (valid for `x.rows() >= n` reads) immediately
// before the flush, and `n` is min-clamped to `out.len()`.
#[target_feature(enable = "avx2")]
unsafe fn gemv_body(x: &Mat, b: &[f64], out: &mut [f64]) {
    out.iter_mut().for_each(|v| *v = 0.0);
    let n = x.rows().min(out.len());
    let po = out.as_mut_ptr();
    let mut tile: [(*const f64, f64); 4] = [(std::ptr::null(), 0.0); 4];
    let mut filled = 0usize;
    for j in 0..x.cols() {
        let bj = b[j];
        if bj == 0.0 {
            continue;
        }
        tile[filled] = (x.col(j).as_ptr(), bj);
        filled += 1;
        if filled == 4 {
            gemv_tile4(&tile, n, po);
            filled = 0;
        }
    }
    for &(c, bj) in tile.iter().take(filled) {
        let col = std::slice::from_raw_parts(c, n);
        axpy_body(bj, col, out);
    }
}

pub(crate) fn gemv(x: &Mat, b: &[f64], out: &mut [f64]) {
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { gemv_body(x, b, out) }
}

/// `out = X^T V`: the AVX2 dot per (column, task) pair in the scalar
/// iteration order.
pub(crate) fn xtm(x: &Mat, v: &Mat, out: &mut Mat) {
    assert_avx2();
    for k in 0..v.cols() {
        let vk = v.col(k);
        for j in 0..x.cols() {
            // SAFETY: AVX2 presence is guaranteed by the dispatch gate
            // and re-asserted above; `dot_body` clamps its loads.
            out[(j, k)] = unsafe { dot_body(x.col(j), vk) };
        }
    }
}

/// CSC gather dot: four `(val, v[idx])` products per pass feeding the
/// same 4-lane tree as [`super::scalar::gather_dot`]. The four loads of
/// `v` stay scalar (bounds-checked like the scalar kernel — AVX2 gathers
/// would skip the check and are microcoded-slow on most cores anyway);
/// the win is the four independent mul/add chains in one register.
// SAFETY: caller must run on an AVX2 CPU. `val` loads are bounded by the
// min-clamped `n`; `v[idx[..]]` gathers use safe (bounds-checked)
// indexing exactly like the scalar kernel.
#[target_feature(enable = "avx2")]
unsafe fn gather_dot_body(idx: &[usize], val: &[f64], v: &[f64]) -> f64 {
    let n = idx.len().min(val.len());
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        // set_pd takes lanes high-to-low: lane 0 holds element i.
        let g = _mm256_set_pd(v[idx[i + 3]], v[idx[i + 2]], v[idx[i + 1]], v[idx[i]]);
        let w = _mm256_loadu_pd(val.as_ptr().add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(w, g));
    }
    let mut s = hsum(acc);
    for i in 4 * chunks..n {
        s += val[i] * v[idx[i]];
    }
    s
}

pub(crate) fn gather_dot(idx: &[usize], val: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    assert_avx2();
    // SAFETY: AVX2 presence is guaranteed by the dispatch gate and
    // re-asserted above in debug builds; the body clamps all accesses.
    unsafe { gather_dot_body(idx, val, v) }
}
