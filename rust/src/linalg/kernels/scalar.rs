//! Portable scalar backend: the reference implementation every other
//! backend must match bit for bit.
//!
//! The dense kernels are the historical `linalg` loops moved here
//! verbatim. The one deliberate numeric change versus the pre-engine
//! crate is [`gather_dot`]: the historical CSC column dot accumulated in
//! a single serial chain, which no SIMD backend can reproduce bitwise;
//! it now uses the same 4-lane strided tree as the dense [`dot`] (lane
//! `k` accumulates elements `4i + k`; horizontal sum in the fixed
//! `((s0 + s1) + s2) + s3` order; remainder folded in sequentially), a
//! one-time ~1-ulp-scale shift on sparse designs that makes
//! cross-backend bitwise parity possible at all. Since the engine
//! landed, *this* file is the bit-exact reference.
//!
//! Length contract (all backends): reduction and update kernels operate
//! on the common prefix of their slices — mismatched lengths are a
//! caller bug (the `linalg` forwarders debug-assert equality), and every
//! backend clamps identically so even buggy callers cannot make two
//! backends diverge.

use crate::linalg::Mat;

/// Dot product, 4-lane strided reduction tree (unrolled by 4 for the
/// scalar pipeline; see EXPERIMENTS.md §Perf).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // common-prefix clamp: identical mismatch behavior in every backend
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `out = a - b` elementwise (residual / link refreshes).
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, ai), bi) in out.iter_mut().zip(a).zip(b) {
        *o = ai - bi;
    }
}

/// Soft-thresholding S_tau (Sec. 2.1), in place.
pub fn soft_threshold(x: &mut [f64], tau: f64) {
    for v in x {
        let a = v.abs() - tau;
        *v = if a > 0.0 { v.signum() * a } else { 0.0 };
    }
}

/// `out[j] = X_j^T v` for all columns — the screening hot spot.
pub fn xtv(x: &Mat, v: &[f64], out: &mut [f64]) {
    for j in 0..x.cols() {
        out[j] = dot(x.col(j), v);
    }
}

/// `out = X * b` (n-vector), walking columns so memory access is
/// unit-stride.
pub fn gemv(x: &Mat, b: &[f64], out: &mut [f64]) {
    out.iter_mut().for_each(|v| *v = 0.0);
    for j in 0..x.cols() {
        let bj = b[j];
        if bj != 0.0 {
            axpy(bj, x.col(j), out);
        }
    }
}

/// `out = X^T V` (p×q), for the multi-task case.
pub fn xtm(x: &Mat, v: &Mat, out: &mut Mat) {
    for k in 0..v.cols() {
        let vk = v.col(k);
        for j in 0..x.cols() {
            out[(j, k)] = dot(x.col(j), vk);
        }
    }
}

/// CSC column dot `sum_k val[k] * v[idx[k]]`, 4-lane strided tree — the
/// same reduction shape as [`dot`], so the AVX2 gather kernel can match
/// it bit for bit (four independent accumulator chains also let the
/// scalar pipeline overlap the loads, where the historical single-chain
/// loop serialized on the add latency).
pub fn gather_dot(idx: &[usize], val: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    let n = idx.len().min(val.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += val[i] * v[idx[i]];
        s1 += val[i + 1] * v[idx[i + 1]];
        s2 += val[i + 2] * v[idx[i + 2]];
        s3 += val[i + 3] * v[idx[i + 3]];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += val[i] * v[idx[i]];
    }
    s
}

/// CSC column update `out[idx[k]] += alpha * val[k]` (scatter). Shared by
/// every backend: the scattered adds are a genuine dependency chain only
/// when indices repeat, but AVX2 has no scatter store either way.
pub fn scatter_axpy(idx: &[usize], alpha: f64, val: &[f64], out: &mut [f64]) {
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &x) in idx.iter().zip(val) {
        out[i] += alpha * x;
    }
}
