//! Runtime-dispatched SIMD kernel engine with a bitwise-reproducibility
//! contract.
//!
//! Every Gap Safe ingredient the solver iterates — the correlation sweep
//! `X^T theta` feeding the sphere test `|x_j^T theta| + r ||x_j|| < 1`,
//! the residual updates inside (block) coordinate descent, and the
//! duality-gap evaluation itself — bottoms out in a handful of dense and
//! CSC-gather loops. This module owns those loops and selects, **once at
//! startup**, a backend implementation for all of them:
//!
//! * [`BackendKind::Scalar`] — portable Rust: the historical dense
//!   kernels of `linalg::mod` verbatim, plus the CSC gather dot
//!   restructured once into the shared 4-lane tree (see
//!   [`scalar::gather_dot`] — the single deliberate numeric change that
//!   makes SIMD parity possible);
//! * [`BackendKind::Avx2`] — 256-bit `std::arch` intrinsics (runtime CPU
//!   detection via `is_x86_feature_detected!`, stable only, zero deps).
//!
//! # The bitwise-reproducibility contract
//!
//! Every backend produces **bit-identical** outputs for every kernel. The
//! AVX2 kernels achieve this by computing the *same 4-lane strided
//! reduction tree* the scalar [`scalar::dot`] uses: lane `k` accumulates
//! elements `4i + k` with vertical `vmulpd` + `vaddpd` (no FMA
//! contraction — Rust never auto-contracts, and the intrinsics are
//! explicit mul-then-add), the horizontal sum is taken in the fixed
//! `((s0 + s1) + s2) + s3` order, and the `n % 4` tail is folded in
//! element-by-element exactly like the scalar remainder loop. Per-element
//! kernels (`axpy`, `sub`, `soft_threshold`) are trivially lane-exact.
//! The CSC gather reduction ([`Kernels::gather_dot`]) uses the same
//! 4-lane tree in *both* backends so the sparse solver path carries the
//! identical guarantee. The one deliberate exception is the CSC scatter
//! update ([`Kernels::scatter_axpy`]): AVX2 has no scatter store, so both
//! backends share the scalar loop (its adds are the dependency chain;
//! there is nothing to vectorize without changing results).
//!
//! Consequences: the backend choice can never change a solver trajectory,
//! a screening decision, or a served prediction — `solve_path` returns
//! bit-identical `PathResult`s under `scalar` and `avx2`, which is pinned
//! by the cross-backend parity gate in `rust/tests/kernel_parity.rs` and
//! keeps every pre-existing bitwise test (compaction transparency,
//! dual-point rescale identity, serve round-trips) green under any
//! backend.
//!
//! # Selection
//!
//! The active backend is resolved on first use from the `GAPSAFE_KERNEL`
//! environment variable (`scalar` | `avx2` | `auto`, default `auto` =
//! best supported), and can be overridden explicitly with [`select`] /
//! [`select_str`] (the CLI `--kernel` flag and `gapsafe serve` do this at
//! startup; `GET /metrics` and `/healthz` report the active backend).
//! Because all backends are bitwise identical, switching backends at any
//! point is always safe — the dispatch table is just a performance knob.

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
pub mod scalar;

use super::Mat;
use std::sync::atomic::{AtomicPtr, Ordering};

/// Which kernel backend a dispatch table implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Portable scalar Rust (the historical kernels; always available).
    Scalar,
    /// 256-bit AVX2 via `std::arch` (x86-64 with runtime detection).
    Avx2,
}

impl BackendKind {
    /// Stable lowercase name (CLI flag values, `/metrics` field).
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Avx2 => "avx2",
        }
    }
}

/// A dispatch table of the hot numerical kernels. All entries of all
/// tables are bitwise-identical functions of their inputs (see the module
/// docs); only their speed differs.
pub struct Kernels {
    pub kind: BackendKind,
    /// Dot product, 4-lane strided reduction tree.
    pub dot: fn(&[f64], &[f64]) -> f64,
    /// `y[i] += alpha * x[i]`.
    pub axpy: fn(f64, &[f64], &mut [f64]),
    /// `out[i] = a[i] - b[i]` (residual / link refreshes).
    pub sub: fn(&[f64], &[f64], &mut [f64]),
    /// In-place soft-thresholding `S_tau` (Sec. 2.1).
    pub soft_threshold: fn(&mut [f64], f64),
    /// `out[j] = X_j^T v` over all columns (register-tiled on AVX2: four
    /// columns per pass so each load of `v` is reused fourfold).
    pub xtv: fn(&Mat, &[f64], &mut [f64]),
    /// `out = X b` (column-major axpy sweep, 4-column tiles on AVX2).
    pub gemv: fn(&Mat, &[f64], &mut [f64]),
    /// `out = X^T V` (p x q), the multi-task correlation block.
    pub xtm: fn(&Mat, &Mat, &mut Mat),
    /// CSC column dot: `sum_k val[k] * v[idx[k]]`, 4-lane strided tree
    /// (the `sptv` gather ingredient of sparse screening sweeps).
    pub gather_dot: fn(&[usize], &[f64], &[f64]) -> f64,
    /// CSC column update: `out[idx[k]] += alpha * val[k]` (the `spmv`
    /// scatter ingredient; scalar in every backend — see module docs).
    pub scatter_axpy: fn(&[usize], f64, &[f64], &mut [f64]),
}

static SCALAR_TABLE: Kernels = Kernels {
    kind: BackendKind::Scalar,
    dot: scalar::dot,
    axpy: scalar::axpy,
    sub: scalar::sub,
    soft_threshold: scalar::soft_threshold,
    xtv: scalar::xtv,
    gemv: scalar::gemv,
    xtm: scalar::xtm,
    gather_dot: scalar::gather_dot,
    scatter_axpy: scalar::scatter_axpy,
};

#[cfg(target_arch = "x86_64")]
static AVX2_TABLE: Kernels = Kernels {
    kind: BackendKind::Avx2,
    dot: avx2::dot,
    axpy: avx2::axpy,
    sub: avx2::sub,
    soft_threshold: avx2::soft_threshold,
    xtv: avx2::xtv,
    gemv: avx2::gemv,
    xtm: avx2::xtm,
    gather_dot: avx2::gather_dot,
    // AVX2 has no scatter store; the add chain is the serial dependency,
    // so the scalar loop *is* the kernel (and parity is trivial).
    scatter_axpy: scalar::scatter_axpy,
};

/// True when this host can run the AVX2 backend.
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The always-available scalar reference table (parity tests compare
/// every other backend against this one).
pub fn scalar_table() -> &'static Kernels {
    &SCALAR_TABLE
}

/// The dispatch table for `kind`, or `None` when this host cannot run it
/// (AVX2 missing, or a non-x86-64 build).
pub fn table(kind: BackendKind) -> Option<&'static Kernels> {
    match kind {
        BackendKind::Scalar => Some(&SCALAR_TABLE),
        BackendKind::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if avx2_supported() {
                    return Some(&AVX2_TABLE);
                }
                None
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                None
            }
        }
    }
}

/// Every backend this host can run, scalar first (test/bench sweep).
pub fn available() -> Vec<&'static Kernels> {
    let mut v = vec![scalar_table()];
    if let Some(t) = table(BackendKind::Avx2) {
        v.push(t);
    }
    v
}

/// The active dispatch table — selected once (null until first use) and
/// then a single relaxed atomic load per call site.
static ACTIVE: AtomicPtr<Kernels> = AtomicPtr::new(std::ptr::null_mut());

/// The active kernel table, initializing from `GAPSAFE_KERNEL` / CPU
/// detection on first use.
///
/// # Panics
///
/// When `GAPSAFE_KERNEL` names an unknown backend or one this host cannot
/// run, the lazy initializer falls back to the scalar backend (with a
/// stderr note) — this function is reachable from the resident serve
/// path, where a panic poisons the pool. CLI entry points call
/// [`validate_env`] first, so a forced-but-unsupported backend still
/// aborts a run before any work (fail-fast for CI parity legs).
pub fn active() -> &'static Kernels {
    // Ordering: Relaxed suffices here (unlike the obs sink's
    // Acquire/Release pair) because every candidate pointee is a
    // compile-time `static` — fully initialized before `main`, immutable
    // forever — so no writes need to be ordered before the publication.
    let p = ACTIVE.load(Ordering::Relaxed);
    if !p.is_null() {
        // SAFETY: tables are `'static` and immutable, and the pointer is
        // only ever set to one of them (see `init_from_env` / `select`),
        // so a non-null pointer always dereferences to a live table.
        return unsafe { &*p };
    }
    init_from_env()
}

/// Backend of the active table (CLI summaries, serve `/metrics`).
pub fn active_kind() -> BackendKind {
    active().kind
}

#[cold]
fn init_from_env() -> &'static Kernels {
    let spec = std::env::var("GAPSAFE_KERNEL").unwrap_or_default();
    let spec = if spec.is_empty() { "auto".to_string() } else { spec };
    // A racing initializer resolves the same environment to the same
    // table, so last-write-wins is benign. A bad spec falls back to the
    // portable scalar backend with a loud stderr note instead of
    // panicking: `active()` is reachable from the resident serve path,
    // and CLI entry points reject a bad spec up front via
    // [`validate_env`], so the fallback only shields embedders.
    let t = match resolve(&spec) {
        Ok(kind) => table(kind).unwrap_or_else(scalar_table),
        Err(e) => {
            eprintln!("GAPSAFE_KERNEL: {e}; falling back to the scalar backend");
            scalar_table()
        }
    };
    // Ordering: Relaxed store — the pointee is an immutable `static`, so
    // there is nothing to publish ahead of it.
    ACTIVE.store(t as *const Kernels as *mut Kernels, Ordering::Relaxed);
    t
}

/// Fail-fast validation of `GAPSAFE_KERNEL` for process entry points: a
/// forced-but-unsupported backend must abort a CLI run *before* any work
/// (silent fallback would fake coverage in CI parity legs), while the
/// lazy [`active`] initializer — reachable from the resident server —
/// degrades to scalar instead of panicking mid-request.
pub fn validate_env() -> Result<(), String> {
    match std::env::var("GAPSAFE_KERNEL") {
        Ok(spec) if !spec.is_empty() => {
            resolve(&spec).map(|_| ()).map_err(|e| format!("GAPSAFE_KERNEL: {e}"))
        }
        _ => Ok(()),
    }
}

/// Resolve a backend spec (`scalar` | `avx2` | `auto`) against this host
/// without activating it.
pub fn resolve(spec: &str) -> Result<BackendKind, String> {
    match spec {
        "auto" => Ok(if avx2_supported() { BackendKind::Avx2 } else { BackendKind::Scalar }),
        "scalar" => Ok(BackendKind::Scalar),
        "avx2" => {
            if table(BackendKind::Avx2).is_some() {
                Ok(BackendKind::Avx2)
            } else {
                Err("avx2 requested but this host does not support AVX2 \
                     (use 'scalar' or 'auto')"
                    .to_string())
            }
        }
        other => Err(format!("unknown kernel backend '{other}' (scalar | avx2 | auto)")),
    }
}

/// Activate a backend explicitly (overrides `GAPSAFE_KERNEL`). Errors
/// when the host cannot run it. Always safe to call at any point: every
/// backend is bitwise identical, so in-flight computations cannot drift.
pub fn select(kind: BackendKind) -> Result<BackendKind, String> {
    match table(kind) {
        Some(t) => {
            // Ordering: Relaxed store — same immutable-static argument
            // as `init_from_env`.
            ACTIVE.store(t as *const Kernels as *mut Kernels, Ordering::Relaxed);
            Ok(kind)
        }
        None => Err(format!("kernel backend '{}' is not supported on this host", kind.label())),
    }
}

/// [`select`] from a spec string (the CLI `--kernel` flag).
pub fn select_str(spec: &str) -> Result<BackendKind, String> {
    select(resolve(spec)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Naive single-accumulator references (deliberately *not* the 4-lane
    /// tree): backends must agree with these to tolerance, and with the
    /// scalar table to the bit.
    fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn rand_vec(rng: &mut Prng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.gaussian()).collect()
    }

    /// The edge shapes of the satellite brief: empty, below one lane,
    /// exact lanes, remainder lanes, and big-ish.
    const SHAPES: [usize; 14] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 33, 100];

    #[test]
    fn resolve_and_labels() {
        assert_eq!(resolve("scalar").unwrap(), BackendKind::Scalar);
        assert!(resolve("bogus").is_err());
        let auto = resolve("auto").unwrap();
        assert!(table(auto).is_some(), "auto resolved to an unrunnable backend");
        assert_eq!(BackendKind::Scalar.label(), "scalar");
        assert_eq!(BackendKind::Avx2.label(), "avx2");
        if !avx2_supported() {
            assert!(resolve("avx2").is_err());
        }
        // the active table is always one of the available ones
        assert!(available().iter().any(|t| t.kind == active_kind()));
    }

    #[test]
    fn dot_axpy_edge_shapes_all_backends() {
        let mut rng = Prng::new(101);
        for &n in &SHAPES {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let want = (scalar_table().dot)(&a, &b);
            let naive = naive_dot(&a, &b);
            assert!((want - naive).abs() <= 1e-12 * (1.0 + naive.abs()));
            for t in available() {
                let got = (t.dot)(&a, &b);
                assert_eq!(got.to_bits(), want.to_bits(), "dot n={n} backend={:?}", t.kind);
                let mut y1 = rand_vec(&mut rng, n);
                let mut y2 = y1.clone();
                (scalar_table().axpy)(-1.75, &a, &mut y1);
                (t.axpy)(-1.75, &a, &mut y2);
                for i in 0..n {
                    assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "axpy {i} {:?}", t.kind);
                }
                let (mut d1, mut d2) = (vec![0.0; n], vec![0.0; n]);
                (scalar_table().sub)(&a, &b, &mut d1);
                (t.sub)(&a, &b, &mut d2);
                for i in 0..n {
                    assert_eq!(d1[i].to_bits(), d2[i].to_bits(), "sub {i} {:?}", t.kind);
                }
            }
        }
    }

    #[test]
    fn unaligned_subslices_all_backends() {
        // Sub-slices starting at every offset mod 4 (and thus every
        // 32-byte phase): the kernels use unaligned loads, so results must
        // stay bit-identical regardless of the base pointer.
        let mut rng = Prng::new(102);
        let a = rand_vec(&mut rng, 70);
        let b = rand_vec(&mut rng, 70);
        for off in 0..4 {
            for &n in &[0, 1, 3, 5, 17, 33] {
                let (sa, sb) = (&a[off..off + n], &b[off..off + n]);
                let want = (scalar_table().dot)(sa, sb);
                for t in available() {
                    assert_eq!(
                        (t.dot)(sa, sb).to_bits(),
                        want.to_bits(),
                        "off={off} n={n} {:?}",
                        t.kind
                    );
                }
            }
        }
    }

    #[test]
    fn soft_threshold_edges_all_backends() {
        let specials = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.5,
            -0.5,
            3.25,
            -3.25,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::MIN_POSITIVE,
            -f64::MIN_POSITIVE,
        ];
        let mut rng = Prng::new(103);
        for tau in [0.0, 1.0, -1.0, 0.75] {
            for &n in &SHAPES {
                let mut base = rand_vec(&mut rng, n);
                // splice the special values in cyclically
                for (i, v) in base.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *v = specials[i % specials.len()];
                    }
                }
                let mut want = base.clone();
                (scalar_table().soft_threshold)(&mut want, tau);
                for t in available() {
                    let mut got = base.clone();
                    (t.soft_threshold)(&mut got, tau);
                    for i in 0..n {
                        assert_eq!(
                            got[i].to_bits(),
                            want[i].to_bits(),
                            "st tau={tau} i={i} in={} {:?}",
                            base[i],
                            t.kind
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn xtv_gemv_xtm_odd_matrices_all_backends() {
        // Odd row counts make every Mat::col an unaligned sub-slice of the
        // column-major buffer — exactly the satellite's edge case.
        let mut rng = Prng::new(104);
        for (n, p) in [(1, 1), (3, 2), (4, 4), (5, 7), (7, 5), (8, 9), (13, 11), (16, 6)] {
            let mut x = Mat::zeros(n, p);
            for v in x.as_mut_slice() {
                *v = rng.gaussian();
            }
            let v = rand_vec(&mut rng, n);
            let mut b = rand_vec(&mut rng, p);
            b[0] = 0.0; // exercise the gemv skip-zero path
            let mut want_c = vec![0.0; p];
            (scalar_table().xtv)(&x, &v, &mut want_c);
            let mut want_z = vec![0.0; n];
            (scalar_table().gemv)(&x, &b, &mut want_z);
            let vm = {
                let mut m = Mat::zeros(n, 3);
                for w in m.as_mut_slice() {
                    *w = rng.gaussian();
                }
                m
            };
            let mut want_m = Mat::zeros(p, 3);
            (scalar_table().xtm)(&x, &vm, &mut want_m);
            for j in 0..p {
                // per-column tiles must equal the plain dot of that column
                assert_eq!(
                    want_c[j].to_bits(),
                    (scalar_table().dot)(x.col(j), &v).to_bits(),
                    "scalar xtv is not dot-per-column at {j}"
                );
            }
            for t in available() {
                let mut c = vec![0.0; p];
                (t.xtv)(&x, &v, &mut c);
                let mut z = vec![1.0; n]; // gemv must overwrite, not accumulate
                (t.gemv)(&x, &b, &mut z);
                let mut m = Mat::zeros(p, 3);
                (t.xtm)(&x, &vm, &mut m);
                for j in 0..p {
                    assert_eq!(c[j].to_bits(), want_c[j].to_bits(), "xtv {j} {:?}", t.kind);
                }
                for i in 0..n {
                    assert_eq!(z[i].to_bits(), want_z[i].to_bits(), "gemv {i} {:?}", t.kind);
                }
                for (a, w) in m.as_slice().iter().zip(want_m.as_slice()) {
                    assert_eq!(a.to_bits(), w.to_bits(), "xtm {:?}", t.kind);
                }
            }
        }
    }

    #[test]
    fn gather_scatter_all_backends() {
        let mut rng = Prng::new(105);
        for &nnz in &SHAPES {
            let rows = (3 * nnz).max(4);
            let v = rand_vec(&mut rng, rows);
            // strided + shuffled-ish indices, duplicates allowed for the
            // raw kernel (CSC never produces them, but the kernel must not
            // care for gather; scatter adds are order-exact anyway)
            let idx: Vec<usize> = (0..nnz).map(|k| (k * 7 + 3) % rows).collect();
            let val = rand_vec(&mut rng, nnz);
            let want = (scalar_table().gather_dot)(&idx, &val, &v);
            let naive: f64 = idx.iter().zip(&val).map(|(&i, &x)| x * v[i]).sum();
            assert!((want - naive).abs() <= 1e-12 * (1.0 + naive.abs()));
            let mut want_out = v.clone();
            (scalar_table().scatter_axpy)(&idx, -0.75, &val, &mut want_out);
            for t in available() {
                assert_eq!(
                    (t.gather_dot)(&idx, &val, &v).to_bits(),
                    want.to_bits(),
                    "gather nnz={nnz} {:?}",
                    t.kind
                );
                let mut out = v.clone();
                (t.scatter_axpy)(&idx, -0.75, &val, &mut out);
                for i in 0..rows {
                    assert_eq!(out[i].to_bits(), want_out[i].to_bits(), "scatter {:?}", t.kind);
                }
            }
        }
    }

    #[test]
    fn select_round_trips() {
        // Switching backends is always observable through active_kind and
        // always reversible. Restore the entry state at the end so a
        // GAPSAFE_KERNEL-forced test run keeps its forced backend for
        // co-resident tests (harmless either way — bitwise identical).
        let before = active_kind();
        select(BackendKind::Scalar).unwrap();
        assert_eq!(active_kind(), BackendKind::Scalar);
        if avx2_supported() {
            assert_eq!(select_str("avx2").unwrap(), BackendKind::Avx2);
            assert_eq!(active_kind(), BackendKind::Avx2);
        } else {
            assert!(select(BackendKind::Avx2).is_err());
        }
        assert!(select_str("nope").is_err());
        select(before).unwrap();
        assert_eq!(active_kind(), before);
    }
}
