//! Active-set compaction: physically repacked working designs.
//!
//! Gap Safe screening only pays off if the solver stops *touching* the
//! coordinates it screens. Skipping dead columns through a bitmap still
//! scans the full feature range every epoch and strides through the full
//! design in memory; after 90%+ of the columns are provably dead, the
//! effective problem is tiny but the working set is not *contiguous*.
//!
//! [`CompactDesign`] fixes that: whenever a screening event kills more
//! than a threshold fraction of the remaining features, the solver
//! repacks the surviving columns into a fresh dense matrix (or CSC slice)
//! plus an index map and cached column norms. Coordinate-descent epochs,
//! the gap-pass correlation sweep and the screening statistics then
//! iterate over a small contiguous matrix.
//!
//! # Bitwise transparency
//!
//! Packing copies column data verbatim ([`Design::select_cols`]), so every
//! per-column kernel (`col_dot`, `col_axpy`, `col_dot_diff`) produces the
//! exact same floating-point results on the packed matrix as on the full
//! one — compaction changes *which memory is read*, never *what is
//! computed*. The solver tests pin packed and full paths bit-for-bit.
//! The per-column kernels themselves dispatch into the SIMD engine of
//! [`crate::linalg::kernels`], whose backends are bitwise identical by
//! contract — so compaction and backend choice compose: any combination
//! of (packed | full) × (scalar | avx2) yields the same bits.
//!
//! # Safety contract
//!
//! A view packed from active set `A` serves any later active set `A' ⊆ A`
//! (safe screening only shrinks the active set within one lambda). The
//! solver rebuilds the view from scratch whenever that monotonicity is
//! broken (KKT repair re-activating strong-rule casualties, a new lambda).

use super::sparse::Design;

/// Sentinel for "feature not in the view" in the full → compact map.
const DEAD: usize = usize::MAX;

/// A physically repacked view over the surviving columns of a design.
///
/// All public column accessors are addressed by the *full* feature index
/// and map to the packed column internally; iteration over the view uses
/// [`CompactDesign::width`] / [`CompactDesign::feat_of`].
#[derive(Debug, Clone)]
pub struct CompactDesign {
    /// Packed design (n x width), same storage kind as the source.
    design: Design,
    /// Compact column -> full feature index (strictly ascending).
    feat_of: Vec<usize>,
    /// Full feature index -> compact column (`DEAD` when not in the view).
    compact_of: Vec<usize>,
    /// `||X_j||_2^2` per packed column (cached at pack time).
    col_norms_sq: Vec<f64>,
}

impl CompactDesign {
    /// Pack the columns with `keep[j] == true` (ascending order preserved).
    pub fn pack(x: &Design, keep: &[bool]) -> CompactDesign {
        assert_eq!(keep.len(), x.cols(), "keep mask must cover all columns");
        let feat_of: Vec<usize> =
            (0..keep.len()).filter(|&j| keep[j]).collect();
        let mut compact_of = vec![DEAD; keep.len()];
        for (c, &j) in feat_of.iter().enumerate() {
            compact_of[j] = c;
        }
        let design = x.select_cols(&feat_of);
        let col_norms_sq = design.col_norms_sq();
        CompactDesign { design, feat_of, compact_of, col_norms_sq }
    }

    /// Number of packed columns.
    #[inline]
    pub fn width(&self) -> usize {
        self.feat_of.len()
    }

    /// Full feature count of the source design.
    #[inline]
    pub fn full_p(&self) -> usize {
        self.compact_of.len()
    }

    /// Full feature index of packed column `c`.
    #[inline]
    pub fn feat_of(&self, c: usize) -> usize {
        self.feat_of[c]
    }

    /// Packed column of full feature `j`, if it survived the pack.
    #[inline]
    pub fn compact_of(&self, j: usize) -> Option<usize> {
        match self.compact_of[j] {
            DEAD => None,
            c => Some(c),
        }
    }

    /// The packed design itself (compact column indexing).
    #[inline]
    pub fn design(&self) -> &Design {
        &self.design
    }

    /// `||X_j||_2^2` of packed column `c`.
    #[inline]
    pub fn col_norm_sq_packed(&self, c: usize) -> f64 {
        self.col_norms_sq[c]
    }

    #[inline]
    fn col(&self, j_full: usize) -> usize {
        let c = self.compact_of[j_full];
        debug_assert!(c != DEAD, "feature {j_full} is not in the compact view");
        c
    }

    /// `X_j^T v`, addressed by the full feature index.
    #[inline]
    pub fn col_dot(&self, j_full: usize, v: &[f64]) -> f64 {
        self.design.col_dot(self.col(j_full), v)
    }

    /// `out += alpha * X_j`, addressed by the full feature index.
    #[inline]
    pub fn col_axpy(&self, j_full: usize, alpha: f64, out: &mut [f64]) {
        self.design.col_axpy(self.col(j_full), alpha, out);
    }

    /// `sum_i X_j[i] * (a[i] - b[i])`, addressed by the full feature index.
    #[inline]
    pub fn col_dot_diff(&self, j_full: usize, a: &[f64], b: &[f64]) -> f64 {
        self.design.col_dot_diff(self.col(j_full), a, b)
    }

    /// Row support of the column of full feature `j` (see
    /// [`Design::col_rows`]).
    #[inline]
    pub fn col_rows(&self, j_full: usize) -> Option<&[usize]> {
        self.design.col_rows(self.col(j_full))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::Csc;
    use crate::linalg::Mat;
    use crate::util::prng::Prng;

    fn rand_dense(rng: &mut Prng, n: usize, p: usize) -> Design {
        let mut m = Mat::zeros(n, p);
        for v in m.as_mut_slice() {
            *v = rng.gaussian();
        }
        Design::Dense(m)
    }

    fn rand_sparse(rng: &mut Prng, n: usize, p: usize, density: f64) -> Design {
        let mut trip = Vec::new();
        for c in 0..p {
            for r in 0..n {
                if rng.bernoulli(density) {
                    trip.push((c, r, rng.gaussian()));
                }
            }
        }
        Design::Sparse(Csc::from_triplets(n, p, trip))
    }

    fn mask(p: usize, keep: &[usize]) -> Vec<bool> {
        let mut m = vec![false; p];
        for &j in keep {
            m[j] = true;
        }
        m
    }

    #[test]
    fn pack_maps_round_trip() {
        let mut rng = Prng::new(21);
        let x = rand_dense(&mut rng, 6, 10);
        let keep = [1usize, 4, 5, 9];
        let cd = CompactDesign::pack(&x, &mask(10, &keep));
        assert_eq!(cd.width(), 4);
        assert_eq!(cd.full_p(), 10);
        for (c, &j) in keep.iter().enumerate() {
            assert_eq!(cd.feat_of(c), j);
            assert_eq!(cd.compact_of(j), Some(c));
        }
        assert_eq!(cd.compact_of(0), None);
        assert_eq!(cd.compact_of(8), None);
    }

    #[test]
    fn packed_kernels_bitwise_match_full() {
        let mut rng = Prng::new(22);
        for x in [rand_dense(&mut rng, 15, 30), rand_sparse(&mut rng, 15, 30, 0.3)] {
            let keep: Vec<usize> = (0..30).filter(|j| j % 3 != 1).collect();
            let cd = CompactDesign::pack(&x, &mask(30, &keep));
            let v: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
            let w: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
            for &j in &keep {
                assert_eq!(
                    x.col_dot(j, &v).to_bits(),
                    cd.col_dot(j, &v).to_bits(),
                    "col_dot differs at {j}"
                );
                assert_eq!(
                    x.col_dot_diff(j, &v, &w).to_bits(),
                    cd.col_dot_diff(j, &v, &w).to_bits(),
                    "col_dot_diff differs at {j}"
                );
                let mut a = vec![0.25; 15];
                let mut b = vec![0.25; 15];
                x.col_axpy(j, -1.75, &mut a);
                cd.col_axpy(j, -1.75, &mut b);
                for i in 0..15 {
                    assert_eq!(a[i].to_bits(), b[i].to_bits(), "axpy differs at ({j},{i})");
                }
            }
            // cached norms match the full design's norms exactly
            let full_norms = x.col_norms_sq();
            for (c, &j) in keep.iter().enumerate() {
                assert_eq!(cd.col_norm_sq_packed(c).to_bits(), full_norms[j].to_bits());
            }
        }
    }

    #[test]
    fn sparse_row_support_preserved() {
        let mut rng = Prng::new(23);
        let x = rand_sparse(&mut rng, 10, 12, 0.4);
        let keep: Vec<usize> = (0..12).step_by(2).collect();
        let cd = CompactDesign::pack(&x, &mask(12, &keep));
        for &j in &keep {
            assert_eq!(cd.col_rows(j), x.col_rows(j));
        }
        let xd = rand_dense(&mut rng, 10, 4);
        let cdd = CompactDesign::pack(&xd, &mask(4, &[0, 2]));
        assert!(cdd.col_rows(0).is_none());
    }

    #[test]
    fn empty_pack_is_valid() {
        let mut rng = Prng::new(24);
        let x = rand_dense(&mut rng, 5, 8);
        let cd = CompactDesign::pack(&x, &[false; 8]);
        assert_eq!(cd.width(), 0);
        assert_eq!(cd.full_p(), 8);
    }
}
